//! Example 3: the Flash-RMSNorm+FFN-SwiGLU mega-kernel (paper §5).
//!
//! 26 steps, including Rule 8 (duplicating the RMS scaling so Rule 4 can
//! swap it past both the W and V projections) and two Rule-6 extensions.
//! The epilogue's redundant-work discussion is reproduced quantitatively:
//! the mega-kernel's flops at `N = K = 1` equal the unreplicated
//! snapshot's, and grow with `N`/`K` — the trade the autotuner settles.
//!
//! Run: `cargo run --release --example rmsnorm_ffn_swiglu`

use blockbuster::array::programs;
use blockbuster::coordinator::workloads;
use blockbuster::cost::{analyze, ShapeEnv};
use blockbuster::exec::{reference, run, Workload};
use blockbuster::fusion::fuse;
use blockbuster::ir::dim::DimSizes;
use blockbuster::loopir::{lower::lower, print::render};
use blockbuster::lower::lower_array;
use blockbuster::rules::RuleId;
use blockbuster::util::bench::fmt_bytes;
use std::collections::HashMap;

fn main() {
    let program = programs::rmsnorm_ffn_swiglu();
    let block = lower_array(&program);
    let res = fuse(block.clone());
    println!(
        "fusion trace: {} steps [{}] — the paper's Example 3 takes 26\n",
        res.trace.len(),
        res.trace.summary()
    );
    print!("{}", res.trace);
    assert_eq!(res.trace.count(RuleId::R8), 1, "one scale duplication");
    assert_eq!(res.trace.count(RuleId::R4), 2, "two scale/dot swaps");
    assert_eq!(res.trace.count(RuleId::R6), 2, "two map extensions");

    let fused = res.snapshots.last().unwrap();
    assert_eq!(fused.interior_buffered_count_recursive(), 0);
    println!("\nderived mega-kernel:\n{}", render(&lower(fused)));

    // --- the epilogue's replication accounting -----------------------------
    let mut full = HashMap::new();
    full.insert("X".to_string(), (16, 32));
    full.insert("WT".to_string(), (32, 32));
    full.insert("VT".to_string(), (32, 32));
    full.insert("UT".to_string(), (16, 32));
    let flops_at = |g: &blockbuster::Graph, k: usize, n: usize| {
        let sizes = DimSizes::of(&[("M", 4), ("D", 2), ("K", k), ("N", n)]);
        let ir = lower(g);
        let env = ShapeEnv::from_full_shapes(&ir, &sizes, &full);
        analyze(&ir, &sizes, &env).flops
    };
    let flat = &res.snapshots[0];
    println!("\nwork replication (flops), mega-kernel vs unreplicated snapshot:");
    for (k, n) in [(1, 1), (2, 1), (1, 2), (2, 2), (4, 2)] {
        println!(
            "  K={k} N={n}:  mega {:>8}  flat {:>8}  ({:+.0}% redundant)",
            flops_at(fused, k, n),
            flops_at(flat, k, n),
            100.0 * (flops_at(fused, k, n) as f64 / flops_at(flat, k, n) as f64 - 1.0)
        );
    }
    assert_eq!(
        flops_at(fused, 1, 1),
        flops_at(flat, 1, 1),
        "at N=K=1 all the redundant work disappears (paper epilogue)"
    );

    // --- execution ----------------------------------------------------------
    let (_, cfg, params, inputs) = workloads::rmsnorm_ffn_swiglu_demo(42);
    let wl = Workload {
        sizes: cfg.sizes.clone(),
        params: params.clone(),
        inputs: inputs.clone(),
        local_capacity: None,
        threads: None,
    };
    let naive = run(&block, &wl);
    let fast = run(fused, &wl);
    let want = reference::rmsnorm_ffn_swiglu_ref(
        &inputs["X"],
        &inputs["WT"],
        &inputs["VT"],
        &inputs["UT"],
    );
    assert!(fast.outputs["O"].max_abs_diff(&want) < 1e-3);
    println!(
        "\nnaive : traffic {}  launches {}",
        fmt_bytes(naive.mem.total_traffic()),
        naive.mem.kernel_launches
    );
    println!(
        "fused : traffic {}  launches {}  stores only the output ({}).",
        fmt_bytes(fast.mem.total_traffic()),
        fast.mem.kernel_launches,
        fmt_bytes(fast.mem.stored_bytes)
    );
}
