//! Example 1: automatically rediscovering Flash Attention (paper §5).
//!
//! Walks the full pipeline: naive attention array program → block program →
//! 17-step fusion trace → the single-pass fused kernel; then autotunes the
//! block counts (recovering the paper's epilogue claim that `D = L = 1`
//! reproduces the original Flash Attention kernel), executes naive vs fused
//! on the memory simulator, and — with `--safe` — runs the Appendix's
//! row-wise significand–exponent stabilization on inputs that overflow the
//! unsafe kernel.
//!
//! Run: `cargo run --release --example flash_attention [-- --safe]`

use blockbuster::array::programs;
use blockbuster::autotune::autotune;
use blockbuster::coordinator::workloads;
use blockbuster::cost::CostModel;
use blockbuster::exec::{reference, run, Workload};
use blockbuster::fusion::fuse;
use blockbuster::ir::dim::Dim;
use blockbuster::loopir::{lower::lower, print::render};
use blockbuster::lower::lower_array;
use blockbuster::stabilize::safe_attention;
use blockbuster::tensor::Rng;
use blockbuster::util::bench::fmt_bytes;
use std::collections::HashMap;

fn main() {
    let program = programs::attention();
    let block = lower_array(&program);
    let res = fuse(block.clone());
    println!(
        "fusion trace: {} steps [{}] — the paper's Example 1 takes 17\n",
        res.trace.len(),
        res.trace.summary()
    );
    print!("{}", res.trace);
    let fused = res.snapshots.last().unwrap();
    assert_eq!(fused.interior_buffered_count_recursive(), 0);
    println!("\nderived Flash Attention kernel:\n{}", render(&lower(fused)));

    // --- autotuning: the epilogue's D = L = 1 -----------------------------
    let mut full = HashMap::new();
    full.insert("Q".to_string(), (64, 32));
    full.insert("KT".to_string(), (64, 32));
    full.insert("VT".to_string(), (32, 64));
    let tune = autotune(fused, &full, 1 << 20, &CostModel::default());
    let best = tune.best().expect("feasible configuration");
    println!(
        "autotuner best block counts: {:?} (traffic {}, peak local {})",
        best.sizes.0,
        fmt_bytes(best.cost.traffic()),
        fmt_bytes(best.cost.peak_local_bytes)
    );
    assert_eq!(best.sizes.get(&Dim::new("D")), 1);
    assert_eq!(best.sizes.get(&Dim::new("L")), 1);
    println!("=> D = L = 1, \"the values that reproduce the original Flash Attention kernel\"\n");

    // --- execution: naive vs fused ----------------------------------------
    let (_, cfg, params, inputs) = workloads::attention_demo(42);
    let wl = Workload {
        sizes: cfg.sizes.clone(),
        params: params.clone(),
        inputs: inputs.clone(),
        local_capacity: None,
        threads: None,
    };
    let naive = run(&block, &wl);
    let fast = run(fused, &wl);
    let want =
        reference::attention_ref(&inputs["Q"], &inputs["KT"], &inputs["VT"], params["DD"]);
    assert!(fast.outputs["O"].max_abs_diff(&want) < 5e-4);
    println!(
        "naive : traffic {}  launches {}",
        fmt_bytes(naive.mem.total_traffic()),
        naive.mem.kernel_launches
    );
    println!(
        "fused : traffic {}  launches {}  ({:.2}x reduction)",
        fmt_bytes(fast.mem.total_traffic()),
        fast.mem.kernel_launches,
        naive.mem.total_traffic() as f64 / fast.mem.total_traffic() as f64
    );

    // --- Appendix: numerical safety ---------------------------------------
    if std::env::args().any(|a| a == "--safe") {
        let mut rng = Rng::new(7);
        let q = rng.mat(16, 8).map(|v| v * 60.0);
        let kt = rng.mat(16, 8).map(|v| v * 60.0);
        let vt = rng.mat(8, 16);
        let scores = q.dot_bt(&kt).map(|v| v * 8.0f32.powf(-0.5));
        let overflowed = scores.map(f32::exp).data.iter().any(|v| !v.is_finite());
        let safe = safe_attention(&q, &kt, &vt, 4);
        println!(
            "\n--safe: logits overflow the unsafe exp ({overflowed}); stabilized kernel finite: {}",
            safe.data.iter().all(|v| v.is_finite())
        );
    }
}
