//! Quickstart: the paper's §1 motivating example, `C = relu(A @ B)`.
//!
//! Builds the array program, converts it to the (fully unfused) block
//! program, runs the fusion algorithm, prints the derived fused kernel in
//! the paper's listing notation, and executes both versions on the
//! two-tier-memory simulator to show the traffic saved.
//!
//! Run: `cargo run --release --example quickstart`

use blockbuster::array::programs;
use blockbuster::coordinator::workloads;
use blockbuster::exec::{reference, run, Workload};
use blockbuster::fusion::fuse;
use blockbuster::loopir::{lower::lower, print::render};
use blockbuster::lower::lower_array;
use blockbuster::util::bench::fmt_bytes;

fn main() {
    let program = programs::matmul_relu();
    println!("array program:\n{program}");

    let block = lower_array(&program);
    println!(
        "initial block program: {} interior buffered edge(s)\n\nnaive listing:\n{}",
        block.interior_buffered_count_recursive(),
        render(&lower(&block))
    );

    let result = fuse(block.clone());
    println!(
        "fusion: {} step(s) [{}]\n\nfused listing:\n{}",
        result.trace.len(),
        result.trace.summary(),
        render(&lower(result.snapshots.last().unwrap()))
    );

    // Execute both on a real workload and compare.
    let (_, cfg, params, inputs) = workloads::matmul_relu_demo(42);
    let wl = Workload {
        sizes: cfg.sizes.clone(),
        params,
        inputs: inputs.clone(),
        local_capacity: None,
        threads: None,
    };
    let naive = run(&block, &wl);
    let fused = run(result.snapshots.last().unwrap(), &wl);
    let want = reference::matmul_relu_ref(&inputs["A"], &inputs["BT"]);
    assert!(naive.outputs["C"].max_abs_diff(&want) < 1e-4);
    assert!(fused.outputs["C"].max_abs_diff(&want) < 1e-4);
    println!(
        "naive : {} traffic, {} kernel launches",
        fmt_bytes(naive.mem.total_traffic()),
        naive.mem.kernel_launches
    );
    println!(
        "fused : {} traffic, {} kernel launches",
        fmt_bytes(fused.mem.total_traffic()),
        fused.mem.kernel_launches
    );
    println!(
        "=> {:.2}x less global-memory traffic, identical numerics",
        naive.mem.total_traffic() as f64 / fused.mem.total_traffic() as f64
    );
}
