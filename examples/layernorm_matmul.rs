//! Example 2: Flash-LayerNorm+Matmul (paper §5).
//!
//! The 22-step trace rides on Rule 4 (swap scale/dot) *and* Rule 5 (swap
//! shift/dot — the distributivity correction with the column-sum and outer
//! product). The derived kernel makes a single pass over `X` and `Yᵀ` per
//! output tile and never materializes `LayerNorm(X)`.
//!
//! Run: `cargo run --release --example layernorm_matmul`

use blockbuster::array::programs;
use blockbuster::coordinator::workloads;
use blockbuster::exec::{reference, run, Workload};
use blockbuster::fusion::fuse;
use blockbuster::loopir::{lower::lower, print::render};
use blockbuster::lower::lower_array;
use blockbuster::rules::RuleId;
use blockbuster::util::bench::fmt_bytes;

fn main() {
    let program = programs::layernorm_matmul();
    let block = lower_array(&program);
    let res = fuse(block.clone());
    println!(
        "fusion trace: {} steps [{}] — the paper's Example 2 takes 22\n",
        res.trace.len(),
        res.trace.summary()
    );
    print!("{}", res.trace);
    assert_eq!(res.trace.count(RuleId::R4), 1, "one scale/dot swap");
    assert_eq!(res.trace.count(RuleId::R5), 1, "one shift/dot swap");

    let fused = res.snapshots.last().unwrap();
    assert_eq!(fused.interior_buffered_count_recursive(), 0);
    println!(
        "\nderived Flash-LayerNorm+Matmul kernel:\n{}",
        render(&lower(fused))
    );

    let (_, cfg, params, inputs) = workloads::layernorm_matmul_demo(42);
    let wl = Workload {
        sizes: cfg.sizes.clone(),
        params: params.clone(),
        inputs: inputs.clone(),
        local_capacity: None,
        threads: None,
    };
    let naive = run(&block, &wl);
    let fast = run(fused, &wl);
    let want = reference::layernorm_matmul_ref(&inputs["X"], &inputs["YT"]);
    assert!(naive.outputs["Z"].max_abs_diff(&want) < 1e-3);
    assert!(fast.outputs["Z"].max_abs_diff(&want) < 1e-3);
    println!(
        "naive : traffic {}  launches {}",
        fmt_bytes(naive.mem.total_traffic()),
        naive.mem.kernel_launches
    );
    println!(
        "fused : traffic {}  launches {}  ({:.2}x reduction, numerics identical)",
        fmt_bytes(fast.mem.total_traffic()),
        fast.mem.kernel_launches,
        naive.mem.total_traffic() as f64 / fast.mem.total_traffic() as f64
    );
}
