//! End-to-end driver: a full transformer decoder block through the whole
//! Blockbuster stack.
//!
//! Pipeline exercised (all layers composing):
//!   1. array program (attention + residual + RMSNorm/FFN-SwiGLU)
//!   2. Table-2 lowering to the block program
//!   3. candidate selection (interval DP) invoking the fusion algorithm,
//!      scoring every snapshot with the static cost model
//!   4. plan execution on the two-tier-memory simulator — the paper's
//!      headline metric: global-memory traffic and kernel launches,
//!      naive vs selected plan
//!   5. cross-validation of the numerics against (a) the Rust tensor-level
//!      reference and (b) the AOT JAX/Pallas artifacts executed via the
//!      PJRT runtime (if `make artifacts` has run)
//!
//! Run: `make artifacts && cargo run --release --example decoder_block`

use blockbuster::coordinator::{compile, execute_plan, plan_report, workloads};
use blockbuster::exec::{reference, run, Workload};
use blockbuster::util::bench::fmt_bytes;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let (program, cfg, params, inputs) = workloads::decoder_demo(42);
    println!("decoder block: {} array operators\n", program.op_count());

    // --- compile -------------------------------------------------------------
    let t0 = Instant::now();
    let compiled = compile(&program, cfg.clone());
    let compile_time = t0.elapsed();
    print!("{}", plan_report(&compiled));
    println!("compile time: {compile_time:?}\n");

    // --- execute: naive vs plan ----------------------------------------------
    let wl = Workload {
        sizes: cfg.sizes.clone(),
        params: params.clone(),
        inputs: inputs.clone(),
        local_capacity: None,
        threads: None,
    };
    let t1 = Instant::now();
    let naive = run(&compiled.block, &wl);
    let naive_time = t1.elapsed();
    let t2 = Instant::now();
    let plan = execute_plan(&compiled.plan, &cfg.sizes, &params, &inputs);
    let plan_time = t2.elapsed();

    println!("metric            naive        fused plan");
    println!(
        "traffic           {:<12} {}",
        fmt_bytes(naive.mem.total_traffic()),
        fmt_bytes(plan.mem.total_traffic())
    );
    println!(
        "kernel launches   {:<12} {}",
        naive.mem.kernel_launches, plan.mem.kernel_launches
    );
    println!(
        "flops             {:<12} {}",
        naive.mem.flops, plan.mem.flops
    );
    println!(
        "sim wall-clock    {:<12?} {plan_time:?}",
        naive_time
    );
    println!(
        "=> {:.2}x traffic reduction, {:.1}x fewer launches\n",
        naive.mem.total_traffic() as f64 / plan.mem.total_traffic() as f64,
        naive.mem.kernel_launches as f64 / plan.mem.kernel_launches as f64
    );

    // --- numeric cross-check vs Rust reference --------------------------------
    let (want_o, want_h) = reference::decoder_block_ref(
        &inputs["Q"],
        &inputs["KT"],
        &inputs["VT"],
        &inputs["R"],
        &inputs["WT"],
        &inputs["VT2"],
        &inputs["UT"],
        params["DD"],
    );
    let dh = plan.outputs["H"].max_abs_diff(&want_h);
    let do_ = plan.outputs["O"].max_abs_diff(&want_o);
    println!("plan vs tensor reference: |ΔH|={dh:.2e} |ΔO|={do_:.2e}");
    assert!(dh < 5e-4 && do_ < 5e-3);

    // --- cross-check vs the XLA/PJRT artifacts --------------------------------
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let mut rt = blockbuster::runtime::Runtime::new("artifacts")?;
        let args = [
            &inputs["Q"],
            &inputs["KT"],
            &inputs["VT"],
            &inputs["R"],
            &inputs["WT"],
            &inputs["VT2"],
            &inputs["UT"],
        ];
        let t3 = Instant::now();
        let xla_naive = rt.execute("decoder_block_naive", &args)?;
        let xla_naive_t = t3.elapsed();
        let t4 = Instant::now();
        let xla_fused = rt.execute("decoder_block_fused", &args)?;
        let xla_fused_t = t4.elapsed();
        println!(
            "XLA artifacts: naive {:?} (first-call incl. compile), pallas-fused {:?}",
            xla_naive_t, xla_fused_t
        );
        let d1 = plan.outputs["O"].max_abs_diff(&xla_naive[0]);
        let d2 = xla_fused[0].max_abs_diff(&xla_naive[0]);
        println!("plan vs XLA naive: |ΔO|={d1:.2e};  pallas vs XLA naive: |ΔO|={d2:.2e}");
        assert!(d1 < 5e-3 && d2 < 5e-3);
        // steady-state latency (compiled executables cached)
        let reps = 20;
        let t5 = Instant::now();
        for _ in 0..reps {
            let _ = rt.execute("decoder_block_fused", &args)?;
        }
        println!(
            "steady-state pallas-fused decoder latency: {:?}/call",
            t5.elapsed() / reps
        );
    } else {
        println!("(artifacts missing — run `make artifacts` for the PJRT cross-check)");
    }

    println!("\nOK: all layers compose; see EXPERIMENTS.md for the recorded run.");
    Ok(())
}
