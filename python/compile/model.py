"""L2: JAX models composed from the L1 kernels.

Two variants of every computation:
* ``*_naive`` — straight jnp composition (what an unoptimized array program
  executes; the baseline the fusion framework starts from);
* ``*_fused`` — the same computation routed through the fused Pallas
  kernels the paper's fusion algorithm derives.

Both lower to HLO text once at build time (`aot.py`); the Rust runtime
loads and executes the artifacts — Python never runs on the request path.
"""

from .kernels import ref
from .kernels.flash_attention import flash_attention
from .kernels.layernorm_matmul import layernorm_matmul
from .kernels.matmul_relu import matmul_relu
from .kernels.rmsnorm_ffn_swiglu import rmsnorm_ffn_swiglu


def matmul_relu_naive(a, bt):
    return (ref.matmul_relu(a, bt),)


def matmul_relu_fused(a, bt):
    return (matmul_relu(a, bt),)


def attention_naive(q, kt, vt):
    return (ref.attention(q, kt, vt),)


def attention_fused(q, kt, vt):
    return (flash_attention(q, kt, vt),)


def layernorm_matmul_naive(x, yt):
    return (ref.layernorm_matmul(x, yt),)


def layernorm_matmul_fused(x, yt):
    return (layernorm_matmul(x, yt),)


def rmsnorm_ffn_swiglu_naive(x, wt, vt, ut):
    return (ref.rmsnorm_ffn_swiglu(x, wt, vt, ut),)


def rmsnorm_ffn_swiglu_fused(x, wt, vt, ut):
    return (rmsnorm_ffn_swiglu(x, wt, vt, ut),)


def decoder_block_naive(q, kt, vt, r, wt, vt2, ut):
    o, h = ref.decoder_block(q, kt, vt, r, wt, vt2, ut)
    return (o, h)


def decoder_block_fused(q, kt, vt, r, wt, vt2, ut):
    """Decoder block built from the two fused mega-kernels."""
    h = flash_attention(q, kt, vt) + r
    o = rmsnorm_ffn_swiglu(h, wt, vt2, ut)
    return (o, h)
