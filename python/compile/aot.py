"""AOT lowering: JAX/Pallas models -> HLO text artifacts for the Rust runtime.

HLO **text** is the interchange format, not serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/load_hlo/).

Runs ONCE at build time (`make artifacts`); emits one ``<name>.hlo.txt``
per model variant plus ``manifest.json`` describing inputs/outputs so the
Rust runtime can wire buffers without re-parsing Python.

Usage:  python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Example shapes baked into the artifacts (small enough for interpret-mode
# Pallas on CPU; block sizes 8 divide everything).
SQ, SKV, D, DV = 32, 32, 16, 16
LM, LK, LN = 32, 32, 16
RM, RD, RK, RN = 32, 16, 32, 16


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


MODELS = {
    "matmul_relu_naive": (model.matmul_relu_naive, [("A", (LM, LK)), ("BT", (LN, LK))]),
    "matmul_relu_fused": (model.matmul_relu_fused, [("A", (LM, LK)), ("BT", (LN, LK))]),
    "attention_naive": (
        model.attention_naive,
        [("Q", (SQ, D)), ("KT", (SKV, D)), ("VT", (DV, SKV))],
    ),
    "attention_fused": (
        model.attention_fused,
        [("Q", (SQ, D)), ("KT", (SKV, D)), ("VT", (DV, SKV))],
    ),
    "layernorm_matmul_naive": (
        model.layernorm_matmul_naive,
        [("X", (LM, LK)), ("YT", (LN, LK))],
    ),
    "layernorm_matmul_fused": (
        model.layernorm_matmul_fused,
        [("X", (LM, LK)), ("YT", (LN, LK))],
    ),
    "rmsnorm_ffn_swiglu_naive": (
        model.rmsnorm_ffn_swiglu_naive,
        [("X", (RM, RD)), ("WT", (RK, RD)), ("VT", (RK, RD)), ("UT", (RN, RK))],
    ),
    "rmsnorm_ffn_swiglu_fused": (
        model.rmsnorm_ffn_swiglu_fused,
        [("X", (RM, RD)), ("WT", (RK, RD)), ("VT", (RK, RD)), ("UT", (RN, RK))],
    ),
    "decoder_block_naive": (
        model.decoder_block_naive,
        [
            ("Q", (SQ, D)),
            ("KT", (SKV, D)),
            ("VT", (DV, SKV)),
            ("R", (SQ, DV)),
            ("WT", (RK, DV)),
            ("VT2", (RK, DV)),
            ("UT", (RN, RK)),
        ],
    ),
    "decoder_block_fused": (
        model.decoder_block_fused,
        [
            ("Q", (SQ, D)),
            ("KT", (SKV, D)),
            ("VT", (DV, SKV)),
            ("R", (SQ, DV)),
            ("WT", (RK, DV)),
            ("VT2", (RK, DV)),
            ("UT", (RN, RK)),
        ],
    ),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower a single model")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {}
    for name, (fn, inputs) in MODELS.items():
        if args.only and name != args.only:
            continue
        specs = [_spec(*shape) for _, shape in inputs]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        out_shapes = [list(s.shape) for s in lowered.out_info]
        manifest[name] = {
            "file": fname,
            "inputs": [{"name": n, "shape": list(s)} for n, s in inputs],
            "outputs": out_shapes,
        }
        print(f"lowered {name}: {len(text)} chars")

    mpath = os.path.join(args.out_dir, "manifest.json")
    existing = {}
    if os.path.exists(mpath) and args.only:
        with open(mpath) as f:
            existing = json.load(f)
    existing.update(manifest)
    with open(mpath, "w") as f:
        json.dump(existing, f, indent=2, sort_keys=True)
    print(f"wrote {mpath} ({len(existing)} models)")


if __name__ == "__main__":
    main()
