"""L1 Pallas kernel: Flash-RMSNorm+FFN-SwiGLU — the Example-3 mega-kernel.

Implements the §5 Example-3 result (Steps 1–26): per row-block of `X`, a
single kernel computes the RMS statistic, then streams the FFN's hidden
dimension (the fused `for k` loop of Step 25's extension) — for each hidden
chunk it forms `swish(x̂·Wᵀ) ⊙ (x̂·Vᵀ)` in local memory and accumulates its
contribution to the output through `Uᵀ` — three matmuls, a Hadamard
product, a reduction and the elementwise ops in one launch, with no
intermediate ever hitting global memory.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, wt_ref, vt_ref, ut_ref, o_ref, *, block_h: int):
    x = x_ref[...]  # (bm, d)
    d = x.shape[1]
    k_ff = wt_ref.shape[0]
    n_out = ut_ref.shape[0]
    n_blocks = k_ff // block_h

    # RMS statistic and normalized rows (the fused D-loop of Step 26)
    ms = (x * x).sum(axis=1) / jnp.float32(d)
    xn = x * jax.lax.rsqrt(ms)[:, None]

    def body(k, acc):
        w = pl.load(wt_ref, (pl.dslice(k * block_h, block_h), slice(None)))
        v = pl.load(vt_ref, (pl.dslice(k * block_h, block_h), slice(None)))
        u = pl.load(ut_ref, (slice(None), pl.dslice(k * block_h, block_h)))
        a = jnp.dot(xn, w.T)  # (bm, bh)
        b = jnp.dot(xn, v.T)  # (bm, bh)
        h = (a / (1.0 + jnp.exp(-a))) * b  # swish ⊙ gate
        return acc + jnp.dot(h, u.T)  # (bm, n_out)

    acc0 = jnp.zeros((x.shape[0], n_out), x.dtype)
    o_ref[...] = jax.lax.fori_loop(0, n_blocks, body, acc0)


def rmsnorm_ffn_swiglu(x, wt, vt, ut, *, block_m: int = 8, block_h: int = 8):
    """Fused ``(swish(RMS(x)@wt.T) * (RMS(x)@vt.T)) @ ut.T``.

    x: (m, d), wt/vt: (k_ff, d), ut: (n_out, k_ff) -> (m, n_out).
    """
    m, d = x.shape
    k_ff = wt.shape[0]
    n_out = ut.shape[0]
    assert wt.shape == vt.shape and ut.shape[1] == k_ff
    assert m % block_m == 0 and k_ff % block_h == 0
    grid = (m // block_m,)
    return pl.pallas_call(
        functools.partial(_kernel, block_h=block_h),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i: (i, 0)),
            pl.BlockSpec((k_ff, d), lambda i: (0, 0)),
            pl.BlockSpec((k_ff, d), lambda i: (0, 0)),
            pl.BlockSpec((n_out, k_ff), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n_out), x.dtype),
        interpret=True,
    )(x, wt, vt, ut)
