"""Pure-jnp reference oracles for the fused Pallas kernels.

Storage conventions match the Rust block programs (and the paper's
diagrams): matmul right operands are the transposed-stored matrices, so
``dot(a, b) = a @ b.T`` throughout —

* attention: ``O = softmax(Q @ KT.T / sqrt(d)) @ VT.T`` with ``KT = K``
  (shape ``(s_kv, d)``) and ``VT = V.T`` (shape ``(d_v, s_kv)``);
* layernorm+matmul: ``Z = LayerNorm(X) @ YT.T``;
* rmsnorm+ffn-swiglu:
  ``O = (swish(RMS(X) @ WT.T) * (RMS(X) @ VT.T)) @ UT.T``.
"""

import jax
import jax.numpy as jnp


def softmax_rows(x):
    return jax.nn.softmax(x, axis=-1)


def layernorm_rows(x):
    mu = x.mean(axis=-1, keepdims=True)
    var = (x * x).mean(axis=-1, keepdims=True) - mu * mu
    return (x - mu) * jax.lax.rsqrt(var)


def rmsnorm_rows(x):
    ms = (x * x).mean(axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms)


def swish(x):
    return x / (1.0 + jnp.exp(-x))


def matmul_relu(a, bt):
    return jnp.maximum(a @ bt.T, 0.0)


def attention(q, kt, vt):
    d = q.shape[-1]
    scores = (q @ kt.T) * (d ** -0.5)
    return softmax_rows(scores) @ vt.T


def layernorm_matmul(x, yt):
    return layernorm_rows(x) @ yt.T


def rmsnorm_ffn_swiglu(x, wt, vt, ut):
    r = rmsnorm_rows(x)
    return (swish(r @ wt.T) * (r @ vt.T)) @ ut.T


def decoder_block(q, kt, vt, r, wt, vt2, ut):
    """Attention + residual + RMSNorm/FFN-SwiGLU (see array::programs)."""
    h = attention(q, kt, vt) + r
    return rmsnorm_ffn_swiglu(h, wt, vt2, ut), h
