"""L1 Pallas kernel: Flash-LayerNorm+Matmul — the Example-2 fused kernel.

Implements the §5 Example-2 result (Steps 1–22): one pass over the K
blocks of `X` and `Yᵀ` per output tile, carrying the running row-sum,
row-sum-of-squares, raw dot accumulator, and the Rule-5 column-sum
correction — then the epilogue applies the swapped shift/scale:

    Z[i,j] = (acc[i,j] − μ_i · ysum_j) · rstd_i

which is exactly `(X − μ·1ᵀ)·Yᵀ` row-scaled by `1/σ` (Rules 4+5 algebra).
Never materializes `LayerNorm(X)` in global memory.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, yt_ref, z_ref, *, block_k: int):
    x_cols = x_ref.shape[1]
    n_blocks = x_cols // block_k
    bm = x_ref.shape[0]
    bn = yt_ref.shape[0]
    kk = jnp.float32(x_cols)

    def body(k, carry):
        s1, s2, acc, ysum = carry
        xk = pl.load(x_ref, (slice(None), pl.dslice(k * block_k, block_k)))
        yk = pl.load(yt_ref, (slice(None), pl.dslice(k * block_k, block_k)))
        s1 = s1 + xk.sum(axis=1)
        s2 = s2 + (xk * xk).sum(axis=1)
        acc = acc + jnp.dot(xk, yk.T)
        ysum = ysum + yk.sum(axis=1)
        return s1, s2, acc, ysum

    z = (
        jnp.zeros((bm,), jnp.float32),
        jnp.zeros((bm,), jnp.float32),
        jnp.zeros((bm, bn), jnp.float32),
        jnp.zeros((bn,), jnp.float32),
    )
    s1, s2, acc, ysum = jax.lax.fori_loop(0, n_blocks, body, z)
    mu = s1 / kk
    rstd = jax.lax.rsqrt(s2 / kk - mu * mu)
    z_ref[...] = (acc - mu[:, None] * ysum[None, :]) * rstd[:, None]


def layernorm_matmul(x, yt, *, block_m: int = 8, block_n: int = 8, block_k: int = 8):
    """Fused ``LayerNorm(x) @ yt.T``. x: (m, k), yt: (n, k) -> (m, n)."""
    m, k = x.shape
    n = yt.shape[0]
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    grid = (m // block_m, n // block_n)
    return pl.pallas_call(
        functools.partial(_kernel, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, yt)
