"""L1 Pallas kernel: Flash Attention — the Example-1 fused kernel.

This is the single-pass kernel the fusion algorithm derives in §5
(Steps 1–17), with the Appendix's row-wise significand–exponent
stabilization (online softmax) applied after fusion: the grid parallelizes
the `forall m` row-block loop; inside the kernel a serial `fori_loop`
streams KV blocks (the fused `for n` loop), carrying the running row-max
`m`, denominator `l`, and output accumulator — never materializing the
(s_q × s_kv) score matrix in global memory.

TPU hardware mapping (DESIGN.md §Hardware-Adaptation): the Q/O row-blocks
and each streamed KV block are the VMEM-resident tiles (BlockSpec /
pl.dslice); the two `jnp.dot`-shaped contractions per step are the MXU work.
`interpret=True` because the image's PJRT is CPU-only.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, kt_ref, vt_ref, o_ref, *, block_kv: int):
    q = q_ref[...]  # (bm, d)
    bm, d = q.shape
    s_kv = kt_ref.shape[0]
    d_v = vt_ref.shape[0]
    scale = d ** -0.5
    n_blocks = s_kv // block_kv

    def body(i, carry):
        m_run, l_run, acc = carry
        k = pl.load(kt_ref, (pl.dslice(i * block_kv, block_kv), slice(None)))
        v = pl.load(vt_ref, (slice(None), pl.dslice(i * block_kv, block_kv)))
        s = jnp.dot(q, k.T) * scale  # (bm, bkv)
        m_new = jnp.maximum(m_run, s.max(axis=1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_run * alpha + p.sum(axis=1)
        acc_new = acc * alpha[:, None] + jnp.dot(p, v.T)  # (bm, d_v)
        return m_new, l_new, acc_new

    m0 = jnp.full((bm,), -jnp.inf, dtype=q.dtype)
    l0 = jnp.zeros((bm,), dtype=q.dtype)
    acc0 = jnp.zeros((bm, d_v), dtype=q.dtype)
    m_fin, l_fin, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    o_ref[...] = acc / l_fin[:, None]


def flash_attention(q, kt, vt, *, block_q: int = 8, block_kv: int = 8):
    """Fused attention: ``softmax(q @ kt.T / sqrt(d)) @ vt.T``.

    q: (s_q, d), kt: (s_kv, d), vt: (d_v, s_kv); returns (s_q, d_v).
    """
    s_q, d = q.shape
    s_kv = kt.shape[0]
    d_v = vt.shape[0]
    assert s_q % block_q == 0, f"s_q={s_q} % block_q={block_q}"
    assert s_kv % block_kv == 0, f"s_kv={s_kv} % block_kv={block_kv}"
    grid = (s_q // block_q,)
    return pl.pallas_call(
        functools.partial(_kernel, block_kv=block_kv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((s_kv, d), lambda i: (0, 0)),
            pl.BlockSpec((d_v, s_kv), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, d_v), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s_q, d_v), q.dtype),
        interpret=True,
    )(q, kt, vt)
