"""L1 Pallas kernel: fused matmul+ReLU — the paper's §1 motivating example.

One launch, tiled over the output grid: each program loads a row-block of
`A` and a row-block of `Bᵀ`, multiplies, applies ReLU in local memory, and
stores the result — the intermediate product never reaches global memory
(§1's fused listing).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, bt_ref, c_ref):
    c_ref[...] = jnp.maximum(jnp.dot(a_ref[...], bt_ref[...].T), 0.0)


def matmul_relu(a, bt, *, block_m: int = 8, block_n: int = 8):
    """Fused ``relu(a @ bt.T)``. a: (m, k), bt: (n, k) -> (m, n)."""
    m, k = a.shape
    n = bt.shape[0]
    assert m % block_m == 0 and n % block_n == 0
    grid = (m // block_m, n // block_n)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, bt)
