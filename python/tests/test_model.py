"""L2 model checks: fused and naive variants agree; AOT shapes line up."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.aot import MODELS


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def test_decoder_block_fused_matches_naive():
    q, kt, vt = rand(0, 32, 16), rand(1, 32, 16), rand(2, 16, 32)
    r = rand(3, 32, 16)
    wt, vt2, ut = rand(4, 32, 16), rand(5, 32, 16), rand(6, 16, 32)
    o_n, h_n = model.decoder_block_naive(q, kt, vt, r, wt, vt2, ut)
    o_f, h_f = model.decoder_block_fused(q, kt, vt, r, wt, vt2, ut)
    np.testing.assert_allclose(np.asarray(h_f), np.asarray(h_n), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(o_f), np.asarray(o_n), atol=1e-4, rtol=1e-3)


def test_all_models_trace_with_manifest_shapes():
    # every registered model must jit-trace at its manifest shapes
    for name, (fn, inputs) in MODELS.items():
        specs = [jax.ShapeDtypeStruct(tuple(s), jnp.float32) for _, s in inputs]
        jax.eval_shape(fn, *specs)


def test_naive_fused_pairs_share_signatures():
    names = set(MODELS)
    for name in names:
        if name.endswith("_naive"):
            other = name.replace("_naive", "_fused")
            assert other in names
            assert [s for _, s in MODELS[name][1]] == [s for _, s in MODELS[other][1]]
