"""Kernel-vs-oracle correctness: the core L1 signal.

Each fused Pallas kernel is checked against its pure-jnp reference on fixed
cases and on hypothesis-generated shape/seed sweeps (shapes constrained to
multiples of the block sizes, like the selection layer guarantees).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.flash_attention import flash_attention
from compile.kernels.layernorm_matmul import layernorm_matmul
from compile.kernels.matmul_relu import matmul_relu
from compile.kernels.rmsnorm_ffn_swiglu import rmsnorm_ffn_swiglu

SETTINGS = dict(max_examples=8, deadline=None)


def rand(key, *shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def assert_close(a, b, atol=2e-5, rtol=2e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol, rtol=rtol)


# ---------------------------------------------------------------- attention

def test_flash_attention_basic():
    q, kt, vt = rand(0, 32, 16), rand(1, 32, 16), rand(2, 16, 32)
    assert_close(flash_attention(q, kt, vt), ref.attention(q, kt, vt))


def test_flash_attention_rectangular():
    q, kt, vt = rand(3, 16, 8), rand(4, 40, 8), rand(5, 24, 40)
    assert_close(flash_attention(q, kt, vt), ref.attention(q, kt, vt))


def test_flash_attention_large_magnitude_inputs():
    # the online-softmax stabilization must survive large logits where the
    # unsafe formula overflows
    q, kt, vt = rand(6, 16, 8, scale=30.0), rand(7, 16, 8, scale=30.0), rand(8, 8, 16)
    out = flash_attention(q, kt, vt)
    assert np.isfinite(np.asarray(out)).all()
    assert_close(out, ref.attention(q, kt, vt), atol=1e-4, rtol=1e-3)


@settings(**SETTINGS)
@given(
    mq=st.integers(1, 4),
    mkv=st.integers(1, 4),
    d=st.sampled_from([4, 8, 16]),
    dv=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**16),
)
def test_flash_attention_sweep(mq, mkv, d, dv, seed):
    q = rand(seed, 8 * mq, d)
    kt = rand(seed + 1, 8 * mkv, d)
    vt = rand(seed + 2, dv, 8 * mkv)
    assert_close(flash_attention(q, kt, vt), ref.attention(q, kt, vt), atol=1e-4, rtol=1e-4)


@settings(**SETTINGS)
@given(bq=st.sampled_from([4, 8, 16]), bkv=st.sampled_from([4, 8, 16]))
def test_flash_attention_block_shape_invariance(bq, bkv):
    # fusion results must not depend on the chosen block shapes (§1)
    q, kt, vt = rand(9, 16, 8), rand(10, 16, 8), rand(11, 8, 16)
    assert_close(
        flash_attention(q, kt, vt, block_q=bq, block_kv=bkv),
        ref.attention(q, kt, vt),
        atol=1e-4,
        rtol=1e-4,
    )


# ----------------------------------------------------------- layernorm+matmul

def test_layernorm_matmul_basic():
    x, yt = rand(20, 32, 32), rand(21, 16, 32)
    assert_close(layernorm_matmul(x, yt), ref.layernorm_matmul(x, yt), atol=1e-4, rtol=1e-3)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 4),
    n=st.integers(1, 4),
    k=st.integers(1, 6),
    seed=st.integers(0, 2**16),
)
def test_layernorm_matmul_sweep(m, n, k, seed):
    x = rand(seed, 8 * m, 8 * k)
    yt = rand(seed + 1, 8 * n, 8 * k)
    assert_close(
        layernorm_matmul(x, yt), ref.layernorm_matmul(x, yt), atol=2e-4, rtol=2e-3
    )


def test_layernorm_matmul_shifted_inputs():
    # non-zero-mean inputs exercise the Rule-5 colsum correction
    x = rand(22, 16, 24) + 5.0
    yt = rand(23, 8, 24)
    assert_close(layernorm_matmul(x, yt), ref.layernorm_matmul(x, yt), atol=2e-4, rtol=2e-3)


# -------------------------------------------------------- rmsnorm+ffn-swiglu

def test_rmsnorm_ffn_swiglu_basic():
    x, wt, vt, ut = rand(30, 32, 16), rand(31, 32, 16), rand(32, 32, 16), rand(33, 16, 32)
    assert_close(
        rmsnorm_ffn_swiglu(x, wt, vt, ut),
        ref.rmsnorm_ffn_swiglu(x, wt, vt, ut),
        atol=1e-4,
        rtol=1e-3,
    )


@settings(**SETTINGS)
@given(
    m=st.integers(1, 3),
    d=st.sampled_from([8, 16]),
    kff=st.integers(1, 4),
    nout=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**16),
)
def test_rmsnorm_ffn_swiglu_sweep(m, d, kff, nout, seed):
    x = rand(seed, 8 * m, d)
    wt = rand(seed + 1, 8 * kff, d)
    vt = rand(seed + 2, 8 * kff, d)
    ut = rand(seed + 3, nout, 8 * kff)
    assert_close(
        rmsnorm_ffn_swiglu(x, wt, vt, ut),
        ref.rmsnorm_ffn_swiglu(x, wt, vt, ut),
        atol=2e-4,
        rtol=2e-3,
    )


# ----------------------------------------------------------------- matmul+relu

def test_matmul_relu_basic():
    a, bt = rand(40, 32, 32), rand(41, 16, 32)
    assert_close(matmul_relu(a, bt), ref.matmul_relu(a, bt))


@settings(**SETTINGS)
@given(m=st.integers(1, 4), n=st.integers(1, 4), k=st.sampled_from([4, 8, 32]))
def test_matmul_relu_sweep(m, n, k):
    a, bt = rand(m, 8 * m, k), rand(n + 50, 8 * n, k)
    assert_close(matmul_relu(a, bt), ref.matmul_relu(a, bt), atol=1e-4, rtol=1e-4)


def test_matmul_relu_clamps_negatives():
    a = -jnp.ones((8, 4), jnp.float32)
    bt = jnp.ones((8, 4), jnp.float32)
    out = matmul_relu(a, bt)
    assert (np.asarray(out) == 0.0).all()
