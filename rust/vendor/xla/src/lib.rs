//! Offline stub of the `xla` PJRT bindings.
//!
//! Mirrors the handful of entry points `blockbuster::runtime` calls, but
//! carries no native code: [`PjRtClient::cpu`] fails with an explanatory
//! error, so every artifact-backed path (which already gates on
//! `artifacts/manifest.json` existing) skips gracefully. Replace with the
//! real `xla` crate to execute AOT artifacts.

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: the native XLA/PJRT runtime is not available in this \
         offline build (vendored stub); install the real `xla` crate to \
         execute AOT artifacts"
    )))
}

pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_p: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

#[derive(Clone)]
pub struct Literal(());

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("offline"));
    }
}
