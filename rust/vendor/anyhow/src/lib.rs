//! Minimal offline stand-in for the `anyhow` crate.
//!
//! Implements the subset the workspace uses: [`Error`], [`Result`],
//! [`anyhow!`], [`bail!`], and the [`Context`] extension trait for
//! `Result` and `Option`. Like the real crate, `Error` deliberately does
//! *not* implement `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion coherent.

use std::error::Error as StdError;
use std::fmt;

/// A type-erased error: a message plus an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from anything printable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error {
            msg: m.to_string(),
            source: None,
        }
    }

    /// Prepend context, `anyhow`-style (`context: original`).
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error {
            msg: format!("{c}: {}", self.msg),
            source: self.source,
        }
    }

    /// The root-cause chain, outermost first (message only).
    pub fn to_string_chain(&self) -> String {
        let mut s = self.msg.clone();
        let mut cur: Option<&(dyn StdError + 'static)> =
            self.source.as_ref().map(|b| b.as_ref() as _);
        while let Some(e) = cur {
            s.push_str(&format!("\ncaused by: {e}"));
            cur = e.source();
        }
        s
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_chain())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error {
            msg: e.to_string(),
            source: Some(Box::new(e)),
        }
    }
}

/// `anyhow::Result<T>` — defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/path")
            .with_context(|| "reading config".to_string())?;
        Ok(s)
    }

    #[test]
    fn context_and_chain() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().starts_with("reading config: "));
        assert!(e.to_string_chain().contains("caused by"));
    }

    #[test]
    fn macros() {
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
        fn f() -> Result<()> {
            bail!("nope {}", 1)
        }
        assert_eq!(f().unwrap_err().to_string(), "nope 1");
    }

    #[test]
    fn option_context() {
        let o: Option<u32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
    }
}
