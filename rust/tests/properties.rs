//! Property tests over randomly generated array programs.
//!
//! The central invariant of the whole paper — every substitution rule is
//! logic-preserving — is checked end to end: random programs are lowered,
//! fused (every snapshot), and executed; outputs must match the unfused
//! program bit-for-tolerance. Structural invariants (validity, acyclicity,
//! full fusion) and the cost model's agreement with the memory simulator
//! are checked on the same corpus.

use blockbuster::cost::{analyze, ShapeEnv};
use blockbuster::exec::{run, Workload};
use blockbuster::fusion::fuse;
use blockbuster::ir::validate::validate;
use blockbuster::loopir::lower::lower;
use blockbuster::lower::lower_array;
use blockbuster::prop::{forall, random_workload};
use blockbuster::tensor::Mat;
use std::collections::HashMap;

fn run_w(
    g: &blockbuster::Graph,
    w: &blockbuster::prop::RandomWorkload,
) -> (HashMap<String, Mat>, blockbuster::loopir::interp::MemSim) {
    let r = run(
        g,
        &Workload {
            sizes: w.sizes.clone(),
            params: w.params.clone(),
            inputs: w.inputs.clone(),
            local_capacity: None,
            threads: None,
        },
    );
    (r.outputs, r.mem)
}

fn close(a: &Mat, b: &Mat) -> Result<(), String> {
    let scale = b.data.iter().fold(1.0f32, |m, v| m.max(v.abs()));
    let d = a.max_abs_diff(b);
    if d > 5e-4 * scale.max(1.0) {
        return Err(format!("max abs diff {d} (scale {scale})"));
    }
    Ok(())
}

/// Every fusion snapshot of every random program computes the same function.
#[test]
fn fusion_preserves_semantics_on_random_programs() {
    forall(40, 0xB10C, |seed| {
        let w = random_workload(seed, 5);
        let g = lower_array(&w.program);
        let (want, naive_mem) = run_w(&g, &w);
        let res = fuse(g);
        for (i, snap) in res.snapshots.iter().enumerate() {
            let errs = validate(snap);
            if !errs.is_empty() {
                return Err(format!("snapshot {i} invalid: {errs:?}"));
            }
            let (got, mem) = run_w(snap, &w);
            for (name, m) in &want {
                let gm = got
                    .get(name)
                    .ok_or_else(|| format!("snapshot {i} lost output {name}"))?;
                close(gm, m).map_err(|e| format!("snapshot {i} output {name}: {e}"))?;
            }
            if i == 0 && mem.total_traffic() > naive_mem.total_traffic() {
                return Err(format!(
                    "snapshot 0 (no replication) traffic {} exceeds naive {}",
                    mem.total_traffic(),
                    naive_mem.total_traffic()
                ));
            }
        }
        Ok(())
    });
}

/// Fusion monotonically removes interior buffered edges and makes real
/// progress whenever there is anything to fuse.
///
/// (Full single-kernel fusion is *not* guaranteed for arbitrary programs: a
/// trailing row-wise softmax/layernorm keeps one buffered edge because its
/// normalizer blocks Rule 1 via an indirect path and there is no downstream
/// matmul for Rule 4 to swap through — the paper's Flash Attention only
/// reaches zero because of the second matmul.)
#[test]
fn fusion_reduces_buffered_census_monotonically() {
    forall(30, 0xFAFA, |seed| {
        let w = random_workload(seed, 4);
        let g = lower_array(&w.program);
        let initial = g.interior_buffered_count_recursive();
        let res = fuse(g);
        let mut prev = usize::MAX;
        for s in &res.snapshots {
            let n = s.interior_buffered_count_recursive();
            if n > prev {
                return Err(format!("buffered census increased: {prev} -> {n}"));
            }
            prev = n;
        }
        let last = res
            .snapshots
            .last()
            .unwrap()
            .interior_buffered_count_recursive();
        if last > initial {
            return Err(format!("census grew: {initial} -> {last}"));
        }
        if initial > 0 && last >= initial {
            return Err(format!(
                "no progress ({initial} -> {last}):\n{}",
                res.trace
            ));
        }
        Ok(())
    });
}

/// The static cost analyzer agrees exactly with the measuring interpreter.
#[test]
fn static_cost_matches_memsim_on_random_programs() {
    forall(30, 0xC057, |seed| {
        let w = random_workload(seed, 4);
        let g = lower_array(&w.program);
        for snap in fuse(g.clone()).snapshots.iter().chain([&g]) {
            let ir = lower(snap);
            let env = ShapeEnv::from_full_shapes(&ir, &w.sizes, &w.full_shapes);
            let st = analyze(&ir, &w.sizes, &env);
            let (_, dy) = run_w(snap, &w);
            if st.loaded_bytes != dy.loaded_bytes
                || st.stored_bytes != dy.stored_bytes
                || st.flops != dy.flops
                || st.launches != dy.kernel_launches
            {
                return Err(format!(
                    "static {st:?} vs measured load={} store={} flops={} launches={}",
                    dy.loaded_bytes, dy.stored_bytes, dy.flops, dy.kernel_launches
                ));
            }
        }
        Ok(())
    });
}

/// Selection plans execute to the same outputs as the naive program, never
/// with more global traffic.
#[test]
fn selection_plans_preserve_semantics() {
    use blockbuster::coordinator::{compile, execute_plan, CompileConfig};
    use blockbuster::cost::CostModel;
    forall(15, 0x5E1E, |seed| {
        let w = random_workload(seed, 4);
        let cfg = CompileConfig {
            sizes: w.sizes.clone(),
            full_shapes: w.full_shapes.clone(),
            model: CostModel::default(),
        };
        let compiled = compile(&w.program, cfg);
        let plan_run = execute_plan(&compiled.plan, &w.sizes, &w.params, &w.inputs);
        let (want, naive_mem) = run_w(&compiled.block, &w);
        for (name, m) in &want {
            let gm = plan_run
                .outputs
                .get(name)
                .ok_or_else(|| format!("plan lost output {name}"))?;
            close(gm, m).map_err(|e| format!("plan output {name}: {e}"))?;
        }
        if plan_run.mem.total_traffic() > naive_mem.total_traffic() {
            return Err(format!(
                "plan traffic {} exceeds naive {}",
                plan_run.mem.total_traffic(),
                naive_mem.total_traffic()
            ));
        }
        Ok(())
    });
}

/// The autotuner's feasibility estimate is sound: executing the program
/// with `local_capacity` slightly above the static peak must not trip the
/// capacity assertion.
#[test]
fn static_peak_local_is_enforceable() {
    forall(15, 0x10CA1, |seed| {
        let w = random_workload(seed, 4);
        let g = lower_array(&w.program);
        let fused = fuse(g).snapshots.pop().unwrap();
        let ir = lower(&fused);
        let env = ShapeEnv::from_full_shapes(&ir, &w.sizes, &w.full_shapes);
        let st = analyze(&ir, &w.sizes, &env);
        let r = std::panic::catch_unwind(|| {
            run(
                &fused,
                &Workload {
                    sizes: w.sizes.clone(),
                    params: w.params.clone(),
                    inputs: w.inputs.clone(),
                    // static peak is an upper-ish approximation; allow 2x
                    local_capacity: Some(st.peak_local_bytes * 2 + 64),
                    threads: None,
                },
            )
        });
        r.map(|_| ())
            .map_err(|_| format!("capacity {} insufficient", st.peak_local_bytes * 2))
    });
}
