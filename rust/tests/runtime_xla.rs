//! PJRT runtime cross-checks: the Rust block-program executor, the naive
//! JAX artifacts, and the fused Pallas-kernel artifacts must all agree.
//!
//! Requires `make artifacts` (skips with a notice if they're absent, so
//! `cargo test` works on a fresh checkout).

use blockbuster::coordinator::workloads;
use blockbuster::exec::{reference, run, Workload};
use blockbuster::lower::lower_array;
use blockbuster::runtime::Runtime;
use blockbuster::tensor::Mat;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing — run `make artifacts`");
        return None;
    }
    Some(Runtime::new("artifacts").expect("runtime init"))
}

fn close(a: &Mat, b: &Mat, tol: f32, what: &str) {
    let d = a.max_abs_diff(b);
    assert!(d < tol, "{what}: max abs diff {d}");
}

#[test]
fn attention_three_way_agreement() {
    let Some(mut rt) = runtime() else { return };
    let (p, cfg, params, inputs) = workloads::attention_demo(11);
    // 1. Rust two-tier executor on the fused block program
    let g = lower_array(&p);
    let fused = blockbuster::fusion::fuse(g).snapshots.pop().unwrap();
    let ours = run(
        &fused,
        &Workload {
            sizes: cfg.sizes.clone(),
            params: params.clone(),
            inputs: inputs.clone(),
            local_capacity: None,
            threads: None,
        },
    );
    // 2. XLA on the naive JAX model; 3. XLA on the fused Pallas kernel
    let args = [&inputs["Q"], &inputs["KT"], &inputs["VT"]];
    let naive = rt.execute("attention_naive", &args).unwrap();
    let pallas = rt.execute("attention_fused", &args).unwrap();
    // 4. Rust tensor-level reference
    let want = reference::attention_ref(&inputs["Q"], &inputs["KT"], &inputs["VT"], 16.0);

    close(&naive[0], &want, 1e-4, "xla naive vs rust reference");
    close(&pallas[0], &want, 1e-4, "pallas fused vs rust reference");
    close(&ours.outputs["O"], &want, 5e-4, "block executor vs reference");
    close(&pallas[0], &naive[0], 1e-4, "pallas vs xla naive");
}

#[test]
fn layernorm_matmul_three_way_agreement() {
    let Some(mut rt) = runtime() else { return };
    let (_, _, _, inputs) = workloads::layernorm_matmul_demo(12);
    let args = [&inputs["X"], &inputs["YT"]];
    let naive = rt.execute("layernorm_matmul_naive", &args).unwrap();
    let pallas = rt.execute("layernorm_matmul_fused", &args).unwrap();
    let want = reference::layernorm_matmul_ref(&inputs["X"], &inputs["YT"]);
    close(&naive[0], &want, 5e-4, "xla naive vs reference");
    close(&pallas[0], &want, 5e-4, "pallas fused vs reference");
}

#[test]
fn rmsnorm_ffn_swiglu_three_way_agreement() {
    let Some(mut rt) = runtime() else { return };
    let (_, _, _, inputs) = workloads::rmsnorm_ffn_swiglu_demo(13);
    let args = [&inputs["X"], &inputs["WT"], &inputs["VT"], &inputs["UT"]];
    let naive = rt.execute("rmsnorm_ffn_swiglu_naive", &args).unwrap();
    let pallas = rt.execute("rmsnorm_ffn_swiglu_fused", &args).unwrap();
    let want =
        reference::rmsnorm_ffn_swiglu_ref(&inputs["X"], &inputs["WT"], &inputs["VT"], &inputs["UT"]);
    close(&naive[0], &want, 1e-3, "xla naive vs reference");
    close(&pallas[0], &want, 1e-3, "pallas fused vs reference");
}

#[test]
fn decoder_block_artifacts_agree() {
    let Some(mut rt) = runtime() else { return };
    let (_, _, params, inputs) = workloads::decoder_demo(14);
    let args = [
        &inputs["Q"],
        &inputs["KT"],
        &inputs["VT"],
        &inputs["R"],
        &inputs["WT"],
        &inputs["VT2"],
        &inputs["UT"],
    ];
    let naive = rt.execute("decoder_block_naive", &args).unwrap();
    let fused = rt.execute("decoder_block_fused", &args).unwrap();
    assert_eq!(naive.len(), 2);
    close(&fused[1], &naive[1], 1e-4, "decoder H fused vs naive");
    close(&fused[0], &naive[0], 1e-3, "decoder O fused vs naive");
    let (want_o, _) = reference::decoder_block_ref(
        &inputs["Q"],
        &inputs["KT"],
        &inputs["VT"],
        &inputs["R"],
        &inputs["WT"],
        &inputs["VT2"],
        &inputs["UT"],
        params["DD"],
    );
    close(&naive[0], &want_o, 1e-3, "decoder O xla vs rust reference");
}

#[test]
fn manifest_covers_all_expected_models() {
    let Some(rt) = runtime() else { return };
    for m in [
        "matmul_relu_naive",
        "matmul_relu_fused",
        "attention_naive",
        "attention_fused",
        "layernorm_matmul_naive",
        "layernorm_matmul_fused",
        "rmsnorm_ffn_swiglu_naive",
        "rmsnorm_ffn_swiglu_fused",
        "decoder_block_naive",
        "decoder_block_fused",
    ] {
        assert!(rt.manifest.models.contains_key(m), "missing artifact {m}");
    }
}
