//! Engine/interpreter parity (the compiled-execution tentpole's contract):
//! every example program run through both `ExecBackend`s must produce
//! **bit-identical** outputs and identical `MemSim` counters
//! (`loaded_bytes`, `stored_bytes`, `kernel_launches`, `flops`), on the
//! naive program and on every fusion snapshot — across thread counts
//! **and across SIMD on/off** (the lane-structured kernels make the
//! vector and scalar paths exact). A random-program property test
//! extends the guarantee beyond the curated examples.

use blockbuster::coordinator::workloads;
use blockbuster::exec::{run_lowered_with, ExecBackend, Workload};
use blockbuster::fusion::fuse;
use blockbuster::loopir::lower::lower;
use blockbuster::loopir::LoopIr;
use blockbuster::lower::lower_array;
use blockbuster::prop::{forall, random_workload};

fn assert_parity(ir: &LoopIr, wl: &Workload, what: &str) {
    let a = run_lowered_with(ir, wl, ExecBackend::Interp);
    let b = run_lowered_with(ir, wl, ExecBackend::Compiled);
    assert_eq!(
        a.outputs.len(),
        b.outputs.len(),
        "{what}: output sets differ"
    );
    let mut names: Vec<&String> = a.outputs.keys().collect();
    names.sort();
    for n in names {
        assert_eq!(
            a.outputs[n], b.outputs[n],
            "{what}: output {n} not bit-identical across backends"
        );
    }
    assert_eq!(
        a.mem.loaded_bytes, b.mem.loaded_bytes,
        "{what}: loaded_bytes"
    );
    assert_eq!(
        a.mem.stored_bytes, b.mem.stored_bytes,
        "{what}: stored_bytes"
    );
    assert_eq!(a.mem.n_loads, b.mem.n_loads, "{what}: n_loads");
    assert_eq!(a.mem.n_stores, b.mem.n_stores, "{what}: n_stores");
    assert_eq!(
        a.mem.kernel_launches, b.mem.kernel_launches,
        "{what}: kernel_launches"
    );
    assert_eq!(a.mem.flops, b.mem.flops, "{what}: flops");
}

/// All five example programs (`quickstart`, `attention`,
/// `layernorm_matmul`, `rmsnorm_ffn_swiglu`, `decoder`): naive program and
/// every fusion snapshot, both backends, exact agreement.
#[test]
fn example_programs_bit_identical_across_backends() {
    for name in workloads::NAMES {
        let (p, cfg, params, inputs) = workloads::by_name(name, 1234).unwrap();
        let wl = Workload {
            sizes: cfg.sizes.clone(),
            params,
            inputs,
            local_capacity: None,
            threads: None,
        };
        let g = lower_array(&p);
        assert_parity(&lower(&g), &wl, &format!("{name}/naive"));
        for (i, snap) in fuse(g).snapshots.iter().enumerate() {
            assert_parity(&lower(snap), &wl, &format!("{name}/snapshot{i}"));
        }
    }
}

/// Parity must be insensitive to the worker count **and** the SIMD
/// switch: the compiled engine at 1/2/8 threads, with vector kernels on
/// or off, produces the same bits as the interpreter run in the same
/// SIMD mode — and the two SIMD modes produce the same bits as each
/// other (the interpreter reference is computed once, with SIMD on).
#[test]
fn parity_insensitive_to_thread_count_and_simd() {
    use blockbuster::loopir::interp::exec;
    use blockbuster::tensor::simd;
    let (p, cfg, params, inputs) = workloads::rmsnorm_ffn_swiglu_demo(77);
    let g = lower_array(&p);
    let fused = fuse(g).snapshots.pop().unwrap();
    let ir = lower(&fused);

    // build the blocked config directly so `threads` can be pinned
    let mut base = blockbuster::loopir::interp::ExecConfig::new(cfg.sizes.clone());
    base.params = params;
    for decl in &ir.bufs {
        if !decl.is_input {
            continue;
        }
        let m = &inputs[&decl.name];
        let rb = cfg.sizes.get(&decl.dims[0]);
        let cb = cfg.sizes.get(&decl.dims[1]);
        base.inputs
            .insert(decl.name.clone(), blockbuster::exec::to_blocks(m, rb, cb));
    }
    simd::set_enabled(true);
    let want = exec(&ir, &base);
    for simd_on in [true, false] {
        simd::set_enabled(simd_on);
        for threads in [1usize, 2, 8] {
            let mut cfg2 = base.clone();
            cfg2.threads = Some(threads);
            let prog = blockbuster::loopir::compile::compile(&ir, &cfg2);
            let got = blockbuster::exec::engine::exec_compiled(&prog, &cfg2);
            for (n, bv) in &want.outputs {
                let gbv = &got.outputs[n];
                assert_eq!(bv.dims, gbv.dims);
                for (i, slot) in bv.data.iter().enumerate() {
                    let a = slot.as_deref();
                    let b = gbv.data[i].as_deref();
                    assert_eq!(a, b, "simd={simd_on}, threads={threads}, output {n}, slot {i}");
                }
            }
            assert_eq!(want.mem.loaded_bytes, got.mem.loaded_bytes);
            assert_eq!(want.mem.stored_bytes, got.mem.stored_bytes);
            assert_eq!(want.mem.flops, got.mem.flops);
            assert_eq!(want.mem.kernel_launches, got.mem.kernel_launches);
            if threads == 1 {
                // sequential engine runs the exact var set/clear sequence
                // of the interpreter, so even the peak-local approximation
                // must match — this pins the engine's duplicated
                // local-memory accounting (and its serial single-worker
                // path) to the interpreter's
                assert_eq!(want.mem.peak_local_bytes, got.mem.peak_local_bytes);
                assert_eq!(want.mem.n_loads, got.mem.n_loads);
                assert_eq!(want.mem.n_stores, got.mem.n_stores);
            }
        }
    }
    simd::set_enabled(true);
}

/// Property: parity holds on random programs, naive and fully fused.
#[test]
fn random_programs_bit_identical_across_backends() {
    forall(25, 0xB17B17, |seed| {
        let w = random_workload(seed, 4);
        let g = lower_array(&w.program);
        let wl = Workload {
            sizes: w.sizes.clone(),
            params: w.params.clone(),
            inputs: w.inputs.clone(),
            local_capacity: None,
            threads: None,
        };
        for ir in [lower(&g), lower(fuse(g.clone()).snapshots.last().unwrap())] {
            let a = run_lowered_with(&ir, &wl, ExecBackend::Interp);
            let b = run_lowered_with(&ir, &wl, ExecBackend::Compiled);
            for (n, m) in &a.outputs {
                if b.outputs.get(n) != Some(m) {
                    return Err(format!("output {n} differs across backends"));
                }
            }
            if a.mem.loaded_bytes != b.mem.loaded_bytes
                || a.mem.stored_bytes != b.mem.stored_bytes
                || a.mem.n_loads != b.mem.n_loads
                || a.mem.n_stores != b.mem.n_stores
                || a.mem.flops != b.mem.flops
                || a.mem.kernel_launches != b.mem.kernel_launches
            {
                return Err(format!(
                    "counters differ: interp {:?} vs compiled {:?}",
                    a.mem, b.mem
                ));
            }
        }
        Ok(())
    });
}
