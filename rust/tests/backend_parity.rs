//! Engine/interpreter parity (the compiled-execution tentpole's contract):
//! every example program run through both `ExecBackend`s must produce
//! **bit-identical** outputs and identical `MemSim` counters
//! (`loaded_bytes`, `stored_bytes`, `kernel_launches`, `flops`), on the
//! naive program and on every fusion snapshot — across thread counts
//! **and across SIMD on/off** (the lane-structured kernels make the
//! vector and scalar paths exact). A random-program property test
//! extends the guarantee beyond the curated examples.

use blockbuster::coordinator::workloads;
use blockbuster::exec::{run_lowered_with, ExecBackend, Workload};
use blockbuster::fusion::fuse;
use blockbuster::loopir::lower::lower;
use blockbuster::loopir::LoopIr;
use blockbuster::lower::lower_array;
use blockbuster::prop::{forall, random_workload};

fn assert_parity(ir: &LoopIr, wl: &Workload, what: &str) {
    let a = run_lowered_with(ir, wl, ExecBackend::Interp);
    for backend in [ExecBackend::Compiled, ExecBackend::Specialized] {
        let b = run_lowered_with(ir, wl, backend);
        let what = &format!("{what} [{}]", backend.name());
        assert_eq!(
            a.outputs.len(),
            b.outputs.len(),
            "{what}: output sets differ"
        );
        let mut names: Vec<&String> = a.outputs.keys().collect();
        names.sort();
        for n in names {
            assert_eq!(
                a.outputs[n], b.outputs[n],
                "{what}: output {n} not bit-identical across backends"
            );
        }
        assert_eq!(
            a.mem.loaded_bytes, b.mem.loaded_bytes,
            "{what}: loaded_bytes"
        );
        assert_eq!(
            a.mem.stored_bytes, b.mem.stored_bytes,
            "{what}: stored_bytes"
        );
        assert_eq!(a.mem.n_loads, b.mem.n_loads, "{what}: n_loads");
        assert_eq!(a.mem.n_stores, b.mem.n_stores, "{what}: n_stores");
        assert_eq!(
            a.mem.kernel_launches, b.mem.kernel_launches,
            "{what}: kernel_launches"
        );
        assert_eq!(a.mem.flops, b.mem.flops, "{what}: flops");
    }
}

/// All five example programs (`quickstart`, `attention`,
/// `layernorm_matmul`, `rmsnorm_ffn_swiglu`, `decoder`): naive program and
/// every fusion snapshot, both backends, exact agreement.
#[test]
fn example_programs_bit_identical_across_backends() {
    for name in workloads::NAMES {
        let (p, cfg, params, inputs) = workloads::by_name(name, 1234).unwrap();
        let wl = Workload {
            sizes: cfg.sizes.clone(),
            params,
            inputs,
            local_capacity: None,
            threads: None,
        };
        let g = lower_array(&p);
        assert_parity(&lower(&g), &wl, &format!("{name}/naive"));
        for (i, snap) in fuse(g).snapshots.iter().enumerate() {
            assert_parity(&lower(snap), &wl, &format!("{name}/snapshot{i}"));
        }
    }
}

/// Parity must be insensitive to the worker count **and** the SIMD
/// switch: the compiled engine at 1/2/8 threads, with vector kernels on
/// or off, produces the same bits as the interpreter run in the same
/// SIMD mode — and the two SIMD modes produce the same bits as each
/// other (the interpreter reference is computed once, with SIMD on).
#[test]
fn parity_insensitive_to_thread_count_and_simd() {
    use blockbuster::loopir::interp::exec;
    use blockbuster::tensor::simd;
    let (p, cfg, params, inputs) = workloads::rmsnorm_ffn_swiglu_demo(77);
    let g = lower_array(&p);
    let fused = fuse(g).snapshots.pop().unwrap();
    let ir = lower(&fused);

    // build the blocked config directly so `threads` can be pinned
    let mut base = blockbuster::loopir::interp::ExecConfig::new(cfg.sizes.clone());
    base.params = params;
    for decl in &ir.bufs {
        if !decl.is_input {
            continue;
        }
        let m = &inputs[&decl.name];
        let rb = cfg.sizes.get(&decl.dims[0]);
        let cb = cfg.sizes.get(&decl.dims[1]);
        base.inputs
            .insert(decl.name.clone(), blockbuster::exec::to_blocks(m, rb, cb));
    }
    simd::set_enabled(true);
    let want = exec(&ir, &base);
    for simd_on in [true, false] {
        simd::set_enabled(simd_on);
        for specialize in [false, true] {
            for threads in [1usize, 2, 8] {
                let mut cfg2 = base.clone();
                cfg2.threads = Some(threads);
                let prog = if specialize {
                    blockbuster::loopir::compile::specialize_skeleton(
                        &blockbuster::loopir::compile::compile_skeleton(&ir, &cfg2),
                    )
                    .bind(&cfg2.sizes)
                } else {
                    blockbuster::loopir::compile::compile(&ir, &cfg2)
                };
                let got = blockbuster::exec::engine::exec_compiled(&prog, &cfg2);
                for (n, bv) in &want.outputs {
                    let gbv = &got.outputs[n];
                    assert_eq!(bv.dims, gbv.dims);
                    for (i, slot) in bv.data.iter().enumerate() {
                        let a = slot.as_deref();
                        let b = gbv.data[i].as_deref();
                        assert_eq!(
                            a, b,
                            "simd={simd_on}, specialize={specialize}, threads={threads}, \
                             output {n}, slot {i}"
                        );
                    }
                }
                assert_eq!(want.mem.loaded_bytes, got.mem.loaded_bytes);
                assert_eq!(want.mem.stored_bytes, got.mem.stored_bytes);
                assert_eq!(want.mem.flops, got.mem.flops);
                assert_eq!(want.mem.kernel_launches, got.mem.kernel_launches);
                if threads == 1 {
                    // sequential engine runs the exact var set/clear sequence
                    // of the interpreter, so even the peak-local approximation
                    // must match — this pins the engine's duplicated
                    // local-memory accounting (and its serial single-worker
                    // path) to the interpreter's
                    assert_eq!(want.mem.peak_local_bytes, got.mem.peak_local_bytes);
                    assert_eq!(want.mem.n_loads, got.mem.n_loads);
                    assert_eq!(want.mem.n_stores, got.mem.n_stores);
                }
            }
        }
    }
    simd::set_enabled(true);
}

/// Two Ew-heavy snapshot programs — the workloads the batched expression
/// VM exists for — swept over backends × simd × threads; everything must
/// agree bitwise with the interpreter reference (computed once, simd on).
///
/// * **softmax tail**: the exp/sub/div chain left after fusing a
///   numerically-safe softmax (`exp(x−shift)` normalized by a shifted
///   denominator), as a two-input elementwise op;
/// * **GELU-style**: a tanh-free erf approximation built from exp/abs
///   (sign recovered as `x/(|x|+ε)`), the long single-input chain shape.
#[test]
fn ew_heavy_programs_bit_identical_across_backends_simd_threads() {
    use blockbuster::ir::dim::DimSizes;
    use blockbuster::ir::expr::Expr;
    use blockbuster::ir::graph::{map_over, ArgMode, Graph};
    use blockbuster::ir::types::Ty;
    use blockbuster::tensor::{simd, Rng};

    // program 1: two mapped inputs feeding the softmax tail per block
    let mut g1 = Graph::new();
    let a = g1.input("X", Ty::blocks(&["M", "N"]));
    let b = g1.input("S", Ty::blocks(&["M", "N"]));
    let o = map_over(&mut g1, "M", &[(a, ArgMode::Mapped), (b, ArgMode::Mapped)], |mb, ins| {
        let inner = map_over(
            &mut mb.g,
            "N",
            &[(ins[0], ArgMode::Mapped), (ins[1], ArgMode::Mapped)],
            |mb2, ins2| {
                let e = Expr::softmax_tail(Expr::var(0), Expr::var(1));
                let r = mb2.g.ew2(e, ins2[0], ins2[1]);
                mb2.collect(r);
            },
        );
        mb.collect(inner[0]);
    });
    g1.output("P", o[0]);

    // program 2: one mapped input through the GELU-style erf chain
    let mut g2 = Graph::new();
    let a = g2.input("X", Ty::blocks(&["M", "N"]));
    let o = map_over(&mut g2, "M", &[(a, ArgMode::Mapped)], |mb, ins| {
        let inner = map_over(&mut mb.g, "N", &[(ins[0], ArgMode::Mapped)], |mb2, ins2| {
            let r = mb2.g.ew1(Expr::gelu_erf(Expr::var(0)), ins2[0]);
            mb2.collect(r);
        });
        mb.collect(inner[0]);
    });
    g2.output("G", o[0]);

    let mut rng = Rng::new(0xE77);
    for (pname, g, out, ins) in [
        ("softmax_tail", g1, "P", vec!["X", "S"]),
        ("gelu_erf", g2, "G", vec!["X"]),
    ] {
        let ir = lower(&g);
        let mut base = Workload::new(DimSizes::of(&[("M", 4), ("N", 6)]));
        for n in &ins {
            base.inputs.insert(n.to_string(), rng.mat(16, 24));
        }
        simd::set_enabled(true);
        let want = run_lowered_with(&ir, &base, ExecBackend::Interp);
        for simd_on in [true, false] {
            simd::set_enabled(simd_on);
            for backend in [
                ExecBackend::Interp,
                ExecBackend::Compiled,
                ExecBackend::Specialized,
            ] {
                for threads in [1usize, 2, 8] {
                    let mut w = Workload::new(base.sizes.clone());
                    w.inputs = base.inputs.clone();
                    w.threads = Some(threads);
                    let got = run_lowered_with(&ir, &w, backend);
                    let tag = format!(
                        "{pname} backend={} simd={simd_on} threads={threads}",
                        backend.name()
                    );
                    assert_eq!(want.outputs[out], got.outputs[out], "{tag}: output");
                    assert_eq!(want.mem.loaded_bytes, got.mem.loaded_bytes, "{tag}");
                    assert_eq!(want.mem.stored_bytes, got.mem.stored_bytes, "{tag}");
                    assert_eq!(want.mem.flops, got.mem.flops, "{tag}");
                    assert_eq!(want.mem.kernel_launches, got.mem.kernel_launches, "{tag}");
                }
            }
        }
        simd::set_enabled(true);
    }
}

/// The decode workload — one query block against a growing KV cache —
/// swept over every cache length the demo cap allows × backends × SIMD
/// on/off × 1/2/8 threads, on the naive program and the fully fused
/// flash-decode kernel. Everything must agree bitwise with the
/// interpreter reference (computed once per length, simd on): the
/// decode-vs-prefill differential in `serve_decode.rs` leans on this
/// exactness, so it gets its own sweep here.
#[test]
fn decode_attention_bit_identical_across_backends_simd_threads() {
    use blockbuster::tensor::simd;

    let (p, cfg, params, full) = workloads::by_name("decode_attention", 0x5EED).unwrap();
    let g = lower_array(&p);
    let naive = lower(&g);
    let fused = lower(fuse(g).snapshots.last().unwrap());
    let cap = cfg.sizes.get(&"N".into());
    assert!(cap >= 2, "demo cap must exercise more than one cache length");

    for t in 1..=cap {
        // Slice the full-cap demo inputs down to a length-t cache: KT
        // keeps its first t row blocks, VT its first t col blocks, and
        // the (zero) mask its first t col blocks.
        let mut sizes = cfg.sizes.clone();
        sizes.set("N", t);
        let mut wl = Workload::new(sizes);
        wl.params = params.clone();
        wl.inputs.insert("Q".into(), full["Q"].clone());
        wl.inputs.insert("KT".into(), full["KT"].slice(0, 0, 8 * t, 16));
        wl.inputs.insert("VT".into(), full["VT"].slice(0, 0, 16, 8 * t));
        wl.inputs.insert("MASK".into(), full["MASK"].slice(0, 0, 8, 8 * t));

        for (ir_name, ir) in [("naive", &naive), ("fused", &fused)] {
            simd::set_enabled(true);
            let want = run_lowered_with(ir, &wl, ExecBackend::Interp);
            for simd_on in [true, false] {
                simd::set_enabled(simd_on);
                for backend in [
                    ExecBackend::Interp,
                    ExecBackend::Compiled,
                    ExecBackend::Specialized,
                ] {
                    for threads in [1usize, 2, 8] {
                        let mut w = Workload::new(wl.sizes.clone());
                        w.params = wl.params.clone();
                        w.inputs = wl.inputs.clone();
                        w.threads = Some(threads);
                        let got = run_lowered_with(ir, &w, backend);
                        let tag = format!(
                            "decode t={t} {ir_name} backend={} simd={simd_on} threads={threads}",
                            backend.name()
                        );
                        assert_eq!(want.outputs["O"], got.outputs["O"], "{tag}: output O");
                        assert_eq!(want.mem.loaded_bytes, got.mem.loaded_bytes, "{tag}");
                        assert_eq!(want.mem.stored_bytes, got.mem.stored_bytes, "{tag}");
                        assert_eq!(want.mem.flops, got.mem.flops, "{tag}");
                        assert_eq!(want.mem.kernel_launches, got.mem.kernel_launches, "{tag}");
                    }
                }
            }
            simd::set_enabled(true);
        }
    }
}

/// Property: parity holds on random programs, naive and fully fused.
#[test]
fn random_programs_bit_identical_across_backends() {
    forall(25, 0xB17B17, |seed| {
        let w = random_workload(seed, 4);
        let g = lower_array(&w.program);
        let wl = Workload {
            sizes: w.sizes.clone(),
            params: w.params.clone(),
            inputs: w.inputs.clone(),
            local_capacity: None,
            threads: None,
        };
        for ir in [lower(&g), lower(fuse(g.clone()).snapshots.last().unwrap())] {
            let a = run_lowered_with(&ir, &wl, ExecBackend::Interp);
            for backend in [ExecBackend::Compiled, ExecBackend::Specialized] {
                let b = run_lowered_with(&ir, &wl, backend);
                for (n, m) in &a.outputs {
                    if b.outputs.get(n) != Some(m) {
                        return Err(format!(
                            "output {n} differs across backends [{}]",
                            backend.name()
                        ));
                    }
                }
                if a.mem.loaded_bytes != b.mem.loaded_bytes
                    || a.mem.stored_bytes != b.mem.stored_bytes
                    || a.mem.n_loads != b.mem.n_loads
                    || a.mem.n_stores != b.mem.n_stores
                    || a.mem.flops != b.mem.flops
                    || a.mem.kernel_launches != b.mem.kernel_launches
                {
                    return Err(format!(
                        "counters differ: interp {:?} vs {} {:?}",
                        a.mem,
                        backend.name(),
                        b.mem
                    ));
                }
            }
        }
        Ok(())
    });
}
