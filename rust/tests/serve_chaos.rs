//! Chaos suite: the serving daemon under seeded fault injection.
//!
//! The acceptance bar (ISSUE PR 6): with faults armed the daemon never
//! aborts, exactly the poisoned batch's requests get error responses,
//! every surviving response is **bit-identical** (outputs + MemSim
//! counters) to an unfaulted sequential execution, and the
//! shed/reject/panic counters reconcile with submitted − served.
//!
//! The injector (`util::fault`) is process-global, so every test here —
//! armed or not — serializes behind one lock; arming is RAII-guarded
//! ([`FaultGuard`]) so a failing assertion can't leave the injector hot
//! for the next test. The fault *stream* is seeded and deterministic,
//! but which concurrent consumer observes the n-th draw is not, so
//! assertions are invariants (containment, accounting, survivor
//! parity), never exact victim identities.
//!
//! Env overrides for CI sweeps: `BB_FAULT_RATE` scales the injected
//! rate, `BB_CHAOS_ITERS` the request counts.

use blockbuster::coordinator::{
    compile, execute_plan_opts, execute_prepared, plan_stack_info, workloads, PlanRun,
};
use blockbuster::exec::{pool, ExecBackend};
use blockbuster::serve::daemon::{Daemon, RetuneConfig, Ticket, INVALID_ID};
use blockbuster::serve::{
    BucketLadder, ModelServer, Rejected, Request, Response, ServerConfig, Verdict,
};
use blockbuster::tensor::Mat;
use blockbuster::util::fault;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Duration;

/// Serialize every test in this binary: the fault injector and the
/// worker pool are process-global.
fn chaos_lock() -> MutexGuard<'static, ()> {
    static M: OnceLock<Mutex<()>> = OnceLock::new();
    M.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

/// RAII arming: disarms the global injector even if the test unwinds on
/// a failed assertion mid-chaos.
struct FaultGuard;

impl FaultGuard {
    fn arm(rate: f64, seed: u64) -> FaultGuard {
        fault::set(rate, seed);
        FaultGuard
    }
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        fault::off();
    }
}

fn env_rate(default: f64) -> f64 {
    std::env::var("BB_FAULT_RATE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn env_iters(default: usize) -> usize {
    std::env::var("BB_CHAOS_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn bits(m: &Mat) -> Vec<u32> {
    m.data.iter().map(|x| x.to_bits()).collect()
}

/// Survivor parity: same fields as `tests/serve_parity.rs`
/// (`peak_local_bytes` excluded — the one counter the engine does not
/// pin across worker fan-outs).
fn assert_survivor_matches(i: usize, r: &Response, seq: &PlanRun) {
    for (name, m) in &seq.outputs {
        assert_eq!(
            bits(m),
            bits(&r.outputs[name]),
            "request {i}: surviving output {name} not bit-identical"
        );
    }
    assert_eq!(r.mem.loaded_bytes, seq.mem.loaded_bytes, "request {i}: loads");
    assert_eq!(r.mem.stored_bytes, seq.mem.stored_bytes, "request {i}: stores");
    assert_eq!(r.mem.n_loads, seq.mem.n_loads, "request {i}: n_loads");
    assert_eq!(r.mem.n_stores, seq.mem.n_stores, "request {i}: n_stores");
    assert_eq!(r.mem.kernel_launches, seq.mem.kernel_launches, "request {i}: launches");
    assert_eq!(r.mem.flops, seq.mem.flops, "request {i}: flops");
}

/// Shared chaos harness: ground truth computed first (unarmed), then the
/// same stream through an armed daemon; returns the responses alongside
/// the recovered server.
fn chaos_run(
    program: &str,
    n: usize,
    rate: f64,
    fault_seed: u64,
    coalesce: bool,
) -> (Vec<Response>, Vec<PlanRun>, ModelServer) {
    let mut server = ModelServer::new(ServerConfig {
        backend: ExecBackend::Compiled,
        threads: Some(2),
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        coalesce,
        ..ServerConfig::default()
    });
    server.register(program).unwrap();

    // Ground truth FIRST, before arming: independent one-shot compile +
    // sequential execution per request seed.
    let (p, cfg, params, _) = workloads::by_name(program, 0).unwrap();
    let compiled = compile(&p, cfg.clone());
    let mut expected = Vec::with_capacity(n);
    let mut reqs = Vec::with_capacity(n);
    for i in 0..n as u64 {
        let inputs = server.synthetic_inputs(program, 7_000 + i).unwrap();
        expected.push(execute_plan_opts(
            &compiled.plan,
            &cfg.sizes,
            &params,
            &inputs,
            ExecBackend::Compiled,
            Some(2),
        ));
        reqs.push(Request::new(program, inputs));
    }

    let guard = FaultGuard::arm(rate, fault_seed);
    let daemon = Daemon::start(server, None);
    let client = daemon.client();
    let tickets: Vec<Ticket> = reqs.into_iter().map(|r| client.submit(r)).collect();
    let responses: Vec<Response> = tickets.into_iter().map(|t| t.wait()).collect();
    let server = daemon.shutdown();
    drop(guard);
    (responses, expected, server)
}

/// The injector itself: off by default, deterministic per (rate, seed),
/// and rate-adherent over a large single-threaded sample.
#[test]
fn armed_injector_is_seeded_and_rate_adherent() {
    let _l = chaos_lock();
    let guard = FaultGuard::arm(0.25, 0x5eed);
    let first: Vec<bool> = (0..64).map(|_| fault::injected(fault::Site::Compute)).collect();
    // Re-arming with the same (rate, seed) replays the same stream.
    fault::set(0.25, 0x5eed);
    let second: Vec<bool> = (0..64).map(|_| fault::injected(fault::Site::Compute)).collect();
    assert_eq!(first, second, "same (rate, seed) must replay the same stream");
    assert!(
        first.iter().any(|&b| b) && first.iter().any(|&b| !b),
        "64 draws at 25% should mix hits and misses"
    );
    fault::set(0.25, 0x5eed);
    let n = 100_000;
    let hits = (0..n)
        .filter(|_| fault::injected(fault::Site::PoolWorker))
        .count();
    let p = hits as f64 / n as f64;
    assert!((0.23..0.27).contains(&p), "empirical rate {p} too far from configured 0.25");
    drop(guard);
    assert_eq!(fault::rate(), 0.0, "guard must disarm on drop");
    assert!(!fault::injected(fault::Site::Compute));
}

/// Acceptance: fan-out serving under ~30% injected panics. The daemon
/// never aborts, failures are typed error responses mentioning the
/// injection, survivors are bit-identical to sequential execution, and
/// the ledger reconciles exactly.
#[test]
fn injected_panics_are_contained_and_survivors_bit_identical() {
    let _l = chaos_lock();
    let n = env_iters(60);
    let rate = env_rate(0.3);
    let (responses, expected, server) = chaos_run("quickstart", n, rate, 0xc4a05, false);

    assert_eq!(responses.len(), n, "every submission must be answered");
    let mut ok = 0u64;
    for (i, r) in responses.iter().enumerate() {
        match &r.verdict {
            Verdict::Ok => {
                ok += 1;
                assert_survivor_matches(i, r, &expected[i]);
            }
            Verdict::Failed(msg) => {
                assert!(
                    msg.contains("injected"),
                    "request {i}: non-injected failure leaked through: {msg}"
                );
            }
            Verdict::Rejected(rej) => panic!("request {i}: unexpected rejection {rej:?}"),
        }
    }
    let st = &server.stats().per_program["quickstart"];
    assert_eq!(st.submitted, n as u64);
    assert_eq!(st.accounted(), st.submitted, "ledger must reconcile under faults");
    assert_eq!(st.served, ok);
    assert_eq!(st.served + st.failed, n as u64);
    if rate >= 0.2 && n >= 40 {
        assert!(st.panics >= 1, "rate {rate} over {n} requests injected nothing");
        // fan-out containment is per-request: each contained panic
        // failed exactly one request
        assert_eq!(st.panics, st.failed, "fan-out containment granularity");
    }
}

/// With coalescing on, a poisoned stacked batch fails as a *unit* —
/// every rider gets the error response — and only that batch is lost;
/// other batches' riders stay bit-identical.
#[test]
fn stacked_batch_poisoning_fails_the_whole_batch_only() {
    let _l = chaos_lock();
    let n = env_iters(64);
    let rate = env_rate(0.5);
    let (responses, expected, server) = chaos_run("quickstart", n, rate, 0x57ac, true);

    assert_eq!(responses.len(), n);
    for (i, r) in responses.iter().enumerate() {
        match &r.verdict {
            Verdict::Ok => assert_survivor_matches(i, r, &expected[i]),
            Verdict::Failed(msg) => assert!(
                msg.contains("injected"),
                "request {i}: non-injected failure leaked through: {msg}"
            ),
            Verdict::Rejected(rej) => panic!("request {i}: unexpected rejection {rej:?}"),
        }
    }
    let st = &server.stats().per_program["quickstart"];
    assert_eq!(st.accounted(), st.submitted, "ledger must reconcile under faults");
    assert_eq!(st.served + st.failed, n as u64);
    if rate >= 0.4 && n >= 40 {
        assert!(st.panics >= 1, "rate {rate} over {n} requests injected nothing");
        // stacked containment is per-batch: one contained panic can fail
        // up to max_batch riders
        assert!(st.failed >= st.panics, "a poisoned stacked batch must fail every rider");
    }
}

/// Ragged traffic under chaos: a mixed-length stream through shape
/// buckets (max ladder, padding on) with faults armed. Containment and
/// the ledger hold exactly as for uniform traffic, and every surviving
/// response is bit-identical to a sequential run at the request's OWN
/// length — pad rows never leak into a survivor's counters even when
/// neighbouring batches are being poisoned.
#[test]
fn ragged_stacked_chaos_survivors_stay_bit_identical() {
    let _l = chaos_lock();
    let program = "quickstart";
    let n = env_iters(48);
    let rate = env_rate(0.3);
    let mut server = ModelServer::new(ServerConfig {
        backend: ExecBackend::Compiled,
        threads: Some(2),
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        coalesce: true,
        buckets: BucketLadder::Max,
        pad: true,
        ..ServerConfig::default()
    });
    server.register(program).unwrap();

    // Ground truth FIRST, before arming: each request sequentially at
    // its own trip (stack dim rebound per request).
    let (p, cfg, params, _) = workloads::by_name(program, 0).unwrap();
    let compiled = compile(&p, cfg.clone());
    let info = plan_stack_info(&server.live_plan(program).unwrap())
        .expect("quickstart stacks along M");
    let mut expected = Vec::with_capacity(n);
    let mut reqs = Vec::with_capacity(n);
    for i in 0..n as u64 {
        let trip = 1 + (i as usize % info.trip);
        let inputs = server.synthetic_inputs_ragged(program, 6_000 + i, trip).unwrap();
        let mut sizes = cfg.sizes.clone();
        sizes.set(info.dim.clone(), trip);
        expected.push(execute_plan_opts(
            &compiled.plan,
            &sizes,
            &params,
            &inputs,
            ExecBackend::Compiled,
            Some(2),
        ));
        reqs.push(Request::new(program, inputs));
    }

    let guard = FaultGuard::arm(rate, 0x4a66);
    let daemon = Daemon::start(server, None);
    let client = daemon.client();
    let tickets: Vec<Ticket> = reqs.into_iter().map(|r| client.submit(r)).collect();
    let responses: Vec<Response> = tickets.into_iter().map(|t| t.wait()).collect();
    let server = daemon.shutdown();
    drop(guard);

    assert_eq!(responses.len(), n, "every ragged submission must be answered");
    for (i, r) in responses.iter().enumerate() {
        match &r.verdict {
            Verdict::Ok => {
                assert_survivor_matches(i, r, &expected[i]);
                assert_eq!(r.mem.padded_flops, 0, "request {i}: pad leaked into own counters");
            }
            Verdict::Failed(msg) => assert!(
                msg.contains("injected"),
                "request {i}: non-injected failure leaked through: {msg}"
            ),
            Verdict::Rejected(rej) => panic!("request {i}: unexpected rejection {rej:?}"),
        }
    }
    let st = &server.stats().per_program[program];
    assert_eq!(st.accounted(), st.submitted, "ragged ledger must reconcile under faults");
    assert_eq!(st.served + st.failed, n as u64);
    assert_eq!(st.compiles, 1, "ragged stacked binds under chaos never recompile");
}

/// Injected worker mortality: every task still completes (workers die
/// only after check-in), dead indexes are respawned, and the pool keeps
/// serving afterwards.
#[test]
fn pool_worker_deaths_are_respawned_and_jobs_complete() {
    let _l = chaos_lock();
    let pool = pool::global();
    let respawns_before = pool.respawns();
    let guard = FaultGuard::arm(env_rate(0.5), 0xdead);
    let total = AtomicUsize::new(0);
    for _ in 0..25 {
        pool.run_tasks(4, 8, &|_t| {
            total.fetch_add(1, Ordering::SeqCst);
        });
    }
    drop(guard);
    assert_eq!(
        total.load(Ordering::SeqCst),
        25 * 8,
        "every task must run despite worker mortality"
    );
    // One more (unarmed) job drains any still-dead indexes into respawns
    // and proves the pool serves normally after the storm.
    let after = AtomicUsize::new(0);
    pool.run_tasks(4, 4, &|_| {
        after.fetch_add(1, Ordering::SeqCst);
    });
    assert_eq!(after.load(Ordering::SeqCst), 4);
    if env_rate(0.5) > 0.0 {
        assert!(
            pool.respawns() > respawns_before,
            "injected deaths must be respawned, not accumulated"
        );
    }
}

/// Shutdown with a full queue and nothing flushed yet (max_wait far in
/// the future): graceful drain serves every queued request instead of
/// dropping it.
#[test]
fn shutdown_with_queued_work_drains_everything() {
    let _l = chaos_lock();
    let program = "quickstart";
    let mut server = ModelServer::new(ServerConfig {
        backend: ExecBackend::Compiled,
        threads: Some(1),
        max_batch: 64,
        max_wait: Duration::from_secs(3600),
        coalesce: false,
        ..ServerConfig::default()
    });
    server.register(program).unwrap();
    let reqs: Vec<Request> = (0..10u64)
        .map(|i| Request::new(program, server.synthetic_inputs(program, i).unwrap()))
        .collect();
    let daemon = Daemon::start(server, None);
    let client = daemon.client();
    let tickets: Vec<Ticket> = reqs.into_iter().map(|r| client.submit(r)).collect();
    // Shut down immediately: the queue (nothing was due yet) must be
    // drained and routed before the flusher exits.
    let server = daemon.shutdown();
    let responses: Vec<Response> = tickets.into_iter().map(|t| t.wait()).collect();
    assert_eq!(responses.len(), 10);
    assert!(
        responses.iter().all(|r| r.is_ok()),
        "graceful drain must serve queued work, not drop it"
    );
    let st = &server.stats().per_program[program];
    assert_eq!(st.served, 10);
    assert_eq!(st.accounted(), st.submitted);
}

/// Plan hot-swap between batches under a live request stream: every
/// batch's responses are bit-identical to `execute_prepared` on the
/// exact plan handle that was live when the batch was submitted.
#[test]
fn hot_swap_between_batches_stays_bit_identical() {
    let _l = chaos_lock();
    let program = "quickstart";
    let mut server = ModelServer::new(ServerConfig {
        backend: ExecBackend::Compiled,
        threads: Some(1),
        max_batch: 2,
        max_wait: Duration::from_secs(3600),
        coalesce: false,
        ..ServerConfig::default()
    });
    server.register(program).unwrap();
    let base_sizes = server.live_plan(program).unwrap().sizes.clone();
    let mut small = base_sizes.clone();
    small.set("M", 2);

    let mut swaps = 0u64;
    for round in 0..6u64 {
        // Alternate the live plan's block sizes between rounds — the
        // atomic Arc swap the daemon's re-tuner uses, driven directly.
        if round > 0 {
            let next = if round % 2 == 1 { &small } else { &base_sizes };
            server.adopt_sizes(program, next).unwrap();
            swaps += 1;
        }
        let live = server.live_plan(program).unwrap();
        let inputs_a = server.synthetic_inputs(program, 100 + round).unwrap();
        let inputs_b = server.synthetic_inputs(program, 200 + round).unwrap();
        let a = server.submit(Request::new(program, inputs_a.clone())).unwrap();
        let b = server.submit(Request::new(program, inputs_b.clone())).unwrap();
        let responses = server.drain();
        assert_eq!(responses.len(), 2);
        for (id, inputs) in [(a, &inputs_a), (b, &inputs_b)] {
            let r = responses.iter().find(|r| r.id == id).unwrap();
            assert!(r.is_ok(), "round {round}: verdict {:?}", r.verdict);
            let seq = execute_prepared(&live, inputs, Some(1));
            assert_survivor_matches(round as usize, r, &seq);
        }
    }
    let st = &server.stats().per_program[program];
    assert_eq!(st.plan_swaps, swaps);
    assert_eq!(st.compiles, 1, "hot-swapping must never recompile the workload");
    assert_eq!(st.served, 12);
    assert_eq!(st.accounted(), st.submitted);
}

/// `Daemon::shutdown` racing concurrent `DaemonClient::submit` calls
/// from many threads. Every ticket must resolve — served, or a typed
/// `Rejected::Shutdown` — and the ledger must reconcile exactly:
/// responses carrying a real id are precisely the ones the server
/// counted (`submitted`), self-replies from an already-gone daemon
/// carry `INVALID_ID` and stay off the ledger, and
/// `accounted() == submitted` holds either way.
#[test]
fn shutdown_racing_concurrent_submits_reconciles_exactly() {
    let _l = chaos_lock();
    let program = "quickstart";
    let mut server = ModelServer::new(ServerConfig {
        backend: ExecBackend::Compiled,
        threads: Some(1),
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        coalesce: false,
        ..ServerConfig::default()
    });
    server.register(program).unwrap();
    const THREADS: usize = 4;
    const PER_THREAD: usize = 12;
    let warm = Request::new(program, server.synthetic_inputs(program, 8_999).unwrap());
    let mut batches: Vec<Vec<Request>> = Vec::new();
    for t in 0..THREADS as u64 {
        let mut reqs = Vec::with_capacity(PER_THREAD);
        for i in 0..PER_THREAD as u64 {
            let inputs = server.synthetic_inputs(program, 9_000 + t * 100 + i).unwrap();
            reqs.push(Request::new(program, inputs));
        }
        batches.push(reqs);
    }

    let daemon = Daemon::start(server, None);
    // Warmup: one request served end-to-end before the race begins, so
    // "at least one served" is guaranteed rather than timing-dependent.
    let first = daemon.client().submit(warm).wait();
    assert!(first.is_ok(), "warmup must serve: {:?}", first.verdict);

    let mut handles = Vec::new();
    for (t, reqs) in batches.into_iter().enumerate() {
        let client = daemon.client();
        handles.push(std::thread::spawn(move || {
            let mut resolved = Vec::with_capacity(PER_THREAD);
            for (i, req) in reqs.into_iter().enumerate() {
                let ticket = client.submit(req);
                // Stagger a little so submissions straddle the shutdown.
                if i % 3 == t % 3 {
                    std::thread::sleep(Duration::from_micros(200));
                }
                resolved.push(ticket.wait());
            }
            resolved
        }));
    }
    // Let some racing traffic land, then yank the daemon mid-stream.
    std::thread::sleep(Duration::from_millis(2));
    let server = daemon.shutdown();

    let mut ok = 0u64;
    let mut rejected_ledger = 0u64;
    let mut rejected_client = 0u64;
    for h in handles {
        for r in h.join().expect("submitter thread must not panic") {
            match &r.verdict {
                Verdict::Ok => ok += 1,
                Verdict::Rejected(Rejected::Shutdown) => {
                    if r.id == INVALID_ID {
                        // Daemon already gone: client-side self-reply.
                        rejected_client += 1;
                    } else {
                        // Raced the drain: the server saw and counted it.
                        rejected_ledger += 1;
                    }
                }
                other => panic!("unexpected verdict racing shutdown: {other:?}"),
            }
        }
    }
    assert_eq!(
        ok + rejected_ledger + rejected_client,
        (THREADS * PER_THREAD) as u64,
        "every ticket must resolve"
    );
    let st = &server.stats().per_program[program];
    assert_eq!(st.served, ok + 1, "every Ok response (plus warmup) is a served ledger entry");
    assert_eq!(st.rejected_shutdown, rejected_ledger);
    assert_eq!(
        st.submitted,
        ok + 1 + rejected_ledger,
        "the ledger covers exactly the ids it issued"
    );
    assert_eq!(st.accounted(), st.submitted, "shutdown race must reconcile exactly");
}

/// PR 9 regression: session-owned KV caches survive plan hot-swaps
/// under live traffic. Two servers run the same two-session decode
/// ladder with stateless requests riding the same flushes; one server
/// hot-swaps the decode plan's block sizes every round, *while that
/// round's steps sit queued* (exercising the session re-bucket branch
/// of `adopt_sizes`). Every step must serve bit-identically to the
/// swap-free control — the session executes its pinned plan, swap or
/// no swap — the final caches must match bitwise, and both ledgers
/// must reconcile with the workload compiled exactly once.
#[test]
fn session_kv_survives_plan_hot_swap_under_live_traffic() {
    let _l = chaos_lock();
    let dname = "decode_attention";
    let stateless = "quickstart";
    let mk = || {
        let mut s = ModelServer::new(ServerConfig {
            backend: ExecBackend::Compiled,
            threads: Some(1),
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            coalesce: true,
            ..ServerConfig::default()
        });
        s.register(dname).unwrap();
        s.register(stateless).unwrap();
        s
    };
    let mut control = mk();
    let mut swapped = mk();

    // The swap alternates the decode plan between its registered sizes
    // and a half-capacity variant. Open sessions pinned their plan (and
    // context cap) at open time, so neither swap direction may touch
    // them — only *new* sessions would see the new geometry.
    let base_sizes = swapped.live_plan(dname).unwrap().sizes.clone();
    let mut alt = base_sizes.clone();
    alt.set("N", 2);

    let seeds: [u64; 2] = [0xA11CE, 0xB0B];
    let c_sids: Vec<u64> = seeds.iter().map(|_| control.open_session(dname).unwrap()).collect();
    let s_sids: Vec<u64> = seeds.iter().map(|_| swapped.open_session(dname).unwrap()).collect();

    let mut swaps = 0u64;
    let mut steps = 0u64;
    let mut round = 0u64;
    // Drive both ladders to their PINNED context cap — the probe refusal
    // proves the cap came from the session, not the currently-live plan.
    while swapped.submit_synthetic_decode(s_sids[0], seeds[0]).is_ok() {
        control.submit_synthetic_decode(c_sids[0], seeds[0]).unwrap();
        swapped.submit_synthetic_decode(s_sids[1], seeds[1]).unwrap();
        control.submit_synthetic_decode(c_sids[1], seeds[1]).unwrap();
        steps += 2;
        let extra = swapped.synthetic_inputs(stateless, 4_000 + round).unwrap();
        swapped.submit(Request::new(stateless, extra)).unwrap();
        // Swap WHILE this round's steps are queued: the queued session
        // steps must re-bucket against their pinned plan and still serve.
        let next = if round % 2 == 0 { &alt } else { &base_sizes };
        swapped.adopt_sizes(dname, next).unwrap();
        swaps += 1;

        let mut a = swapped.drain();
        let mut b = control.drain();
        assert_eq!(a.len(), 3, "round {round}: two decode steps + one stateless ride-along");
        assert_eq!(b.len(), 2);
        for r in a.iter().chain(b.iter()) {
            assert!(r.is_ok(), "round {round}: verdict {:?}", r.verdict);
        }
        // Submission order fixes id order per server: session 0's step,
        // session 1's step (then the stateless request, swapped only).
        a.sort_by_key(|r| r.id);
        b.sort_by_key(|r| r.id);
        let a_dec: Vec<&Response> = a.iter().filter(|r| r.workload == dname).collect();
        assert_eq!(a_dec.len(), 2);
        for (k, (ra, rb)) in a_dec.iter().zip(b.iter()).enumerate() {
            assert_eq!(
                bits(&ra.outputs["O"]),
                bits(&rb.outputs["O"]),
                "round {round} session {k}: decode step diverged under hot-swap"
            );
            assert_eq!(
                (ra.mem.loaded_bytes, ra.mem.stored_bytes, ra.mem.flops, ra.mem.kernel_launches),
                (rb.mem.loaded_bytes, rb.mem.stored_bytes, rb.mem.flops, rb.mem.kernel_launches),
                "round {round} session {k}: traffic diverged under hot-swap"
            );
            assert_eq!(
                (ra.mem.state_appended_bytes, ra.mem.state_appends),
                (rb.mem.state_appended_bytes, rb.mem.state_appends),
                "round {round} session {k}: append breakout diverged under hot-swap"
            );
        }
        round += 1;
    }
    assert!(swaps >= 2 && steps >= 4, "ladder too short to exercise both swap directions");

    for (k, (&cs, &ss)) in c_sids.iter().zip(&s_sids).enumerate() {
        assert_eq!(control.session_len(cs), swapped.session_len(ss), "session {k} length");
        for input in ["KT", "VT"] {
            let c = control.session_cache(cs, input).unwrap();
            let s = swapped.session_cache(ss, input).unwrap();
            assert_eq!(bits(c), bits(s), "session {k}: cache {input} diverged under hot-swaps");
        }
    }
    let st = &swapped.stats().per_program[dname];
    assert_eq!(st.plan_swaps, swaps);
    assert_eq!(st.compiles, 1, "hot-swapping must never recompile the decode workload");
    assert_eq!(st.served, steps);
    assert_eq!(st.decode_steps, steps);
    assert_eq!(st.state_appends, steps * 4, "4 appended blocks per step (2 per cache)");
    assert_eq!(st.accounted(), st.submitted, "decode ledger must reconcile across swaps");
    let sq = &swapped.stats().per_program[stateless];
    assert_eq!(sq.served, round);
    assert_eq!(sq.accounted(), sq.submitted, "ride-along ledger must reconcile");
}

/// The daemon's own re-tune path (`--retune-every`): measured re-tuning
/// runs between batches under live traffic and every response still
/// serves correctly with the workload compiled exactly once.
#[test]
fn daemon_retunes_between_batches_under_live_traffic() {
    let _l = chaos_lock();
    let program = "quickstart";
    let mut server = ModelServer::new(ServerConfig {
        backend: ExecBackend::Compiled,
        threads: Some(1),
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        coalesce: false,
        ..ServerConfig::default()
    });
    server.register(program).unwrap();
    let reqs: Vec<Request> = (0..24u64)
        .map(|i| Request::new(program, server.synthetic_inputs(program, 500 + i).unwrap()))
        .collect();
    let daemon = Daemon::start(
        server,
        Some(RetuneConfig {
            every: 6,
            local_capacity: 1 << 20,
            trials: 2,
        }),
    );
    let client = daemon.client();
    let tickets: Vec<Ticket> = reqs.into_iter().map(|r| client.submit(r)).collect();
    let responses: Vec<Response> = tickets.into_iter().map(|t| t.wait()).collect();
    let server = daemon.shutdown();
    assert_eq!(responses.len(), 24);
    assert!(responses.iter().all(|r| r.is_ok()));
    let st = &server.stats().per_program[program];
    assert_eq!(st.served, 24);
    assert_eq!(st.accounted(), st.submitted);
    assert_eq!(st.compiles, 1, "re-tuning re-binds cached skeletons, never recompiles");
}
