//! Reproduction of the paper's §5 fusion traces.
//!
//! For each of the three worked examples we assert:
//!  * the total number of rule applications matches the paper's step count
//!    (Flash Attention: 17, LayerNorm+Matmul: 22, RMSNorm+FFN-SwiGLU: 26);
//!  * the per-rule application counts match the walkthroughs;
//!  * the final program is fully fused — zero interior buffered edges at
//!    every level (the paper's termination criterion);
//!  * every snapshot is numerically equivalent to the unfused program and
//!    to the tensor-level reference (logic preservation);
//!  * fused global-memory traffic is strictly below unfused traffic.

use blockbuster::array::programs;
use blockbuster::exec::{reference, run, Workload};
use blockbuster::fusion::fuse;
use blockbuster::ir::dim::DimSizes;
use blockbuster::ir::validate::assert_valid;
use blockbuster::lower::lower_array;
use blockbuster::rules::RuleId;
use blockbuster::tensor::{Mat, Rng};

fn assert_close(a: &Mat, b: &Mat, tol: f32, what: &str) {
    let d = a.max_abs_diff(b);
    assert!(d < tol, "{what}: max abs diff {d} >= {tol}");
}

// ---------------------------------------------------------------------------
// Example 1: Flash Attention
// ---------------------------------------------------------------------------

#[test]
fn flash_attention_trace_matches_paper() {
    let g = lower_array(&programs::attention());
    let res = fuse(g);
    let t = &res.trace;
    eprintln!("FA trace ({} steps): {}\n{t}", t.len(), t.summary());

    // The paper's Example 1 takes exactly 17 steps:
    // 6×(R1/R2) top-level, R4, R3, 4×R1, R9, 2×R3, R6, R1.
    assert_eq!(t.len(), 17, "total steps; trace:\n{t}");
    assert_eq!(t.count(RuleId::R1) + t.count(RuleId::R2), 11);
    assert_eq!(t.count(RuleId::R3), 3);
    assert_eq!(t.count(RuleId::R4), 1);
    assert_eq!(t.count(RuleId::R6), 1);
    assert_eq!(t.count(RuleId::R9), 1);
    assert_eq!(t.count(RuleId::R5), 0);
    assert_eq!(t.count(RuleId::R8), 0);

    // Two snapshots: quiescent pre-extension, and the final fused kernel.
    assert_eq!(res.snapshots.len(), 2);
    let fused = res.snapshots.last().unwrap();
    assert_valid(fused);
    assert_eq!(
        fused.interior_buffered_count_recursive(),
        0,
        "the only remaining buffered edges touch program inputs/outputs"
    );
}

#[test]
fn flash_attention_numerics_and_traffic() {
    let g0 = lower_array(&programs::attention());
    let res = fuse(g0.clone());

    let mut rng = Rng::new(42);
    let d_model = 16usize;
    let (sq, skv, dv) = (8usize, 12usize, 10usize);
    let q = rng.mat(sq, d_model);
    let kt = rng.mat(skv, d_model);
    let vt = rng.mat(dv, skv);
    let want = reference::attention_ref(&q, &kt, &vt, d_model as f32);

    let wl = || {
        Workload::new(DimSizes::of(&[("M", 2), ("N", 3), ("D", 2), ("L", 2)]))
            .input("Q", q.clone())
            .input("KT", kt.clone())
            .input("VT", vt.clone())
            .param("DD", d_model as f32)
    };
    let unfused = run(&g0, &wl());
    assert_close(&unfused.outputs["O"], &want, 2e-4, "unfused vs reference");

    let mut last_traffic = unfused.mem.total_traffic();
    for (i, snap) in res.snapshots.iter().enumerate() {
        let r = run(snap, &wl());
        assert_close(
            &r.outputs["O"],
            &want,
            2e-4,
            &format!("snapshot {i} vs reference"),
        );
        assert!(
            r.mem.total_traffic() < unfused.mem.total_traffic(),
            "snapshot {i} traffic {} not below unfused {}",
            r.mem.total_traffic(),
            unfused.mem.total_traffic()
        );
        last_traffic = r.mem.total_traffic();
    }
    // the fused kernel launches exactly one kernel
    let fused = run(res.snapshots.last().unwrap(), &wl());
    assert_eq!(fused.mem.kernel_launches, 1);
    assert_eq!(fused.mem.total_traffic(), last_traffic);
    eprintln!(
        "FA traffic: unfused={}B fused={}B ({}x reduction), launches {} -> 1",
        unfused.mem.total_traffic(),
        last_traffic,
        unfused.mem.total_traffic() as f64 / last_traffic as f64,
        unfused.mem.kernel_launches,
    );
}

// ---------------------------------------------------------------------------
// Example 2: LayerNorm + Matmul
// ---------------------------------------------------------------------------

#[test]
fn layernorm_matmul_trace_matches_paper() {
    let g = lower_array(&programs::layernorm_matmul());
    let res = fuse(g);
    let t = &res.trace;
    eprintln!("LN+MM trace ({} steps): {}\n{t}", t.len(), t.summary());

    // The paper's Example 2 takes exactly 22 steps:
    // 7×(R1/R2), R4, R5, 2×R3, 6×(R1/R2), 2×R3, R2, R6, R2.
    assert_eq!(t.len(), 22, "total steps; trace:\n{t}");
    assert_eq!(t.count(RuleId::R1) + t.count(RuleId::R2), 15);
    assert_eq!(t.count(RuleId::R3), 4);
    assert_eq!(t.count(RuleId::R4), 1);
    assert_eq!(t.count(RuleId::R5), 1);
    assert_eq!(t.count(RuleId::R6), 1);
    assert_eq!(t.count(RuleId::R8), 0);
    assert_eq!(t.count(RuleId::R9), 0);

    assert_eq!(res.snapshots.len(), 2);
    let fused = res.snapshots.last().unwrap();
    assert_valid(fused);
    assert_eq!(fused.interior_buffered_count_recursive(), 0);
}

#[test]
fn layernorm_matmul_numerics_and_traffic() {
    let g0 = lower_array(&programs::layernorm_matmul());
    let res = fuse(g0.clone());

    let mut rng = Rng::new(7);
    let (rows, k, n) = (8usize, 24usize, 10usize);
    let x = rng.mat(rows, k);
    let yt = rng.mat(n, k);
    let want = reference::layernorm_matmul_ref(&x, &yt);

    let wl = || {
        Workload::new(DimSizes::of(&[("M", 2), ("K", 3), ("N", 2)]))
            .input("X", x.clone())
            .input("YT", yt.clone())
            .param("KK", k as f32)
    };
    let unfused = run(&g0, &wl());
    assert_close(&unfused.outputs["Z"], &want, 5e-4, "unfused vs reference");

    for (i, snap) in res.snapshots.iter().enumerate() {
        let r = run(snap, &wl());
        assert_close(
            &r.outputs["Z"],
            &want,
            5e-4,
            &format!("snapshot {i} vs reference"),
        );
        assert!(r.mem.total_traffic() < unfused.mem.total_traffic());
    }
    let fused = run(res.snapshots.last().unwrap(), &wl());
    assert_eq!(fused.mem.kernel_launches, 1);
}

// ---------------------------------------------------------------------------
// Example 3: RMSNorm + FFN-SwiGLU
// ---------------------------------------------------------------------------

#[test]
fn rmsnorm_ffn_swiglu_trace_matches_paper() {
    let g = lower_array(&programs::rmsnorm_ffn_swiglu());
    let res = fuse(g);
    let t = &res.trace;
    eprintln!("RMS+FFN trace ({} steps): {}\n{t}", t.len(), t.summary());

    // The paper's Example 3 takes exactly 26 steps:
    // 8×(R1/R2), R8, 2×R4, R3, 6×(R1/R2), 2×R3, R2, R3, R6, R1, R6, R2.
    assert_eq!(t.len(), 26, "total steps; trace:\n{t}");
    assert_eq!(t.count(RuleId::R1) + t.count(RuleId::R2), 17);
    assert_eq!(t.count(RuleId::R3), 4);
    assert_eq!(t.count(RuleId::R4), 2);
    assert_eq!(t.count(RuleId::R5), 0);
    assert_eq!(t.count(RuleId::R6), 2);
    assert_eq!(t.count(RuleId::R8), 1);
    assert_eq!(t.count(RuleId::R9), 0);

    // Three snapshots: quiescent, after 1st extension, after 2nd extension.
    assert_eq!(res.snapshots.len(), 3);
    let fused = res.snapshots.last().unwrap();
    assert_valid(fused);
    assert_eq!(fused.interior_buffered_count_recursive(), 0);
}

#[test]
fn rmsnorm_ffn_swiglu_numerics_and_traffic() {
    let g0 = lower_array(&programs::rmsnorm_ffn_swiglu());
    let res = fuse(g0.clone());

    let mut rng = Rng::new(9);
    let (rows, d, k, n) = (4usize, 16usize, 12usize, 8usize);
    let x = rng.mat(rows, d);
    let wt = rng.mat(k, d);
    let vt = rng.mat(k, d);
    let ut = rng.mat(n, k);
    let want = reference::rmsnorm_ffn_swiglu_ref(&x, &wt, &vt, &ut);

    let wl = || {
        Workload::new(DimSizes::of(&[("M", 2), ("D", 2), ("K", 3), ("N", 2)]))
            .input("X", x.clone())
            .input("WT", wt.clone())
            .input("VT", vt.clone())
            .input("UT", ut.clone())
            .param("DD", d as f32)
    };
    let unfused = run(&g0, &wl());
    assert_close(&unfused.outputs["O"], &want, 5e-4, "unfused vs reference");

    for (i, snap) in res.snapshots.iter().enumerate() {
        let r = run(snap, &wl());
        assert_close(
            &r.outputs["O"],
            &want,
            5e-4,
            &format!("snapshot {i} vs reference"),
        );
    }
    // Traffic: snapshot 0 (no replication) strictly below unfused; the fully
    // extended mega-kernel trades replicated *loads* for zero intermediate
    // stores — the paper's epilogue discusses exactly this tradeoff, to be
    // settled by the autotuner's choice of N and K.
    let snap0 = run(&res.snapshots[0], &wl());
    assert!(snap0.mem.total_traffic() < unfused.mem.total_traffic());
    let fused = run(res.snapshots.last().unwrap(), &wl());
    assert_eq!(fused.mem.kernel_launches, 1);
    assert_eq!(fused.mem.stored_bytes, fused.outputs["O"].bytes() as u64);
}
