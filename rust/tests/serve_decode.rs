//! KV-cache decode differential spine.
//!
//! The decode workload family's correctness contract, end to end:
//!
//! * **Decode == prefill, bitwise.** `T` decode steps through a serving
//!   session — cache grown one block per step, each step a stacked
//!   launch of the *pinned* plan re-bound at the current cache length —
//!   produce outputs bit-identical to ONE length-`T` prefill launch of
//!   the same plan under a block-causal mask: row block `t-1` of the
//!   prefill output is exactly step `t`'s output. This works because
//!   the unsafe (rowmax-free) softmax makes masked `-inf` tail blocks
//!   exact bitwise no-ops: `exp(-inf) == 0.0` and the tail blocks come
//!   *after* the live prefix in reduction order, so every partial sum
//!   sees `s + 0.0 == s` bit-for-bit.
//! * **Per-step MemSim == stateless reference + append breakout.** Each
//!   step's counters equal a stateless one-shot at `(M=1, N=t)` on the
//!   read side, and exceed it on the write side by exactly the step's
//!   own KV append (itemized as `state_appended_bytes`/`state_appends`)
//!   — MemSim charges the *incremental* traffic of a stateful buffer,
//!   never a full-cache rewrite.
//! * **All three backends agree bitwise** (interp / compiled /
//!   specialized), outputs and counters, across SIMD on/off and worker
//!   caps 1/2/8.
//! * **The session cache IS the append stream**: the grown `KT`/`VT`
//!   caches equal the concatenation of the per-step slabs.
//! * **Fusion**: `decode_attention` fuses to a single flash-decode
//!   kernel (zero interior buffered edges, one launch) with strictly
//!   less traffic than the unfused program on every snapshot.

use blockbuster::array::programs;
use blockbuster::coordinator::{
    bind_stacked_sized, compile, execute_plan_opts, execute_prepared_stacked_spec,
    plan_stack_info, workloads, StackSpec,
};
use blockbuster::exec::{reference, run, ExecBackend, Workload};
use blockbuster::fusion::fuse;
use blockbuster::ir::dim::Dim;
use blockbuster::ir::validate::assert_valid;
use blockbuster::loopir::interp::MemSim;
use blockbuster::lower::lower_array;
use blockbuster::serve::{ModelServer, ServerConfig};
use blockbuster::tensor::{simd, Mat};
use std::collections::HashMap;
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Duration;

const SEED: u64 = 0xD5EED;

/// Serialize tests that flip the global SIMD switch (same idiom as
/// `tests/serve_parity.rs`).
fn toggle_lock() -> MutexGuard<'static, ()> {
    static M: OnceLock<Mutex<()>> = OnceLock::new();
    M.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

fn assert_bits_eq(a: &Mat, b: &Mat, what: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{what}: shape");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert!(
            x.to_bits() == y.to_bits(),
            "{what}: bit divergence at flat index {i}: {x} vs {y}"
        );
    }
}

/// Stack step matrices top-to-bottom (the `Q` / `KT` growth axis).
fn vstack(mats: &[Mat]) -> Mat {
    let cols = mats[0].cols;
    let mut data = Vec::new();
    let mut rows = 0usize;
    for m in mats {
        assert_eq!(m.cols, cols, "vstack: ragged widths");
        data.extend_from_slice(&m.data);
        rows += m.rows;
    }
    Mat { rows, cols, data }
}

/// Stack step matrices left-to-right (the `VT` growth axis).
fn hstack(mats: &[Mat]) -> Mat {
    let rows = mats[0].rows;
    let cols: usize = mats.iter().map(|m| m.cols).sum();
    let mut data = Vec::with_capacity(rows * cols);
    for r in 0..rows {
        for m in mats {
            assert_eq!(m.rows, rows, "hstack: ragged heights");
            data.extend_from_slice(&m.data[r * m.cols..(r + 1) * m.cols]);
        }
    }
    Mat { rows, cols, data }
}

/// Block-causal prefill mask over `tb` 8-row query blocks and `tb`
/// 8-col cache blocks: block `(i, j)` is live (0.0) iff `j <= i`, else
/// `-inf` — row block `t-1` attends exactly the length-`t` cache prefix
/// a decode step at cache length `t` sees.
fn block_causal(tb: usize) -> Mat {
    let n = 8 * tb;
    Mat::from_fn(n, n, |i, j| if j / 8 <= i / 8 { 0.0 } else { f32::NEG_INFINITY })
}

struct SessionRun {
    /// Step `t`'s served output (index `t-1`), an 8-row query block.
    step_outputs: Vec<Mat>,
    /// Step `t`'s served counters, append breakout included.
    step_mems: Vec<MemSim>,
    /// The length-`T` prefill launch's output (8T rows).
    prefill_rows: Mat,
    /// The session's grown caches after the final step.
    kt_cache: Mat,
    vt_cache: Mat,
    /// The per-step append slabs (the fixed synthetic state stream).
    kt_slabs: Vec<Mat>,
    vt_slabs: Vec<Mat>,
}

/// Drive one session to a full cache on `backend`, checking every step
/// against its stateless `(M=1, N=t)` reference as it serves; then run
/// the length-`T` prefill launch on the same pinned plan.
fn run_decode_session(backend: ExecBackend) -> SessionRun {
    run_decode_session_with(backend, 1)
}

fn run_decode_session_with(backend: ExecBackend, threads: usize) -> SessionRun {
    let mut server = ModelServer::new(ServerConfig {
        backend,
        threads: Some(threads),
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        coalesce: true,
        ..ServerConfig::default()
    });
    server.register("decode_attention").unwrap();
    let (p, ccfg, params, _) = workloads::by_name("decode_attention", 0).unwrap();
    let compiled = compile(&p, ccfg.clone());
    let sid = server.open_session("decode_attention").unwrap();

    let mut step_outputs = Vec::new();
    let mut step_mems = Vec::new();
    let mut q_steps = Vec::new();
    let mut kt_slabs = Vec::new();
    let mut vt_slabs = Vec::new();
    let mut t = 0usize;
    while server.submit_synthetic_decode(sid, SEED).is_ok() {
        t += 1;
        let mut resp = server.drain();
        assert_eq!(resp.len(), 1, "one response per decode step");
        let r = resp.pop().unwrap();
        assert!(r.is_ok(), "step {t} must serve: {:?}", r.verdict);

        // Regenerate this step's inputs (the generator is pure) and
        // snapshot the grown cache — together they form the stateless
        // reference at the current length.
        let gen = server.synthetic_decode_inputs("decode_attention", SEED, t).unwrap();
        let kt = server.session_cache(sid, "KT").unwrap().clone();
        let vt = server.session_cache(sid, "VT").unwrap().clone();
        assert_eq!((kt.rows, kt.cols), (8 * t, 16), "KT grows one row block per step");
        assert_eq!((vt.rows, vt.cols), (16, 8 * t), "VT grows one col block per step");
        let mut ref_inputs: HashMap<String, Mat> = HashMap::new();
        ref_inputs.insert("Q".into(), gen["Q"].clone());
        ref_inputs.insert("MASK".into(), gen["MASK"].clone());
        ref_inputs.insert("KT".into(), kt);
        ref_inputs.insert("VT".into(), vt);
        let mut sizes = ccfg.sizes.clone();
        sizes.set("N", t);
        let seq = execute_plan_opts(
            &compiled.plan,
            &sizes,
            &params,
            &ref_inputs,
            backend,
            Some(threads),
        );

        assert_bits_eq(
            &seq.outputs["O"],
            &r.outputs["O"],
            &format!("step {t} output vs its stateless length-{t} reference"),
        );
        assert_eq!(
            (seq.mem.loaded_bytes, seq.mem.n_loads, seq.mem.kernel_launches, seq.mem.flops),
            (r.mem.loaded_bytes, r.mem.n_loads, r.mem.kernel_launches, r.mem.flops),
            "step {t}: read-side counters vs the stateless reference"
        );
        assert!(r.mem.state_appended_bytes > 0, "every decode step appends KV state");
        assert_eq!(
            (r.mem.stored_bytes, r.mem.n_stores),
            (
                seq.mem.stored_bytes + r.mem.state_appended_bytes,
                seq.mem.n_stores + r.mem.state_appends
            ),
            "step {t}: stores must be the stateless reference plus the step's own append"
        );

        q_steps.push(gen["Q"].clone());
        kt_slabs.push(gen["KT"].clone());
        vt_slabs.push(gen["VT"].clone());
        step_outputs.push(r.outputs["O"].clone());
        step_mems.push(r.mem);
    }
    assert!(t >= 2, "context cap must allow a multi-step differential (got {t})");
    assert_eq!(server.session_len(sid), Some(t));

    // One length-T prefill launch on the SAME pinned plan: the stack
    // dim carries all T query blocks, the growth dim is overridden to
    // the full cache length, and the caches ride as ordinary inputs.
    let prepared = server.live_plan("decode_attention").unwrap();
    let info = plan_stack_info(&prepared).unwrap();
    assert_eq!(info.trip, 1, "decode registers one query block per step");
    let stacked = bind_stacked_sized(&prepared, &info, t, &[(Dim::from("N"), t)]);
    let spec = StackSpec {
        trips: vec![t],
        pads: vec![0],
    };
    let kt_cache = server.session_cache(sid, "KT").unwrap().clone();
    let vt_cache = server.session_cache(sid, "VT").unwrap().clone();
    let mut prefill: HashMap<String, Mat> = HashMap::new();
    prefill.insert("Q".into(), vstack(&q_steps));
    prefill.insert("KT".into(), kt_cache.clone());
    prefill.insert("VT".into(), vt_cache.clone());
    prefill.insert("MASK".into(), block_causal(t));
    let batch =
        execute_prepared_stacked_spec(&prepared, &stacked, &spec, &[&prefill], Some(threads));
    let prefill_rows = batch.runs[0].outputs["O"].clone();
    assert_eq!(prefill_rows.rows, 8 * t, "prefill emits every query block");

    SessionRun {
        step_outputs,
        step_mems,
        prefill_rows,
        kt_cache,
        vt_cache,
        kt_slabs,
        vt_slabs,
    }
}

/// Row block `t-1` of the prefill output must be bit-identical to
/// decode step `t`'s output.
fn check_prefill(run: &SessionRun) {
    let t = run.step_outputs.len();
    assert_eq!(run.prefill_rows.rows, 8 * t);
    for (i, step_o) in run.step_outputs.iter().enumerate() {
        let rows = run.prefill_rows.slice(8 * i, 0, 8, run.prefill_rows.cols);
        assert_bits_eq(
            &rows,
            step_o,
            &format!("prefill row block {i} vs decode step {}", i + 1),
        );
    }
}

/// Two session runs (different backend / SIMD mode / worker cap) must
/// agree bitwise on every step output and counter, and on the prefill.
/// `exact_transfers` additionally pins `n_loads`/`n_stores` — a
/// threads==1 contract (see `backend_parity`), so matrix cells at
/// other worker caps compare the thread-invariant counters only.
fn assert_sessions_match(a: &SessionRun, b: &SessionRun, exact_transfers: bool, tag: &str) {
    assert_eq!(a.step_outputs.len(), b.step_outputs.len(), "{tag}: step count");
    for (i, (x, y)) in a.step_outputs.iter().zip(&b.step_outputs).enumerate() {
        assert_bits_eq(x, y, &format!("{tag}: step {}", i + 1));
    }
    for (i, (x, y)) in a.step_mems.iter().zip(&b.step_mems).enumerate() {
        assert_eq!(
            (x.loaded_bytes, x.stored_bytes, x.flops),
            (y.loaded_bytes, y.stored_bytes, y.flops),
            "{tag}: step {} traffic",
            i + 1
        );
        if exact_transfers {
            assert_eq!(
                (x.n_loads, x.n_stores),
                (y.n_loads, y.n_stores),
                "{tag}: step {} transfer counts",
                i + 1
            );
        }
        assert_eq!(
            (x.kernel_launches, x.state_appended_bytes, x.state_appends),
            (y.kernel_launches, y.state_appended_bytes, y.state_appends),
            "{tag}: step {} launches/appends",
            i + 1
        );
    }
    assert_bits_eq(&a.prefill_rows, &b.prefill_rows, &format!("{tag}: prefill"));
}

#[test]
fn decode_steps_match_prefill_bitwise_interp() {
    let _g = toggle_lock();
    simd::set_enabled(true);
    check_prefill(&run_decode_session(ExecBackend::Interp));
}

#[test]
fn decode_steps_match_prefill_bitwise_compiled() {
    let _g = toggle_lock();
    simd::set_enabled(true);
    check_prefill(&run_decode_session(ExecBackend::Compiled));
}

#[test]
fn decode_steps_match_prefill_bitwise_specialized() {
    let _g = toggle_lock();
    simd::set_enabled(true);
    check_prefill(&run_decode_session(ExecBackend::Specialized));
}

/// All three backends agree bitwise on every decode step — outputs AND
/// counters, append breakout included.
#[test]
fn decode_outputs_bitwise_equal_across_backends() {
    let _g = toggle_lock();
    simd::set_enabled(true);
    let a = run_decode_session(ExecBackend::Interp);
    for backend in [ExecBackend::Compiled, ExecBackend::Specialized] {
        let b = run_decode_session(backend);
        assert_sessions_match(&a, &b, true, &format!("interp vs {}", backend.name()));
    }
}

/// The full decode sweep: 3 backends × SIMD on/off × worker caps 1/2/8,
/// every cell bit-identical (outputs and counters) to the SIMD-on
/// single-worker interpreter session.
#[test]
fn decode_sweep_backend_matrix_simd_threads() {
    let _g = toggle_lock();
    simd::set_enabled(true);
    let want = run_decode_session_with(ExecBackend::Interp, 1);
    for simd_on in [true, false] {
        simd::set_enabled(simd_on);
        for backend in [
            ExecBackend::Interp,
            ExecBackend::Compiled,
            ExecBackend::Specialized,
        ] {
            for threads in [1usize, 2, 8] {
                let got = run_decode_session_with(backend, threads);
                let tag = format!("backend={} simd={simd_on} threads={threads}", backend.name());
                assert_sessions_match(&want, &got, threads == 1, &tag);
            }
        }
    }
    simd::set_enabled(true);
}

/// The session's grown caches are exactly the concatenation of the
/// per-step append slabs — nothing rewritten, nothing reordered.
#[test]
fn session_cache_is_the_concatenated_state_stream() {
    let _g = toggle_lock();
    simd::set_enabled(true);
    let run = run_decode_session(ExecBackend::Compiled);
    assert_bits_eq(&run.kt_cache, &vstack(&run.kt_slabs), "KT cache vs appended slabs");
    assert_bits_eq(&run.vt_cache, &hstack(&run.vt_slabs), "VT cache vs appended slabs");
}

/// Fusion snapshot: `decode_attention` fully fuses into one
/// flash-decode kernel, every snapshot stays numerically faithful to
/// the tensor-level attention reference (the demo mask is zero, so
/// masked attention == attention), and fused traffic is strictly below
/// the unfused program's.
#[test]
fn decode_attention_fuses_to_one_flash_decode_kernel() {
    let _g = toggle_lock();
    simd::set_enabled(true);
    let g0 = lower_array(&programs::decode_attention());
    let res = fuse(g0.clone());
    let fused_graph = res.snapshots.last().unwrap();
    assert_valid(fused_graph);
    assert_eq!(
        fused_graph.interior_buffered_count_recursive(),
        0,
        "flash-decode must fuse completely"
    );

    let (_, ccfg, params, inputs) = workloads::by_name("decode_attention", 7).unwrap();
    let want = reference::attention_ref(&inputs["Q"], &inputs["KT"], &inputs["VT"], 16.0);
    let wl = || {
        let mut w = Workload::new(ccfg.sizes.clone());
        for (k, v) in &inputs {
            w = w.input(k, v.clone());
        }
        for (k, v) in &params {
            w = w.param(k, *v);
        }
        w
    };
    let unfused = run(&g0, &wl());
    let d = unfused.outputs["O"].max_abs_diff(&want);
    assert!(d < 2e-4, "unfused vs reference: {d}");
    for (i, snap) in res.snapshots.iter().enumerate() {
        let r = run(snap, &wl());
        let d = r.outputs["O"].max_abs_diff(&want);
        assert!(d < 2e-4, "snapshot {i} vs reference: {d}");
        assert!(
            r.mem.total_traffic() < unfused.mem.total_traffic(),
            "snapshot {i} traffic {} not below unfused {}",
            r.mem.total_traffic(),
            unfused.mem.total_traffic()
        );
    }
    let fused = run(res.snapshots.last().unwrap(), &wl());
    assert_eq!(fused.mem.kernel_launches, 1, "one fused flash-decode launch");
    eprintln!(
        "decode traffic: unfused={}B fused={}B ({:.2}x reduction), launches {} -> 1",
        unfused.mem.total_traffic(),
        fused.mem.total_traffic(),
        unfused.mem.total_traffic() as f64 / fused.mem.total_traffic() as f64,
        unfused.mem.kernel_launches,
    );
}
