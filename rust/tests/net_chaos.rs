//! Chaos suite for the TCP ingress (`serve::net`): a real loopback
//! socket abused every way the ISSUE's acceptance bar demands.
//!
//! Invariants pinned here, per scenario:
//!
//! * Malformed bytes (unknown kinds, oversized length prefixes,
//!   checksum corruption, torn frames, wrong protocol version) get a
//!   *typed* error frame and a close — never a panic, never a hang —
//!   and the server keeps serving well-behaved clients afterwards.
//! * Slow clients are bounded: a trickled frame dies at
//!   `frame_timeout`, a silent connection at `idle_timeout`.
//! * Surviving responses are **bit-identical** over the wire to an
//!   independent sequential execution of the same inputs.
//! * The edge ledger reconciles exactly: every admitted request
//!   resolves as delivered or disconnected, overflow beyond the
//!   in-flight cap gets typed rejects, and the daemon's own
//!   `accounted() == submitted` holds underneath it all.
//!
//! The fault injector is process-global (and its rate applies to every
//! site, including the daemon's compute path), so armed sections
//! tolerate `Failed("injected …")` verdicts and every test serializes
//! behind one lock, same as `tests/serve_chaos.rs`.

use blockbuster::coordinator::{compile, execute_plan_opts, workloads, PlanRun};
use blockbuster::exec::ExecBackend;
use blockbuster::serve::daemon::Daemon;
use blockbuster::serve::net::client::{synthetic_request, BackoffConfig, ClientConfig, NetClient};
use blockbuster::serve::net::proto::{self, ErrorCode, Frame, WireResponse};
use blockbuster::serve::net::{NetConfig, NetServer, NetStats};
use blockbuster::serve::{ModelServer, Rejected, ServerConfig, Verdict};
use blockbuster::tensor::Mat;
use blockbuster::util::fault;
use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

/// Serialize every test in this binary: the fault injector is
/// process-global, and socket-timing assertions dislike CPU contention.
fn chaos_lock() -> MutexGuard<'static, ()> {
    static M: OnceLock<Mutex<()>> = OnceLock::new();
    M.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

/// RAII arming: disarms the global injector even if the test unwinds.
struct FaultGuard;

impl FaultGuard {
    fn arm(rate: f64, seed: u64) -> FaultGuard {
        fault::set(rate, seed);
        FaultGuard
    }
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        fault::off();
    }
}

fn env_rate(default: f64) -> f64 {
    std::env::var("BB_FAULT_RATE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn env_iters(default: usize) -> usize {
    std::env::var("BB_CHAOS_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn bits(m: &Mat) -> Vec<u32> {
    m.data.iter().map(|x| x.to_bits()).collect()
}

/// Per-test timeout knobs: only the reaper test uses tight clocks —
/// everywhere else generous timeouts keep a CI scheduling stall from
/// reaping a healthy connection mid-assertion.
fn net_cfg(max_inflight: usize, idle: Duration, frame: Duration) -> NetConfig {
    NetConfig {
        max_inflight,
        idle_timeout: idle,
        frame_timeout: frame,
        write_timeout: Duration::from_millis(500),
        poll: Duration::from_millis(5),
        ..NetConfig::default()
    }
}

/// The lenient variant for tests not exercising the reapers.
fn lenient_cfg(max_inflight: usize) -> NetConfig {
    net_cfg(max_inflight, Duration::from_secs(10), Duration::from_secs(2))
}

fn client_cfg() -> ClientConfig {
    ClientConfig {
        read_timeout: Duration::from_secs(10),
        backoff: BackoffConfig {
            attempts: 3,
            base: Duration::from_millis(2),
            cap: Duration::from_millis(20),
        },
        ..ClientConfig::default()
    }
}

fn start_stack(max_wait: Duration, cfg: NetConfig) -> (Daemon, NetServer) {
    let mut server = ModelServer::new(ServerConfig {
        backend: ExecBackend::Compiled,
        threads: Some(1),
        max_batch: 4,
        max_wait,
        coalesce: false,
        ..ServerConfig::default()
    });
    server.register("quickstart").unwrap();
    let daemon = Daemon::start(server, None);
    let net = NetServer::start("127.0.0.1:0", daemon.client(), cfg).unwrap();
    (daemon, net)
}

/// Graceful drain in the documented order; returns both ledgers.
fn drain(daemon: Daemon, net: NetServer) -> (ModelServer, NetStats) {
    net.begin_shutdown();
    let server = daemon.shutdown();
    let stats = net.shutdown();
    (server, stats)
}

/// A raw (non-`NetClient`) socket with bounded reads, for speaking
/// deliberately broken protocol.
fn raw(addr: SocketAddr) -> TcpStream {
    let s = TcpStream::connect(addr).unwrap();
    s.set_nodelay(true).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s
}

fn handshake_raw(addr: SocketAddr) -> TcpStream {
    let mut s = raw(addr);
    s.write_all(&proto::encode_preamble()).unwrap();
    let mut echo = [0u8; proto::PREAMBLE_LEN];
    s.read_exact(&mut echo).unwrap();
    assert!(proto::check_preamble(&echo).is_ok());
    s
}

fn read_frame_raw(s: &mut TcpStream) -> Frame {
    let mut hdr = [0u8; proto::HEADER_LEN];
    s.read_exact(&mut hdr).unwrap();
    let header = proto::decode_header(&hdr, proto::DEFAULT_MAX_FRAME).unwrap();
    let mut payload = vec![0u8; header.payload_len as usize];
    s.read_exact(&mut payload).unwrap();
    proto::decode_frame(&header, &payload).unwrap()
}

fn expect_error(s: &mut TcpStream) -> ErrorCode {
    match read_frame_raw(s) {
        Frame::Error { code, .. } => code,
        other => panic!("expected an Error frame, got {other:?}"),
    }
}

/// Independent ground truth: one-shot compile + sequential execution of
/// the exact inputs `synthetic_request` sends for each seed.
fn ground_truth(seeds: &[u64]) -> Vec<PlanRun> {
    let (p, cfg, params, _) = workloads::by_name("quickstart", 0).unwrap();
    let compiled = compile(&p, cfg.clone());
    seeds
        .iter()
        .map(|&seed| {
            let (_, _, _, inputs) = workloads::by_name("quickstart", seed).unwrap();
            execute_plan_opts(
                &compiled.plan,
                &cfg.sizes,
                &params,
                &inputs,
                ExecBackend::Compiled,
                Some(1),
            )
        })
        .collect()
}

/// Bit-identity of a wire response against sequential ground truth
/// (same field set as `tests/serve_chaos.rs`; `peak_local_bytes` is the
/// one counter the engine does not pin across fan-outs).
fn assert_wire_matches(i: u64, r: &WireResponse, seq: &PlanRun) {
    assert_eq!(r.outputs.len(), seq.outputs.len(), "request {i}: output set size");
    for (name, m) in &r.outputs {
        assert_eq!(
            bits(m),
            bits(&seq.outputs[name]),
            "request {i}: output {name} not bit-identical over the wire"
        );
    }
    assert_eq!(r.mem.loaded_bytes, seq.mem.loaded_bytes, "request {i}: loads");
    assert_eq!(r.mem.stored_bytes, seq.mem.stored_bytes, "request {i}: stores");
    assert_eq!(r.mem.n_loads, seq.mem.n_loads, "request {i}: n_loads");
    assert_eq!(r.mem.n_stores, seq.mem.n_stores, "request {i}: n_stores");
    assert_eq!(r.mem.kernel_launches, seq.mem.kernel_launches, "request {i}: launches");
    assert_eq!(r.mem.flops, seq.mem.flops, "request {i}: flops");
}

/// Every class of malformed bytes gets its typed error code and a
/// close, the counters attribute each one correctly, and a well-behaved
/// client is served immediately afterwards.
#[test]
fn malformed_frames_get_typed_errors_and_the_server_survives() {
    let _l = chaos_lock();
    let (daemon, net) = start_stack(Duration::from_millis(1), lenient_cfg(64));
    let addr = net.local_addr();

    // Wrong protocol version: rejected at the handshake, typed.
    let mut s = raw(addr);
    let mut pre = proto::encode_preamble();
    pre[4] = 0xff;
    s.write_all(&pre).unwrap();
    assert_eq!(expect_error(&mut s), ErrorCode::BadVersion);
    drop(s);

    // Unknown frame kind.
    let mut s = handshake_raw(addr);
    s.write_all(&[99u8, 0, 0, 0, 0, 0, 0, 0, 0, 0]).unwrap();
    assert_eq!(expect_error(&mut s), ErrorCode::Malformed);
    drop(s);

    // Adversarial length prefix: refused from the header alone.
    let mut s = handshake_raw(addr);
    let mut hdr = [0u8; proto::HEADER_LEN];
    hdr[0] = 1;
    hdr[2..6].copy_from_slice(&u32::MAX.to_le_bytes());
    s.write_all(&hdr).unwrap();
    assert_eq!(expect_error(&mut s), ErrorCode::Oversized);
    drop(s);

    // Corrupted checksum field on an otherwise valid frame.
    let mut s = handshake_raw(addr);
    let mut bytes = proto::encode_frame(&Frame::Health);
    bytes[6] ^= 0xff;
    s.write_all(&bytes).unwrap();
    assert_eq!(expect_error(&mut s), ErrorCode::BadChecksum);
    drop(s);

    // Torn frame: a valid request minus its last byte, then FIN.
    let mut s = handshake_raw(addr);
    let req = synthetic_request("quickstart", 0, 0).unwrap();
    let bytes = proto::encode_frame(&Frame::Request(req));
    s.write_all(&bytes[..bytes.len() - 1]).unwrap();
    s.shutdown(Shutdown::Write).unwrap();
    assert_eq!(expect_error(&mut s), ErrorCode::Malformed);
    drop(s);

    // The server took five kinds of abuse; a real client is unfazed.
    let mut cli = NetClient::connect(&addr.to_string(), client_cfg()).unwrap();
    let resp = cli.call_synthetic("quickstart", 7, 7).unwrap();
    assert_eq!(resp.verdict, Verdict::Ok);
    drop(cli);

    let (_server, stats) = drain(daemon, net);
    assert_eq!(stats.handshake_failures, 1, "{stats:?}");
    assert_eq!(stats.malformed, 3, "{stats:?}");
    assert_eq!(stats.oversized, 1, "{stats:?}");
    assert_eq!(stats.requests_in, 1);
    assert_eq!(stats.delivered, 1);
    assert!(stats.reconciles(), "{stats:?}");
}

/// Slow-client defense: a trickled frame is closed at `frame_timeout`,
/// a fully silent connection at `idle_timeout` — both with typed error
/// frames, both without collateral damage to a healthy client.
#[test]
fn slowloris_and_idle_connections_are_reaped() {
    let _l = chaos_lock();
    let cfg = net_cfg(64, Duration::from_millis(300), Duration::from_millis(150));
    let (daemon, net) = start_stack(Duration::from_millis(1), cfg);
    let addr = net.local_addr();

    // Slowloris: start a frame, send three header bytes, stall.
    let mut trickler = handshake_raw(addr);
    trickler.write_all(&[1u8, 0, 0]).unwrap();
    let t0 = Instant::now();
    assert_eq!(expect_error(&mut trickler), ErrorCode::FrameTimeout);
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "frame timeout must fire promptly, waited {:?}",
        t0.elapsed()
    );
    drop(trickler);

    // Fully quiet connection: reaped by the idle clock.
    let mut silent = handshake_raw(addr);
    let t0 = Instant::now();
    assert_eq!(expect_error(&mut silent), ErrorCode::IdleTimeout);
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "idle reaper must fire promptly, waited {:?}",
        t0.elapsed()
    );
    drop(silent);

    let mut cli = NetClient::connect(&addr.to_string(), client_cfg()).unwrap();
    assert_eq!(cli.call_synthetic("quickstart", 0, 3).unwrap().verdict, Verdict::Ok);
    drop(cli);

    let (_server, stats) = drain(daemon, net);
    assert_eq!(stats.frame_timeouts, 1, "{stats:?}");
    assert_eq!(stats.idle_closed, 1, "{stats:?}");
    assert!(stats.reconciles(), "{stats:?}");
}

/// Pipelining: many requests in flight on one connection come back in
/// submission order, every payload bit-identical to an independent
/// sequential execution of the same inputs.
#[test]
fn pipelined_responses_are_bit_identical_to_sequential() {
    let _l = chaos_lock();
    let (daemon, net) = start_stack(Duration::from_millis(1), lenient_cfg(64));
    let n = 8u64;
    let seeds: Vec<u64> = (0..n).map(|i| 1_000 + i).collect();
    let expected = ground_truth(&seeds);

    let mut cli = NetClient::connect(&net.local_addr().to_string(), client_cfg()).unwrap();
    for (i, &seed) in seeds.iter().enumerate() {
        let req = synthetic_request("quickstart", i as u64, seed).unwrap();
        cli.send(&req).unwrap();
    }
    for i in 0..n {
        match cli.recv().unwrap() {
            Frame::Response(r) => {
                assert_eq!(r.corr, i, "pipelined responses must arrive in submission order");
                assert_eq!(r.verdict, Verdict::Ok);
                assert_wire_matches(i, &r, &expected[i as usize]);
            }
            other => panic!("request {i}: unexpected frame {other:?}"),
        }
    }
    drop(cli);

    let (server, stats) = drain(daemon, net);
    assert_eq!(stats.requests_in, n);
    assert_eq!(stats.delivered, n);
    assert!(stats.reconciles(), "{stats:?}");
    let st = &server.stats().per_program["quickstart"];
    assert_eq!(st.submitted, n);
    assert_eq!(st.accounted(), st.submitted);
}

/// A storm past the in-flight cap: overflow gets immediate typed
/// `Reject(QueueFull)` frames at the edge (never touching the daemon),
/// admitted work survives the drain, and a post-drain connect is
/// refused.
#[test]
fn inflight_cap_rejects_overflow_and_drain_serves_the_rest() {
    let _l = chaos_lock();
    // max_wait far in the future: admitted requests park in the queue,
    // holding the in-flight gauge up until the drain flushes them.
    let (daemon, net) = start_stack(Duration::from_secs(3600), lenient_cfg(2));
    let addr = net.local_addr().to_string();

    let mut cli = NetClient::connect(&addr, client_cfg()).unwrap();
    for i in 0..5u64 {
        let req = synthetic_request("quickstart", i, 3_000 + i).unwrap();
        cli.send(&req).unwrap();
    }
    // Wait for the reader to classify the whole burst before draining.
    let t0 = Instant::now();
    loop {
        let s = net.stats();
        if s.requests_in + s.rejected_inflight >= 5 {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "ingress never admitted the burst: {s:?}");
        std::thread::sleep(Duration::from_millis(5));
    }

    net.begin_shutdown();
    let server = daemon.shutdown();
    // Responses resolve FIFO: the two admitted requests (served by the
    // graceful drain), then the three edge rejections, then Shutdown.
    for i in 0..2u64 {
        match cli.recv().unwrap() {
            Frame::Response(r) => {
                assert_eq!(r.corr, i);
                assert_eq!(r.verdict, Verdict::Ok, "drain must serve admitted work");
            }
            other => panic!("request {i}: unexpected frame {other:?}"),
        }
    }
    for i in 2..5u64 {
        match cli.recv().unwrap() {
            Frame::Reject { corr, reason } => {
                assert_eq!(corr, i);
                assert_eq!(reason, Rejected::QueueFull);
            }
            other => panic!("request {i}: unexpected frame {other:?}"),
        }
    }
    assert_eq!(cli.recv().unwrap(), Frame::Shutdown);
    drop(cli);

    let stats = net.shutdown();
    assert_eq!(stats.requests_in, 2, "{stats:?}");
    assert_eq!(stats.delivered, 2, "{stats:?}");
    assert_eq!(stats.rejected_inflight, 3, "{stats:?}");
    assert!(stats.reconciles(), "{stats:?}");
    let st = &server.stats().per_program["quickstart"];
    assert_eq!(st.submitted, 2, "edge rejections must never reach the daemon");
    assert_eq!(st.accounted(), st.submitted);

    // The ingress is gone: a fresh connect exhausts its backoff.
    assert!(
        NetClient::connect(&addr, client_cfg()).is_err(),
        "connect must fail after the ingress shut down"
    );
}

/// The acceptance scenario: a client stream under injected torn writes,
/// stalled reads, and mid-request disconnects (plus the injector's
/// usual compute panics server-side). No panic, no hang, surviving
/// responses bit-identical, and both ledgers — edge and daemon —
/// reconcile exactly.
#[test]
fn injected_network_faults_reconcile_exactly() {
    let _l = chaos_lock();
    let n = env_iters(36);
    let rate = env_rate(0.2);
    let (daemon, net) = start_stack(Duration::from_millis(1), lenient_cfg(64));
    let addr = net.local_addr().to_string();
    let seeds: Vec<u64> = (0..n as u64).map(|i| 2_000 + i).collect();
    let expected = ground_truth(&seeds);

    let mut cli = NetClient::connect(&addr, client_cfg()).unwrap();
    let guard = FaultGuard::arm(rate, 0x4e7f);
    let mut admitted = 0u64;
    let mut oks = 0u64;
    let mut torn = 0u64;
    let mut aborted = 0u64;
    for i in 0..n as u64 {
        let req = synthetic_request("quickstart", i, seeds[i as usize]).unwrap();
        match cli.send(&req) {
            Ok(()) => admitted += 1,
            Err(e) if e.kind() == ErrorKind::BrokenPipe => {
                // Torn write: the frame never arrived whole, so the
                // request was never admitted. Reconnect, move on.
                torn += 1;
                cli.reconnect().expect("reconnect after torn write");
                continue;
            }
            Err(e) if e.kind() == ErrorKind::ConnectionAborted => {
                // Written in full, then vanished: admitted server-side,
                // where it must resolve as a disconnect — not a leak.
                admitted += 1;
                aborted += 1;
                cli.reconnect().expect("reconnect after disconnect");
                continue;
            }
            Err(e) => panic!("request {i}: unexpected send error: {e}"),
        }
        match cli.recv() {
            Ok(Frame::Response(r)) => {
                assert_eq!(r.corr, i);
                match &r.verdict {
                    Verdict::Ok => {
                        oks += 1;
                        assert_wire_matches(i, &r, &expected[i as usize]);
                    }
                    Verdict::Failed(msg) => {
                        assert!(msg.contains("injected"), "request {i}: leaked failure: {msg}");
                    }
                    Verdict::Rejected(rej) => panic!("request {i}: unexpected rejection {rej:?}"),
                }
            }
            Ok(other) => panic!("request {i}: unexpected frame {other:?}"),
            Err(e) => {
                // Response fate unknown (the contract for recv errors):
                // the ledgers absorb it as delivered-or-disconnected.
                cli.reconnect().unwrap_or_else(|r| panic!("request {i}: recv {e}, reconnect {r}"));
            }
        }
    }
    drop(guard);
    drop(cli);

    let (server, stats) = drain(daemon, net);
    assert_eq!(
        stats.requests_in, admitted,
        "edge admissions must match the client's error-kind contract: {stats:?}"
    );
    assert_eq!(stats.malformed, torn, "each torn write is one torn frame: {stats:?}");
    assert!(stats.reconciles(), "{stats:?}");
    let st = &server.stats().per_program["quickstart"];
    assert_eq!(st.submitted, admitted, "every admitted request reached the daemon");
    assert_eq!(st.served + st.failed, st.submitted);
    assert_eq!(st.accounted(), st.submitted, "daemon ledger must reconcile under net faults");
    assert!(oks <= st.served, "client cannot observe more successes than were served");
    if rate >= 0.2 && n >= 30 {
        assert!(
            torn + aborted >= 1,
            "rate {rate} over {n} requests injected no network faults"
        );
        assert!(oks >= 1, "rate {rate} should leave some survivors");
    }
}
