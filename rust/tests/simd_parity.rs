//! SIMD vs scalar kernel parity: the vector paths must be **bit-identical**
//! to the lane-structured scalar fallback — compared via `to_bits`, so NaN
//! payloads count — on odd / non-multiple-of-lane shapes, empty dims, and
//! NaN/±inf inputs (regression-guarding the `0·NaN` class of bug fixed in
//! PR 1 at the SIMD layer).
//!
//! On machines without AVX2 (or builds without the `simd` feature) both
//! runs take the scalar path and the assertions are trivially true — the
//! suite is then exercised for real by the CI x86_64 runners.

use blockbuster::tensor::{simd, Mat, Rng};
use std::sync::{Mutex, MutexGuard, OnceLock};

/// Serialize tests that flip the global SIMD switch (the paths are
/// bit-identical, so concurrent readers are safe — this lock only keeps
/// each test's "scalar run" honestly scalar).
fn toggle_lock() -> MutexGuard<'static, ()> {
    static M: OnceLock<Mutex<()>> = OnceLock::new();
    M.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

fn bits(m: &Mat) -> Vec<u32> {
    m.data.iter().map(|x| x.to_bits()).collect()
}

fn vbits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Run `f` with SIMD disabled, then enabled; the two results must match
/// exactly.
fn assert_modes_agree<T: PartialEq + std::fmt::Debug>(what: &str, f: impl Fn() -> T) {
    let _g = toggle_lock();
    simd::set_enabled(false);
    let scalar = f();
    simd::set_enabled(true);
    let vector = f();
    assert_eq!(scalar, vector, "{what}: scalar and SIMD paths disagree");
}

/// Shapes straddling every lane/tile boundary: 1, lane-1, lane, lane+1,
/// multiple tiles, row tails, long tails.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (2, 3, 7),
    (4, 4, 8),
    (5, 7, 9),
    (8, 8, 16),
    (9, 6, 13),
    (3, 12, 33),
    (16, 1, 8),
    (1, 16, 100),
    (7, 5, 24),
];

#[test]
fn dot_bt_and_matmul_parity_on_awkward_shapes() {
    let mut rng = Rng::new(0xD07);
    for &(m, n, k) in SHAPES {
        let a = rng.mat(m, k);
        let bt = rng.mat(n, k);
        let b = rng.mat(k, n);
        assert_modes_agree(&format!("dot_bt {m}x{n}x{k}"), || bits(&a.dot_bt(&bt)));
        assert_modes_agree(&format!("matmul {m}x{n}x{k}"), || bits(&a.matmul(&b)));
    }
}

#[test]
fn elementwise_and_row_op_parity_on_awkward_shapes() {
    let mut rng = Rng::new(0xE1E);
    for &(m, n, _) in SHAPES {
        let a = rng.mat(m, n);
        let b = rng.mat(m, n);
        let c: Vec<f32> = (0..m).map(|_| rng.f32()).collect();
        assert_modes_agree(&format!("add {m}x{n}"), || bits(&a.add(&b)));
        assert_modes_agree(&format!("hadamard {m}x{n}"), || bits(&a.hadamard(&b)));
        assert_modes_agree(&format!("row_shift {m}x{n}"), || bits(&a.row_shift(&c)));
        assert_modes_agree(&format!("row_scale {m}x{n}"), || bits(&a.row_scale(&c)));
        assert_modes_agree(&format!("row_sum {m}x{n}"), || vbits(&a.row_sum()));
        assert_modes_agree(&format!("row_max {m}x{n}"), || vbits(&a.row_max()));
    }
}

#[test]
fn empty_dims_parity() {
    // 0-row / 0-col operands: kernels must no-op identically (and the
    // reductions of an empty row give 0 / -inf deterministically).
    let e05 = Mat::zeros(0, 5);
    let e50 = Mat::zeros(5, 0);
    assert_modes_agree("dot_bt 0x5 @ (3x5)^T", || {
        let b = Mat::from_fn(3, 5, |i, j| (i + j) as f32);
        let r = e05.dot_bt(&b);
        ((r.rows, r.cols), bits(&r))
    });
    assert_modes_agree("dot_bt 5x0 @ (4x0)^T", || {
        let b = Mat::zeros(4, 0);
        let r = e50.dot_bt(&b);
        ((r.rows, r.cols), bits(&r))
    });
    assert_modes_agree("row_sum/max of 0-col rows", || {
        (vbits(&e50.row_sum()), vbits(&e50.row_max()))
    });
    let s = e50.row_sum();
    let m = e50.row_max();
    assert!(s.iter().all(|&x| x == 0.0));
    assert!(m.iter().all(|&x| x == f32::NEG_INFINITY));
}

/// Scatter NaN / +inf / -inf through otherwise-finite matrices.
fn poisoned(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
    let mut m = rng.mat(rows, cols);
    let specials = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 0.0, -0.0];
    for (i, s) in specials.iter().cycle().take(rows.max(1) * 2).enumerate() {
        let idx = (i * 7 + 3) % (rows * cols).max(1);
        if idx < m.data.len() {
            m.data[idx] = *s;
        }
    }
    m
}

#[test]
fn nan_inf_propagation_parity() {
    let mut rng = Rng::new(0x1F);
    for &(m, n, k) in &[(5usize, 7usize, 9usize), (8, 8, 16), (3, 4, 33)] {
        let a = poisoned(&mut rng, m, k);
        let bt = poisoned(&mut rng, n, k);
        let b = poisoned(&mut rng, k, n);
        let e = poisoned(&mut rng, m, n);
        let f = poisoned(&mut rng, m, n);
        assert_modes_agree(&format!("dot_bt nan/inf {m}x{n}x{k}"), || {
            bits(&a.dot_bt(&bt))
        });
        assert_modes_agree(&format!("matmul nan/inf {m}x{n}x{k}"), || bits(&a.matmul(&b)));
        assert_modes_agree(&format!("add nan/inf {m}x{n}"), || bits(&e.add(&f)));
        assert_modes_agree(&format!("hadamard nan/inf {m}x{n}"), || {
            bits(&e.hadamard(&f))
        });
        assert_modes_agree(&format!("row_sum nan/inf {m}x{n}"), || vbits(&e.row_sum()));
        assert_modes_agree(&format!("row_max nan/inf {m}x{n}"), || vbits(&e.row_max()));
    }
}

/// `0 · NaN` and `0 · inf` must stay NaN through the SIMD matmul exactly
/// as through the scalar one (the PR 1 regression, now at the SIMD layer).
#[test]
fn zero_times_nan_is_preserved_in_both_modes() {
    let _g = toggle_lock();
    let a = Mat::from_vec(1, 2, vec![0.0, 2.0]);
    let b = Mat::from_vec(2, 2, vec![f32::NAN, f32::INFINITY, 3.0, 4.0]);
    for on in [false, true] {
        simd::set_enabled(on);
        let c = a.matmul(&b);
        assert!(c.at(0, 0).is_nan(), "simd={on}: 0*NaN + 2*3 must be NaN");
        assert!(c.at(0, 1).is_nan(), "simd={on}: 0*inf + 2*4 must be NaN");
    }
    simd::set_enabled(true);
}
