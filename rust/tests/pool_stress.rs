//! Persistent-pool stress: repeated `run`/`run_lowered_cached` cycles
//! across worker counts, on programs with top-level grids *and* nested
//! fan-out (a parallel `forall` under a serial `for`), must
//!
//! * terminate (no handoff deadlock, job after job on one process-wide
//!   pool),
//! * keep the pool capped (workers are reused, never re-spawned per
//!   region — `spawned()` stays ≤ the largest worker count ever used and
//!   never exceeds `MAX_WORKERS`),
//! * stay **bit-identical** to the interpreter ground truth — outputs
//!   and the `loaded_bytes`/`stored_bytes`/`kernel_launches`/`flops`
//!   counters — at every thread count, exactly as the scoped-thread
//!   engine was.

use blockbuster::exec::engine::{exec_compiled, MAX_WORKERS, NESTED_FANOUT_MIN_WORK};
use blockbuster::exec::{
    pool, run_lowered_cached, run_lowered_with, ExecBackend, TapeCache, Workload,
};
use blockbuster::ir::dim::{Dim, DimSizes};
use blockbuster::ir::expr::Expr;
use blockbuster::ir::func::FuncOp;
use blockbuster::ir::graph::{map_over, ArgMode, Graph};
use blockbuster::ir::types::{Item, Ty};
use blockbuster::loopir::interp::{exec, BufVal, ExecConfig, ExecResult};
use blockbuster::loopir::lower::lower;
use blockbuster::loopir::{analyze_clears, BufDecl, COp, Index, LoopIr, LoopKind, Stmt};
use blockbuster::tensor::{Rng, Val};

/// for m (serial) { forall n (parallel) { B[m,n] = ew(A[m,n]) } } — the
/// nested fan-out shape: each outer iteration hands the pool a fresh job.
fn nested_fanout_ir() -> LoopIr {
    let (m, n) = (Dim::new("M"), Dim::new("N"));
    let buf = |name: &str, is_input: bool| BufDecl {
        name: name.into(),
        dims: vec![m.clone(), n.clone()],
        item: Item::Block,
        is_input,
        is_output: !is_input,
        state_dim: None,
    };
    let mut ir = LoopIr {
        bufs: vec![buf("A", true), buf("B", false)],
        body: vec![Stmt::Loop {
            kind: LoopKind::For,
            dim: m.clone(),
            skip_first: false,
            clears: vec![],
            body: vec![Stmt::Loop {
                kind: LoopKind::ForAll,
                dim: n.clone(),
                skip_first: false,
                clears: vec![],
                body: vec![
                    Stmt::Load {
                        var: 0,
                        buf: 0,
                        idx: vec![Index::Iter(m.clone()), Index::Iter(n.clone())],
                    },
                    Stmt::Compute {
                        var: 1,
                        op: COp::Func(FuncOp::Ew(Expr::swish(Expr::var(0)))),
                        args: vec![0],
                    },
                    Stmt::Store {
                        var: 1,
                        buf: 1,
                        idx: vec![Index::Iter(m), Index::Iter(n)],
                    },
                ],
            }],
        }],
        n_vars: 2,
        params: vec![],
    };
    analyze_clears(&mut ir);
    ir
}

fn nested_cfg(seed: u64, mm: usize, nn: usize) -> ExecConfig {
    let mut rng = Rng::new(seed);
    let mut bv = BufVal::new(vec![mm, nn]);
    for i in 0..mm {
        for j in 0..nn {
            bv.set(&[i, j], Val::Block(rng.mat(4, 4)));
        }
    }
    let mut cfg = ExecConfig::new(DimSizes::of(&[("M", mm), ("N", nn)]));
    cfg.inputs.insert("A".into(), bv);
    cfg
}

fn assert_mem_eq(want: &ExecResult, got: &ExecResult, what: &str) {
    assert_eq!(want.mem.loaded_bytes, got.mem.loaded_bytes, "{what}: loaded_bytes");
    assert_eq!(want.mem.stored_bytes, got.mem.stored_bytes, "{what}: stored_bytes");
    assert_eq!(want.mem.n_loads, got.mem.n_loads, "{what}: n_loads");
    assert_eq!(want.mem.n_stores, got.mem.n_stores, "{what}: n_stores");
    assert_eq!(want.mem.flops, got.mem.flops, "{what}: flops");
    assert_eq!(
        want.mem.kernel_launches, got.mem.kernel_launches,
        "{what}: kernel_launches"
    );
}

/// Nested fan-out cycled many times over threads 1/2/8: every cycle
/// bit-identical to the interpreter, pool capped throughout.
#[test]
fn nested_fanout_cycles_stay_bit_identical_and_capped() {
    let ir = nested_fanout_ir();
    let (mm, nn) = (3usize, 512usize);
    let cfg = nested_cfg(31, mm, nn);
    let want = exec(&ir, &cfg);
    for cycle in 0..4 {
        for threads in [1usize, 2, 8] {
            let mut c2 = cfg.clone();
            c2.threads = Some(threads);
            let prog = blockbuster::loopir::compile::compile(&ir, &c2);
            assert!(
                prog.loops[1].weight >= NESTED_FANOUT_MIN_WORK,
                "test grid must actually fan out (weight {})",
                prog.loops[1].weight
            );
            let got = exec_compiled(&prog, &c2);
            for i in 0..mm {
                for j in 0..nn {
                    assert_eq!(
                        want.outputs["B"].get(&[i, j]),
                        got.outputs["B"].get(&[i, j]),
                        "cycle {cycle} threads {threads} slot ({i},{j})"
                    );
                }
            }
            assert_mem_eq(&want, &got, &format!("cycle {cycle} threads {threads}"));
            assert!(pool::global().spawned() <= MAX_WORKERS, "pool exceeded the hard cap");
        }
    }
    // 4 cycles × 3 thread counts × 3 outer iterations of pooled regions:
    // the pool must have reused its workers, not accumulated them. The
    // suite never asks for more than 8 workers, so more than 8 spawned
    // threads would mean regions leak workers instead of reusing them.
    let spawned = pool::global().spawned();
    assert!(spawned >= 2, "fan-out must have engaged the pool");
    assert!(spawned <= 8, "pool grew past the largest request: {spawned}");
}

/// Top-level grids through the high-level `run_lowered_with` /
/// `run_lowered_cached` entry points, cycled across thread counts with a
/// shared tape cache — Workload-level outputs and counters must agree
/// with the interpreter backend on every cycle.
#[test]
fn cached_runs_across_thread_counts_match_interp() {
    let mut g = Graph::new();
    let a = g.input("A", Ty::blocks(&["M", "N"]));
    let o = map_over(&mut g, "M", &[(a, ArgMode::Mapped)], |mb, ins| {
        let inner = map_over(&mut mb.g, "N", &[(ins[0], ArgMode::Mapped)], |mb2, ins2| {
            let r = mb2.g.ew1(Expr::var(0).exp().neg().max(Expr::cst(-0.75)), ins2[0]);
            mb2.collect(r);
        });
        mb.collect(inner[0]);
    });
    g.output("B", o[0]);
    let ir = lower(&g);

    let mut rng = Rng::new(97);
    let input = rng.mat(32, 32);
    let mut cache = TapeCache::new();
    for cycle in 0..3 {
        for threads in [1usize, 2, 8] {
            let w = Workload::new(DimSizes::of(&[("M", 8), ("N", 8)]))
                .input("A", input.clone())
                .threads(threads);
            let base = run_lowered_with(&ir, &w, ExecBackend::Interp);
            let plain = run_lowered_with(&ir, &w, ExecBackend::Compiled);
            let cached = run_lowered_cached(&ir, &w, ExecBackend::Compiled, &mut cache);
            for (out, m) in [("plain", &plain), ("cached", &cached)] {
                assert_eq!(
                    base.outputs["B"], m.outputs["B"],
                    "cycle {cycle} threads {threads} {out}: output"
                );
                assert_eq!(m.mem.loaded_bytes, base.mem.loaded_bytes);
                assert_eq!(m.mem.stored_bytes, base.mem.stored_bytes);
                assert_eq!(m.mem.flops, base.mem.flops);
                assert_eq!(m.mem.kernel_launches, base.mem.kernel_launches);
            }
            assert!(pool::global().spawned() <= MAX_WORKERS);
        }
    }
    assert_eq!(cache.misses, 1, "one skeleton across all cycles");
}
