//! Serving parity: batched / mixed-traffic serving through
//! `serve::ModelServer` must be **bit-identical** — outputs compared via
//! `to_bits`, traffic counters compared exactly — to sequential
//! `coordinator::execute_plan_opts` runs on the same inputs, across
//! worker caps 1/2/8, SIMD on/off, all three backends (interp /
//! compiled / specialized), and cross-request kernel coalescing on/off,
//! and it must never compile more than once per registered workload no
//! matter how much traffic flows.
//!
//! With coalescing on, the suite additionally pins the launch ledger:
//! every multi-request batch of the (stackable) canonical workloads must
//! ride a stacked launch, and the kernel launches *actually executed*
//! must be one request's worth per stacked batch — while each response
//! still reports the launches its request would have paid alone.
//!
//! (`peak_local_bytes` is excluded from the counter comparison, matching
//! the backend-parity suite: peak merging across worker fan-outs is the
//! one counter the engine does not pin across thread counts.)

use blockbuster::coordinator::{compile, execute_plan_opts, workloads, PlanRun};
use blockbuster::exec::ExecBackend;
use blockbuster::serve::{ModelServer, Response, ServerConfig};
use blockbuster::tensor::{simd, Mat};
use std::collections::HashMap;
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Duration;

/// Serialize tests that flip the global SIMD switch (same idiom as
/// `tests/simd_parity.rs`).
fn toggle_lock() -> MutexGuard<'static, ()> {
    static M: OnceLock<Mutex<()>> = OnceLock::new();
    M.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

fn bits(m: &Mat) -> Vec<u32> {
    m.data.iter().map(|x| x.to_bits()).collect()
}

/// The three-workload mix the acceptance criteria name.
const MIX: &[&str] = &["quickstart", "attention", "rmsnorm_ffn_swiglu"];

fn assert_response_matches(name: &str, r: &Response, seq: &PlanRun) {
    assert!(r.is_ok(), "{name}: verdict is {:?}", r.verdict);
    assert_eq!(r.outputs.len(), seq.outputs.len(), "{name}: output set differs");
    for (out_name, m) in &seq.outputs {
        assert_eq!(
            bits(m),
            bits(&r.outputs[out_name]),
            "{name}: output {out_name} not bit-identical"
        );
    }
    assert_eq!(r.mem.loaded_bytes, seq.mem.loaded_bytes, "{name}: loads");
    assert_eq!(r.mem.stored_bytes, seq.mem.stored_bytes, "{name}: stores");
    assert_eq!(r.mem.n_loads, seq.mem.n_loads, "{name}: n_loads");
    assert_eq!(r.mem.n_stores, seq.mem.n_stores, "{name}: n_stores");
    assert_eq!(r.mem.kernel_launches, seq.mem.kernel_launches, "{name}: launches");
    assert_eq!(r.mem.flops, seq.mem.flops, "{name}: flops");
}

/// Serve an interleaved 3-workload stream batched up to 4, then check
/// every response bit-for-bit against an independent one-shot compile +
/// sequential execution of the same request. With `coalesce`, also pin
/// the launch ledger: every multi-request batch must ride a stacked
/// launch that executes ONE request's worth of kernel launches.
fn serve_vs_sequential(backend: ExecBackend, threads: usize, coalesce: bool) {
    let mut server = ModelServer::new(ServerConfig {
        backend,
        threads: Some(threads),
        max_batch: 4,
        // no latency-bound flushes: batches are size-triggered or drained
        max_wait: Duration::from_secs(3600),
        coalesce,
        ..ServerConfig::default()
    });
    for name in MIX {
        server.register(name).unwrap();
    }
    let misses_after_register = server.cache_misses();

    // interleaved submission: 6 requests per workload, distinct seeds
    let mut submitted: Vec<(u64, &str, u64)> = Vec::new();
    for i in 0..18u64 {
        let name = MIX[(i % 3) as usize];
        let seed = 1000 + i;
        let id = server.submit_synthetic(name, seed).unwrap();
        submitted.push((id, name, seed));
    }
    let responses = server.drain();
    assert_eq!(responses.len(), 18, "drain must serve every request");
    assert_eq!(server.pending(), 0);

    // compile-once semantics: exactly one compile per workload, no
    // skeleton compiled after registration, binds == segments once
    for name in MIX {
        let st = &server.stats().per_program[*name];
        assert_eq!(st.compiles, 1, "{name}: compile-once violated");
        assert_eq!(st.served, 6, "{name}: all requests served");
        assert!(st.batches <= 2, "{name}: 6 requests in ≤2 batches of 4");
        assert!(st.peak_batch >= 2, "{name}: batching actually coalesced");
        if coalesce {
            // synthetic requests share weights bit-for-bit, and all
            // canonical plans stack along M: every multi-request batch
            // (every batch here — 6 requests split 4+2) must coalesce
            assert_eq!(st.coalesced, 6, "{name}: all requests coalesced");
            assert_eq!(st.stacked_batches, st.batches, "{name}: all batches stacked");
        } else {
            assert_eq!(st.coalesced, 0, "{name}: coalescing off");
            assert_eq!(st.stacked_batches, 0, "{name}");
        }
    }
    assert_eq!(
        server.cache_misses(),
        misses_after_register,
        "serving traffic (stacked binds included) must never compile a skeleton"
    );

    // ground truth: one independent compile per workload, then
    // sequential one-shot executions
    let mut plans = HashMap::new();
    for name in MIX {
        let (p, cfg, params, _) = workloads::by_name(name, 0).unwrap();
        let compiled = compile(&p, cfg.clone());
        plans.insert(*name, (compiled, cfg, params));
    }
    let mut per_req_launches: HashMap<&str, u64> = HashMap::new();
    for (id, name, seed) in &submitted {
        let r = responses
            .iter()
            .find(|r| r.id == *id)
            .unwrap_or_else(|| panic!("request {id} has no response"));
        assert_eq!(&r.workload, name);
        assert_eq!(r.coalesced, coalesce, "{name}: coalesced flag");
        let (compiled, cfg, params) = &plans[name];
        let inputs = server.synthetic_inputs(name, *seed).unwrap();
        let seq = execute_plan_opts(
            &compiled.plan,
            &cfg.sizes,
            params,
            &inputs,
            backend,
            Some(threads),
        );
        assert_response_matches(name, r, &seq);
        per_req_launches.insert(*name, seq.mem.kernel_launches);
    }

    // launch ledger: a stacked batch executes one request's worth of
    // kernel launches; a fanned batch executes every request's
    for name in MIX {
        let st = &server.stats().per_program[*name];
        let per_req = per_req_launches[name];
        let want = if coalesce {
            st.batches * per_req
        } else {
            st.served * per_req
        };
        assert_eq!(st.launches, want, "{name}: launch ledger (coalesce={coalesce})");
    }
}

/// Run `serve_vs_sequential` with SIMD off then on (both sides of the
/// comparison run under the same mode).
fn sweep(backend: ExecBackend, threads: usize, coalesce: bool) {
    let _g = toggle_lock();
    simd::set_enabled(false);
    serve_vs_sequential(backend, threads, coalesce);
    simd::set_enabled(true);
    serve_vs_sequential(backend, threads, coalesce);
}

#[test]
fn batched_serving_matches_sequential_threads_1() {
    sweep(ExecBackend::Compiled, 1, false);
}

#[test]
fn batched_serving_matches_sequential_threads_2() {
    sweep(ExecBackend::Compiled, 2, false);
}

#[test]
fn batched_serving_matches_sequential_threads_8() {
    sweep(ExecBackend::Compiled, 8, false);
}

#[test]
fn coalesced_serving_matches_sequential_threads_1() {
    sweep(ExecBackend::Compiled, 1, true);
}

#[test]
fn coalesced_serving_matches_sequential_threads_2() {
    sweep(ExecBackend::Compiled, 2, true);
}

#[test]
fn coalesced_serving_matches_sequential_threads_8() {
    sweep(ExecBackend::Compiled, 8, true);
}

#[test]
fn specialized_batched_serving_matches_sequential_threads_1() {
    sweep(ExecBackend::Specialized, 1, false);
}

#[test]
fn specialized_batched_serving_matches_sequential_threads_2() {
    sweep(ExecBackend::Specialized, 2, false);
}

#[test]
fn specialized_batched_serving_matches_sequential_threads_8() {
    sweep(ExecBackend::Specialized, 8, false);
}

#[test]
fn specialized_coalesced_serving_matches_sequential_threads_1() {
    sweep(ExecBackend::Specialized, 1, true);
}

#[test]
fn specialized_coalesced_serving_matches_sequential_threads_2() {
    sweep(ExecBackend::Specialized, 2, true);
}

#[test]
fn specialized_coalesced_serving_matches_sequential_threads_8() {
    sweep(ExecBackend::Specialized, 8, true);
}

/// The interpreter backend serves too (no tapes, still compile-once).
#[test]
fn interp_serving_matches_sequential() {
    let _g = toggle_lock();
    simd::set_enabled(true);
    serve_vs_sequential(ExecBackend::Interp, 2, false);
}

/// Coalesced stacked execution on the interpreter backend: no tapes,
/// same per-request parity and launch ledger.
#[test]
fn interp_coalesced_serving_matches_sequential() {
    let _g = toggle_lock();
    simd::set_enabled(true);
    serve_vs_sequential(ExecBackend::Interp, 2, true);
}

/// Degenerate batching (max_batch 1) must still serve correctly — every
/// request its own launch.
#[test]
fn unbatched_serving_is_just_sequential() {
    let _g = toggle_lock();
    simd::set_enabled(true);
    let mut server = ModelServer::new(ServerConfig {
        backend: ExecBackend::Compiled,
        threads: Some(2),
        max_batch: 1,
        max_wait: Duration::from_secs(3600),
        coalesce: true, // irrelevant at batch size 1 — stays serial
        ..ServerConfig::default()
    });
    server.register("attention").unwrap();
    for i in 0..3u64 {
        server.submit_synthetic("attention", i).unwrap();
    }
    let responses = server.drain();
    assert_eq!(responses.len(), 3);
    assert!(responses.iter().all(|r| r.batch_size == 1));
    let st = &server.stats().per_program["attention"];
    assert_eq!(st.batches, 3);
    assert_eq!(st.compiles, 1);

    let (p, cfg, params, _) = workloads::by_name("attention", 0).unwrap();
    let compiled = compile(&p, cfg.clone());
    for (i, r) in responses.iter().enumerate() {
        let inputs = server.synthetic_inputs("attention", i as u64).unwrap();
        let seq = execute_plan_opts(
            &compiled.plan,
            &cfg.sizes,
            &params,
            &inputs,
            ExecBackend::Compiled,
            Some(2),
        );
        assert_response_matches("attention", r, &seq);
    }
}

/// A batch whose shared weight operands differ across requests must
/// fall back to per-request fan-out — and still be bit-identical to
/// sequential execution of each request's own inputs.
#[test]
fn differing_weights_fall_back_to_fanout() {
    let _g = toggle_lock();
    simd::set_enabled(true);
    let mut server = ModelServer::new(ServerConfig {
        backend: ExecBackend::Compiled,
        threads: Some(2),
        max_batch: 4,
        max_wait: Duration::from_secs(3600),
        coalesce: true,
        ..ServerConfig::default()
    });
    server.register("quickstart").unwrap();
    // four requests, one of which perturbs the shared weight BT
    let mut submitted: Vec<(u64, std::collections::HashMap<String, Mat>)> = Vec::new();
    for i in 0..4u64 {
        let mut inputs = server.synthetic_inputs("quickstart", 2000 + i).unwrap();
        if i == 2 {
            let bt = inputs.get_mut("BT").unwrap();
            bt.data[0] += 1.0;
        }
        let id = server
            .submit(blockbuster::serve::Request::new("quickstart", inputs.clone()))
            .unwrap();
        submitted.push((id, inputs));
    }
    let responses = server.drain();
    assert_eq!(responses.len(), 4);
    assert!(
        responses.iter().all(|r| !r.coalesced),
        "weight mismatch must disable coalescing for the batch"
    );
    let st = &server.stats().per_program["quickstart"];
    assert_eq!(st.coalesced, 0);
    assert_eq!(st.stacked_batches, 0);

    let (p, cfg, params, _) = workloads::by_name("quickstart", 0).unwrap();
    let compiled = compile(&p, cfg.clone());
    for (id, inputs) in &submitted {
        let r = responses.iter().find(|r| r.id == *id).unwrap();
        let seq = execute_plan_opts(
            &compiled.plan,
            &cfg.sizes,
            &params,
            inputs,
            ExecBackend::Compiled,
            Some(2),
        );
        assert_response_matches("quickstart", r, &seq);
    }
}

/// Mixed-shape traffic: different workloads never share a batch, so a
/// coalescing server handles a mixed stream as per-workload stacked
/// launches — and a single-request flush (the latency-bound path) falls
/// back to the serial path with `coalesced == false`.
#[test]
fn coalesce_single_request_batches_stay_serial() {
    let _g = toggle_lock();
    simd::set_enabled(true);
    let mut server = ModelServer::new(ServerConfig {
        backend: ExecBackend::Compiled,
        threads: Some(2),
        max_batch: 8,
        max_wait: Duration::ZERO,
        coalesce: true,
        ..ServerConfig::default()
    });
    server.register("quickstart").unwrap();
    server.submit_synthetic("quickstart", 7).unwrap();
    let r = server.poll();
    assert_eq!(r.len(), 1);
    assert!(!r[0].coalesced, "a lone request has nothing to stack");
    let st = &server.stats().per_program["quickstart"];
    assert_eq!(st.coalesced, 0);
    assert_eq!(st.launches, r[0].mem.kernel_launches);
}

/// Oversized traffic bursts: a queue much longer than max_batch flushes
/// in max_batch-sized launches, round-robin with the other workloads.
#[test]
fn burst_traffic_batches_at_max_batch() {
    let _g = toggle_lock();
    simd::set_enabled(true);
    let mut server = ModelServer::new(ServerConfig {
        backend: ExecBackend::Compiled,
        threads: Some(4),
        max_batch: 4,
        max_wait: Duration::from_secs(3600),
        coalesce: false,
        ..ServerConfig::default()
    });
    server.register("quickstart").unwrap();
    server.register("layernorm_matmul").unwrap();
    for i in 0..12u64 {
        server.submit_synthetic("quickstart", i).unwrap();
    }
    for i in 0..2u64 {
        server.submit_synthetic("layernorm_matmul", 100 + i).unwrap();
    }
    let responses = server.drain();
    assert_eq!(responses.len(), 14);
    let qs = &server.stats().per_program["quickstart"];
    assert_eq!(qs.batches, 3, "12 requests at max_batch 4");
    assert_eq!(qs.peak_batch, 4);
    let ln = &server.stats().per_program["layernorm_matmul"];
    assert_eq!(ln.batches, 1);
    assert_eq!(ln.peak_batch, 2);
    // drain interleaves: the small queue must not wait for the burst to
    // finish — its batch appears among the first four launches' worth
    // of responses (round-robin order: qs[4], ln[2], qs[4], qs[4])
    let first_ln = responses
        .iter()
        .position(|r| r.workload == "layernorm_matmul")
        .unwrap();
    assert!(first_ln < 8, "round-robin starved the small queue (first at {first_ln})");
}
