//! Ragged-traffic serving parity: mixed-length streams (requests that
//! differ only along the plan's stackable grid dim) served through
//! `serve::ModelServer` shape buckets must be **bit-identical** — outputs
//! compared via `to_bits`, traffic counters compared exactly — to
//! sequential `coordinator::execute_plan_opts` runs of each request at
//! its OWN length, across worker caps 1/2/8, SIMD on/off, both
//! backends, and padding on/off.
//!
//! The pad ledger is pinned quantitatively: with padding on, a
//! workload's `padded_*` counters must equal the summed difference
//! between a full-length sequential run and each request's own-length
//! run (pad blocks charge exactly like real blocks — counters are
//! shape-deterministic) — and `padded_*` must never leak into any
//! request's own MemSim.

use blockbuster::coordinator::{
    compile, execute_plan_opts, plan_stack_info, workloads, PlanRun,
};
use blockbuster::exec::ExecBackend;
use blockbuster::serve::{BucketLadder, ModelServer, Request, Response, ServerConfig};
use blockbuster::tensor::{simd, Mat};
use std::collections::HashMap;
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Duration;

/// Serialize tests that flip the global SIMD switch (same idiom as
/// `tests/serve_parity.rs`).
fn toggle_lock() -> MutexGuard<'static, ()> {
    static M: OnceLock<Mutex<()>> = OnceLock::new();
    M.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

fn bits(m: &Mat) -> Vec<u32> {
    m.data.iter().map(|x| x.to_bits()).collect()
}

/// The three-workload mix of `tests/serve_parity.rs` — all stack along
/// `M` with a registered trip of 4.
const MIX: &[&str] = &["quickstart", "attention", "rmsnorm_ffn_swiglu"];

/// Per-workload ragged lengths: four distinct trips, some repeated.
const TRIPS: &[usize] = &[1, 2, 3, 4, 2, 3];

fn assert_response_matches(name: &str, r: &Response, seq: &PlanRun) {
    assert!(r.is_ok(), "{name}: verdict is {:?}", r.verdict);
    assert_eq!(r.outputs.len(), seq.outputs.len(), "{name}: output set differs");
    for (out_name, m) in &seq.outputs {
        assert_eq!(
            bits(m),
            bits(&r.outputs[out_name]),
            "{name}: output {out_name} not bit-identical"
        );
    }
    assert_eq!(r.mem.loaded_bytes, seq.mem.loaded_bytes, "{name}: loads");
    assert_eq!(r.mem.stored_bytes, seq.mem.stored_bytes, "{name}: stores");
    assert_eq!(r.mem.n_loads, seq.mem.n_loads, "{name}: n_loads");
    assert_eq!(r.mem.n_stores, seq.mem.n_stores, "{name}: n_stores");
    assert_eq!(r.mem.kernel_launches, seq.mem.kernel_launches, "{name}: launches");
    assert_eq!(r.mem.flops, seq.mem.flops, "{name}: flops");
    // pad waste is the program's, never the request's
    assert_eq!(r.mem.padded_loaded_bytes, 0, "{name}: pad leaked into loads");
    assert_eq!(r.mem.padded_stored_bytes, 0, "{name}: pad leaked into stores");
    assert_eq!(r.mem.padded_flops, 0, "{name}: pad leaked into flops");
}

/// One independent sequential run of a ragged synthetic request at its
/// own length: fresh compile, stack dim bound to `trip`.
fn seq_ragged(
    server: &ModelServer,
    name: &str,
    seed: u64,
    trip: usize,
    backend: ExecBackend,
    threads: usize,
) -> PlanRun {
    let (p, cfg, params, _) = workloads::by_name(name, 0).unwrap();
    let compiled = compile(&p, cfg.clone());
    let info = plan_stack_info(&server.live_plan(name).unwrap())
        .expect("canonical workloads stack along M");
    let inputs = server.synthetic_inputs_ragged(name, seed, trip).unwrap();
    let mut sizes = cfg.sizes.clone();
    sizes.set(info.dim.clone(), trip);
    execute_plan_opts(&compiled.plan, &sizes, &params, &inputs, backend, Some(threads))
}

/// Serve an interleaved ragged 3-workload stream under the `max` ladder
/// (every length shares one bucket per workload), then check every
/// response bit-for-bit against a sequential run at its own length, and
/// the pad ledger quantitatively.
fn ragged_vs_sequential(backend: ExecBackend, threads: usize, pad: bool) {
    let mut server = ModelServer::new(ServerConfig {
        backend,
        threads: Some(threads),
        max_batch: 4,
        // no latency-bound flushes: batches are size-triggered or drained
        max_wait: Duration::from_secs(3600),
        coalesce: true,
        buckets: BucketLadder::Max,
        pad,
        ..ServerConfig::default()
    });
    for &name in MIX {
        server.register(name).unwrap();
    }
    let misses_after_register = server.cache_misses();

    // interleaved ragged submission: 6 requests per workload, 4 distinct
    // lengths, distinct seeds
    let mut submitted: Vec<(u64, &str, u64, usize)> = Vec::new();
    for &trip in TRIPS {
        for &name in MIX {
            let seed = 3000 + submitted.len() as u64;
            let id = server.submit_synthetic_ragged(name, seed, trip).unwrap();
            submitted.push((id, name, seed, trip));
        }
    }
    let responses = server.drain();
    assert_eq!(responses.len(), 18, "drain must serve every request");
    assert_eq!(server.pending(), 0);
    assert_eq!(
        server.cache_misses(),
        misses_after_register,
        "ragged stacked binds must never compile a skeleton"
    );

    // ground truth: one independent compile per workload, sequential
    // executions at each request's own trip
    let mut plans = HashMap::new();
    for &name in MIX {
        let (p, cfg, params, _) = workloads::by_name(name, 0).unwrap();
        let compiled = compile(&p, cfg.clone());
        let info = plan_stack_info(&server.live_plan(name).unwrap())
            .expect("canonical workloads stack along M");
        plans.insert(name, (compiled, cfg, params, info));
    }
    // per-(workload, trip) counters for the pad ledger (counters are
    // shape-deterministic, so one run per length suffices)
    let mut seq_mem: HashMap<(&str, usize), (u64, u64, u64)> = HashMap::new();
    for (id, name, seed, trip) in &submitted {
        let r = responses
            .iter()
            .find(|r| r.id == *id)
            .unwrap_or_else(|| panic!("request {id} has no response"));
        assert_eq!(&r.workload, name);
        assert!(r.coalesced, "{name}: every max-ladder batch here is ≥2 and must stack");
        let (compiled, cfg, params, info) = &plans[name];
        let inputs = server.synthetic_inputs_ragged(name, *seed, *trip).unwrap();
        let mut sizes = cfg.sizes.clone();
        sizes.set(info.dim.clone(), *trip);
        let seq =
            execute_plan_opts(&compiled.plan, &sizes, params, &inputs, backend, Some(threads));
        assert_response_matches(name, r, &seq);
        let seq_counters = (seq.mem.loaded_bytes, seq.mem.stored_bytes, seq.mem.flops);
        seq_mem.insert((*name, *trip), seq_counters);
    }

    // pad ledger, per workload
    for &name in MIX {
        let st = &server.stats().per_program[name];
        assert_eq!(st.served, 6, "{name}: all requests served");
        assert!(st.stacked_batches > 0, "{name}: ragged traffic coalesced");
        assert_eq!(st.stacked_batches, st.batches, "{name}: all batches stacked");
        if !pad {
            assert_eq!(
                (st.padded_loaded_bytes, st.padded_stored_bytes, st.padded_flops),
                (0, 0, 0),
                "{name}: ragged stacking without padding charges no pad waste"
            );
            continue;
        }
        // under the max ladder every request pads to the registered
        // trip: expected waste = Σ (full-length run − own-length run)
        let (compiled, cfg, params, info) = &plans[name];
        let full = {
            let inputs = server.synthetic_inputs_ragged(name, 0, info.trip).unwrap();
            let seq = execute_plan_opts(
                &compiled.plan,
                &cfg.sizes,
                params,
                &inputs,
                backend,
                Some(threads),
            );
            (seq.mem.loaded_bytes, seq.mem.stored_bytes, seq.mem.flops)
        };
        let mut want = (0u64, 0u64, 0u64);
        for (_, n, _, trip) in &submitted {
            if *n != name {
                continue;
            }
            let own = seq_mem[&(*n, *trip)];
            want.0 += full.0 - own.0;
            want.1 += full.1 - own.1;
            want.2 += full.2 - own.2;
        }
        assert_eq!(
            (st.padded_loaded_bytes, st.padded_stored_bytes, st.padded_flops),
            want,
            "{name}: pad ledger — stacked totals must equal per-request + pad"
        );
    }
}

/// Run `ragged_vs_sequential` with SIMD off then on (both sides of the
/// comparison run under the same mode).
fn sweep(backend: ExecBackend, threads: usize, pad: bool) {
    let _g = toggle_lock();
    simd::set_enabled(false);
    ragged_vs_sequential(backend, threads, pad);
    simd::set_enabled(true);
    ragged_vs_sequential(backend, threads, pad);
}

#[test]
fn ragged_serving_matches_sequential_threads_1() {
    sweep(ExecBackend::Compiled, 1, false);
}

#[test]
fn ragged_serving_matches_sequential_threads_2() {
    sweep(ExecBackend::Compiled, 2, false);
}

#[test]
fn ragged_serving_matches_sequential_threads_8() {
    sweep(ExecBackend::Compiled, 8, false);
}

#[test]
fn padded_ragged_serving_matches_sequential_threads_1() {
    sweep(ExecBackend::Compiled, 1, true);
}

#[test]
fn padded_ragged_serving_matches_sequential_threads_2() {
    sweep(ExecBackend::Compiled, 2, true);
}

#[test]
fn padded_ragged_serving_matches_sequential_threads_8() {
    sweep(ExecBackend::Compiled, 8, true);
}

/// The interpreter backend serves ragged traffic too (no tapes, same
/// per-request parity and pad ledger).
#[test]
fn interp_ragged_serving_matches_sequential() {
    let _g = toggle_lock();
    simd::set_enabled(true);
    ragged_vs_sequential(ExecBackend::Interp, 2, false);
}

#[test]
fn interp_padded_ragged_serving_matches_sequential() {
    let _g = toggle_lock();
    simd::set_enabled(true);
    ragged_vs_sequential(ExecBackend::Interp, 2, true);
}

/// The default `exact` ladder still coalesces — but only within a
/// length: two rounds of trips 1..4 form four same-trip stacked pairs,
/// never a cross-trip batch, and never any padding.
#[test]
fn exact_ladder_coalesces_same_trip_only() {
    let _g = toggle_lock();
    simd::set_enabled(true);
    let mut server = ModelServer::new(ServerConfig {
        backend: ExecBackend::Compiled,
        threads: Some(2),
        max_batch: 2,
        max_wait: Duration::from_secs(3600),
        coalesce: true,
        ..ServerConfig::default() // buckets: Exact, pad: false
    });
    server.register("quickstart").unwrap();
    for round in 0..2u64 {
        for trip in 1..=4usize {
            let seed = 10 * round + trip as u64;
            server.submit_synthetic_ragged("quickstart", seed, trip).unwrap();
        }
    }
    let responses = server.drain();
    assert_eq!(responses.len(), 8);
    assert!(responses.iter().all(|r| r.is_ok() && r.coalesced && r.batch_size == 2));
    let st = &server.stats().per_program["quickstart"];
    assert_eq!(st.batches, 4, "one batch per exact-trip bucket");
    assert_eq!(st.stacked_batches, 4);
    assert_eq!((st.padded_loaded_bytes, st.padded_flops), (0, 0), "exact edges never pad");
}

/// `pow2` + padding: trips 3 and 4 share the 4-edge bucket; the trip-3
/// request pads by exactly one block, charged as exactly the counter
/// difference between a 4-trip and a 3-trip sequential run.
#[test]
fn pow2_ladder_pads_to_the_bucket_edge() {
    let _g = toggle_lock();
    simd::set_enabled(true);
    let mut server = ModelServer::new(ServerConfig {
        backend: ExecBackend::Compiled,
        threads: Some(2),
        max_batch: 2,
        max_wait: Duration::from_secs(3600),
        coalesce: true,
        buckets: BucketLadder::Pow2,
        pad: true,
        ..ServerConfig::default()
    });
    server.register("quickstart").unwrap();
    let a = server.submit_synthetic_ragged("quickstart", 1, 3).unwrap();
    let b = server.submit_synthetic_ragged("quickstart", 2, 4).unwrap();
    let responses = server.drain();
    assert_eq!(responses.len(), 2);
    let r3 = responses.iter().find(|r| r.id == a).unwrap();
    let r4 = responses.iter().find(|r| r.id == b).unwrap();
    assert!(r3.coalesced && r4.coalesced, "trips 3 and 4 share the pow2 edge 4");
    let s3 = seq_ragged(&server, "quickstart", 1, 3, ExecBackend::Compiled, 2);
    let s4 = seq_ragged(&server, "quickstart", 2, 4, ExecBackend::Compiled, 2);
    assert_response_matches("quickstart", r3, &s3);
    assert_response_matches("quickstart", r4, &s4);
    let st = &server.stats().per_program["quickstart"];
    assert_eq!(st.stacked_batches, 1);
    assert_eq!(
        (st.padded_loaded_bytes, st.padded_stored_bytes, st.padded_flops),
        (
            s4.mem.loaded_bytes - s3.mem.loaded_bytes,
            s4.mem.stored_bytes - s3.mem.stored_bytes,
            s4.mem.flops - s3.mem.flops
        ),
        "one pad block: exactly the charge of the missing trip"
    );
}

/// A lone ragged request (batch of one — the fan-out path) executes via
/// a single-request stacked bind at its own length, and with padding on
/// still pads to its bucket edge with the same explicit accounting.
#[test]
fn single_ragged_request_pads_on_the_fanout_path() {
    let _g = toggle_lock();
    simd::set_enabled(true);
    let mut server = ModelServer::new(ServerConfig {
        backend: ExecBackend::Compiled,
        threads: Some(2),
        max_batch: 1,
        max_wait: Duration::from_secs(3600),
        coalesce: true,
        buckets: BucketLadder::Max,
        pad: true,
        ..ServerConfig::default()
    });
    server.register("quickstart").unwrap();
    server.submit_synthetic_ragged("quickstart", 5, 2).unwrap();
    let responses = server.drain();
    assert_eq!(responses.len(), 1);
    let r = &responses[0];
    assert!(r.is_ok());
    assert!(!r.coalesced, "a lone request has nothing to stack with");
    let s2 = seq_ragged(&server, "quickstart", 5, 2, ExecBackend::Compiled, 2);
    let s4 = seq_ragged(&server, "quickstart", 5, 4, ExecBackend::Compiled, 2);
    assert_response_matches("quickstart", r, &s2);
    let st = &server.stats().per_program["quickstart"];
    assert_eq!(st.stacked_batches, 0);
    assert_eq!(
        (st.padded_loaded_bytes, st.padded_stored_bytes, st.padded_flops),
        (
            s4.mem.loaded_bytes - s2.mem.loaded_bytes,
            s4.mem.stored_bytes - s2.mem.stored_bytes,
            s4.mem.flops - s2.mem.flops
        ),
        "fan-out singles pad to the bucket edge with the same accounting"
    );
}

/// A ragged batch whose shared weight operands differ across requests
/// must fall back to per-request fan-out — each request still executes
/// at its own length, bit-identical to a sequential run of its own
/// (perturbed) inputs.
#[test]
fn differing_weights_ragged_falls_back_to_fanout() {
    let _g = toggle_lock();
    simd::set_enabled(true);
    let mut server = ModelServer::new(ServerConfig {
        backend: ExecBackend::Compiled,
        threads: Some(2),
        max_batch: 3,
        max_wait: Duration::from_secs(3600),
        coalesce: true,
        buckets: BucketLadder::Max,
        ..ServerConfig::default()
    });
    server.register("quickstart").unwrap();
    let mut submitted: Vec<(u64, usize, HashMap<String, Mat>)> = Vec::new();
    for (i, trip) in [1usize, 2, 3].into_iter().enumerate() {
        let mut inputs = server
            .synthetic_inputs_ragged("quickstart", 4000 + i as u64, trip)
            .unwrap();
        if i == 1 {
            inputs.get_mut("BT").unwrap().data[0] += 1.0;
        }
        let id = server.submit(Request::new("quickstart", inputs.clone())).unwrap();
        submitted.push((id, trip, inputs));
    }
    let responses = server.drain();
    assert_eq!(responses.len(), 3);
    assert!(
        responses.iter().all(|r| r.is_ok() && !r.coalesced),
        "weight mismatch must disable coalescing for the batch"
    );
    let st = &server.stats().per_program["quickstart"];
    assert_eq!(st.stacked_batches, 0);

    let (p, cfg, params, _) = workloads::by_name("quickstart", 0).unwrap();
    let compiled = compile(&p, cfg.clone());
    let info = plan_stack_info(&server.live_plan("quickstart").unwrap()).unwrap();
    for (id, trip, inputs) in &submitted {
        let r = responses.iter().find(|r| r.id == *id).unwrap();
        let mut sizes = cfg.sizes.clone();
        sizes.set(info.dim.clone(), *trip);
        let seq = execute_plan_opts(
            &compiled.plan,
            &sizes,
            &params,
            inputs,
            ExecBackend::Compiled,
            Some(2),
        );
        assert_response_matches("quickstart", r, &seq);
    }
}

/// Full-shape and ragged synthetic requests share the weight stream, so
/// under a coarse ladder they share a bucket — and one stacked launch.
#[test]
fn full_and_ragged_requests_share_a_stacked_launch() {
    let _g = toggle_lock();
    simd::set_enabled(true);
    let mut server = ModelServer::new(ServerConfig {
        backend: ExecBackend::Compiled,
        threads: Some(2),
        max_batch: 2,
        max_wait: Duration::from_secs(3600),
        coalesce: true,
        buckets: BucketLadder::Max,
        ..ServerConfig::default()
    });
    server.register("attention").unwrap();
    let a = server.submit_synthetic("attention", 1).unwrap();
    let b = server.submit_synthetic_ragged("attention", 2, 2).unwrap();
    let responses = server.drain();
    assert_eq!(responses.len(), 2);
    assert!(responses.iter().all(|r| r.is_ok() && r.coalesced));
    let st = &server.stats().per_program["attention"];
    assert_eq!(st.stacked_batches, 1);

    // parity: the full-shape request against its registered-shape run,
    // the ragged one against its own length
    let (p, cfg, params, _) = workloads::by_name("attention", 0).unwrap();
    let compiled = compile(&p, cfg.clone());
    let r_full = responses.iter().find(|r| r.id == a).unwrap();
    let inputs = server.synthetic_inputs("attention", 1).unwrap();
    let seq_full = execute_plan_opts(
        &compiled.plan,
        &cfg.sizes,
        &params,
        &inputs,
        ExecBackend::Compiled,
        Some(2),
    );
    assert_response_matches("attention", r_full, &seq_full);
    let r_ragged = responses.iter().find(|r| r.id == b).unwrap();
    let seq_r = seq_ragged(&server, "attention", 2, 2, ExecBackend::Compiled, 2);
    assert_response_matches("attention", r_ragged, &seq_r);
}
