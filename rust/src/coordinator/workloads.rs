//! Canonical demo workloads for the CLI, examples, and benches: each returns
//! (array program, compile config, params, synthetic inputs).

use super::CompileConfig;
use crate::array::{programs, ArrayProgram};
use crate::cost::CostModel;
use crate::ir::dim::DimSizes;
use crate::tensor::{Mat, Rng};
use std::collections::{BTreeMap, HashMap};

pub type Demo = (
    ArrayProgram,
    CompileConfig,
    BTreeMap<String, f32>,
    HashMap<String, Mat>,
);

fn mats(seed: u64, specs: &[(&str, usize, usize)]) -> HashMap<String, Mat> {
    let mut rng = Rng::new(seed);
    specs
        .iter()
        .map(|(n, r, c)| (n.to_string(), rng.mat(*r, *c)))
        .collect()
}

fn shapes(specs: &[(&str, usize, usize)]) -> HashMap<String, (usize, usize)> {
    specs
        .iter()
        .map(|(n, r, c)| (n.to_string(), (*r, *c)))
        .collect()
}

/// §1 quickstart: matmul + ReLU.
pub fn matmul_relu_demo(seed: u64) -> Demo {
    let specs = [("A", 32, 32), ("BT", 16, 32)];
    let cfg = CompileConfig {
        sizes: DimSizes::of(&[("M", 4), ("K", 4), ("N", 2)]),
        full_shapes: shapes(&specs),
        model: CostModel::default(),
    };
    (
        programs::matmul_relu(),
        cfg,
        BTreeMap::new(),
        mats(seed, &specs),
    )
}

/// Example 1 at the artifact shapes (see python/compile/aot.py).
pub fn attention_demo(seed: u64) -> Demo {
    let specs = [("Q", 32, 16), ("KT", 32, 16), ("VT", 16, 32)];
    let cfg = CompileConfig {
        sizes: DimSizes::of(&[("M", 4), ("N", 4), ("D", 2), ("L", 2)]),
        full_shapes: shapes(&specs),
        model: CostModel::default(),
    };
    let mut params = BTreeMap::new();
    params.insert("DD".to_string(), 16.0);
    (programs::attention(), cfg, params, mats(seed, &specs))
}

/// KV-cache decode attention: one 8-row query block (`M` = 1 block)
/// against a cache registered at its capacity (`N` = 4 blocks = the
/// context cap `T`). `KT`/`VT` are the stateful caches; `MASK` ships
/// zeroed (a stateless one-shot sees the whole cache) — the serving
/// layer's sessions grow the caches block by block and scale the mask
/// to the current length. Block shapes match [`attention_demo`] (8×8),
/// so decode traffic can ride the same bucket ladder as prefill.
pub fn decode_attention_demo(seed: u64) -> Demo {
    let specs = [("Q", 8, 16), ("KT", 32, 16), ("VT", 16, 32), ("MASK", 8, 32)];
    let cfg = CompileConfig {
        sizes: DimSizes::of(&[("M", 1), ("N", 4), ("D", 2), ("L", 2)]),
        full_shapes: shapes(&specs),
        model: CostModel::default(),
    };
    let mut params = BTreeMap::new();
    params.insert("DD".to_string(), 16.0);
    let mut inputs = mats(seed, &specs);
    inputs.insert("MASK".to_string(), Mat::zeros(8, 32));
    (programs::decode_attention(), cfg, params, inputs)
}

/// Example 2 at the artifact shapes.
pub fn layernorm_matmul_demo(seed: u64) -> Demo {
    let specs = [("X", 32, 32), ("YT", 16, 32)];
    let cfg = CompileConfig {
        sizes: DimSizes::of(&[("M", 4), ("K", 4), ("N", 2)]),
        full_shapes: shapes(&specs),
        model: CostModel::default(),
    };
    let mut params = BTreeMap::new();
    params.insert("KK".to_string(), 32.0);
    (
        programs::layernorm_matmul(),
        cfg,
        params,
        mats(seed, &specs),
    )
}

/// Example 3 at the artifact shapes.
pub fn rmsnorm_ffn_swiglu_demo(seed: u64) -> Demo {
    let specs = [
        ("X", 32, 16),
        ("WT", 32, 16),
        ("VT", 32, 16),
        ("UT", 16, 32),
    ];
    let cfg = CompileConfig {
        sizes: DimSizes::of(&[("M", 4), ("D", 2), ("K", 4), ("N", 2)]),
        full_shapes: shapes(&specs),
        model: CostModel::default(),
    };
    let mut params = BTreeMap::new();
    params.insert("DD".to_string(), 16.0);
    (
        programs::rmsnorm_ffn_swiglu(),
        cfg,
        params,
        mats(seed, &specs),
    )
}

/// End-to-end decoder block at the artifact shapes.
pub fn decoder_demo(seed: u64) -> Demo {
    let specs = [
        ("Q", 32, 16),
        ("KT", 32, 16),
        ("VT", 16, 32),
        ("R", 32, 16),
        ("WT", 32, 16),
        ("VT2", 32, 16),
        ("UT", 16, 32),
    ];
    let cfg = CompileConfig {
        sizes: DimSizes::of(&[
            ("M", 4),
            ("N", 4),
            ("D", 2),
            ("L", 2),
            ("K", 4),
            ("L2", 2),
        ]),
        full_shapes: shapes(&specs),
        model: CostModel::default(),
    };
    let mut params = BTreeMap::new();
    params.insert("DD".to_string(), 16.0);
    params.insert("LL".to_string(), 16.0);
    (programs::decoder_block(), cfg, params, mats(seed, &specs))
}

/// Lookup by CLI name.
pub fn by_name(name: &str, seed: u64) -> Option<Demo> {
    Some(match name {
        "quickstart" | "matmul_relu" => matmul_relu_demo(seed),
        "attention" | "flash_attention" => attention_demo(seed),
        // Not in `NAMES`: stateful — synthetic *stateless* streams
        // (`--mix`, benches) must not submit it; decode traffic flows
        // through sessions (`serve --decode` / `--mix-decode`).
        "decode_attention" | "decode" => decode_attention_demo(seed),
        "layernorm_matmul" => layernorm_matmul_demo(seed),
        "rmsnorm_ffn_swiglu" | "ffn" => rmsnorm_ffn_swiglu_demo(seed),
        "decoder" | "decoder_block" => decoder_demo(seed),
        _ => return None,
    })
}

pub const NAMES: &[&str] = &[
    "quickstart",
    "attention",
    "layernorm_matmul",
    "rmsnorm_ffn_swiglu",
    "decoder",
];
