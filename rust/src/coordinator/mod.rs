//! The end-to-end compiler driver (L3).
//!
//! Pipeline: array program → Table-2 lowering → candidate selection (which
//! invokes the fusion algorithm per candidate and scores every snapshot) →
//! optional block-shape autotuning → an executable [`SelectionPlan`] whose
//! segments run back-to-back on the two-tier-memory executor, with
//! intermediates flowing between segments through (simulated) global
//! memory. The paper's contribution is the compiler, so this layer is a
//! thin deterministic driver; reports quantify what fusion bought.
//!
//! Execution comes in two flavors:
//!
//! * **one-shot** — [`execute_plan`] / [`execute_plan_opts`] lower and
//!   (on the compiled backend) flatten every segment per call; right for
//!   a single run of a plan;
//! * **compile-once** — [`prepare_plan`] lowers each segment once and,
//!   on [`ExecBackend::Compiled`], binds its tape skeleton once, yielding
//!   a [`PreparedPlan`] that [`execute_prepared`] can run any number of
//!   times on fresh inputs with zero per-request compilation. This is
//!   the substrate of the serving layer ([`crate::serve`]); the one-shot
//!   entry points are a thin wrapper over it, so the two paths cannot
//!   drift apart.

pub mod workloads;

use crate::cost::CostModel;
use crate::exec::{
    exec_ir, from_blocks, stack_blocks_ragged, to_blocks, unstack_blocks_range, ExecBackend,
    TapeCache,
};
use crate::ir::dim::{Dim, DimSizes};
use crate::ir::graph::Graph;
use crate::loopir::compile::{stackable_grid_dim, CompiledProgram, TapeSkeleton};
use crate::loopir::interp::{BufVal, ExecConfig, MemSim};
use crate::loopir::lower::lower;
use crate::loopir::LoopIr;
use crate::lower::lower_array;
use crate::select::{select, SelectCtx, SelectionPlan, ValueRef};
use crate::tensor::{Mat, Val};
use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::Arc;

/// Compiler configuration.
#[derive(Clone, Debug)]
pub struct CompileConfig {
    pub sizes: DimSizes,
    pub full_shapes: HashMap<String, (usize, usize)>,
    pub model: CostModel,
}

/// A compiled program: the initial block program plus the selected plan.
pub struct Compiled {
    pub block: Graph,
    pub plan: SelectionPlan,
    pub cfg: CompileConfig,
}

/// Run the full compilation pipeline.
pub fn compile(p: &crate::array::ArrayProgram, cfg: CompileConfig) -> Compiled {
    let block = lower_array(p);
    let ctx = SelectCtx {
        sizes: cfg.sizes.clone(),
        full_shapes: cfg.full_shapes.clone(),
        model: cfg.model,
    };
    let plan = select(&block, &ctx);
    Compiled { block, plan, cfg }
}

/// Result of executing a plan.
pub struct PlanRun {
    pub outputs: HashMap<String, Mat>,
    /// Aggregated two-tier traffic across all segments.
    pub mem: MemSim,
    pub per_segment: Vec<MemSim>,
}

/// Execute a selected plan segment by segment on the interpreter backend.
pub fn execute_plan(
    plan: &SelectionPlan,
    sizes: &DimSizes,
    params: &BTreeMap<String, f32>,
    inputs: &HashMap<String, Mat>,
) -> PlanRun {
    execute_plan_with(plan, sizes, params, inputs, ExecBackend::Interp)
}

/// Execute a selected plan segment by segment, passing intermediates
/// through (simulated) global memory, on the chosen [`ExecBackend`].
pub fn execute_plan_with(
    plan: &SelectionPlan,
    sizes: &DimSizes,
    params: &BTreeMap<String, f32>,
    inputs: &HashMap<String, Mat>,
    backend: ExecBackend,
) -> PlanRun {
    execute_plan_opts(plan, sizes, params, inputs, backend, None)
}

/// [`execute_plan_with`] plus a worker cap for the compiled engine's
/// parallel grid loops (the CLI's `--threads`).
///
/// One-shot: lowers (and on the compiled backend flattens) every segment
/// on each call. Callers that execute one plan many times should
/// [`prepare_plan`] once and call [`execute_prepared`] per run instead —
/// this function is exactly that pair with a throwaway cache, so the two
/// paths are equivalent by construction.
pub fn execute_plan_opts(
    plan: &SelectionPlan,
    sizes: &DimSizes,
    params: &BTreeMap<String, f32>,
    inputs: &HashMap<String, Mat>,
    backend: ExecBackend,
    threads: Option<usize>,
) -> PlanRun {
    let mut cache = TapeCache::new();
    let prepared = prepare_plan(plan, sizes, params, backend, &mut cache);
    execute_prepared(&prepared, inputs, threads)
}

/// One segment of a [`PreparedPlan`]: the lowered Loop IR, the bound
/// instruction tape (compiled backend only), and the I/O wiring copied
/// from the source [`crate::select::Segment`].
pub struct PreparedSegment {
    /// The segment's lowered loop nest (lowering runs once, at prepare
    /// time).
    pub ir: LoopIr,
    /// `Some` iff the plan was prepared for [`ExecBackend::Compiled`]
    /// or [`ExecBackend::Specialized`]: the tape skeleton bound to the
    /// plan's `DimSizes` (kernel-specialized for the latter).
    pub tape: Option<CompiledProgram>,
    /// The cached skeleton behind `tape` (same `Some`-ness): kept so
    /// stacked-batch execution ([`bind_stacked`]) can re-bind to an
    /// enlarged `DimSizes` without touching the [`TapeCache`] again.
    pub skeleton: Option<Arc<TapeSkeleton>>,
    /// For each segment input label: where its value comes from.
    pub inputs: Vec<(String, ValueRef)>,
    /// For each segment output label: the program output it implements.
    pub outputs: Vec<(String, Option<String>)>,
}

/// A [`SelectionPlan`] made ready for compile-once/execute-many use:
/// every segment lowered once and (on the compiled backend) its tape
/// bound once. [`execute_prepared`] runs it on fresh inputs with zero
/// per-request compilation — the serving layer's hot path.
pub struct PreparedPlan {
    pub backend: ExecBackend,
    pub sizes: DimSizes,
    pub params: BTreeMap<String, f32>,
    pub segments: Vec<PreparedSegment>,
    /// Tape binds performed while preparing (== segment count on the
    /// compiled/specialized backends, 0 on the interpreter) —
    /// compile-once telemetry.
    pub binds: u64,
}

impl PreparedPlan {
    /// Specialization coverage summed over segments:
    /// `(fused_nests, total_nests)`. `None` unless the plan was
    /// prepared for [`ExecBackend::Specialized`] — the observable
    /// answer to "which loop nests run through fused kernel bodies and
    /// which fell back to the generic interpreter loop".
    pub fn spec_coverage(&self) -> Option<(usize, usize)> {
        let mut any = false;
        let (mut fused, mut total) = (0usize, 0usize);
        for seg in &self.segments {
            if let Some(rep) = seg.skeleton.as_ref().and_then(|sk| sk.spec.as_ref()) {
                any = true;
                fused += rep.fused_nests;
                total += rep.total_nests;
            }
        }
        any.then_some((fused, total))
    }
}

/// Lower every segment of `plan` and, on the compiled and specialized
/// backends, pull its tape skeleton from `cache` (compiling — and for
/// [`ExecBackend::Specialized`], kernel-specializing — it on first
/// sight) and bind it to `sizes`. All per-structure work happens here,
/// once; the returned
/// [`PreparedPlan`] is immutable and shareable across any number of
/// [`execute_prepared`] calls (it is `Sync` — the serving layer fans
/// batches of requests over it from worker threads).
pub fn prepare_plan(
    plan: &SelectionPlan,
    sizes: &DimSizes,
    params: &BTreeMap<String, f32>,
    backend: ExecBackend,
    cache: &mut TapeCache,
) -> PreparedPlan {
    let mut segments = Vec::with_capacity(plan.segments.len());
    let mut binds = 0u64;
    for seg in &plan.segments {
        let ir = lower(&seg.graph);
        let (tape, skeleton) = match backend {
            ExecBackend::Interp => (None, None),
            ExecBackend::Compiled | ExecBackend::Specialized => {
                // The skeleton depends on params and misc registries but
                // never on `DimSizes`; the bind is the cheap phase. The
                // cache hands back the kernel-specialized flavor for
                // `Specialized` (the backend is part of its key).
                let mut cfg = ExecConfig::new(sizes.clone());
                cfg.params = params.clone();
                let skel = cache.skeleton(&ir, &cfg, backend);
                binds += 1;
                (Some(skel.bind(sizes)), Some(skel))
            }
        };
        segments.push(PreparedSegment {
            ir,
            tape,
            skeleton,
            inputs: seg.inputs.clone(),
            outputs: seg.outputs.clone(),
        });
    }
    PreparedPlan {
        backend,
        sizes: sizes.clone(),
        params: params.clone(),
        segments,
        binds,
    }
}

/// Execute a [`PreparedPlan`] on fresh inputs: segment by segment,
/// intermediates flowing through (simulated) global memory — identical
/// semantics (outputs and traffic counters) to [`execute_plan_opts`] on
/// the same plan, but with no lowering or tape compilation on the hot
/// path. `threads` caps the compiled engine's parallel grid loops.
pub fn execute_prepared(
    prepared: &PreparedPlan,
    inputs: &HashMap<String, Mat>,
    threads: Option<usize>,
) -> PlanRun {
    let sizes = &prepared.sizes;
    let mut inter: HashMap<(usize, String), BufVal> = HashMap::new();
    let mut outputs = HashMap::new();
    let mut total = MemSim::default();
    let mut per_segment = Vec::new();

    for (si, seg) in prepared.segments.iter().enumerate() {
        let mut cfg = ExecConfig::new(sizes.clone());
        cfg.params = prepared.params.clone();
        cfg.threads = threads;
        for decl in &seg.ir.bufs {
            if !decl.is_input {
                continue;
            }
            let (_, vref) = seg
                .inputs
                .iter()
                .find(|(l, _)| *l == decl.name)
                .unwrap_or_else(|| panic!("segment {si}: no wiring for input {}", decl.name));
            let bv = match vref {
                ValueRef::ProgramInput(name) => {
                    let m = inputs
                        .get(name)
                        .unwrap_or_else(|| panic!("missing program input {name}"));
                    assert_eq!(decl.dims.len(), 2, "program input {name} must be 2-d");
                    to_blocks(m, sizes.get(&decl.dims[0]), sizes.get(&decl.dims[1]))
                }
                ValueRef::SegmentOutput { segment, label } => inter
                    .get(&(*segment, label.clone()))
                    .unwrap_or_else(|| panic!("segment {si}: missing intermediate {label}"))
                    .clone(),
            };
            cfg.inputs.insert(decl.name.clone(), bv);
        }
        let res = match &seg.tape {
            Some(prog) => crate::exec::engine::exec_compiled(prog, &cfg),
            None => exec_ir(&seg.ir, &cfg, ExecBackend::Interp),
        };
        for (label, prog_out) in &seg.outputs {
            let bv = res.outputs.get(label).unwrap_or_else(|| {
                panic!("segment {si}: executor produced no output {label}")
            });
            if let Some(name) = prog_out {
                outputs.insert(name.clone(), from_blocks(bv));
            }
            inter.insert((si, label.clone()), bv.clone());
        }
        total.add_counters(&res.mem);
        per_segment.push(res.mem);
    }

    PlanRun {
        outputs,
        mem: total,
        per_segment,
    }
}

// ---------------------------------------------------------------------------
// Cross-request kernel coalescing: stacked batch execution
// ---------------------------------------------------------------------------

/// How a prepared plan coalesces batches: the grid dimension every
/// segment's top-level loops iterate (typically the row-block dim `M`)
/// and its per-request trip count. Produced by [`plan_stack_info`].
#[derive(Clone, Debug)]
pub struct StackInfo {
    pub dim: Dim,
    /// Per-request block count along `dim` (the plan's own binding).
    pub trip: usize,
}

/// Whether `prepared` can execute a batch of same-shape requests as
/// **one stacked launch**: every segment must expose the same stackable
/// grid dim (`loopir::compile::stackable_grid_dim` — all top-level
/// nests are `forall dim` grids whose iterations are provably
/// independent and slice-aligned). Returns the dim and its per-request
/// trip, or `None` (callers fall back to per-request fan-out).
pub fn plan_stack_info(prepared: &PreparedPlan) -> Option<StackInfo> {
    let mut dim: Option<Dim> = None;
    for seg in &prepared.segments {
        let d = stackable_grid_dim(&seg.ir)?;
        match &dim {
            None => dim = Some(d),
            Some(d0) if *d0 == d => {}
            Some(_) => return None,
        }
    }
    let dim = dim?;
    let trip = prepared.sizes.try_get(&dim)?;
    Some(StackInfo { dim, trip })
}

/// Names of program inputs that do **not** carry the stack dim — shared
/// weight-like operands. A coalesced batch binds request 0's copy of
/// each for the whole stacked launch, so the caller must verify they
/// are bit-identical across the batch before coalescing (the serving
/// layer falls back to fan-out otherwise).
pub fn unstacked_inputs(prepared: &PreparedPlan, info: &StackInfo) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for seg in &prepared.segments {
        for (label, vref) in &seg.inputs {
            if let ValueRef::ProgramInput(name) = vref {
                let decl = seg
                    .ir
                    .bufs
                    .iter()
                    .find(|b| b.name == *label)
                    .expect("wired segment input is declared");
                if !decl.dims.contains(&info.dim) {
                    out.insert(name.clone());
                }
            }
        }
    }
    out
}

/// A [`PreparedPlan`] re-bound for stacked execution at one **total
/// trip**: the enlarged `DimSizes` (`dim -> total_trip`) plus, on the
/// compiled backend, each segment's tape skeleton re-bound to it. No
/// compilation happens here — skeletons were cached by
/// [`prepare_plan`]; this is only the cheap bind phase, so servers can
/// afford one per observed total trip (for a uniform batch of `b`
/// registered-shape requests, `total_trip == b · info.trip`; ragged
/// batches sum their per-request trips plus any pad blocks).
pub struct StackedPlan {
    /// Total block count along `info.dim` this bind was sized for —
    /// the sum of every request's trip plus pad blocks.
    pub total_trip: usize,
    pub info: StackInfo,
    pub sizes: DimSizes,
    /// Tape binds this stacked re-bind performed (== compiled segments;
    /// 0 on the interpreter backend) — telemetry for the serving
    /// layer's compile-once ledger.
    pub binds: u64,
    tapes: Vec<Option<CompiledProgram>>,
}

/// How a stacked launch is carved into per-request slices along the
/// stack dim. `trips[r]` is request `r`'s own block count; `pads[r]`
/// is the number of zero pad blocks appended after it to reach its
/// bucket edge (all zeros when padding is off). Pad blocks execute —
/// their traffic lands in the launch's aggregate — but are attributed
/// to the aggregate's `padded_*` counters, never to a request.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StackSpec {
    pub trips: Vec<usize>,
    pub pads: Vec<usize>,
}

impl StackSpec {
    /// Spec for a uniform batch: every request at the registered trip,
    /// no padding.
    pub fn uniform(batch: usize, trip: usize) -> StackSpec {
        StackSpec {
            trips: vec![trip; batch],
            pads: vec![0; batch],
        }
    }

    /// Total block count along the stack dim (requests + pads) — the
    /// `total_trip` the launch's [`StackedPlan`] must be bound at.
    pub fn total_trip(&self) -> usize {
        self.trips.iter().sum::<usize>() + self.pads.iter().sum::<usize>()
    }

    /// Pad blocks across the whole batch.
    pub fn pad_trip(&self) -> usize {
        self.pads.iter().sum()
    }

    /// Executor slice widths: `[trip_0, pad_0, trip_1, pad_1, …]` —
    /// slice `2r` is request `r`, slice `2r+1` its pad run (width 0
    /// charges nothing and takes no launch).
    pub fn widths(&self) -> Vec<usize> {
        let mut w = Vec::with_capacity(2 * self.trips.len());
        for (t, p) in self.trips.iter().zip(&self.pads) {
            w.push(*t);
            w.push(*p);
        }
        w
    }
}

/// Bind `prepared` for stacked execution of a uniform batch of `batch`
/// registered-shape requests (see [`StackedPlan`]). `info` must come
/// from [`plan_stack_info`] on the same plan.
pub fn bind_stacked(prepared: &PreparedPlan, info: &StackInfo, batch: usize) -> StackedPlan {
    assert!(batch >= 1, "bind_stacked: empty batch");
    bind_stacked_trip(prepared, info, info.trip * batch)
}

/// Bind `prepared` for stacked execution at an arbitrary `total_trip`
/// along the stack dim — the ragged generalisation of [`bind_stacked`].
/// Any partition of `total_trip` into request trips and pads (a
/// [`StackSpec`] with matching [`StackSpec::total_trip`]) can execute
/// on this bind.
pub fn bind_stacked_trip(
    prepared: &PreparedPlan,
    info: &StackInfo,
    total_trip: usize,
) -> StackedPlan {
    bind_stacked_sized(prepared, info, total_trip, &[])
}

/// [`bind_stacked_trip`] with extra dim-size `overrides` applied to the
/// bind (never the stack dim itself). This is how a decode plan
/// registered at its cache-capacity `N` is re-bound at the *current*
/// cache length: the stack dim carries the batch as usual while `N` is
/// overridden to the session's length, so every input blocked from the
/// bind's sizes — the KV caches and the mask — gets the right grid.
pub fn bind_stacked_sized(
    prepared: &PreparedPlan,
    info: &StackInfo,
    total_trip: usize,
    overrides: &[(Dim, usize)],
) -> StackedPlan {
    assert!(total_trip >= 1, "bind_stacked_sized: empty stack");
    let mut sizes = prepared.sizes.clone();
    sizes.set(info.dim.clone(), total_trip);
    for (d, n) in overrides {
        assert!(*d != info.dim, "bind_stacked_sized: override of the stack dim {d:?}");
        assert!(*n >= 1, "bind_stacked_sized: zero-block override for {d:?}");
        sizes.set(d.clone(), *n);
    }
    let tapes: Vec<Option<CompiledProgram>> = prepared
        .segments
        .iter()
        .map(|seg| seg.skeleton.as_ref().map(|sk| sk.bind(&sizes)))
        .collect();
    let binds = tapes.iter().filter(|t| t.is_some()).count() as u64;
    StackedPlan {
        total_trip,
        info: info.clone(),
        sizes,
        binds,
        tapes,
    }
}

/// For each *stateful* program input of `prepared` (see
/// `BufDecl::state_dim`): its growth dim and the matrix axis
/// (0 = rows, 1 = cols) that dim occupies. Empty for stateless plans.
/// The serving layer uses this to discover which inputs a session must
/// own and along which axis each decode step appends.
pub fn state_input_axes(prepared: &PreparedPlan) -> BTreeMap<String, (Dim, usize)> {
    let mut out = BTreeMap::new();
    for seg in &prepared.segments {
        for (label, vref) in &seg.inputs {
            if let ValueRef::ProgramInput(name) = vref {
                let decl = seg
                    .ir
                    .bufs
                    .iter()
                    .find(|b| b.name == *label)
                    .expect("wired segment input is declared");
                if let Some(dim) = &decl.state_dim {
                    let axis = decl
                        .dims
                        .iter()
                        .position(|d| d == dim)
                        .unwrap_or_else(|| {
                            panic!("state dim {dim:?} is not a dim of buffer {label}")
                        });
                    let prev = out.insert(name.clone(), (dim.clone(), axis));
                    if let Some(prev) = prev {
                        assert_eq!(
                            prev,
                            (dim.clone(), axis),
                            "program input {name} stateful on inconsistent dims/axes"
                        );
                    }
                }
            }
        }
    }
    out
}

/// The registered block grid `(row blocks, col blocks)` of a program
/// input, from its segment declaration and the prepared sizes. `None`
/// for an unknown input (or a non-matrix declaration). The serving
/// layer uses this to charge stateful-buffer appends at block
/// granularity: one decode step appends a slab of `1 × other` (or
/// `other × 1`) blocks.
pub fn input_block_grid(prepared: &PreparedPlan, input: &str) -> Option<(usize, usize)> {
    for seg in &prepared.segments {
        for (label, vref) in &seg.inputs {
            if let ValueRef::ProgramInput(name) = vref {
                if name == input {
                    let decl = seg.ir.bufs.iter().find(|b| b.name == *label)?;
                    if decl.dims.len() != 2 {
                        return None;
                    }
                    let rb = prepared.sizes.get(&decl.dims[0]);
                    let cb = prepared.sizes.get(&decl.dims[1]);
                    return Some((rb, cb));
                }
            }
        }
    }
    None
}

/// For each program input that carries the stack dim: which matrix
/// axis (0 = rows, 1 = cols) it stacks along. Inputs absent from the
/// map are the shared weight-like operands of [`unstacked_inputs`].
/// The serving layer uses this to derive a ragged request's trip from
/// its input extents.
pub fn stacked_input_axes(prepared: &PreparedPlan, info: &StackInfo) -> BTreeMap<String, usize> {
    input_dim_axes(prepared, &info.dim)
}

/// For each program input of `prepared` that carries `dim`: the matrix
/// axis (0 = rows, 1 = cols) it occupies. The generalisation behind
/// [`stacked_input_axes`]; the serving layer also applies it to a
/// stateful plan's *growth* dim to find which request inputs (the
/// decode mask) must arrive scaled to the current cache length.
pub fn input_dim_axes(prepared: &PreparedPlan, dim: &Dim) -> BTreeMap<String, usize> {
    let mut out = BTreeMap::new();
    for seg in &prepared.segments {
        for (label, vref) in &seg.inputs {
            if let ValueRef::ProgramInput(name) = vref {
                let decl = seg
                    .ir
                    .bufs
                    .iter()
                    .find(|b| b.name == *label)
                    .expect("wired segment input is declared");
                if let Some(axis) = decl.dims.iter().position(|d| d == dim) {
                    if let Some(prev) = out.insert(name.clone(), axis) {
                        assert_eq!(
                            prev, axis,
                            "program input {name} carries {dim:?} on inconsistent axes"
                        );
                    }
                }
            }
        }
    }
    out
}

/// Result of a stacked batch execution: one [`PlanRun`] per request
/// plus the launch's true aggregate counters.
pub struct BatchRun {
    /// Per-request runs, batch order. Outputs and traffic counters are
    /// bit-identical to a sequential [`execute_prepared`] of the same
    /// request (`peak_local_bytes` excepted, as everywhere).
    pub runs: Vec<PlanRun>,
    /// What actually executed: `kernel_launches` here is one per
    /// top-level nest per segment — independent of the batch size. The
    /// per-request counters deliberately report the launches each
    /// request *would have paid* alone (the parity contract); this
    /// field is where the coalescing win shows.
    pub agg: MemSim,
}

/// Execute one **stacked launch** for a uniform batch of
/// registered-shape requests — the common fast path, equivalent to
/// [`execute_prepared_stacked_spec`] with [`StackSpec::uniform`].
///
/// Caller contract (the serving layer enforces both): `stacked` was
/// bound from this `prepared` at `inputs.len()` requests, and every
/// input named by [`unstacked_inputs`] is bit-identical across the
/// batch.
pub fn execute_prepared_stacked(
    prepared: &PreparedPlan,
    stacked: &StackedPlan,
    inputs: &[&HashMap<String, Mat>],
    threads: Option<usize>,
) -> BatchRun {
    let spec = StackSpec::uniform(inputs.len(), stacked.info.trip);
    execute_prepared_stacked_spec(prepared, stacked, &spec, inputs, threads)
}

/// Build a grid of zero blocks shaped like `part`'s, `pad` wide along
/// `axis` — the pad run appended after a ragged request to reach its
/// bucket edge. `to_blocks` splits evenly, so every block in `part`
/// shares one shape; clone-on-`Arc` keeps this O(pad · grid) pointers
/// plus a single zero payload.
fn pad_blocks(part: &BufVal, axis: usize, pad: usize) -> BufVal {
    let (bh, bw) = match part.data.first().and_then(|v| v.as_deref()) {
        Some(Val::Block(m)) => (m.rows, m.cols),
        _ => panic!("pad_blocks: request part has no payload block"),
    };
    let zero = Arc::new(Val::Block(Mat::zeros(bh, bw)));
    let mut dims = part.dims.clone();
    dims[axis] = pad;
    let mut bv = BufVal::new(dims.clone());
    let n: usize = dims.iter().product();
    for i in 0..n {
        bv.data[i] = Some(zero.clone());
    }
    bv
}

/// Execute one **stacked launch** for a (possibly ragged) batch: each
/// request's `dim`-carrying inputs are blocked at its own trip
/// (`spec.trips[r]`) and stacked along that axis of the block grid
/// (pointer moves — payload blocks are `Arc`-shared), `spec.pads[r]`
/// zero blocks follow each request when padding to a bucket edge,
/// shared weight operands are bound once, every segment runs as a
/// single enlarged tape execution across the full worker budget, and
/// outputs are de-stacked per request at its own range. Per-request
/// `MemSim` counters come from the executor's variable-width
/// grid-slice attribution (`ExecConfig::slices`), so each response's
/// traffic is bit-identical to a sequential run of that request alone
/// at its own size. Pad slices execute for real — their traffic is in
/// the aggregate's totals — and are additionally broken out in the
/// aggregate's `padded_loaded_bytes` / `padded_stored_bytes` /
/// `padded_flops`, so `agg.loaded_bytes == Σ per-request loaded_bytes
/// + agg.padded_loaded_bytes` (and likewise for stores and flops).
///
/// Caller contract: `stacked` was bound at `spec.total_trip()`, every
/// `spec.trips[r] >= 1`, and shared operands are bit-identical across
/// the batch.
pub fn execute_prepared_stacked_spec(
    prepared: &PreparedPlan,
    stacked: &StackedPlan,
    spec: &StackSpec,
    inputs: &[&HashMap<String, Mat>],
    threads: Option<usize>,
) -> BatchRun {
    execute_prepared_stacked_extra(prepared, stacked, spec, inputs, &HashMap::new(), threads)
}

/// [`execute_prepared_stacked_spec`] plus `extra`: shared operands
/// resolved from a side map when absent from the per-request inputs.
/// The serving layer binds session-owned KV caches here — state inputs
/// never travel in the request, the session's cache prefix is bound
/// once for the whole launch, exactly like a shared weight. Lookup
/// order is request 0 first, then `extra`, so a request-supplied copy
/// (the stateless differential tests) still wins.
pub fn execute_prepared_stacked_extra(
    prepared: &PreparedPlan,
    stacked: &StackedPlan,
    spec: &StackSpec,
    inputs: &[&HashMap<String, Mat>],
    extra: &HashMap<String, Mat>,
    threads: Option<usize>,
) -> BatchRun {
    let b = spec.trips.len();
    assert_eq!(
        inputs.len(),
        b,
        "stacked execution: {} request(s) for a {b}-slice spec",
        inputs.len()
    );
    assert_eq!(spec.pads.len(), b, "stack spec: trips/pads length mismatch");
    assert!(
        spec.trips.iter().all(|&t| t >= 1),
        "stack spec: every request needs at least one block"
    );
    assert_eq!(
        spec.total_trip(),
        stacked.total_trip,
        "stack spec totals {} blocks but the bind is sized for {}",
        spec.total_trip(),
        stacked.total_trip
    );
    let dim = &stacked.info.dim;
    let widths = spec.widths();
    let mut inter: HashMap<(usize, String), BufVal> = HashMap::new();
    let mut agg = MemSim::default();
    let mut outs: Vec<HashMap<String, Mat>> = (0..b).map(|_| HashMap::new()).collect();
    let mut mems: Vec<MemSim> = vec![MemSim::default(); b];
    let mut per_seg: Vec<Vec<MemSim>> = (0..b).map(|_| Vec::new()).collect();
    // request r's blocks start at offsets[r] along the stack axis
    let mut offsets = Vec::with_capacity(b);
    let mut at = 0usize;
    for r in 0..b {
        offsets.push(at);
        at += spec.trips[r] + spec.pads[r];
    }

    for (si, seg) in prepared.segments.iter().enumerate() {
        let mut cfg = ExecConfig::new(stacked.sizes.clone());
        cfg.params = prepared.params.clone();
        cfg.threads = threads;
        cfg.slices = Some(widths.clone());
        for decl in &seg.ir.bufs {
            if !decl.is_input {
                continue;
            }
            let (_, vref) = seg
                .inputs
                .iter()
                .find(|(l, _)| *l == decl.name)
                .unwrap_or_else(|| panic!("segment {si}: no wiring for input {}", decl.name));
            let bv = match vref {
                ValueRef::ProgramInput(name) => {
                    assert_eq!(decl.dims.len(), 2, "program input {name} must be 2-d");
                    // non-stack block counts come from the *bind's*
                    // sizes (the plan's own sizes plus any
                    // `bind_stacked_sized` overrides — identical for
                    // ordinary binds); the stack axis carries each
                    // request's trip
                    let rb = stacked.sizes.get(&decl.dims[0]);
                    let cb = stacked.sizes.get(&decl.dims[1]);
                    match decl.dims.iter().position(|d| d == dim) {
                        Some(axis) => {
                            let mut parts: Vec<BufVal> = Vec::with_capacity(2 * b);
                            for (r, req) in inputs.iter().enumerate() {
                                let m = req.get(name).unwrap_or_else(|| {
                                    panic!("missing program input {name}")
                                });
                                let (rbk, cbk) = if axis == 0 {
                                    (spec.trips[r], cb)
                                } else {
                                    (rb, spec.trips[r])
                                };
                                let part = to_blocks(m, rbk, cbk);
                                if spec.pads[r] > 0 {
                                    let pad = pad_blocks(&part, axis, spec.pads[r]);
                                    parts.push(part);
                                    parts.push(pad);
                                } else {
                                    parts.push(part);
                                }
                            }
                            stack_blocks_ragged(&parts, axis)
                        }
                        None => {
                            // shared operand: bind request 0's copy —
                            // or the `extra` side map's (session KV
                            // caches) — for every slice (caller
                            // verified bit-equality across the batch)
                            let m = inputs[0]
                                .get(name)
                                .or_else(|| extra.get(name))
                                .unwrap_or_else(|| panic!("missing program input {name}"));
                            to_blocks(m, rb, cb)
                        }
                    }
                }
                ValueRef::SegmentOutput { segment, label } => inter
                    .get(&(*segment, label.clone()))
                    .unwrap_or_else(|| panic!("segment {si}: missing intermediate {label}"))
                    .clone(),
            };
            cfg.inputs.insert(decl.name.clone(), bv);
        }
        let res = match &stacked.tapes[si] {
            Some(prog) => crate::exec::engine::exec_compiled(prog, &cfg),
            None => exec_ir(&seg.ir, &cfg, ExecBackend::Interp),
        };
        assert_eq!(
            res.per_slice.len(),
            2 * b,
            "executor must attribute {} slices",
            2 * b
        );
        agg.add_counters(&res.mem);
        for r in 0..b {
            mems[r].add_counters(&res.per_slice[2 * r]);
            per_seg[r].push(res.per_slice[2 * r].clone());
            // pad slice: traffic is already in the aggregate totals;
            // break it out so callers can reconcile request counters
            // against the launch
            let pad = &res.per_slice[2 * r + 1];
            agg.padded_loaded_bytes += pad.loaded_bytes;
            agg.padded_stored_bytes += pad.stored_bytes;
            agg.padded_flops += pad.flops;
        }
        for (label, prog_out) in &seg.outputs {
            let bv = res.outputs.get(label).unwrap_or_else(|| {
                panic!("segment {si}: executor produced no output {label}")
            });
            if let Some(name) = prog_out {
                let decl = seg
                    .ir
                    .bufs
                    .iter()
                    .find(|bd| bd.name == *label)
                    .expect("output buffer is declared");
                let axis = decl
                    .dims
                    .iter()
                    .position(|d| d == dim)
                    .unwrap_or_else(|| panic!("stacked output {label} does not carry {dim}"));
                for (r, o) in outs.iter_mut().enumerate() {
                    o.insert(
                        name.clone(),
                        from_blocks(&unstack_blocks_range(bv, axis, offsets[r], spec.trips[r])),
                    );
                }
            }
            inter.insert((si, label.clone()), bv.clone());
        }
    }

    let runs = outs
        .into_iter()
        .zip(mems)
        .zip(per_seg)
        .map(|((outputs, mem), per_segment)| PlanRun {
            outputs,
            mem,
            per_segment,
        })
        .collect();
    BatchRun { runs, agg }
}

/// Human-readable report of a compiled plan.
pub fn plan_report(c: &Compiled) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    let _ = writeln!(
        s,
        "plan: {} segment(s), total model cost {:.0}",
        c.plan.segments.len(),
        c.plan.total_cost
    );
    for (i, seg) in c.plan.segments.iter().enumerate() {
        let _ = writeln!(
            s,
            "  segment {i}: {} op(s), snapshot {}, cost {:.0}, maps at top {}",
            seg.node_ids.len(),
            seg.snapshot_index,
            seg.cost_scalar,
            crate::rules::map_ids(&seg.graph).len()
        );
        for (label, vr) in &seg.inputs {
            let _ = writeln!(s, "    in  {label} <- {vr:?}");
        }
        for (label, po) in &seg.outputs {
            let _ = writeln!(s, "    out {label} -> {po:?}");
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::programs;
    use crate::exec::reference;
    use crate::tensor::Rng;

    #[test]
    fn compile_and_execute_attention_plan() {
        let (p, cfg, params, inputs) = workloads::attention_demo(42);
        let compiled = compile(&p, cfg.clone());
        let run = execute_plan(&compiled.plan, &cfg.sizes, &params, &inputs);
        let want = reference::attention_ref(
            &inputs["Q"],
            &inputs["KT"],
            &inputs["VT"],
            params["DD"],
        );
        assert!(run.outputs["O"].max_abs_diff(&want) < 5e-4);
        // the plan must beat the naive (fully unfused) execution
        let naive = crate::exec::run(
            &compiled.block,
            &crate::exec::Workload {
                sizes: cfg.sizes.clone(),
                params: params.clone(),
                inputs: inputs.clone(),
                local_capacity: None,
                threads: None,
            },
        );
        assert!(run.mem.total_traffic() < naive.mem.total_traffic());
        assert!(run.mem.kernel_launches < naive.mem.kernel_launches);
    }

    /// All three executor backends must agree bit-for-bit segment by
    /// segment.
    #[test]
    fn plan_backends_agree_bitwise() {
        let (p, cfg, params, inputs) = workloads::attention_demo(42);
        let compiled = compile(&p, cfg.clone());
        let a = execute_plan_with(
            &compiled.plan,
            &cfg.sizes,
            &params,
            &inputs,
            ExecBackend::Interp,
        );
        for backend in [ExecBackend::Compiled, ExecBackend::Specialized] {
            let b = execute_plan_with(&compiled.plan, &cfg.sizes, &params, &inputs, backend);
            for (name, m) in &a.outputs {
                assert_eq!(
                    m,
                    &b.outputs[name],
                    "output {name} differs on {}",
                    backend.name()
                );
            }
            assert_eq!(a.mem.loaded_bytes, b.mem.loaded_bytes, "{}", backend.name());
            assert_eq!(a.mem.stored_bytes, b.mem.stored_bytes, "{}", backend.name());
            assert_eq!(
                a.mem.kernel_launches,
                b.mem.kernel_launches,
                "{}",
                backend.name()
            );
            assert_eq!(a.mem.flops, b.mem.flops, "{}", backend.name());
        }
    }

    /// Compile-once path: `prepare_plan` + `execute_prepared` must be
    /// bit-identical to the one-shot `execute_plan_opts` on both
    /// backends, repeated executions must stay bit-identical, and a
    /// second prepare of the same plan must be served from the cache.
    #[test]
    fn prepared_plan_matches_one_shot_and_caches() {
        let (p, cfg, params, inputs) = workloads::attention_demo(42);
        let compiled = compile(&p, cfg.clone());
        for backend in [
            ExecBackend::Interp,
            ExecBackend::Compiled,
            ExecBackend::Specialized,
        ] {
            let mut cache = TapeCache::new();
            let prepared = prepare_plan(&compiled.plan, &cfg.sizes, &params, backend, &mut cache);
            assert_eq!(
                prepared.binds,
                if backend != ExecBackend::Interp {
                    compiled.plan.segments.len() as u64
                } else {
                    0
                }
            );
            match backend {
                ExecBackend::Specialized => {
                    let (fused, total) = prepared.spec_coverage().expect("coverage recorded");
                    assert!(fused >= 1, "attention must fuse at least one nest");
                    assert!(fused <= total);
                }
                _ => assert_eq!(prepared.spec_coverage(), None),
            }
            let one_shot =
                execute_plan_opts(&compiled.plan, &cfg.sizes, &params, &inputs, backend, Some(2));
            let a = execute_prepared(&prepared, &inputs, Some(2));
            let b = execute_prepared(&prepared, &inputs, Some(2));
            // traffic counters, minus the peak estimate (the one field
            // the engine does not pin across worker fan-outs)
            let counters = |r: &PlanRun| {
                (
                    r.mem.loaded_bytes,
                    r.mem.stored_bytes,
                    r.mem.n_loads,
                    r.mem.n_stores,
                    r.mem.kernel_launches,
                    r.mem.flops,
                )
            };
            for (name, m) in &one_shot.outputs {
                assert_eq!(m, &a.outputs[name], "{} output {name}", backend.name());
                assert_eq!(m, &b.outputs[name], "{} re-run {name}", backend.name());
            }
            assert_eq!(counters(&one_shot), counters(&a));
            assert_eq!(counters(&one_shot), counters(&b));
            let misses = cache.misses;
            let again = prepare_plan(&compiled.plan, &cfg.sizes, &params, backend, &mut cache);
            assert_eq!(cache.misses, misses, "re-prepare must hit the cache");
            let c = execute_prepared(&again, &inputs, Some(2));
            assert_eq!(counters(&one_shot), counters(&c));
        }
    }

    /// The coalescing tentpole's core contract: a stacked batch of 3
    /// requests (fresh activations, shared weights) must be
    /// bit-identical **per request** — outputs and traffic counters —
    /// to sequential `execute_prepared` runs, on both backends, while
    /// the aggregate launch count stays that of ONE request.
    #[test]
    fn stacked_batch_matches_sequential_per_request() {
        let (p, cfg, params, base_inputs) = workloads::attention_demo(42);
        let compiled = compile(&p, cfg.clone());
        for backend in [
            ExecBackend::Interp,
            ExecBackend::Compiled,
            ExecBackend::Specialized,
        ] {
            let mut cache = TapeCache::new();
            let prepared = prepare_plan(&compiled.plan, &cfg.sizes, &params, backend, &mut cache);
            let info =
                plan_stack_info(&prepared).expect("attention stacks along its row-block grid");
            assert_eq!(info.dim.name(), "M");
            assert_eq!(info.trip, 4);
            let shared = unstacked_inputs(&prepared, &info);
            assert!(
                shared.contains("KT") && shared.contains("VT"),
                "weights are shared operands: {shared:?}"
            );
            assert!(!shared.contains("Q"), "activations stack: {shared:?}");

            // 3 requests: same KT/VT, fresh Q per request
            let mut rng = Rng::new(99);
            let reqs: Vec<HashMap<String, Mat>> = (0..3)
                .map(|_| {
                    let mut m = base_inputs.clone();
                    let q = &base_inputs["Q"];
                    m.insert("Q".into(), rng.mat(q.rows, q.cols));
                    m
                })
                .collect();
            let misses = cache.misses;
            let sp = bind_stacked(&prepared, &info, 3);
            assert_eq!(cache.misses, misses, "stacked bind must not compile");
            let refs: Vec<&HashMap<String, Mat>> = reqs.iter().collect();
            let br = execute_prepared_stacked(&prepared, &sp, &refs, Some(2));
            assert_eq!(br.runs.len(), 3);
            let mut per_req_launches = 0;
            for (r, run) in br.runs.iter().enumerate() {
                let seq = execute_prepared(&prepared, &reqs[r], Some(2));
                for (name, m) in &seq.outputs {
                    assert_eq!(
                        m,
                        &run.outputs[name],
                        "{} request {r} output {name}",
                        backend.name()
                    );
                }
                assert_eq!(run.mem.loaded_bytes, seq.mem.loaded_bytes, "request {r}");
                assert_eq!(run.mem.stored_bytes, seq.mem.stored_bytes, "request {r}");
                assert_eq!(run.mem.n_loads, seq.mem.n_loads, "request {r}");
                assert_eq!(run.mem.n_stores, seq.mem.n_stores, "request {r}");
                assert_eq!(run.mem.flops, seq.mem.flops, "request {r}");
                assert_eq!(
                    run.mem.kernel_launches, seq.mem.kernel_launches,
                    "request {r}"
                );
                assert_eq!(run.per_segment.len(), seq.per_segment.len());
                per_req_launches = seq.mem.kernel_launches;
            }
            // the coalescing win: the stacked launch performed ONE
            // request's worth of kernel launches for the whole batch
            assert_eq!(br.agg.kernel_launches, per_req_launches);
            assert_eq!(
                br.agg.flops,
                br.runs.iter().map(|r| r.mem.flops).sum::<u64>(),
                "aggregate flops are the batch total"
            );
        }
    }

    /// Ragged generalisation of the stacked-batch contract: requests
    /// whose `M` trips differ (1/4/2/3 row blocks) share one stacked
    /// launch, each padded to its power-of-two bucket edge. Every
    /// request's outputs and traffic counters must be bit-identical to
    /// a sequential one-shot run **at its own size**, per-request
    /// counters never see pad traffic, and the aggregate reconciles
    /// exactly: launch totals == Σ per-request + `padded_*`.
    #[test]
    fn ragged_stacked_batch_matches_sequential_per_request() {
        let (p, cfg, params, base_inputs) = workloads::attention_demo(42);
        let compiled = compile(&p, cfg.clone());
        let trips = [1usize, 4, 2, 3];
        let pads = [1usize, 0, 2, 1]; // next power of two minus trip
        for backend in [
            ExecBackend::Interp,
            ExecBackend::Compiled,
            ExecBackend::Specialized,
        ] {
            let mut cache = TapeCache::new();
            let prepared = prepare_plan(&compiled.plan, &cfg.sizes, &params, backend, &mut cache);
            let info =
                plan_stack_info(&prepared).expect("attention stacks along its row-block grid");
            assert_eq!(info.trip, 4);
            let axes = stacked_input_axes(&prepared, &info);
            assert_eq!(axes.get("Q"), Some(&0), "Q stacks along rows: {axes:?}");
            assert!(!axes.contains_key("KT"), "weights carry no stack dim");

            // one request per trip: fresh Q at k row blocks, shared KT/VT
            let q0 = &base_inputs["Q"];
            let h = q0.rows / info.trip;
            let mut rng = Rng::new(7);
            let reqs: Vec<HashMap<String, Mat>> = trips
                .iter()
                .map(|&k| {
                    let mut m = base_inputs.clone();
                    m.insert("Q".into(), rng.mat(k * h, q0.cols));
                    m
                })
                .collect();
            let spec = StackSpec {
                trips: trips.to_vec(),
                pads: pads.to_vec(),
            };
            assert_eq!(spec.total_trip(), 14);
            let misses = cache.misses;
            let stacked = bind_stacked_trip(&prepared, &info, spec.total_trip());
            assert_eq!(cache.misses, misses, "ragged bind must not compile");
            let refs: Vec<&HashMap<String, Mat>> = reqs.iter().collect();
            let br = execute_prepared_stacked_spec(&prepared, &stacked, &spec, &refs, Some(2));
            assert_eq!(br.runs.len(), trips.len());

            for (r, run) in br.runs.iter().enumerate() {
                let mut sizes_k = cfg.sizes.clone();
                sizes_k.set(info.dim.clone(), trips[r]);
                let seq = execute_plan_opts(
                    &compiled.plan,
                    &sizes_k,
                    &params,
                    &reqs[r],
                    backend,
                    Some(2),
                );
                for (name, m) in &seq.outputs {
                    assert_eq!(
                        m,
                        &run.outputs[name],
                        "{} request {r} output {name}",
                        backend.name()
                    );
                }
                assert_eq!(run.mem.loaded_bytes, seq.mem.loaded_bytes, "request {r}");
                assert_eq!(run.mem.stored_bytes, seq.mem.stored_bytes, "request {r}");
                assert_eq!(run.mem.n_loads, seq.mem.n_loads, "request {r}");
                assert_eq!(run.mem.n_stores, seq.mem.n_stores, "request {r}");
                assert_eq!(run.mem.flops, seq.mem.flops, "request {r}");
                assert_eq!(
                    run.mem.kernel_launches, seq.mem.kernel_launches,
                    "request {r}"
                );
                // pad traffic never leaks into a request's own counters
                assert_eq!(run.mem.padded_loaded_bytes, 0, "request {r}");
                assert_eq!(run.mem.padded_stored_bytes, 0, "request {r}");
                assert_eq!(run.mem.padded_flops, 0, "request {r}");
            }
            // pad waste is real traffic in the aggregate, broken out
            // exactly: totals == Σ per-request + padded_*
            assert!(br.agg.padded_flops > 0, "pads executed");
            assert_eq!(
                br.agg.loaded_bytes,
                br.runs.iter().map(|r| r.mem.loaded_bytes).sum::<u64>()
                    + br.agg.padded_loaded_bytes
            );
            assert_eq!(
                br.agg.stored_bytes,
                br.runs.iter().map(|r| r.mem.stored_bytes).sum::<u64>()
                    + br.agg.padded_stored_bytes
            );
            assert_eq!(
                br.agg.flops,
                br.runs.iter().map(|r| r.mem.flops).sum::<u64>() + br.agg.padded_flops
            );
            // still one request's worth of kernel launches for the batch
            assert_eq!(br.agg.kernel_launches, br.runs[0].mem.kernel_launches);
        }
    }

    /// Every canonical serving workload must expose a stackable grid dim
    /// (the serving layer's coalescing relies on it) — and the stack dim
    /// is always the row-block grid `M`.
    #[test]
    fn canonical_workloads_are_stackable() {
        for name in workloads::NAMES {
            let (p, cfg, params, _) = workloads::by_name(name, 0).unwrap();
            let compiled = compile(&p, cfg.clone());
            let mut cache = TapeCache::new();
            let prepared = prepare_plan(
                &compiled.plan,
                &cfg.sizes,
                &params,
                ExecBackend::Compiled,
                &mut cache,
            );
            let info = plan_stack_info(&prepared)
                .unwrap_or_else(|| panic!("{name}: plan is not stackable"));
            assert_eq!(info.dim.name(), "M", "{name}");
        }
    }

    /// Specialization coverage floor: every canonical workload matches
    /// at least one fused nest, and flash attention's inner softmax·V
    /// nest is matched end to end (a `flash_inner` site driving a
    /// `dot_acc` child) — the pattern table covers the paper's
    /// workloads, not just toy programs.
    #[test]
    fn canonical_workloads_specialize() {
        for name in workloads::NAMES {
            let (p, cfg, params, _) = workloads::by_name(name, 0).unwrap();
            let compiled = compile(&p, cfg.clone());
            let mut cache = TapeCache::new();
            let prepared = prepare_plan(
                &compiled.plan,
                &cfg.sizes,
                &params,
                ExecBackend::Specialized,
                &mut cache,
            );
            let (fused, total) = prepared
                .spec_coverage()
                .unwrap_or_else(|| panic!("{name}: no coverage report"));
            assert!(fused >= 1, "{name}: 0/{total} nests fused");
            let kernels: Vec<&str> = prepared
                .segments
                .iter()
                .filter_map(|s| s.skeleton.as_ref())
                .filter_map(|sk| sk.spec.as_ref())
                .flat_map(|rep| rep.by_kernel.keys().copied())
                .collect();
            if name.contains("attention") {
                assert!(
                    kernels.contains(&"flash_inner"),
                    "{name}: inner softmax·V nest unmatched (saw {kernels:?})"
                );
            }
        }
    }

    #[test]
    fn plan_report_mentions_segments() {
        let (p, cfg, _, _) = workloads::attention_demo(1);
        let compiled = compile(&p, cfg);
        let rep = plan_report(&compiled);
        assert!(rep.contains("segment 0"));
    }

    #[test]
    fn decoder_block_plan_runs_end_to_end() {
        let (p, cfg, params, inputs) = workloads::decoder_demo(7);
        let compiled = compile(&p, cfg.clone());
        let run = execute_plan(&compiled.plan, &cfg.sizes, &params, &inputs);
        let (want_o, want_h) = reference::decoder_block_ref(
            &inputs["Q"],
            &inputs["KT"],
            &inputs["VT"],
            &inputs["R"],
            &inputs["WT"],
            &inputs["VT2"],
            &inputs["UT"],
            params["DD"],
        );
        assert!(run.outputs["H"].max_abs_diff(&want_h) < 5e-4);
        assert!(run.outputs["O"].max_abs_diff(&want_o) < 5e-3);
        let _ = programs::decoder_block(); // symmetry with workloads
        let _ = Rng::new(0);
    }
}
