//! The end-to-end compiler driver (L3).
//!
//! Pipeline: array program → Table-2 lowering → candidate selection (which
//! invokes the fusion algorithm per candidate and scores every snapshot) →
//! optional block-shape autotuning → an executable [`SelectionPlan`] whose
//! segments run back-to-back on the two-tier-memory executor, with
//! intermediates flowing between segments through (simulated) global
//! memory. The paper's contribution is the compiler, so this layer is a
//! thin deterministic driver; reports quantify what fusion bought.

pub mod workloads;

use crate::cost::CostModel;
use crate::exec::{exec_ir, from_blocks, to_blocks, ExecBackend};
use crate::ir::dim::DimSizes;
use crate::ir::graph::Graph;
use crate::loopir::interp::{BufVal, ExecConfig, MemSim};
use crate::loopir::lower::lower;
use crate::lower::lower_array;
use crate::select::{select, SelectCtx, SelectionPlan, ValueRef};
use crate::tensor::Mat;
use std::collections::{BTreeMap, HashMap};

/// Compiler configuration.
#[derive(Clone, Debug)]
pub struct CompileConfig {
    pub sizes: DimSizes,
    pub full_shapes: HashMap<String, (usize, usize)>,
    pub model: CostModel,
}

/// A compiled program: the initial block program plus the selected plan.
pub struct Compiled {
    pub block: Graph,
    pub plan: SelectionPlan,
    pub cfg: CompileConfig,
}

/// Run the full compilation pipeline.
pub fn compile(p: &crate::array::ArrayProgram, cfg: CompileConfig) -> Compiled {
    let block = lower_array(p);
    let ctx = SelectCtx {
        sizes: cfg.sizes.clone(),
        full_shapes: cfg.full_shapes.clone(),
        model: cfg.model,
    };
    let plan = select(&block, &ctx);
    Compiled { block, plan, cfg }
}

/// Result of executing a plan.
pub struct PlanRun {
    pub outputs: HashMap<String, Mat>,
    /// Aggregated two-tier traffic across all segments.
    pub mem: MemSim,
    pub per_segment: Vec<MemSim>,
}

/// Execute a selected plan segment by segment on the interpreter backend.
pub fn execute_plan(
    plan: &SelectionPlan,
    sizes: &DimSizes,
    params: &BTreeMap<String, f32>,
    inputs: &HashMap<String, Mat>,
) -> PlanRun {
    execute_plan_with(plan, sizes, params, inputs, ExecBackend::Interp)
}

/// Execute a selected plan segment by segment, passing intermediates
/// through (simulated) global memory, on the chosen [`ExecBackend`].
pub fn execute_plan_with(
    plan: &SelectionPlan,
    sizes: &DimSizes,
    params: &BTreeMap<String, f32>,
    inputs: &HashMap<String, Mat>,
    backend: ExecBackend,
) -> PlanRun {
    execute_plan_opts(plan, sizes, params, inputs, backend, None)
}

/// [`execute_plan_with`] plus a worker cap for the compiled engine's
/// parallel grid loops (the CLI's `--threads`).
pub fn execute_plan_opts(
    plan: &SelectionPlan,
    sizes: &DimSizes,
    params: &BTreeMap<String, f32>,
    inputs: &HashMap<String, Mat>,
    backend: ExecBackend,
    threads: Option<usize>,
) -> PlanRun {
    let mut inter: HashMap<(usize, String), BufVal> = HashMap::new();
    let mut outputs = HashMap::new();
    let mut total = MemSim::default();
    let mut per_segment = Vec::new();

    for (si, seg) in plan.segments.iter().enumerate() {
        let ir = lower(&seg.graph);
        let mut cfg = ExecConfig::new(sizes.clone());
        cfg.params = params.clone();
        cfg.threads = threads;
        for decl in &ir.bufs {
            if !decl.is_input {
                continue;
            }
            let (_, vref) = seg
                .inputs
                .iter()
                .find(|(l, _)| *l == decl.name)
                .unwrap_or_else(|| panic!("segment {si}: no wiring for input {}", decl.name));
            let bv = match vref {
                ValueRef::ProgramInput(name) => {
                    let m = inputs
                        .get(name)
                        .unwrap_or_else(|| panic!("missing program input {name}"));
                    assert_eq!(decl.dims.len(), 2, "program input {name} must be 2-d");
                    to_blocks(m, sizes.get(&decl.dims[0]), sizes.get(&decl.dims[1]))
                }
                ValueRef::SegmentOutput { segment, label } => inter
                    .get(&(*segment, label.clone()))
                    .unwrap_or_else(|| panic!("segment {si}: missing intermediate {label}"))
                    .clone(),
            };
            cfg.inputs.insert(decl.name.clone(), bv);
        }
        let res = exec_ir(&ir, &cfg, backend);
        for (label, prog_out) in &seg.outputs {
            let bv = res.outputs.get(label).unwrap_or_else(|| {
                panic!("segment {si}: executor produced no output {label}")
            });
            if let Some(name) = prog_out {
                outputs.insert(name.clone(), from_blocks(bv));
            }
            inter.insert((si, label.clone()), bv.clone());
        }
        total.loaded_bytes += res.mem.loaded_bytes;
        total.stored_bytes += res.mem.stored_bytes;
        total.n_loads += res.mem.n_loads;
        total.n_stores += res.mem.n_stores;
        total.kernel_launches += res.mem.kernel_launches;
        total.flops += res.mem.flops;
        total.peak_local_bytes = total.peak_local_bytes.max(res.mem.peak_local_bytes);
        per_segment.push(res.mem);
    }

    PlanRun {
        outputs,
        mem: total,
        per_segment,
    }
}

/// Human-readable report of a compiled plan.
pub fn plan_report(c: &Compiled) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    let _ = writeln!(
        s,
        "plan: {} segment(s), total model cost {:.0}",
        c.plan.segments.len(),
        c.plan.total_cost
    );
    for (i, seg) in c.plan.segments.iter().enumerate() {
        let _ = writeln!(
            s,
            "  segment {i}: {} op(s), snapshot {}, cost {:.0}, maps at top {}",
            seg.node_ids.len(),
            seg.snapshot_index,
            seg.cost_scalar,
            crate::rules::map_ids(&seg.graph).len()
        );
        for (label, vr) in &seg.inputs {
            let _ = writeln!(s, "    in  {label} <- {vr:?}");
        }
        for (label, po) in &seg.outputs {
            let _ = writeln!(s, "    out {label} -> {po:?}");
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::programs;
    use crate::exec::reference;
    use crate::tensor::Rng;

    #[test]
    fn compile_and_execute_attention_plan() {
        let (p, cfg, params, inputs) = workloads::attention_demo(42);
        let compiled = compile(&p, cfg.clone());
        let run = execute_plan(&compiled.plan, &cfg.sizes, &params, &inputs);
        let want = reference::attention_ref(
            &inputs["Q"],
            &inputs["KT"],
            &inputs["VT"],
            params["DD"],
        );
        assert!(run.outputs["O"].max_abs_diff(&want) < 5e-4);
        // the plan must beat the naive (fully unfused) execution
        let naive = crate::exec::run(
            &compiled.block,
            &crate::exec::Workload {
                sizes: cfg.sizes.clone(),
                params: params.clone(),
                inputs: inputs.clone(),
                local_capacity: None,
                threads: None,
            },
        );
        assert!(run.mem.total_traffic() < naive.mem.total_traffic());
        assert!(run.mem.kernel_launches < naive.mem.kernel_launches);
    }

    /// Both executor backends must agree bit-for-bit segment by segment.
    #[test]
    fn plan_backends_agree_bitwise() {
        let (p, cfg, params, inputs) = workloads::attention_demo(42);
        let compiled = compile(&p, cfg.clone());
        let a = execute_plan_with(
            &compiled.plan,
            &cfg.sizes,
            &params,
            &inputs,
            ExecBackend::Interp,
        );
        let b = execute_plan_with(
            &compiled.plan,
            &cfg.sizes,
            &params,
            &inputs,
            ExecBackend::Compiled,
        );
        for (name, m) in &a.outputs {
            assert_eq!(m, &b.outputs[name], "output {name} differs across backends");
        }
        assert_eq!(a.mem.loaded_bytes, b.mem.loaded_bytes);
        assert_eq!(a.mem.stored_bytes, b.mem.stored_bytes);
        assert_eq!(a.mem.kernel_launches, b.mem.kernel_launches);
        assert_eq!(a.mem.flops, b.mem.flops);
    }

    #[test]
    fn plan_report_mentions_segments() {
        let (p, cfg, _, _) = workloads::attention_demo(1);
        let compiled = compile(&p, cfg);
        let rep = plan_report(&compiled);
        assert!(rep.contains("segment 0"));
    }

    #[test]
    fn decoder_block_plan_runs_end_to_end() {
        let (p, cfg, params, inputs) = workloads::decoder_demo(7);
        let compiled = compile(&p, cfg.clone());
        let run = execute_plan(&compiled.plan, &cfg.sizes, &params, &inputs);
        let (want_o, want_h) = reference::decoder_block_ref(
            &inputs["Q"],
            &inputs["KT"],
            &inputs["VT"],
            &inputs["R"],
            &inputs["WT"],
            &inputs["VT2"],
            &inputs["UT"],
            params["DD"],
        );
        assert!(run.outputs["H"].max_abs_diff(&want_h) < 5e-4);
        assert!(run.outputs["O"].max_abs_diff(&want_o) < 5e-3);
        let _ = programs::decoder_block(); // symmetry with workloads
        let _ = Rng::new(0);
    }
}
