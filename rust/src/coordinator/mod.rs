//! The end-to-end compiler driver (L3).
//!
//! Pipeline: array program → Table-2 lowering → candidate selection (which
//! invokes the fusion algorithm per candidate and scores every snapshot) →
//! optional block-shape autotuning → an executable [`SelectionPlan`] whose
//! segments run back-to-back on the two-tier-memory executor, with
//! intermediates flowing between segments through (simulated) global
//! memory. The paper's contribution is the compiler, so this layer is a
//! thin deterministic driver; reports quantify what fusion bought.
//!
//! Execution comes in two flavors:
//!
//! * **one-shot** — [`execute_plan`] / [`execute_plan_opts`] lower and
//!   (on the compiled backend) flatten every segment per call; right for
//!   a single run of a plan;
//! * **compile-once** — [`prepare_plan`] lowers each segment once and,
//!   on [`ExecBackend::Compiled`], binds its tape skeleton once, yielding
//!   a [`PreparedPlan`] that [`execute_prepared`] can run any number of
//!   times on fresh inputs with zero per-request compilation. This is
//!   the substrate of the serving layer ([`crate::serve`]); the one-shot
//!   entry points are a thin wrapper over it, so the two paths cannot
//!   drift apart.

pub mod workloads;

use crate::cost::CostModel;
use crate::exec::{exec_ir, from_blocks, to_blocks, ExecBackend, TapeCache};
use crate::ir::dim::DimSizes;
use crate::ir::graph::Graph;
use crate::loopir::compile::CompiledProgram;
use crate::loopir::interp::{BufVal, ExecConfig, MemSim};
use crate::loopir::lower::lower;
use crate::loopir::LoopIr;
use crate::lower::lower_array;
use crate::select::{select, SelectCtx, SelectionPlan, ValueRef};
use crate::tensor::Mat;
use std::collections::{BTreeMap, HashMap};

/// Compiler configuration.
#[derive(Clone, Debug)]
pub struct CompileConfig {
    pub sizes: DimSizes,
    pub full_shapes: HashMap<String, (usize, usize)>,
    pub model: CostModel,
}

/// A compiled program: the initial block program plus the selected plan.
pub struct Compiled {
    pub block: Graph,
    pub plan: SelectionPlan,
    pub cfg: CompileConfig,
}

/// Run the full compilation pipeline.
pub fn compile(p: &crate::array::ArrayProgram, cfg: CompileConfig) -> Compiled {
    let block = lower_array(p);
    let ctx = SelectCtx {
        sizes: cfg.sizes.clone(),
        full_shapes: cfg.full_shapes.clone(),
        model: cfg.model,
    };
    let plan = select(&block, &ctx);
    Compiled { block, plan, cfg }
}

/// Result of executing a plan.
pub struct PlanRun {
    pub outputs: HashMap<String, Mat>,
    /// Aggregated two-tier traffic across all segments.
    pub mem: MemSim,
    pub per_segment: Vec<MemSim>,
}

/// Execute a selected plan segment by segment on the interpreter backend.
pub fn execute_plan(
    plan: &SelectionPlan,
    sizes: &DimSizes,
    params: &BTreeMap<String, f32>,
    inputs: &HashMap<String, Mat>,
) -> PlanRun {
    execute_plan_with(plan, sizes, params, inputs, ExecBackend::Interp)
}

/// Execute a selected plan segment by segment, passing intermediates
/// through (simulated) global memory, on the chosen [`ExecBackend`].
pub fn execute_plan_with(
    plan: &SelectionPlan,
    sizes: &DimSizes,
    params: &BTreeMap<String, f32>,
    inputs: &HashMap<String, Mat>,
    backend: ExecBackend,
) -> PlanRun {
    execute_plan_opts(plan, sizes, params, inputs, backend, None)
}

/// [`execute_plan_with`] plus a worker cap for the compiled engine's
/// parallel grid loops (the CLI's `--threads`).
///
/// One-shot: lowers (and on the compiled backend flattens) every segment
/// on each call. Callers that execute one plan many times should
/// [`prepare_plan`] once and call [`execute_prepared`] per run instead —
/// this function is exactly that pair with a throwaway cache, so the two
/// paths are equivalent by construction.
pub fn execute_plan_opts(
    plan: &SelectionPlan,
    sizes: &DimSizes,
    params: &BTreeMap<String, f32>,
    inputs: &HashMap<String, Mat>,
    backend: ExecBackend,
    threads: Option<usize>,
) -> PlanRun {
    let mut cache = TapeCache::new();
    let prepared = prepare_plan(plan, sizes, params, backend, &mut cache);
    execute_prepared(&prepared, inputs, threads)
}

/// One segment of a [`PreparedPlan`]: the lowered Loop IR, the bound
/// instruction tape (compiled backend only), and the I/O wiring copied
/// from the source [`crate::select::Segment`].
pub struct PreparedSegment {
    /// The segment's lowered loop nest (lowering runs once, at prepare
    /// time).
    pub ir: LoopIr,
    /// `Some` iff the plan was prepared for [`ExecBackend::Compiled`]:
    /// the tape skeleton bound to the plan's `DimSizes`.
    pub tape: Option<CompiledProgram>,
    /// For each segment input label: where its value comes from.
    pub inputs: Vec<(String, ValueRef)>,
    /// For each segment output label: the program output it implements.
    pub outputs: Vec<(String, Option<String>)>,
}

/// A [`SelectionPlan`] made ready for compile-once/execute-many use:
/// every segment lowered once and (on the compiled backend) its tape
/// bound once. [`execute_prepared`] runs it on fresh inputs with zero
/// per-request compilation — the serving layer's hot path.
pub struct PreparedPlan {
    pub backend: ExecBackend,
    pub sizes: DimSizes,
    pub params: BTreeMap<String, f32>,
    pub segments: Vec<PreparedSegment>,
    /// Tape binds performed while preparing (== segment count on the
    /// compiled backend, 0 on the interpreter) — compile-once telemetry.
    pub binds: u64,
}

/// Lower every segment of `plan` and, on [`ExecBackend::Compiled`], pull
/// its tape skeleton from `cache` (compiling it on first sight) and bind
/// it to `sizes`. All per-structure work happens here, once; the returned
/// [`PreparedPlan`] is immutable and shareable across any number of
/// [`execute_prepared`] calls (it is `Sync` — the serving layer fans
/// batches of requests over it from worker threads).
pub fn prepare_plan(
    plan: &SelectionPlan,
    sizes: &DimSizes,
    params: &BTreeMap<String, f32>,
    backend: ExecBackend,
    cache: &mut TapeCache,
) -> PreparedPlan {
    let mut segments = Vec::with_capacity(plan.segments.len());
    let mut binds = 0u64;
    for seg in &plan.segments {
        let ir = lower(&seg.graph);
        let tape = match backend {
            ExecBackend::Interp => None,
            ExecBackend::Compiled => {
                // The skeleton depends on params and misc registries but
                // never on `DimSizes`; the bind is the cheap phase.
                let mut cfg = ExecConfig::new(sizes.clone());
                cfg.params = params.clone();
                let skel = cache.skeleton(&ir, &cfg, backend);
                binds += 1;
                Some(skel.bind(sizes))
            }
        };
        segments.push(PreparedSegment {
            ir,
            tape,
            inputs: seg.inputs.clone(),
            outputs: seg.outputs.clone(),
        });
    }
    PreparedPlan {
        backend,
        sizes: sizes.clone(),
        params: params.clone(),
        segments,
        binds,
    }
}

/// Execute a [`PreparedPlan`] on fresh inputs: segment by segment,
/// intermediates flowing through (simulated) global memory — identical
/// semantics (outputs and traffic counters) to [`execute_plan_opts`] on
/// the same plan, but with no lowering or tape compilation on the hot
/// path. `threads` caps the compiled engine's parallel grid loops.
pub fn execute_prepared(
    prepared: &PreparedPlan,
    inputs: &HashMap<String, Mat>,
    threads: Option<usize>,
) -> PlanRun {
    let sizes = &prepared.sizes;
    let mut inter: HashMap<(usize, String), BufVal> = HashMap::new();
    let mut outputs = HashMap::new();
    let mut total = MemSim::default();
    let mut per_segment = Vec::new();

    for (si, seg) in prepared.segments.iter().enumerate() {
        let mut cfg = ExecConfig::new(sizes.clone());
        cfg.params = prepared.params.clone();
        cfg.threads = threads;
        for decl in &seg.ir.bufs {
            if !decl.is_input {
                continue;
            }
            let (_, vref) = seg
                .inputs
                .iter()
                .find(|(l, _)| *l == decl.name)
                .unwrap_or_else(|| panic!("segment {si}: no wiring for input {}", decl.name));
            let bv = match vref {
                ValueRef::ProgramInput(name) => {
                    let m = inputs
                        .get(name)
                        .unwrap_or_else(|| panic!("missing program input {name}"));
                    assert_eq!(decl.dims.len(), 2, "program input {name} must be 2-d");
                    to_blocks(m, sizes.get(&decl.dims[0]), sizes.get(&decl.dims[1]))
                }
                ValueRef::SegmentOutput { segment, label } => inter
                    .get(&(*segment, label.clone()))
                    .unwrap_or_else(|| panic!("segment {si}: missing intermediate {label}"))
                    .clone(),
            };
            cfg.inputs.insert(decl.name.clone(), bv);
        }
        let res = match &seg.tape {
            Some(prog) => crate::exec::engine::exec_compiled(prog, &cfg),
            None => exec_ir(&seg.ir, &cfg, ExecBackend::Interp),
        };
        for (label, prog_out) in &seg.outputs {
            let bv = res.outputs.get(label).unwrap_or_else(|| {
                panic!("segment {si}: executor produced no output {label}")
            });
            if let Some(name) = prog_out {
                outputs.insert(name.clone(), from_blocks(bv));
            }
            inter.insert((si, label.clone()), bv.clone());
        }
        total.loaded_bytes += res.mem.loaded_bytes;
        total.stored_bytes += res.mem.stored_bytes;
        total.n_loads += res.mem.n_loads;
        total.n_stores += res.mem.n_stores;
        total.kernel_launches += res.mem.kernel_launches;
        total.flops += res.mem.flops;
        total.peak_local_bytes = total.peak_local_bytes.max(res.mem.peak_local_bytes);
        per_segment.push(res.mem);
    }

    PlanRun {
        outputs,
        mem: total,
        per_segment,
    }
}

/// Human-readable report of a compiled plan.
pub fn plan_report(c: &Compiled) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    let _ = writeln!(
        s,
        "plan: {} segment(s), total model cost {:.0}",
        c.plan.segments.len(),
        c.plan.total_cost
    );
    for (i, seg) in c.plan.segments.iter().enumerate() {
        let _ = writeln!(
            s,
            "  segment {i}: {} op(s), snapshot {}, cost {:.0}, maps at top {}",
            seg.node_ids.len(),
            seg.snapshot_index,
            seg.cost_scalar,
            crate::rules::map_ids(&seg.graph).len()
        );
        for (label, vr) in &seg.inputs {
            let _ = writeln!(s, "    in  {label} <- {vr:?}");
        }
        for (label, po) in &seg.outputs {
            let _ = writeln!(s, "    out {label} -> {po:?}");
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::programs;
    use crate::exec::reference;
    use crate::tensor::Rng;

    #[test]
    fn compile_and_execute_attention_plan() {
        let (p, cfg, params, inputs) = workloads::attention_demo(42);
        let compiled = compile(&p, cfg.clone());
        let run = execute_plan(&compiled.plan, &cfg.sizes, &params, &inputs);
        let want = reference::attention_ref(
            &inputs["Q"],
            &inputs["KT"],
            &inputs["VT"],
            params["DD"],
        );
        assert!(run.outputs["O"].max_abs_diff(&want) < 5e-4);
        // the plan must beat the naive (fully unfused) execution
        let naive = crate::exec::run(
            &compiled.block,
            &crate::exec::Workload {
                sizes: cfg.sizes.clone(),
                params: params.clone(),
                inputs: inputs.clone(),
                local_capacity: None,
                threads: None,
            },
        );
        assert!(run.mem.total_traffic() < naive.mem.total_traffic());
        assert!(run.mem.kernel_launches < naive.mem.kernel_launches);
    }

    /// Both executor backends must agree bit-for-bit segment by segment.
    #[test]
    fn plan_backends_agree_bitwise() {
        let (p, cfg, params, inputs) = workloads::attention_demo(42);
        let compiled = compile(&p, cfg.clone());
        let a = execute_plan_with(
            &compiled.plan,
            &cfg.sizes,
            &params,
            &inputs,
            ExecBackend::Interp,
        );
        let b = execute_plan_with(
            &compiled.plan,
            &cfg.sizes,
            &params,
            &inputs,
            ExecBackend::Compiled,
        );
        for (name, m) in &a.outputs {
            assert_eq!(m, &b.outputs[name], "output {name} differs across backends");
        }
        assert_eq!(a.mem.loaded_bytes, b.mem.loaded_bytes);
        assert_eq!(a.mem.stored_bytes, b.mem.stored_bytes);
        assert_eq!(a.mem.kernel_launches, b.mem.kernel_launches);
        assert_eq!(a.mem.flops, b.mem.flops);
    }

    /// Compile-once path: `prepare_plan` + `execute_prepared` must be
    /// bit-identical to the one-shot `execute_plan_opts` on both
    /// backends, repeated executions must stay bit-identical, and a
    /// second prepare of the same plan must be served from the cache.
    #[test]
    fn prepared_plan_matches_one_shot_and_caches() {
        let (p, cfg, params, inputs) = workloads::attention_demo(42);
        let compiled = compile(&p, cfg.clone());
        for backend in [ExecBackend::Interp, ExecBackend::Compiled] {
            let mut cache = TapeCache::new();
            let prepared = prepare_plan(&compiled.plan, &cfg.sizes, &params, backend, &mut cache);
            assert_eq!(
                prepared.binds,
                if backend == ExecBackend::Compiled {
                    compiled.plan.segments.len() as u64
                } else {
                    0
                }
            );
            let one_shot =
                execute_plan_opts(&compiled.plan, &cfg.sizes, &params, &inputs, backend, Some(2));
            let a = execute_prepared(&prepared, &inputs, Some(2));
            let b = execute_prepared(&prepared, &inputs, Some(2));
            // traffic counters, minus the peak estimate (the one field
            // the engine does not pin across worker fan-outs)
            let counters = |r: &PlanRun| {
                (
                    r.mem.loaded_bytes,
                    r.mem.stored_bytes,
                    r.mem.n_loads,
                    r.mem.n_stores,
                    r.mem.kernel_launches,
                    r.mem.flops,
                )
            };
            for (name, m) in &one_shot.outputs {
                assert_eq!(m, &a.outputs[name], "{} output {name}", backend.name());
                assert_eq!(m, &b.outputs[name], "{} re-run {name}", backend.name());
            }
            assert_eq!(counters(&one_shot), counters(&a));
            assert_eq!(counters(&one_shot), counters(&b));
            let misses = cache.misses;
            let again = prepare_plan(&compiled.plan, &cfg.sizes, &params, backend, &mut cache);
            assert_eq!(cache.misses, misses, "re-prepare must hit the cache");
            let c = execute_prepared(&again, &inputs, Some(2));
            assert_eq!(counters(&one_shot), counters(&c));
        }
    }

    #[test]
    fn plan_report_mentions_segments() {
        let (p, cfg, _, _) = workloads::attention_demo(1);
        let compiled = compile(&p, cfg);
        let rep = plan_report(&compiled);
        assert!(rep.contains("segment 0"));
    }

    #[test]
    fn decoder_block_plan_runs_end_to_end() {
        let (p, cfg, params, inputs) = workloads::decoder_demo(7);
        let compiled = compile(&p, cfg.clone());
        let run = execute_plan(&compiled.plan, &cfg.sizes, &params, &inputs);
        let (want_o, want_h) = reference::decoder_block_ref(
            &inputs["Q"],
            &inputs["KT"],
            &inputs["VT"],
            &inputs["R"],
            &inputs["WT"],
            &inputs["VT2"],
            &inputs["UT"],
            params["DD"],
        );
        assert!(run.outputs["H"].max_abs_diff(&want_h) < 5e-4);
        assert!(run.outputs["O"].max_abs_diff(&want_o) < 5e-3);
        let _ = programs::decoder_block(); // symmetry with workloads
        let _ = Rng::new(0);
    }
}
