//! Property-testing framework (proptest is unavailable offline).
//!
//! A seeded-PRNG `forall` runner plus a random array-program generator.
//! Failures report the case seed so any run reproduces deterministically:
//! `forall` re-derives each case's seed from the base seed, so
//! `case(seed)` replays one failing input exactly.

use crate::array::{ABlocking, ArrayProgram};
use crate::ir::dim::DimSizes;
use crate::ir::expr::Expr;
use crate::tensor::{Mat, Rng};
use std::collections::{BTreeMap, HashMap};

/// Run `cases` generated checks; panic with the failing seed on error.
pub fn forall(cases: usize, base_seed: u64, check: impl Fn(u64) -> Result<(), String>) {
    let mut failures = Vec::new();
    for i in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(i as u64);
        if let Err(e) = check(seed) {
            failures.push((seed, e));
            if failures.len() >= 3 {
                break;
            }
        }
    }
    assert!(
        failures.is_empty(),
        "property failed on {} case(s); first: seed={} — {}",
        failures.len(),
        failures[0].0,
        failures[0].1
    );
}

/// The dim pool for random programs: (name, full extent, block count).
pub const DIM_POOL: &[(&str, usize, usize)] = &[
    ("M", 8, 2),
    ("K", 8, 2),
    ("N", 4, 1),
    ("P", 4, 2),
];

/// A randomly generated workload: program + sizes + full shapes + params +
/// concrete inputs.
pub struct RandomWorkload {
    pub program: ArrayProgram,
    pub sizes: DimSizes,
    pub full_shapes: HashMap<String, (usize, usize)>,
    pub params: BTreeMap<String, f32>,
    pub inputs: HashMap<String, Mat>,
}

/// Generate a random (standard-ops-only) array program of `max_ops`
/// operators over the dim pool, with every leaf value exported.
pub fn random_workload(seed: u64, max_ops: usize) -> RandomWorkload {
    let mut rng = Rng::new(seed);
    let mut p = ArrayProgram::new();
    let mut full_shapes = HashMap::new();
    let mut inputs = HashMap::new();
    let extent: HashMap<&str, usize> = DIM_POOL.iter().map(|(n, e, _)| (*n, *e)).collect();

    let fresh_input = |p: &mut ArrayProgram,
                           rng: &mut Rng,
                           rows: &str,
                           cols: &str,
                           transposed: bool,
                           full_shapes: &mut HashMap<String, (usize, usize)>,
                           inputs: &mut HashMap<String, Mat>| {
        let name = format!("IN{}", inputs.len());
        let (r, c) = (extent[rows], extent[cols]);
        full_shapes.insert(name.clone(), (r, c));
        inputs.insert(name.clone(), rng.mat(r, c));
        if transposed {
            p.input_t(&name, rows, cols)
        } else {
            p.input(&name, rows, cols)
        }
    };

    // start with one value (rows dim must differ from cols dim — nested
    // same-dim loops are not expressible)
    let dims_of = |rng: &mut Rng| {
        let r = rng.below(DIM_POOL.len());
        let mut c = rng.below(DIM_POOL.len());
        while c == r {
            c = rng.below(DIM_POOL.len());
        }
        (DIM_POOL[r].0, DIM_POOL[c].0)
    };
    let (r0, c0) = dims_of(&mut rng);
    let v0 = fresh_input(&mut p, &mut rng, r0, c0, false, &mut full_shapes, &mut inputs);
    let mut values = vec![v0];
    let mut consumed = vec![false];

    let n_ops = 1 + rng.below(max_ops);
    for _ in 0..n_ops {
        let pick = rng.below(values.len());
        let v = values[pick];
        let blocking: ABlocking = p.nodes[v].blocking.clone();
        let new = match rng.below(8) {
            0 => p.relu(v),
            1 => p.ew(
                "scaled",
                Expr::var(0).mul(Expr::cst(0.5)).add(Expr::cst(0.1)),
                v,
            ),
            2 => p.softmax(v),
            3 => p.layernorm(v),
            4 => p.rmsnorm(v),
            5 | 6 => {
                // binary elementwise with a value of the same blocking (or a
                // fresh input if none exists)
                let other = values
                    .iter()
                    .copied()
                    .filter(|&o| o != v && p.nodes[o].blocking == blocking)
                    .last()
                    .unwrap_or_else(|| {
                        fresh_input(
                            &mut p,
                            &mut rng,
                            blocking.rows.name(),
                            blocking.cols.name(),
                            false,
                            &mut full_shapes,
                            &mut inputs,
                        )
                    });
                if consumed.len() < values.len() {
                    consumed.resize(values.len(), false);
                }
                if rng.below(2) == 0 {
                    p.add(v, other)
                } else {
                    p.hadamard(v, other)
                }
            }
            _ => {
                // matmul with a fresh transposed weight; the output dim must
                // differ from the left operand's row dim
                let n = loop {
                    let (n, ..) = DIM_POOL[rng.below(DIM_POOL.len())];
                    if n != blocking.rows.name() && n != blocking.cols.name() {
                        break n;
                    }
                };
                let bt = fresh_input(
                    &mut p,
                    &mut rng,
                    n,
                    blocking.cols.name(),
                    true,
                    &mut full_shapes,
                    &mut inputs,
                );
                values.push(bt);
                consumed.push(true); // weights are not leaves
                p.matmul(v, bt)
            }
        };
        consumed[pick] = true;
        values.push(new);
        consumed.push(false);
    }

    // every unconsumed non-input value becomes an output (plus always the last)
    let mut any = false;
    for (i, &v) in values.iter().enumerate() {
        let is_input = matches!(p.nodes[v].op, crate::array::AOp::Input { .. });
        if !consumed[i] && !is_input {
            p.output(&format!("OUT{i}"), v);
            any = true;
        }
    }
    if !any {
        let last = *values.last().unwrap();
        p.output("OUT", last);
    }

    let mut sizes = DimSizes::new();
    let mut params = BTreeMap::new();
    for (name, ext, blocks) in DIM_POOL {
        sizes.set(*name, *blocks);
        params.insert(format!("{name}{name}"), *ext as f32);
    }
    RandomWorkload {
        program: p,
        sizes,
        full_shapes,
        params,
        inputs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::validate::validate;
    use crate::lower::lower_array;

    #[test]
    fn generator_produces_valid_programs() {
        forall(25, 7, |seed| {
            let w = random_workload(seed, 5);
            if w.program.outputs.is_empty() {
                return Err("no outputs".into());
            }
            let g = lower_array(&w.program);
            let errs = validate(&g);
            if !errs.is_empty() {
                return Err(format!("invalid lowering: {errs:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn forall_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            forall(5, 1, |s| {
                if s % 2 == 1 {
                    Err("odd".into())
                } else {
                    Ok(())
                }
            })
        });
        assert!(r.is_err());
    }
}
