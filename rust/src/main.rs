//! `blockbuster` CLI — the compiler driver.
//!
//! ```text
//! blockbuster trace <program> [--listing] [--dot]   fusion trace (+ fused code)
//! blockbuster compile <program>                     selection plan report
//! blockbuster run <program> [--seed N] [--backend interp|compiled]
//!                 [--threads N] [--no-simd]         execute plan vs naive
//! blockbuster tune <program> [--capacity BYTES]     autotune block counts
//! blockbuster xla <model> [--artifacts DIR]         run an AOT artifact (PJRT)
//! blockbuster list                                  available programs/models
//! ```
//!
//! `--threads N` caps the compiled engine's worker budget — both the
//! persistent pool behind parallel grid loops and nested fan-out
//! (default: one per available core; 1 keeps the exact serial path).
//! `--no-simd` throws the runtime kill-switch on the AVX2 kernels *and*
//! the batched elementwise expression VM's slice kernels (bit-identical
//! scalar fallbacks — a debugging/benching aid, not a correctness knob).

use blockbuster::autotune::autotune;
use blockbuster::coordinator::{compile, execute_plan_opts, plan_report, workloads};
use blockbuster::cost::CostModel;
use blockbuster::exec::{run_with, ExecBackend, Workload};
use blockbuster::fusion::fuse;
use blockbuster::ir::display::{dump, to_dot};
use blockbuster::loopir::lower::lower;
use blockbuster::loopir::print::render;
use blockbuster::lower::lower_array;
use blockbuster::tensor::{Mat, Rng};
use blockbuster::util::bench::fmt_bytes;
use blockbuster::util::cli::Args;

fn usage() -> ! {
    eprintln!(
        "usage: blockbuster <trace|compile|run|tune|xla|list> [args]\n\
         programs: {}",
        workloads::NAMES.join(", ")
    );
    std::process::exit(2);
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(
        std::env::args().skip(1),
        &["seed", "capacity", "artifacts", "backend", "threads"],
    );
    if args.flag("no-simd") {
        blockbuster::tensor::simd::set_enabled(false);
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    match cmd {
        "trace" => cmd_trace(&args),
        "compile" => cmd_compile(&args),
        "run" => cmd_run(&args),
        "tune" => cmd_tune(&args),
        "xla" => cmd_xla(&args),
        "list" => {
            println!("programs: {}", workloads::NAMES.join(", "));
            Ok(())
        }
        _ => usage(),
    }
}

fn demo_or_die(args: &Args) -> workloads::Demo {
    let name = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or_else(|| usage());
    let seed = args.opt_usize("seed", 42) as u64;
    workloads::by_name(name, seed).unwrap_or_else(|| {
        eprintln!(
            "unknown program {name}; have {}",
            workloads::NAMES.join(", ")
        );
        std::process::exit(2);
    })
}

fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    let (p, _cfg, _, _) = demo_or_die(args);
    let g = lower_array(&p);
    println!("array program:\n{p}");
    println!(
        "initial block program: {} top-level ops, {} interior buffered edges\n",
        blockbuster::rules::map_ids(&g).len(),
        g.interior_buffered_count_recursive()
    );
    let res = fuse(g);
    println!(
        "fusion trace ({} steps, {}):",
        res.trace.len(),
        res.trace.summary()
    );
    print!("{}", res.trace);
    let fused = res.snapshots.last().unwrap();
    println!(
        "\nfinal: {} snapshot(s); interior buffered edges = {}",
        res.snapshots.len(),
        fused.interior_buffered_count_recursive()
    );
    if args.flag("listing") {
        println!(
            "\nfused kernel (paper-style listing):\n{}",
            render(&lower(fused))
        );
    }
    if args.flag("dot") {
        println!("{}", to_dot(fused, "fused"));
    }
    if args.flag("dump") {
        println!("{}", dump(fused));
    }
    Ok(())
}

fn cmd_compile(args: &Args) -> anyhow::Result<()> {
    let (p, cfg, _, _) = demo_or_die(args);
    let compiled = compile(&p, cfg);
    print!("{}", plan_report(&compiled));
    Ok(())
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let backend = match args.opt("backend") {
        None => ExecBackend::default(),
        Some(s) => ExecBackend::from_name(s).unwrap_or_else(|| {
            eprintln!("unknown backend {s}; have: interp, compiled");
            std::process::exit(2);
        }),
    };
    let threads = args.opt("threads").map(|s| {
        s.parse::<usize>().unwrap_or_else(|_| {
            eprintln!("--threads expects a number, got {s}");
            std::process::exit(2);
        })
    });
    let (p, cfg, params, inputs) = demo_or_die(args);
    let compiled = compile(&p, cfg.clone());
    print!("{}", plan_report(&compiled));
    println!(
        "executor backend: {} (threads: {}, simd: {})",
        backend.name(),
        threads.map_or("auto".to_string(), |t| t.to_string()),
        if blockbuster::tensor::simd::simd_active() {
            "on"
        } else {
            "off"
        }
    );

    let naive = run_with(
        &compiled.block,
        &Workload {
            sizes: cfg.sizes.clone(),
            params: params.clone(),
            inputs: inputs.clone(),
            local_capacity: None,
            threads,
        },
        backend,
    );
    let plan = execute_plan_opts(&compiled.plan, &cfg.sizes, &params, &inputs, backend, threads);
    println!(
        "\nnaive : traffic {}  launches {}  flops {}",
        fmt_bytes(naive.mem.total_traffic()),
        naive.mem.kernel_launches,
        naive.mem.flops
    );
    println!(
        "fused : traffic {}  launches {}  flops {}",
        fmt_bytes(plan.mem.total_traffic()),
        plan.mem.kernel_launches,
        plan.mem.flops
    );
    println!(
        "reduction: {:.2}x traffic, {:.1}x launches",
        naive.mem.total_traffic() as f64 / plan.mem.total_traffic() as f64,
        naive.mem.kernel_launches as f64 / plan.mem.kernel_launches as f64
    );
    let mut names: Vec<&String> = plan.outputs.keys().collect();
    names.sort();
    for name in names {
        let d = plan.outputs[name].max_abs_diff(&naive.outputs[name]);
        println!("output {name}: max |fused - naive| = {d:.2e}");
        assert!(d < 1e-2, "numeric mismatch on {name}");
    }
    Ok(())
}

fn cmd_tune(args: &Args) -> anyhow::Result<()> {
    let (p, cfg, _, _) = demo_or_die(args);
    let capacity = args.opt_usize("capacity", 1 << 20) as u64;
    let g = lower_array(&p);
    let fused = fuse(g).snapshots.pop().unwrap();
    let res = autotune(&fused, &cfg.full_shapes, capacity, &CostModel::default());
    println!(
        "{} configurations; best under {} first:",
        res.points.len(),
        fmt_bytes(capacity)
    );
    for p in res.points.iter().take(8) {
        println!(
            "  {:?} -> traffic {} flops {} peak-local {} {}",
            p.sizes.0,
            fmt_bytes(p.cost.traffic()),
            p.cost.flops,
            fmt_bytes(p.cost.peak_local_bytes),
            if p.feasible { "" } else { "(infeasible)" }
        );
    }
    Ok(())
}

fn cmd_xla(args: &Args) -> anyhow::Result<()> {
    let model = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("attention_fused");
    let dir = args.opt("artifacts").unwrap_or("artifacts");
    let mut rt = blockbuster::runtime::Runtime::new(dir)?;
    println!("platform: {}", rt.platform());
    let info = rt.manifest.model(model)?.clone();
    let mut rng = Rng::new(args.opt_usize("seed", 42) as u64);
    let mats: Vec<Mat> = info
        .inputs
        .iter()
        .map(|(_, s)| rng.mat(s[0], s[1]))
        .collect();
    let refs: Vec<&Mat> = mats.iter().collect();
    let t0 = std::time::Instant::now();
    let out = rt.execute(model, &refs)?;
    println!(
        "{model}: {} output(s) in {:?}; out[0] is {}x{}",
        out.len(),
        t0.elapsed(),
        out[0].rows,
        out[0].cols
    );
    Ok(())
}
