//! `blockbuster` CLI — the compiler driver and model server.
//!
//! ```text
//! blockbuster trace <program> [--seed N] [--listing] [--dot] [--dump]
//! blockbuster compile <program> [--seed N]
//! blockbuster run <program> [--seed N] [--backend interp|compiled|specialized]
//!                 [--threads N] [--no-simd]
//! blockbuster tune <program> [--seed N] [--capacity BYTES]
//! blockbuster serve [--requests N] [--mix a,b:2,c] [--max-batch N]
//!                   [--max-wait-ms MS] [--coalesce]
//!                   [--ragged] [--buckets exact|pow2|max|E1,E2,..] [--pad]
//!                   [--decode] [--mix-decode] [--sessions N] [--steps N]
//!                   [--queue-cap N] [--deadline-ms MS]
//!                   [--shed-policy reject-new|drop-oldest]
//!                   [--retune-every N] [--weights a:4,b:1]
//!                   [--listen ADDR] [--serve-for-ms MS] [--max-inflight N]
//!                   [--backend interp|compiled|specialized]
//!                   [--threads N] [--seed N] [--no-simd]
//! blockbuster client [--addr HOST:PORT] [--requests N] [--mix a,b]
//!                   [--pipeline N] [--seed N] [--backoff-attempts N]
//!                   [--backoff-base-ms MS] [--backoff-cap-ms MS]
//! blockbuster xla [<model>] [--artifacts DIR] [--seed N]
//! blockbuster list
//! ```
//!
//! `trace` prints the fusion trace (plus the fused kernel listing /
//! graphviz / IR dump on request); `compile` the selection-plan report;
//! `run` executes one plan against the naive unfused baseline; `tune`
//! ranks block-count assignments under a local-memory budget; `serve`
//! runs the fault-tolerant serving daemon (channel ingest + background
//! flusher) over a mixed request stream with dynamic batching,
//! admission control, deadlines, and optional live re-tuning — over a
//! synthetic local stream by default, or over TCP with `--listen`
//! (hardened framed wire protocol, graceful drain at the end of the
//! serve window); `client` drives such a TCP daemon with pipelined
//! framed requests and reconnect-with-backoff; `xla`
//! runs an AOT artifact through PJRT;
//! `list` names the available programs. Full flag semantics are in
//! `usage()` (run with no arguments) and the README's quickstart.
//!
//! `--threads N` caps the compiled engine's worker budget — the
//! persistent pool behind parallel grid loops, nested fan-out, and
//! `serve`'s batch fan-out (default: one per available core; 1 keeps
//! the exact serial path).
//! `--no-simd` throws the runtime kill-switch on the AVX2 kernels *and*
//! the batched elementwise expression VM's slice kernels (bit-identical
//! scalar fallbacks — a debugging/benching aid, not a correctness knob).

use blockbuster::autotune::autotune;
use blockbuster::coordinator::{
    compile, execute_plan_opts, execute_prepared, plan_report, plan_stack_info, prepare_plan,
    workloads,
};
use blockbuster::cost::CostModel;
use blockbuster::exec::{run_with, ExecBackend, TapeCache, Workload};
use blockbuster::fusion::fuse;
use blockbuster::ir::display::{dump, to_dot};
use blockbuster::loopir::lower::lower;
use blockbuster::loopir::print::render;
use blockbuster::lower::lower_array;
use blockbuster::serve::daemon::{Daemon, RetuneConfig, Ticket};
use blockbuster::serve::net::client::{synthetic_request, BackoffConfig, ClientConfig, NetClient};
use blockbuster::serve::net::proto::Frame;
use blockbuster::serve::net::{NetConfig, NetServer};
use blockbuster::serve::{
    BucketLadder, ModelServer, Request, Response, ServerConfig, ShedPolicy, Verdict,
};
use blockbuster::tensor::{Mat, Rng};
use blockbuster::util::bench::{fmt_bytes, percentile, Table};
use blockbuster::util::cli::Args;
use std::collections::{HashMap, VecDeque};
use std::io::ErrorKind;
use std::time::{Duration, Instant};

fn usage() -> ! {
    eprintln!(
        "usage: blockbuster <command> [args]

commands:
  trace <program>    print the fusion trace for a program
      --seed N           input seed (default 42)
      --listing          also print the fused kernel, paper-listing style
      --dot              also print the fused graph as graphviz
      --dump             also print the raw block-program IR
  compile <program>  print the selection-plan report
      --seed N           input seed (default 42)
  run <program>      execute the selected plan vs the naive baseline
      --seed N           input seed (default 42)
      --backend B        executor backend: interp | compiled | specialized
                         (default interp; specialized = compiled tape with
                         recognized nests fused into pre-monomorphized
                         kernel bodies, dispatch resolved at bind time)
      --threads N        worker cap for parallel grid loops (default: cores)
      --no-simd          force the bit-identical scalar kernels
  tune <program>     rank block-count assignments by the static cost model
      --seed N           input seed (default 42)
      --capacity BYTES   local-memory budget (default 1048576)
  serve              run the serving daemon on a request stream
      --requests N       requests to generate (default 64)
      --mix SPEC         workload mix, name[:weight],... (default
                         quickstart,attention,rmsnorm_ffn_swiglu)
      --max-batch N      batch up to N same-program requests (default 8)
      --max-wait-ms MS   flush a partial batch after MS ms (default 2)
      --coalesce         stack a same-shape batch along the plan's row-block
                         grid into ONE tape launch (per-segment launch
                         overhead paid once per batch, not once per request;
                         falls back to per-request fan-out when a plan has no
                         stackable grid dim or batch weights differ)
      --ragged           make the synthetic stream ragged: each request draws
                         a random length (1..= the registered trip) along the
                         stackable grid dim instead of the full shape
      --buckets L        shape-bucket ladder for ragged coalescing: exact
                         (default; only same-length requests share a queue),
                         pow2, max, or explicit ascending edges like 2,4,8 —
                         requests sharing a bucket edge share stacked launches
      --pad              pad each request up to its bucket edge; pad waste is
                         charged to the explicit padded_* counters, never to
                         a request's own MemSim
      --decode           decode-only traffic: KV-cache sessions stepping the
                         stateful decode_attention workload block by block —
                         same-cache-length steps across sessions coalesce into
                         stacked launches per cache-length bucket
      --mix-decode       mixed traffic: the --mix prefill stream plus decode
                         sessions, sharing the daemon and the bucket ladder
      --sessions N       concurrent KV-cache sessions (default 4)
      --steps N          decode steps per session; bounded by the registered
                         context cap (default 4)
      --queue-cap N      admission control: bound each workload's queue at N
                         pending requests; over-cap submissions are shed with
                         a typed QueueFull rejection (default: unbounded)
      --deadline-ms MS   per-request deadline from admission; expired work is
                         shed (at admission or batch formation) instead of
                         executed (default: none)
      --shed-policy P    who pays when a queue is full: reject-new (default)
                         or drop-oldest
      --retune-every N   re-tune each workload's block shapes after every N
                         served requests and hot-swap measured winners into
                         the live plan between batches (default: off)
      --weights SPEC     scheduler weights, name:w,...: deficit round-robin
                         flush order — a weight-w workload may flush up to
                         w*max_batch requests per sweep turn (default: 1 each,
                         i.e. plain round-robin)
      --listen ADDR      serve over TCP (framed wire protocol) instead of the
                         synthetic local stream; registered workloads come
                         from --mix, traffic from connected clients
      --serve-for-ms MS  TCP serve window before the graceful drain
                         (default 5000)
      --max-inflight N   global cap on in-flight network requests; overflow
                         gets typed QueueFull rejects at the edge (default 256)
      --backend B        executor backend: interp | compiled | specialized
                         (default compiled)
      --threads N        worker cap: batch fan-out + grid loops (default: cores)
      --seed N           request-stream seed (default 42)
      --no-simd          force the bit-identical scalar kernels
      (env) BB_FAULT_RATE / BB_FAULT_SEED arm the seeded fault injector —
            injected batch panics are contained as error responses
  client             drive a TCP serving daemon (see serve --listen)
      --addr HOST:PORT   server address (default 127.0.0.1:7571)
      --requests N       requests to send (default 16)
      --mix SPEC         workload names, comma-separated (default quickstart)
      --pipeline N       max requests in flight on the connection (default 4)
      --seed N           input seed (default 42)
      --backoff-attempts N   reconnect tries per (re)connect (default 5)
      --backoff-base-ms MS   first reconnect sleep; doubles per try (default 50)
      --backoff-cap-ms MS    reconnect sleep ceiling (default 2000)
  xla [<model>]      run an AOT artifact through PJRT (default attention_fused)
      --artifacts DIR    artifact directory (default artifacts)
      --seed N           input seed (default 42)
  list               list the available programs

programs: {}",
        workloads::NAMES.join(", ")
    );
    std::process::exit(2);
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(
        std::env::args().skip(1),
        &[
            "seed",
            "capacity",
            "artifacts",
            "backend",
            "threads",
            "requests",
            "mix",
            "max-batch",
            "max-wait-ms",
            "buckets",
            "queue-cap",
            "deadline-ms",
            "shed-policy",
            "retune-every",
            "weights",
            "sessions",
            "steps",
            "listen",
            "serve-for-ms",
            "max-inflight",
            "addr",
            "pipeline",
            "backoff-attempts",
            "backoff-base-ms",
            "backoff-cap-ms",
        ],
    );
    if args.flag("no-simd") {
        blockbuster::tensor::simd::set_enabled(false);
    }
    blockbuster::util::fault::init_from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    match cmd {
        "trace" => cmd_trace(&args),
        "compile" => cmd_compile(&args),
        "run" => cmd_run(&args),
        "tune" => cmd_tune(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "xla" => cmd_xla(&args),
        "list" => {
            println!("programs: {}", workloads::NAMES.join(", "));
            Ok(())
        }
        _ => usage(),
    }
}

/// `--backend` / `--threads`, shared by `run` and `serve`.
fn backend_or_die(args: &Args, default: ExecBackend) -> ExecBackend {
    match args.opt("backend") {
        None => default,
        Some(s) => ExecBackend::from_name(s).unwrap_or_else(|| {
            eprintln!("unknown backend {s}; have: interp, compiled, specialized");
            std::process::exit(2);
        }),
    }
}

fn threads_or_die(args: &Args) -> Option<usize> {
    args.opt("threads").map(|s| {
        s.parse::<usize>().unwrap_or_else(|_| {
            eprintln!("--threads expects a number, got {s}");
            std::process::exit(2);
        })
    })
}

fn demo_or_die(args: &Args) -> workloads::Demo {
    let name = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or_else(|| usage());
    let seed = args.opt_usize("seed", 42) as u64;
    workloads::by_name(name, seed).unwrap_or_else(|| {
        eprintln!("unknown program {name}; have {}", workloads::NAMES.join(", "));
        std::process::exit(2);
    })
}

fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    let (p, _cfg, _, _) = demo_or_die(args);
    let g = lower_array(&p);
    println!("array program:\n{p}");
    println!(
        "initial block program: {} top-level ops, {} interior buffered edges\n",
        blockbuster::rules::map_ids(&g).len(),
        g.interior_buffered_count_recursive()
    );
    let res = fuse(g);
    println!("fusion trace ({} steps, {}):", res.trace.len(), res.trace.summary());
    print!("{}", res.trace);
    let fused = res.snapshots.last().unwrap();
    println!(
        "\nfinal: {} snapshot(s); interior buffered edges = {}",
        res.snapshots.len(),
        fused.interior_buffered_count_recursive()
    );
    if args.flag("listing") {
        println!("\nfused kernel (paper-style listing):\n{}", render(&lower(fused)));
    }
    if args.flag("dot") {
        println!("{}", to_dot(fused, "fused"));
    }
    if args.flag("dump") {
        println!("{}", dump(fused));
    }
    Ok(())
}

fn cmd_compile(args: &Args) -> anyhow::Result<()> {
    let (p, cfg, _, _) = demo_or_die(args);
    let compiled = compile(&p, cfg);
    print!("{}", plan_report(&compiled));
    Ok(())
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let backend = backend_or_die(args, ExecBackend::default());
    let threads = threads_or_die(args);
    let (p, cfg, params, inputs) = demo_or_die(args);
    let compiled = compile(&p, cfg.clone());
    print!("{}", plan_report(&compiled));
    println!(
        "executor backend: {} (threads: {}, simd: {})",
        backend.name(),
        threads.map_or("auto".to_string(), |t| t.to_string()),
        if blockbuster::tensor::simd::simd_active() {
            "on"
        } else {
            "off"
        }
    );

    let naive = run_with(
        &compiled.block,
        &Workload {
            sizes: cfg.sizes.clone(),
            params: params.clone(),
            inputs: inputs.clone(),
            local_capacity: None,
            threads,
        },
        backend,
    );
    let mut cache = TapeCache::new();
    let prepared = prepare_plan(&compiled.plan, &cfg.sizes, &params, backend, &mut cache);
    match prepared.spec_coverage() {
        Some((fused, total)) => println!("specialization: {fused}/{total} nests fused"),
        None => println!("specialization: off"),
    }
    let plan = execute_prepared(&prepared, &inputs, threads);
    println!(
        "\nnaive : traffic {}  launches {}  flops {}",
        fmt_bytes(naive.mem.total_traffic()),
        naive.mem.kernel_launches,
        naive.mem.flops
    );
    println!(
        "fused : traffic {}  launches {}  flops {}",
        fmt_bytes(plan.mem.total_traffic()),
        plan.mem.kernel_launches,
        plan.mem.flops
    );
    println!(
        "reduction: {:.2}x traffic, {:.1}x launches",
        naive.mem.total_traffic() as f64 / plan.mem.total_traffic() as f64,
        naive.mem.kernel_launches as f64 / plan.mem.kernel_launches as f64
    );
    let mut names: Vec<&String> = plan.outputs.keys().collect();
    names.sort();
    for name in names {
        let d = plan.outputs[name].max_abs_diff(&naive.outputs[name]);
        println!("output {name}: max |fused - naive| = {d:.2e}");
        assert!(d < 1e-2, "numeric mismatch on {name}");
    }
    Ok(())
}

fn cmd_tune(args: &Args) -> anyhow::Result<()> {
    let (p, cfg, _, _) = demo_or_die(args);
    let capacity = args.opt_usize("capacity", 1 << 20) as u64;
    let g = lower_array(&p);
    let fused = fuse(g).snapshots.pop().unwrap();
    let res = autotune(&fused, &cfg.full_shapes, capacity, &CostModel::default());
    println!("{} configurations; best under {} first:", res.points.len(), fmt_bytes(capacity));
    for p in res.points.iter().take(8) {
        println!(
            "  {:?} -> traffic {} flops {} peak-local {} {}",
            p.sizes.0,
            fmt_bytes(p.cost.traffic()),
            p.cost.flops,
            fmt_bytes(p.cost.peak_local_bytes),
            if p.feasible { "" } else { "(infeasible)" }
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let backend = backend_or_die(args, ExecBackend::Compiled);
    let threads = threads_or_die(args);
    let requests = args.opt_usize("requests", 64);
    let max_batch = args.opt_usize("max-batch", 8);
    let max_wait = Duration::from_millis(args.opt_usize("max-wait-ms", 2) as u64);
    let coalesce = args.flag("coalesce");
    let seed = args.opt_usize("seed", 42) as u64;
    let queue_cap = args.opt("queue-cap").map(|s| {
        s.parse::<usize>().unwrap_or_else(|_| {
            eprintln!("--queue-cap expects a number, got {s}");
            std::process::exit(2);
        })
    });
    let deadline = args
        .opt("deadline-ms")
        .map(|s| {
            s.parse::<u64>().unwrap_or_else(|_| {
                eprintln!("--deadline-ms expects a number, got {s}");
                std::process::exit(2);
            })
        })
        .map(Duration::from_millis);
    let shed_policy = match args.opt("shed-policy") {
        None => ShedPolicy::RejectNew,
        Some(s) => ShedPolicy::from_name(s).unwrap_or_else(|| {
            eprintln!("unknown shed policy {s}; have: reject-new, drop-oldest");
            std::process::exit(2);
        }),
    };
    let retune_every = args.opt_usize("retune-every", 0) as u64;
    let buckets = match args.opt("buckets") {
        None => BucketLadder::Exact,
        Some(s) => BucketLadder::from_name(s).unwrap_or_else(|| {
            eprintln!(
                "unknown bucket ladder {s}; have: exact, pow2, max, or ascending edges like 2,4,8"
            );
            std::process::exit(2);
        }),
    };
    let pad = args.flag("pad");
    let ragged = args.flag("ragged");
    let decode_only = args.flag("decode");
    let mix_decode = args.flag("mix-decode");
    let want_decode = decode_only || mix_decode;
    let n_sessions = args.opt_usize("sessions", 4);
    let n_steps = args.opt_usize("steps", 4);

    // --mix name[:weight],... — the traffic composition. Repeated names
    // merge their weights (so "a,a:3" weighs a at 4) instead of
    // double-registering the workload; an explicit weight 0 is a spec
    // error, not a silent "weight 1".
    let mix = args
        .opt("mix")
        .unwrap_or("quickstart,attention,rmsnorm_ffn_swiglu");
    let mut spec: Vec<(String, usize)> = Vec::new();
    for part in mix.split(',').filter(|s| !s.is_empty()) {
        let (name, weight) = match part.split_once(':') {
            Some((n, w)) => {
                let w = w.parse::<usize>().unwrap_or_else(|_| {
                    eprintln!("--mix: bad weight in {part}");
                    std::process::exit(2);
                });
                if w == 0 {
                    eprintln!("--mix: {n} has weight 0 — omit the workload instead");
                    std::process::exit(2);
                }
                (n, w)
            }
            None => (part, 1),
        };
        match spec.iter_mut().find(|(n, _)| n == name) {
            Some((_, w0)) => *w0 += weight,
            None => spec.push((name.to_string(), weight)),
        }
    }
    if spec.is_empty() {
        eprintln!("--mix named no workloads");
        std::process::exit(2);
    }
    if spec.iter().any(|(n, _)| n == "decode_attention" || n == "decode") {
        eprintln!("--mix: decode_attention is stateful; use --decode / --mix-decode instead");
        std::process::exit(2);
    }
    if want_decode && args.opt("listen").is_some() {
        eprintln!("--decode / --mix-decode drive the local synthetic stream, not --listen");
        std::process::exit(2);
    }

    let mut server = ModelServer::new(ServerConfig {
        backend,
        threads,
        max_batch,
        max_wait,
        coalesce,
        queue_cap,
        deadline,
        shed_policy,
        buckets: buckets.clone(),
        pad,
    });
    for (name, _) in &spec {
        server.register(name)?;
    }
    if want_decode {
        server.register("decode_attention")?;
    }
    // --weights name:w,... — deficit-round-robin scheduler weights
    // (distinct from --mix's traffic-composition weights).
    if let Some(wspec) = args.opt("weights") {
        for part in wspec.split(',').filter(|s| !s.is_empty()) {
            let Some((name, w)) = part.split_once(':') else {
                eprintln!("--weights expects name:weight, got {part}");
                std::process::exit(2);
            };
            let w = w.parse::<u64>().unwrap_or_else(|_| {
                eprintln!("--weights: bad weight in {part}");
                std::process::exit(2);
            });
            server.set_weight(name, w)?;
        }
        println!("fairness: deficit round-robin weights {wspec}");
    }
    println!(
        "serving {} workload(s) on backend {} (threads: {}, simd: {})",
        spec.len(),
        backend.name(),
        threads.map_or("auto".to_string(), |t| t.to_string()),
        if blockbuster::tensor::simd::simd_active() {
            "on"
        } else {
            "off"
        }
    );
    println!(
        "batching: max_batch {max_batch}, max_wait {max_wait:?}, coalesce {}, ragged {}, \
         buckets {buckets:?}, pad {}",
        if coalesce { "on" } else { "off" },
        if ragged { "on" } else { "off" },
        if pad { "on" } else { "off" }
    );
    if want_decode {
        println!(
            "decode: {n_sessions} session(s) x {n_steps} step(s) on decode_attention \
             (stateful KV cache, grown one block per step){}",
            if decode_only { "" } else { " + the prefill mix" }
        );
    }
    println!(
        "admission: queue_cap {}, deadline {}, shed_policy {:?}, retune_every {}",
        queue_cap.map_or("unbounded".to_string(), |c| c.to_string()),
        deadline.map_or("none".to_string(), |d| format!("{d:?}")),
        shed_policy,
        if retune_every == 0 {
            "off".to_string()
        } else {
            retune_every.to_string()
        }
    );
    let fault_rate = blockbuster::util::fault::rate();
    if fault_rate > 0.0 {
        println!("fault injection: armed at rate {fault_rate} (BB_FAULT_RATE)");
    }
    let retune = (retune_every > 0).then(|| RetuneConfig {
        every: retune_every,
        local_capacity: 1 << 20,
        trials: 3,
    });

    // --listen: serve over TCP for the serve window, then drain in the
    // documented order — net.begin_shutdown() so no new work is
    // admitted, daemon.shutdown() so every in-flight ticket resolves,
    // net.shutdown() so writers flush and every open connection gets a
    // Shutdown frame.
    if let Some(addr) = args.opt("listen") {
        let serve_for = Duration::from_millis(args.opt_usize("serve-for-ms", 5000) as u64);
        let net_cfg = NetConfig {
            max_inflight: args.opt_usize("max-inflight", 256),
            ..NetConfig::default()
        };
        let daemon = Daemon::start(server, retune);
        let net = NetServer::start(addr, daemon.client(), net_cfg)
            .map_err(|e| anyhow::anyhow!("cannot listen on {addr}: {e}"))?;
        println!("listening on {} (serve window {serve_for:?})", net.local_addr());
        std::thread::sleep(serve_for);
        net.begin_shutdown();
        let server = daemon.shutdown();
        let stats = net.shutdown();
        println!(
            "net ingress: {} conn(s) accepted, {} frame(s); {} request(s) = {} delivered + \
             {} disconnected; {} edge-rejected, {} malformed, {} oversized, {} idle-closed, \
             {} frame-timeout(s), {} handshake failure(s), {} shutdown frame(s)",
            stats.accepted,
            stats.frames_in,
            stats.requests_in,
            stats.delivered,
            stats.disconnected,
            stats.rejected_inflight,
            stats.malformed,
            stats.oversized,
            stats.idle_closed,
            stats.frame_timeouts,
            stats.handshake_failures,
            stats.shutdown_frames
        );
        assert!(stats.reconciles(), "net ledger must reconcile after the drain: {stats:?}");
        let sstats = server.stats();
        for (name, st) in &sstats.per_program {
            assert_eq!(st.accounted(), st.submitted, "{name}: daemon ledger must reconcile");
        }
        println!(
            "robustness: {} submitted = {} served + {} rejected/shed + {} failed",
            sstats.total_submitted(),
            sstats.total_served(),
            sstats.total_rejected(),
            sstats.total_failed()
        );
        return Ok(());
    }

    // Deterministic weighted request stream, fully generated up front so
    // the daemon sees a pure ingest workload (inputs need &server for
    // the registered shape specs, and the server moves into the daemon).
    // With --ragged, each request of a stackable workload draws a random
    // length (1..= the registered trip) along the stackable grid dim.
    let stack_trips: Vec<Option<usize>> = spec
        .iter()
        .map(|(name, _)| {
            server
                .live_plan(name)
                .and_then(|p| plan_stack_info(&p))
                .map(|i| i.trip)
        })
        .collect();
    let total_weight: usize = spec.iter().map(|(_, w)| w).sum();
    let mut lcg: u64 = seed | 1;
    let prefill_requests = if decode_only { 0 } else { requests };
    // (workload, seed, ragged trip), submission order
    let mut meta: Vec<(String, u64, Option<usize>)> = Vec::new();
    let mut stream: Vec<Request> = Vec::new();
    for i in 0..prefill_requests {
        lcg = lcg
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        let idx = {
            let mut pick = (lcg >> 33) as usize % total_weight;
            spec.iter()
                .position(|(_, w)| {
                    if pick < *w {
                        true
                    } else {
                        pick -= w;
                        false
                    }
                })
                .expect("weighted pick in range")
        };
        let name = spec[idx].0.clone();
        let req_seed = seed.wrapping_add(i as u64);
        let trip = if ragged {
            stack_trips[idx].map(|t| {
                lcg = lcg
                    .wrapping_mul(6364136223846793005)
                    .wrapping_add(1442695040888963407);
                1 + (lcg >> 33) as usize % t
            })
        } else {
            None
        };
        let inputs = match trip {
            Some(t) => server.synthetic_inputs_ragged(&name, req_seed, t)?,
            None => server.synthetic_inputs(&name, req_seed)?,
        };
        stream.push(Request::new(&name, inputs));
        meta.push((name, req_seed, trip));
    }

    // Decode traffic, also generated up front (the generator needs
    // &server). Round-major order — step t for EVERY session before any
    // step t+1 — so same-cache-length steps land in one bucket queue
    // and coalesce. All sessions share the synthetic per-step KV stream
    // (bit-identical caches), which is what a stacked launch requires.
    let session_seed = |s: usize| seed.wrapping_add(0x5e55).wrapping_add(s as u64);
    let mut decode_rounds: Vec<Vec<HashMap<String, Mat>>> = Vec::new();
    if want_decode {
        for t in 1..=n_steps {
            let mut round = Vec::with_capacity(n_sessions);
            for s in 0..n_sessions {
                round.push(server.synthetic_decode_inputs(
                    "decode_attention",
                    session_seed(s),
                    t,
                )?);
            }
            decode_rounds.push(round);
        }
    }
    // Session 0's final step, kept for the decode parity check below.
    let parity_step = decode_rounds.last().and_then(|r| r.first()).cloned();

    // Channel ingest → background flusher → worker pool; shutdown() is a
    // graceful drain that hands the server back for stats + parity.
    let daemon = Daemon::start(server, retune);
    let client = daemon.client();
    let serve_t0 = Instant::now();
    let mut session_ids: Vec<u64> = Vec::with_capacity(n_sessions);
    if want_decode {
        for _ in 0..n_sessions {
            session_ids.push(client.open_session("decode_attention")?);
        }
    }
    let tickets: Vec<Ticket> = stream.into_iter().map(|r| client.submit(r)).collect();
    // Per-session step order is admission order on the daemon channel:
    // step t+1's cache length is established when step t is *admitted*
    // (appends happen at admission), so the whole ladder can be in
    // flight at once — no wait-per-step lockstep.
    let mut decode_tickets: Vec<Ticket> = Vec::new();
    for round in decode_rounds {
        for (s, inputs) in round.into_iter().enumerate() {
            decode_tickets.push(client.submit_decode(session_ids[s], inputs));
        }
    }
    let responses: Vec<Response> = tickets.into_iter().map(|t| t.wait()).collect();
    let decode_responses: Vec<Response> = decode_tickets.into_iter().map(|t| t.wait()).collect();
    let serve_secs = serve_t0.elapsed().as_secs_f64();
    let server = daemon.shutdown();
    assert_eq!(
        responses.len(),
        prefill_requests,
        "every submission must yield exactly one response"
    );

    // Parity spot-check: for each workload, re-run the first *served*
    // request through an independent one-shot compile + sequential
    // execution; outputs and traffic counters must match bit-for-bit.
    // Skipped when re-tuning is on (the live plan may legitimately
    // diverge from the registration-time plan) or faults are armed.
    if retune_every == 0 && fault_rate == 0.0 {
        for (name, _) in &spec {
            let Some((idx, r)) = responses
                .iter()
                .enumerate()
                .find(|(_, r)| &r.workload == name && r.is_ok())
            else {
                continue; // workload drew no (served) traffic in this stream
            };
            let (_, req_seed, trip) = &meta[idx];
            let (p, ccfg, params, _) = workloads::by_name(name, 0).expect("registered name");
            let compiled = compile(&p, ccfg.clone());
            // A ragged request compares against a sequential run at its
            // OWN length (stack dim bound to its trip) — never against
            // the padded bucket edge it may have ridden.
            let (inputs, sizes) = match trip {
                Some(t) => {
                    let info = plan_stack_info(&server.live_plan(name).expect("registered"))
                        .expect("ragged trip implies a stackable plan");
                    let mut sizes = ccfg.sizes.clone();
                    sizes.set(info.dim.clone(), *t);
                    (server.synthetic_inputs_ragged(name, *req_seed, *t)?, sizes)
                }
                None => (server.synthetic_inputs(name, *req_seed)?, ccfg.sizes.clone()),
            };
            let seq = execute_plan_opts(&compiled.plan, &sizes, &params, &inputs, backend, threads);
            for (out_name, m) in &seq.outputs {
                assert_eq!(
                    m, &r.outputs[out_name],
                    "served output {out_name} of {name} diverged from sequential execution"
                );
            }
            assert_eq!(
                (
                    seq.mem.loaded_bytes,
                    seq.mem.stored_bytes,
                    seq.mem.kernel_launches,
                    seq.mem.flops
                ),
                (
                    r.mem.loaded_bytes,
                    r.mem.stored_bytes,
                    r.mem.kernel_launches,
                    r.mem.flops
                ),
                "served traffic counters of {name} diverged from sequential execution"
            );
            println!("parity OK: {name} (batched == sequential, bit-identical)");
        }

        // Decode parity: session 0's FINAL step against a stateless
        // one-shot at the final cache length — the caches the session
        // grew block-by-block, bound as ordinary full-size inputs, must
        // reproduce the step's output and traffic bit-for-bit (stores
        // differ by exactly the step's own KV append, which the
        // response itemizes).
        let final_step = n_steps
            .checked_sub(1)
            .map(|t| t * n_sessions)
            .and_then(|i| decode_responses.get(i));
        if let Some(r) = final_step.filter(|r| r.is_ok()) {
            let name = "decode_attention";
            let (p, ccfg, params, _) = workloads::by_name(name, 0).expect("registered name");
            let compiled = compile(&p, ccfg.clone());
            let sid = session_ids[0];
            let t_final = server.session_len(sid).expect("sessions survive the drain");
            let step = parity_step.expect("decode rounds were generated");
            let mut inputs: HashMap<String, Mat> = HashMap::new();
            inputs.insert("Q".to_string(), step["Q"].clone());
            inputs.insert("MASK".to_string(), step["MASK"].clone());
            for cache in ["KT", "VT"] {
                let m = server.session_cache(sid, cache).expect("session cache").clone();
                inputs.insert(cache.to_string(), m);
            }
            let mut sizes = ccfg.sizes.clone();
            // The demo's growth dim: one N block per cached decode step.
            sizes.set("N", t_final);
            let seq = execute_plan_opts(&compiled.plan, &sizes, &params, &inputs, backend, threads);
            assert_eq!(
                seq.outputs["O"], r.outputs["O"],
                "decode step {t_final} diverged from its stateless length-{t_final} reference"
            );
            assert_eq!(
                (seq.mem.loaded_bytes, seq.mem.kernel_launches, seq.mem.flops),
                (r.mem.loaded_bytes, r.mem.kernel_launches, r.mem.flops),
                "decode traffic counters diverged from the stateless reference"
            );
            assert_eq!(
                (r.mem.stored_bytes, r.mem.n_stores),
                (
                    seq.mem.stored_bytes + r.mem.state_appended_bytes,
                    seq.mem.n_stores + r.mem.state_appends
                ),
                "decode stores must be the stateless reference plus the step's own KV append"
            );
            println!(
                "parity OK: decode_attention (step {t_final} == stateless length-{t_final} \
                 prefill reference, bit-identical)"
            );
        }
    }

    let mut t = Table::new(
        "Serving stats (per workload)",
        &[
            "workload", "served", "shed", "failed", "batches", "avg batch", "peak", "coalesced",
            "launches", "pad flops", "p50 lat", "p95 lat", "p99 lat",
        ],
    );
    let stats = server.stats();
    for (name, st) in &stats.per_program {
        let fmt_ms = |ns: u128| format!("{:.2}ms", ns as f64 / 1e6);
        assert_eq!(
            st.accounted(),
            st.submitted,
            "{name}: shed/reject/failed counters must reconcile with submissions"
        );
        t.row(vec![
            name.clone(),
            st.served.to_string(),
            st.rejected().to_string(),
            st.failed.to_string(),
            st.batches.to_string(),
            format!("{:.2}", st.mean_batch()),
            st.peak_batch.to_string(),
            st.coalesced.to_string(),
            st.launches.to_string(),
            st.padded_flops.to_string(),
            fmt_ms(percentile(&st.latency_ns, 50.0)),
            fmt_ms(st.percentile_latency_ns(95.0)),
            fmt_ms(st.percentile_latency_ns(99.0)),
        ]);
    }
    t.print();
    if backend == ExecBackend::Specialized {
        println!("\nspecialization coverage (fused nests / total nests):");
        let mut names: Vec<&String> = stats.per_program.keys().collect();
        names.sort();
        for name in names {
            if let Some((fused, total)) =
                server.live_plan(name).and_then(|plan| plan.spec_coverage())
            {
                println!("  {name}: {fused}/{total}");
            }
        }
    }
    if coalesce {
        let coalesced: u64 = stats.per_program.values().map(|s| s.coalesced).sum();
        let stacked: u64 = stats.per_program.values().map(|s| s.stacked_batches).sum();
        let launches: u64 = stats.per_program.values().map(|s| s.launches).sum();
        println!(
            "\ncoalescing: {coalesced} request(s) rode {stacked} stacked launch(es); \
             {launches} kernel launch(es) actually executed"
        );
        let (pl, ps, pf) = stats.per_program.values().fold((0u64, 0u64, 0u64), |a, s| {
            (
                a.0 + s.padded_loaded_bytes,
                a.1 + s.padded_stored_bytes,
                a.2 + s.padded_flops,
            )
        });
        if pl + ps + pf > 0 {
            println!(
                "pad waste: {pl} byte(s) loaded, {ps} byte(s) stored, {pf} flop(s) — \
                 charged to the bucket edges, never to a request's own counters"
            );
        }
    }
    if want_decode {
        let st = &stats.per_program["decode_attention"];
        let final_len = session_ids
            .first()
            .and_then(|&sid| server.session_len(sid))
            .unwrap_or(0);
        println!(
            "\ndecode coalescing: {} session(s) x {} step(s): {} step(s) served, {} coalesced \
             across {} stacked launch(es); {} KV append(s) = {} byte(s) of cache growth; \
             session 0 ended at cache length {} block(s)",
            st.sessions_opened,
            n_steps,
            st.decode_steps,
            st.coalesced,
            st.stacked_batches,
            st.state_appends,
            st.state_appended_bytes,
            final_len
        );
    }
    let compiles: u64 = stats.per_program.values().map(|s| s.compiles).sum();
    let binds: u64 = stats.per_program.values().map(|s| s.binds).sum();
    let swaps: u64 = stats.per_program.values().map(|s| s.plan_swaps).sum();
    let panics: u64 = stats.per_program.values().map(|s| s.panics).sum();
    println!(
        "\ncompile-once: {} workload(s), {compiles} compile(s), {binds} tape bind(s), \
         {} skeleton(s) compiled, {swaps} live plan swap(s)",
        spec.len(),
        server.cache_misses()
    );
    println!(
        "robustness: {} submitted = {} served + {} rejected/shed + {} failed \
         ({panics} contained panic(s), {} pool respawn(s))",
        stats.total_submitted(),
        stats.total_served(),
        stats.total_rejected(),
        stats.total_failed(),
        blockbuster::exec::pool::global().respawns()
    );
    // submit→drain window only (excludes registration compiles and the
    // parity spot-check above)
    println!(
        "throughput: {:.0} req/s over {} served request(s)",
        if serve_secs > 0.0 {
            stats.total_served() as f64 / serve_secs
        } else {
            0.0
        },
        stats.total_served()
    );
    Ok(())
}

/// `blockbuster client` — drive a TCP serving daemon over the framed
/// wire protocol: windowed pipelining, reconnect with capped
/// exponential backoff, and a ledger-style summary at the end. The
/// error-kind contract from `serve::net::client` decides what a failed
/// send means: `BrokenPipe` = never admitted (safe to retry),
/// `ConnectionAborted` = possibly in flight server-side (counted lost,
/// never retried — at-most-once, no duplicates).
fn cmd_client(args: &Args) -> anyhow::Result<()> {
    let addr = args.opt("addr").unwrap_or("127.0.0.1:7571");
    let requests = args.opt_usize("requests", 16) as u64;
    let pipeline = args.opt_usize("pipeline", 4).max(1);
    let seed = args.opt_usize("seed", 42) as u64;
    let names: Vec<String> = args
        .opt("mix")
        .unwrap_or("quickstart")
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.to_string())
        .collect();
    if names.is_empty() {
        eprintln!("--mix named no workloads");
        std::process::exit(2);
    }
    for name in &names {
        if workloads::by_name(name, 0).is_none() {
            eprintln!("unknown program {name}; have {}", workloads::NAMES.join(", "));
            std::process::exit(2);
        }
    }
    let cfg = ClientConfig {
        backoff: BackoffConfig {
            attempts: args.opt_usize("backoff-attempts", 5) as u32,
            base: Duration::from_millis(args.opt_usize("backoff-base-ms", 50) as u64),
            cap: Duration::from_millis(args.opt_usize("backoff-cap-ms", 2000) as u64),
        },
        ..ClientConfig::default()
    };
    let mut cli =
        NetClient::connect(addr, cfg).map_err(|e| anyhow::anyhow!("cannot reach {addr}: {e}"))?;
    println!("connected to {addr} (pipeline window {pipeline})");

    let mut ok = 0u64;
    let mut rejected = 0u64;
    let mut failed = 0u64;
    let mut lost = 0u64;
    let mut lat_ns: Vec<u128> = Vec::new();
    let mut inflight: VecDeque<(u64, Instant)> = VecDeque::new();
    let mut next = 0u64;
    let mut draining = false;
    while !draining && (next < requests || !inflight.is_empty()) {
        // Fill the pipeline window.
        while next < requests && inflight.len() < pipeline {
            let name = &names[next as usize % names.len()];
            let req = synthetic_request(name, next, seed.wrapping_add(next))
                .expect("validated workload");
            match cli.send(&req) {
                Ok(()) => {
                    inflight.push_back((next, Instant::now()));
                    next += 1;
                }
                Err(e) if e.kind() == ErrorKind::BrokenPipe => {
                    // Torn write: this request never arrived whole, but
                    // anything already in flight died with the
                    // connection. Reconnect and retry this request.
                    lost += inflight.len() as u64;
                    inflight.clear();
                    cli.reconnect()
                        .map_err(|e| anyhow::anyhow!("reconnect to {addr} failed: {e}"))?;
                }
                Err(e) if e.kind() == ErrorKind::ConnectionAborted => {
                    // Written whole, then dropped: may be in flight
                    // server-side — counted lost, never retried.
                    lost += inflight.len() as u64 + 1;
                    inflight.clear();
                    next += 1;
                    cli.reconnect()
                        .map_err(|e| anyhow::anyhow!("reconnect to {addr} failed: {e}"))?;
                }
                Err(e) => return Err(anyhow::anyhow!("send failed: {e}")),
            }
        }
        let Some(&(_, t0)) = inflight.front() else {
            continue;
        };
        match cli.recv() {
            Ok(Frame::Response(r)) => {
                inflight.pop_front();
                lat_ns.push(t0.elapsed().as_nanos());
                match &r.verdict {
                    Verdict::Ok => ok += 1,
                    Verdict::Rejected(_) => rejected += 1,
                    Verdict::Failed(_) => failed += 1,
                }
            }
            Ok(Frame::Reject { .. }) => {
                inflight.pop_front();
                rejected += 1;
            }
            Ok(Frame::Shutdown) => {
                // Server draining: no further responses are coming.
                lost += inflight.len() as u64;
                inflight.clear();
                draining = true;
            }
            Ok(Frame::Error { code, msg }) => {
                return Err(anyhow::anyhow!("server closed the connection: {code:?}: {msg}"));
            }
            Ok(other) => return Err(anyhow::anyhow!("unexpected frame {other:?}")),
            Err(_) => {
                // Response fate unknown: the whole window is lost.
                lost += inflight.len() as u64;
                inflight.clear();
                cli.reconnect()
                    .map_err(|e| anyhow::anyhow!("reconnect to {addr} failed: {e}"))?;
            }
        }
    }
    if !draining {
        // Polite half-close: the server drains and answers Shutdown.
        if cli.finish().is_ok() {
            let _ = cli.recv();
        }
    }
    let unsent = requests - next;
    println!(
        "client: {requests} requested = {ok} ok + {rejected} rejected + {failed} failed + \
         {lost} lost + {unsent} unsent"
    );
    if !lat_ns.is_empty() {
        let ms = |p: f64| percentile(&lat_ns, p) as f64 / 1e6;
        println!(
            "latency: p50 {:.2}ms  p95 {:.2}ms  p99 {:.2}ms over {} response(s)",
            ms(50.0),
            ms(95.0),
            ms(99.0),
            lat_ns.len()
        );
    }
    Ok(())
}

fn cmd_xla(args: &Args) -> anyhow::Result<()> {
    let model = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("attention_fused");
    let dir = args.opt("artifacts").unwrap_or("artifacts");
    let mut rt = blockbuster::runtime::Runtime::new(dir)?;
    println!("platform: {}", rt.platform());
    let info = rt.manifest.model(model)?.clone();
    let mut rng = Rng::new(args.opt_usize("seed", 42) as u64);
    let mats: Vec<Mat> = info
        .inputs
        .iter()
        .map(|(_, s)| rng.mat(s[0], s[1]))
        .collect();
    let refs: Vec<&Mat> = mats.iter().collect();
    let t0 = std::time::Instant::now();
    let out = rt.execute(model, &refs)?;
    println!(
        "{model}: {} output(s) in {:?}; out[0] is {}x{}",
        out.len(),
        t0.elapsed(),
        out[0].rows,
        out[0].cols
    );
    Ok(())
}
