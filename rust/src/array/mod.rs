//! The array-program layer: the input representation of the compiler.
//!
//! An array program is a DAG of operators over large matrices (the paper's
//! §1 "array program"/"tensor program"). Values are logical matrices tagged
//! with the two blocking dimensions the selection layer will later size
//! (`(M, K)` = row blocks × column blocks). Right-hand matmul operands are
//! declared in transposed block storage (`KT`, `YT`, `WT`, …) to match the
//! `dot(a, b) = a @ b.T` block-operator convention of Table 1.

pub mod programs;

use crate::ir::dim::Dim;
use crate::ir::expr::Expr;
use std::fmt;

pub type ANodeId = usize;

/// Logical blocking of a matrix value: row-block dim × column-block dim.
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct ABlocking {
    pub rows: Dim,
    pub cols: Dim,
}

impl ABlocking {
    pub fn new(rows: &str, cols: &str) -> Self {
        ABlocking {
            rows: Dim::new(rows),
            cols: Dim::new(cols),
        }
    }
}

/// Array operators. The vocabulary covers everything the paper's three
/// examples (and the decoder-block workload) need; anything else enters the
/// block program as a miscellaneous operator via [`AOp::Custom`].
#[derive(Clone, Debug)]
pub enum AOp {
    /// Program input (stored row-major in global memory). If `transposed`,
    /// the *blocks* hold the transposed matrix (a matmul right operand).
    Input { name: String, transposed: bool },
    /// `C = A @ B` where the second operand is stored transposed.
    /// Blocking: A `(m,k)`, Bᵀ `(n,k)` → C `(m,n)`.
    MatMul,
    /// Elementwise scalar function applied to every element.
    Ew { expr: Expr, label: String },
    /// Elementwise (Hadamard) product of same-shape matrices.
    Hadamard,
    /// Elementwise sum of same-shape matrices.
    Add,
    /// Row-wise softmax.
    Softmax,
    /// Row-wise LayerNorm (no affine parameters, as in the paper).
    LayerNorm,
    /// Row-wise RMSNorm.
    RmsNorm,
    /// An opaque custom operator (lowers to a Misc block operator and is
    /// never selected into fusion candidates).
    Custom { tag: String },
}

impl AOp {
    pub fn name(&self) -> String {
        match self {
            AOp::Input { name, .. } => format!("input {name}"),
            AOp::MatMul => "matmul".into(),
            AOp::Ew { label, .. } => label.clone(),
            AOp::Hadamard => "hadamard".into(),
            AOp::Add => "add".into(),
            AOp::Softmax => "softmax".into(),
            AOp::LayerNorm => "layernorm".into(),
            AOp::RmsNorm => "rmsnorm".into(),
            AOp::Custom { tag } => format!("custom {tag}"),
        }
    }

    /// Is this a standard operator (eligible for fusion candidates)?
    pub fn is_standard(&self) -> bool {
        !matches!(self, AOp::Custom { .. })
    }
}

#[derive(Clone, Debug)]
pub struct ANode {
    pub op: AOp,
    pub inputs: Vec<ANodeId>,
    pub blocking: ABlocking,
    pub label: String,
}

/// An array program: a DAG of array operators with named outputs.
#[derive(Clone, Debug, Default)]
pub struct ArrayProgram {
    pub nodes: Vec<ANode>,
    pub outputs: Vec<(String, ANodeId)>,
    /// Inputs declared *stateful*: `(input name, growth dim)` pairs. A
    /// stateful input is a buffer that persists across program
    /// invocations and is appended along the named dim each step (a KV
    /// cache). Carried through `lower_array` onto [`crate::ir::Graph`].
    pub state: Vec<(String, String)>,
}

impl ArrayProgram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Mark input `name` as a stateful buffer growing along dim `dim`.
    pub fn mark_state(&mut self, name: &str, dim: &str) {
        self.state.push((name.into(), dim.into()));
    }

    fn push(&mut self, op: AOp, inputs: Vec<ANodeId>, blocking: ABlocking) -> ANodeId {
        let label = format!("a{}:{}", self.nodes.len(), op.name());
        self.nodes.push(ANode {
            op,
            inputs,
            blocking,
            label,
        });
        self.nodes.len() - 1
    }

    /// Declare a program input blocked as `(rows, cols)`.
    pub fn input(&mut self, name: &str, rows: &str, cols: &str) -> ANodeId {
        self.push(
            AOp::Input {
                name: name.into(),
                transposed: false,
            },
            vec![],
            ABlocking::new(rows, cols),
        )
    }

    /// Declare a matmul right-operand input stored transposed: `name` holds
    /// Bᵀ blocked `(n, k)`.
    pub fn input_t(&mut self, name: &str, n: &str, k: &str) -> ANodeId {
        self.push(
            AOp::Input {
                name: name.into(),
                transposed: true,
            },
            vec![],
            ABlocking::new(n, k),
        )
    }

    /// `C = A @ B`, with `bt` the transposed-stored right operand.
    pub fn matmul(&mut self, a: ANodeId, bt: ANodeId) -> ANodeId {
        let ab = self.nodes[a].blocking.clone();
        let bb = self.nodes[bt].blocking.clone();
        assert_eq!(
            ab.cols, bb.cols,
            "matmul: contraction dims differ ({} vs {})",
            ab.cols, bb.cols
        );
        let blocking = ABlocking {
            rows: ab.rows,
            cols: bb.rows,
        };
        self.push(AOp::MatMul, vec![a, bt], blocking)
    }

    pub fn ew(&mut self, label: &str, expr: Expr, a: ANodeId) -> ANodeId {
        let blocking = self.nodes[a].blocking.clone();
        self.push(
            AOp::Ew {
                expr,
                label: label.into(),
            },
            vec![a],
            blocking,
        )
    }

    pub fn relu(&mut self, a: ANodeId) -> ANodeId {
        self.ew("relu", Expr::relu(Expr::var(0)), a)
    }

    pub fn swish(&mut self, a: ANodeId) -> ANodeId {
        self.ew("swish", Expr::swish(Expr::var(0)), a)
    }

    /// Divide by `sqrt(d)` where `d` is the named parameter (Attention).
    pub fn div_sqrt(&mut self, a: ANodeId, param: &str) -> ANodeId {
        self.ew(
            "div_sqrt",
            Expr::var(0).mul(Expr::param(param).pow(Expr::cst(-0.5))),
            a,
        )
    }

    pub fn hadamard(&mut self, a: ANodeId, b: ANodeId) -> ANodeId {
        assert_eq!(self.nodes[a].blocking, self.nodes[b].blocking);
        let blocking = self.nodes[a].blocking.clone();
        self.push(AOp::Hadamard, vec![a, b], blocking)
    }

    pub fn add(&mut self, a: ANodeId, b: ANodeId) -> ANodeId {
        assert_eq!(self.nodes[a].blocking, self.nodes[b].blocking);
        let blocking = self.nodes[a].blocking.clone();
        self.push(AOp::Add, vec![a, b], blocking)
    }

    pub fn softmax(&mut self, a: ANodeId) -> ANodeId {
        let blocking = self.nodes[a].blocking.clone();
        self.push(AOp::Softmax, vec![a], blocking)
    }

    /// `param` names the row length (the paper's `KK`).
    pub fn layernorm(&mut self, a: ANodeId) -> ANodeId {
        let blocking = self.nodes[a].blocking.clone();
        self.push(AOp::LayerNorm, vec![a], blocking)
    }

    pub fn rmsnorm(&mut self, a: ANodeId) -> ANodeId {
        let blocking = self.nodes[a].blocking.clone();
        self.push(AOp::RmsNorm, vec![a], blocking)
    }

    pub fn custom(&mut self, tag: &str, inputs: Vec<ANodeId>) -> ANodeId {
        let blocking = self.nodes[inputs[0]].blocking.clone();
        self.push(AOp::Custom { tag: tag.into() }, inputs, blocking)
    }

    pub fn output(&mut self, name: &str, a: ANodeId) {
        self.outputs.push((name.into(), a));
    }

    /// Number of operator nodes (excluding inputs).
    pub fn op_count(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| !matches!(n.op, AOp::Input { .. }))
            .count()
    }

    /// The parameter name for a row-length constant of a node, derived from
    /// its column dim (`KK` for dim K, `DD` for dim D, …).
    pub fn row_len_param(&self, id: ANodeId) -> String {
        let d = &self.nodes[id].blocking.cols;
        format!("{}{}", d.name(), d.name())
    }
}

impl fmt::Display for ArrayProgram {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, n) in self.nodes.iter().enumerate() {
            writeln!(
                f,
                "a{i}: {} ({},{}) <- {:?}",
                n.op.name(),
                n.blocking.rows,
                n.blocking.cols,
                n.inputs
            )?;
        }
        for (name, id) in &self.outputs {
            writeln!(f, "output {name} = a{id}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_attention_shape() {
        let p = programs::attention();
        assert_eq!(p.op_count(), 4); // matmul, div, softmax, matmul
        assert_eq!(p.outputs.len(), 1);
    }

    #[test]
    fn matmul_blocking_checked() {
        let mut p = ArrayProgram::new();
        let a = p.input("A", "M", "K");
        let bt = p.input_t("BT", "N", "K");
        let c = p.matmul(a, bt);
        assert_eq!(p.nodes[c].blocking, ABlocking::new("M", "N"));
    }

    #[test]
    #[should_panic(expected = "contraction dims differ")]
    fn matmul_dim_mismatch_panics() {
        let mut p = ArrayProgram::new();
        let a = p.input("A", "M", "K");
        let bt = p.input_t("BT", "N", "J");
        p.matmul(a, bt);
    }

    #[test]
    fn row_len_param_name() {
        let mut p = ArrayProgram::new();
        let a = p.input("X", "M", "K");
        assert_eq!(p.row_len_param(a), "KK");
    }
}
