//! The paper's example array programs plus the end-to-end workloads.

use super::ArrayProgram;
use crate::ir::expr::Expr;

/// §1's motivating example: `C = relu(A @ B)`.
pub fn matmul_relu() -> ArrayProgram {
    let mut p = ArrayProgram::new();
    let a = p.input("A", "M", "K");
    let bt = p.input_t("BT", "N", "K");
    let mm = p.matmul(a, bt);
    let r = p.relu(mm);
    p.output("C", r);
    p
}

/// Example 1: (unsafe) Attention — `O = softmax(Q·Kᵀ/√d)·V`.
///
/// Inputs are `Q (M,D)`, `KT (N,D)` (= K, already transposed-stored), and
/// `VT (L,N)` (= Vᵀ blocked over L column blocks), exactly as in the paper's
/// initial block program. `DD` is the model-width parameter for the √d.
pub fn attention() -> ArrayProgram {
    let mut p = ArrayProgram::new();
    let q = p.input("Q", "M", "D");
    let kt = p.input_t("KT", "N", "D");
    let vt = p.input_t("VT", "L", "N");
    let scores = p.matmul(q, kt); // (M,N)
    let scaled = p.div_sqrt(scores, "DD");
    let probs = p.softmax(scaled);
    let o = p.matmul(probs, vt); // (M,L)
    p.output("O", o);
    p
}

/// KV-cache decode attention — one autoregressive step:
/// `O = softmax(Q·Kᵀ/√d + MASK)·V` with `KT`/`VT` *stateful* along the
/// cache dim `N`.
///
/// Same block program as [`attention`] plus an additive mask applied to
/// the scaled scores (so a longer cache can be replayed with future
/// positions masked to `-inf` — exact bitwise no-ops under the unsafe
/// softmax, which is what makes T decode steps bit-identical to one
/// length-T prefill). At decode time `M` is tiny (one query block) and
/// `N` grows by one block per step; the serving layer owns the growth
/// (`serve` sessions append to the caches, the plan just reads its
/// prefix).
pub fn decode_attention() -> ArrayProgram {
    let mut p = ArrayProgram::new();
    let q = p.input("Q", "M", "D");
    let kt = p.input_t("KT", "N", "D");
    let vt = p.input_t("VT", "L", "N");
    let mask = p.input("MASK", "M", "N");
    let scores = p.matmul(q, kt); // (M,N)
    let scaled = p.div_sqrt(scores, "DD");
    let masked = p.add(scaled, mask);
    let probs = p.softmax(masked);
    let o = p.matmul(probs, vt); // (M,L)
    p.output("O", o);
    p.mark_state("KT", "N");
    p.mark_state("VT", "N");
    p
}

/// Example 2: LayerNorm + Matmul — `Z = LayerNorm(X)·Y`.
pub fn layernorm_matmul() -> ArrayProgram {
    let mut p = ArrayProgram::new();
    let x = p.input("X", "M", "K");
    let yt = p.input_t("YT", "N", "K");
    let ln = p.layernorm(x);
    let z = p.matmul(ln, yt);
    p.output("Z", z);
    p
}

/// Example 3: RMSNorm + FFN-SwiGLU —
/// `O = (swish(RMS(X)·W) ⊙ (RMS(X)·V)) · U`.
pub fn rmsnorm_ffn_swiglu() -> ArrayProgram {
    let mut p = ArrayProgram::new();
    let x = p.input("X", "M", "D");
    let wt = p.input_t("WT", "K", "D");
    let vt = p.input_t("VT", "K", "D");
    let ut = p.input_t("UT", "N", "K");
    let rms = p.rmsnorm(x);
    let w_proj = p.matmul(rms, wt); // (M,K)
    let v_proj = p.matmul(rms, vt); // (M,K)
    let sw = p.swish(w_proj);
    let had = p.hadamard(sw, v_proj);
    let o = p.matmul(had, ut); // (M,N)
    p.output("O", o);
    p
}

/// End-to-end workload: a decoder block —
/// attention (over pre-projected Q/K/V), residual add, then
/// RMSNorm + FFN-SwiGLU with a second residual add.
///
/// `R (M,L)` is the residual stream entering the block (blocked like the
/// attention output).
pub fn decoder_block() -> ArrayProgram {
    let mut p = ArrayProgram::new();
    let q = p.input("Q", "M", "D");
    let kt = p.input_t("KT", "N", "D");
    let vt = p.input_t("VT", "L", "N");
    let r = p.input("R", "M", "L");
    let wt = p.input_t("WT", "K", "L");
    let vt2 = p.input_t("VT2", "K", "L");
    let ut = p.input_t("UT", "L2", "K");

    // attention
    let scores = p.matmul(q, kt);
    let scaled = p.div_sqrt(scores, "DD");
    let probs = p.softmax(scaled);
    let attn = p.matmul(probs, vt); // (M,L)
    let h = p.add(attn, r); // residual

    // feed-forward
    let rms = p.rmsnorm(h);
    let w_proj = p.matmul(rms, wt); // (M,K)
    let v_proj = p.matmul(rms, vt2); // (M,K)
    let sw = p.swish(w_proj);
    let had = p.hadamard(sw, v_proj);
    let ffn = p.matmul(had, ut); // (M,L2)
    p.output("O", ffn);
    p.output("H", h);
    p
}

/// A two-layer MLP with ReLU — used by selection/autotune tests.
pub fn mlp() -> ArrayProgram {
    let mut p = ArrayProgram::new();
    let x = p.input("X", "M", "K");
    let w1t = p.input_t("W1T", "N", "K");
    let w2t = p.input_t("W2T", "P", "N");
    let h = p.matmul(x, w1t);
    let a = p.relu(h);
    let o = p.matmul(a, w2t);
    p.output("Y", o);
    p
}

/// A program containing a custom operator (selection must split around it).
pub fn with_custom_op() -> ArrayProgram {
    let mut p = ArrayProgram::new();
    let x = p.input("X", "M", "K");
    let e = p.ew("exp", Expr::var(0).exp(), x);
    let c = p.custom("mystery", vec![e]);
    let r = p.relu(c);
    p.output("Y", r);
    p
}
