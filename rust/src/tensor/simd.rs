//! Explicit-width SIMD substrate for the [`super::Mat`] kernels.
//!
//! The numeric contract of every kernel in this module is defined by a
//! fixed **virtual lane width** ([`LANES`] = 8), not by whatever vector
//! unit happens to execute it:
//!
//! * reductions (`dot`, `sum`) accumulate into 8 stride-8 partial lanes,
//!   combine the lanes in one fixed tree order
//!   (`((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`), and fold the tail in
//!   ascending order;
//! * `max` uses an explicit `if x > m` select per lane (deterministic for
//!   NaN — a NaN is never `>` — and for `±0.0`), the same tree combine
//!   shape, and an ascending tail;
//! * elementwise kernels (`add_assign`, `mul_assign`, `axpy`,
//!   `add_scalar`, `mul_scalar`) perform one rounding per element in a
//!   lane-independent order.
//!
//! The AVX2 path executes exactly that recipe with 256-bit vectors
//! (explicit mul-then-add — **no FMA**, which would change rounding); the
//! portable path executes it with scalar arrays. Results are therefore
//! **bit-identical** whether the `simd` cargo feature is on or off,
//! whether the CPU has AVX2 or not, and whether the runtime kill-switch
//! ([`set_enabled`]) is thrown — which is what lets
//! `tests/backend_parity.rs` and `tests/simd_parity.rs` demand exact
//! equality instead of tolerances.
//!
//! Dispatch is resolved at runtime per kernel call (one relaxed atomic
//! load plus `std`'s cached CPUID probe), hoisted out of all inner loops.

use std::sync::atomic::{AtomicBool, Ordering};

/// Virtual lane width that defines the canonical reduction order.
pub const LANES: usize = 8;

/// Runtime kill-switch (the CLI's `--no-simd`); `true` means *disabled*.
static SIMD_DISABLED: AtomicBool = AtomicBool::new(false);

/// Enable or disable the vector paths at runtime. Scalar and vector paths
/// are bit-identical, so flipping this mid-run changes wall-clock only.
pub fn set_enabled(on: bool) {
    SIMD_DISABLED.store(!on, Ordering::Relaxed);
}

/// Whether the runtime kill-switch currently allows vector paths.
pub fn runtime_enabled() -> bool {
    !SIMD_DISABLED.load(Ordering::Relaxed)
}

/// True when the vector paths will actually run: `simd` feature compiled
/// in, runtime switch on, and AVX2 available on this CPU.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[inline]
pub fn simd_active() -> bool {
    runtime_enabled() && std::is_x86_feature_detected!("avx2")
}

/// Scalar-only build (feature off or non-x86_64): never active.
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
#[inline]
pub fn simd_active() -> bool {
    false
}

/// The fixed lane-combine tree for additive reductions.
#[inline]
fn combine_add(l: &[f32; LANES]) -> f32 {
    ((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]))
}

/// The fixed lane-combine tree for the `>`-select max.
#[inline]
fn combine_max(l: &[f32; LANES]) -> f32 {
    let g = |a: f32, b: f32| if b > a { b } else { a };
    g(g(g(l[0], l[1]), g(l[2], l[3])), g(g(l[4], l[5]), g(l[6], l[7])))
}

// ---------------------------------------------------------------------------
// Portable scalar implementations of the canonical recipes
// ---------------------------------------------------------------------------

fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    let mut lanes = [0.0f32; LANES];
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
        for ((lane, &x), &y) in lanes.iter_mut().zip(xa).zip(xb) {
            *lane += x * y;
        }
    }
    let mut s = combine_add(&lanes);
    for (&x, &y) in ca.remainder().iter().zip(cb.remainder()) {
        s += x * y;
    }
    s
}

fn sum_scalar(x: &[f32]) -> f32 {
    let mut lanes = [0.0f32; LANES];
    let mut chunks = x.chunks_exact(LANES);
    for c in chunks.by_ref() {
        for (lane, &v) in lanes.iter_mut().zip(c) {
            *lane += v;
        }
    }
    let mut s = combine_add(&lanes);
    for &v in chunks.remainder() {
        s += v;
    }
    s
}

fn max_scalar(x: &[f32]) -> f32 {
    let mut lanes = [f32::NEG_INFINITY; LANES];
    let mut chunks = x.chunks_exact(LANES);
    for c in chunks.by_ref() {
        for (lane, &v) in lanes.iter_mut().zip(c) {
            if v > *lane {
                *lane = v;
            }
        }
    }
    let mut m = combine_max(&lanes);
    for &v in chunks.remainder() {
        if v > m {
            m = v;
        }
    }
    m
}

fn dot_bt_scalar(a: &[f32], b: &[f32], out: &mut [f32], m: usize, n: usize, k: usize) {
    for i in 0..m {
        let ar = &a[i * k..(i + 1) * k];
        for j in 0..n {
            out[i * n + j] = dot_scalar(ar, &b[j * k..(j + 1) * k]);
        }
    }
}

fn axpy_scalar(out: &mut [f32], a: f32, x: &[f32]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o += a * v;
    }
}

fn add_assign_scalar(out: &mut [f32], x: &[f32]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o += v;
    }
}

fn mul_assign_scalar(out: &mut [f32], x: &[f32]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o *= v;
    }
}

fn add_scalar_scalar(out: &mut [f32], c: f32) {
    for o in out.iter_mut() {
        *o += c;
    }
}

fn mul_scalar_scalar(out: &mut [f32], c: f32) {
    for o in out.iter_mut() {
        *o *= c;
    }
}

// --- elementwise expression-VM slice kernels (portable recipes) ------------
//
// These back `ir::exprvm`: every kernel applies one scalar operation per
// element, in a lane-independent order, using exactly the operation the
// scalar `CompiledExpr::eval_with` interpreter would apply — which is what
// makes the batched VM bit-identical to the per-element path. Kernels with
// an AVX2 twin below are restricted to the operations whose 256-bit forms
// are IEEE-identical to their scalar forms (add/sub/mul/div, sqrt,
// sign-bit neg/abs, and `1.0/x` via a real division — never `rcp_ps`).
// exp/ln/pow and the `f32::max`/`f32::min` selects have no bit-identical
// vector form available offline, so their "kernels" are the scalar loop on
// every path (still batched: one call per slice, not per element).

fn ew_sub_scalar_impl(out: &mut [f32], x: &[f32]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o -= v;
    }
}

fn ew_div_scalar_impl(out: &mut [f32], x: &[f32]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o /= v;
    }
}

fn ew_sub_c_scalar_impl(out: &mut [f32], c: f32) {
    for o in out.iter_mut() {
        *o -= c;
    }
}

fn ew_div_c_scalar_impl(out: &mut [f32], c: f32) {
    for o in out.iter_mut() {
        *o /= c;
    }
}

fn ew_neg_scalar_impl(out: &mut [f32]) {
    for o in out.iter_mut() {
        *o = -*o;
    }
}

fn ew_abs_scalar_impl(out: &mut [f32]) {
    for o in out.iter_mut() {
        *o = o.abs();
    }
}

fn ew_sqrt_scalar_impl(out: &mut [f32]) {
    for o in out.iter_mut() {
        *o = o.sqrt();
    }
}

fn ew_recip_scalar_impl(out: &mut [f32]) {
    for o in out.iter_mut() {
        *o = 1.0 / *o;
    }
}

// ---------------------------------------------------------------------------
// AVX2 implementations (x86_64 + `simd` feature only)
// ---------------------------------------------------------------------------

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx {
    //! 256-bit executions of the canonical lane recipes. Every function is
    //! `unsafe` only because of `#[target_feature]`; callers must have
    //! verified AVX2 via [`super::simd_active`]. All loads/stores are
    //! unaligned (`Mat` data is a plain `Vec<f32>`).

    use super::{combine_add, LANES};
    use std::arch::x86_64::*;

    /// Spill a vector accumulator and run the fixed scalar combine tree,
    /// so the horizontal step is bit-identical to the portable path.
    #[inline]
    unsafe fn hsum(v: __m256) -> f32 {
        let mut l = [0.0f32; LANES];
        _mm256_storeu_ps(l.as_mut_ptr(), v);
        combine_add(&l)
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let k = a.len();
        let mut acc = _mm256_setzero_ps();
        let mut kk = 0;
        while kk + LANES <= k {
            let av = _mm256_loadu_ps(a.as_ptr().add(kk));
            let bv = _mm256_loadu_ps(b.as_ptr().add(kk));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(av, bv));
            kk += LANES;
        }
        let mut s = hsum(acc);
        while kk < k {
            s += a[kk] * b[kk];
            kk += 1;
        }
        s
    }

    /// 4-row register-tiled `A @ B^T` micro-kernel: four k-accumulator
    /// vectors stay live while each `B` row is loaded once per row group.
    /// Per output element the operation sequence is exactly [`dot`]'s.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_bt_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, n: usize, k: usize) {
        const MR: usize = 4;
        let mut i = 0;
        while i + MR <= m {
            let a0 = a.as_ptr().add(i * k);
            let a1 = a.as_ptr().add((i + 1) * k);
            let a2 = a.as_ptr().add((i + 2) * k);
            let a3 = a.as_ptr().add((i + 3) * k);
            for j in 0..n {
                let bp = b.as_ptr().add(j * k);
                let mut acc0 = _mm256_setzero_ps();
                let mut acc1 = _mm256_setzero_ps();
                let mut acc2 = _mm256_setzero_ps();
                let mut acc3 = _mm256_setzero_ps();
                let mut kk = 0;
                while kk + LANES <= k {
                    let bv = _mm256_loadu_ps(bp.add(kk));
                    acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(_mm256_loadu_ps(a0.add(kk)), bv));
                    acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(_mm256_loadu_ps(a1.add(kk)), bv));
                    acc2 = _mm256_add_ps(acc2, _mm256_mul_ps(_mm256_loadu_ps(a2.add(kk)), bv));
                    acc3 = _mm256_add_ps(acc3, _mm256_mul_ps(_mm256_loadu_ps(a3.add(kk)), bv));
                    kk += LANES;
                }
                let mut s0 = hsum(acc0);
                let mut s1 = hsum(acc1);
                let mut s2 = hsum(acc2);
                let mut s3 = hsum(acc3);
                while kk < k {
                    let bx = *bp.add(kk);
                    s0 += *a0.add(kk) * bx;
                    s1 += *a1.add(kk) * bx;
                    s2 += *a2.add(kk) * bx;
                    s3 += *a3.add(kk) * bx;
                    kk += 1;
                }
                out[i * n + j] = s0;
                out[(i + 1) * n + j] = s1;
                out[(i + 2) * n + j] = s2;
                out[(i + 3) * n + j] = s3;
            }
            i += MR;
        }
        while i < m {
            let ar = &a[i * k..(i + 1) * k];
            for j in 0..n {
                out[i * n + j] = dot(ar, &b[j * k..(j + 1) * k]);
            }
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn sum(x: &[f32]) -> f32 {
        let n = x.len();
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i + LANES <= n {
            acc = _mm256_add_ps(acc, _mm256_loadu_ps(x.as_ptr().add(i)));
            i += LANES;
        }
        let mut s = hsum(acc);
        while i < n {
            s += x[i];
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn max(x: &[f32]) -> f32 {
        let n = x.len();
        // `cmp GT (ordered, quiet)` + blend reproduces the scalar
        // `if v > lane` select exactly, including NaN (never greater)
        // and ±0.0 (+0 > -0 is false).
        let mut acc = _mm256_set1_ps(f32::NEG_INFINITY);
        let mut i = 0;
        while i + LANES <= n {
            let v = _mm256_loadu_ps(x.as_ptr().add(i));
            let gt = _mm256_cmp_ps::<_CMP_GT_OQ>(v, acc);
            acc = _mm256_blendv_ps(acc, v, gt);
            i += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut m = super::combine_max(&lanes);
        while i < n {
            if x[i] > m {
                m = x[i];
            }
            i += 1;
        }
        m
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy(out: &mut [f32], a: f32, x: &[f32]) {
        let n = out.len();
        let av = _mm256_set1_ps(a);
        let mut i = 0;
        while i + LANES <= n {
            let o = _mm256_loadu_ps(out.as_ptr().add(i));
            let v = _mm256_loadu_ps(x.as_ptr().add(i));
            _mm256_storeu_ps(
                out.as_mut_ptr().add(i),
                _mm256_add_ps(o, _mm256_mul_ps(av, v)),
            );
            i += LANES;
        }
        while i < n {
            out[i] += a * x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn add_assign(out: &mut [f32], x: &[f32]) {
        let n = out.len();
        let mut i = 0;
        while i + LANES <= n {
            let o = _mm256_loadu_ps(out.as_ptr().add(i));
            let v = _mm256_loadu_ps(x.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_add_ps(o, v));
            i += LANES;
        }
        while i < n {
            out[i] += x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn mul_assign(out: &mut [f32], x: &[f32]) {
        let n = out.len();
        let mut i = 0;
        while i + LANES <= n {
            let o = _mm256_loadu_ps(out.as_ptr().add(i));
            let v = _mm256_loadu_ps(x.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_mul_ps(o, v));
            i += LANES;
        }
        while i < n {
            out[i] *= x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn add_scalar(out: &mut [f32], c: f32) {
        let n = out.len();
        let cv = _mm256_set1_ps(c);
        let mut i = 0;
        while i + LANES <= n {
            let o = _mm256_loadu_ps(out.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_add_ps(o, cv));
            i += LANES;
        }
        while i < n {
            out[i] += c;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn mul_scalar(out: &mut [f32], c: f32) {
        let n = out.len();
        let cv = _mm256_set1_ps(c);
        let mut i = 0;
        while i + LANES <= n {
            let o = _mm256_loadu_ps(out.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_mul_ps(o, cv));
            i += LANES;
        }
        while i < n {
            out[i] *= c;
            i += 1;
        }
    }

    // --- expression-VM elementwise kernels ---------------------------------
    // Only operations whose 256-bit forms are IEEE-identical to the scalar
    // forms appear here: vsubps/vdivps (correctly rounded like subss/divss),
    // vsqrtps (correctly rounded), sign-bit xor/andnot for neg/abs, and
    // `1.0/x` as a real division. `rcp_ps` (approximate) is deliberately
    // never used.

    #[target_feature(enable = "avx2")]
    pub unsafe fn ew_sub(out: &mut [f32], x: &[f32]) {
        let n = out.len();
        let mut i = 0;
        while i + LANES <= n {
            let o = _mm256_loadu_ps(out.as_ptr().add(i));
            let v = _mm256_loadu_ps(x.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_sub_ps(o, v));
            i += LANES;
        }
        while i < n {
            out[i] -= x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn ew_div(out: &mut [f32], x: &[f32]) {
        let n = out.len();
        let mut i = 0;
        while i + LANES <= n {
            let o = _mm256_loadu_ps(out.as_ptr().add(i));
            let v = _mm256_loadu_ps(x.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_div_ps(o, v));
            i += LANES;
        }
        while i < n {
            out[i] /= x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn ew_sub_c(out: &mut [f32], c: f32) {
        let n = out.len();
        let cv = _mm256_set1_ps(c);
        let mut i = 0;
        while i + LANES <= n {
            let o = _mm256_loadu_ps(out.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_sub_ps(o, cv));
            i += LANES;
        }
        while i < n {
            out[i] -= c;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn ew_div_c(out: &mut [f32], c: f32) {
        let n = out.len();
        let cv = _mm256_set1_ps(c);
        let mut i = 0;
        while i + LANES <= n {
            let o = _mm256_loadu_ps(out.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_div_ps(o, cv));
            i += LANES;
        }
        while i < n {
            out[i] /= c;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn ew_neg(out: &mut [f32]) {
        let n = out.len();
        // IEEE negation is a sign-bit flip, NaN payloads included —
        // exactly what scalar `-x` lowers to.
        let sign = _mm256_set1_ps(-0.0);
        let mut i = 0;
        while i + LANES <= n {
            let o = _mm256_loadu_ps(out.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_xor_ps(o, sign));
            i += LANES;
        }
        while i < n {
            out[i] = -out[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn ew_abs(out: &mut [f32]) {
        let n = out.len();
        // `f32::abs` clears the sign bit (NaN payloads included).
        let sign = _mm256_set1_ps(-0.0);
        let mut i = 0;
        while i + LANES <= n {
            let o = _mm256_loadu_ps(out.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_andnot_ps(sign, o));
            i += LANES;
        }
        while i < n {
            out[i] = out[i].abs();
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn ew_sqrt(out: &mut [f32]) {
        let n = out.len();
        let mut i = 0;
        while i + LANES <= n {
            let o = _mm256_loadu_ps(out.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_sqrt_ps(o));
            i += LANES;
        }
        while i < n {
            out[i] = out[i].sqrt();
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn ew_recip(out: &mut [f32]) {
        let n = out.len();
        let ones = _mm256_set1_ps(1.0);
        let mut i = 0;
        while i + LANES <= n {
            let o = _mm256_loadu_ps(out.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_div_ps(ones, o));
            i += LANES;
        }
        while i < n {
            out[i] = 1.0 / out[i];
            i += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// Public dispatching kernels
// ---------------------------------------------------------------------------

/// Lane-structured dot product `Σ a[i]·b[i]` (lengths must match).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd_active() {
            // SAFETY: AVX2 presence verified by `simd_active`.
            return unsafe { avx::dot(a, b) };
        }
    }
    dot_scalar(a, b)
}

/// `out[i*n + j] = dot(row i of a, row j of b)` for row-major `a` (m×k)
/// and `b` (n×k) — the `A @ B^T` kernel behind [`super::Mat::dot_bt`].
pub fn dot_bt_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, n: usize, k: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd_active() {
            // SAFETY: AVX2 presence verified by `simd_active`.
            unsafe { avx::dot_bt_into(a, b, out, m, n, k) };
            return;
        }
    }
    dot_bt_scalar(a, b, out, m, n, k);
}

/// Lane-structured sum of `x`.
pub fn sum(x: &[f32]) -> f32 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd_active() {
            // SAFETY: AVX2 presence verified by `simd_active`.
            return unsafe { avx::sum(x) };
        }
    }
    sum_scalar(x)
}

/// `>`-select maximum of `x` (NaN elements are never selected); returns
/// `-inf` for an empty slice.
pub fn max(x: &[f32]) -> f32 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd_active() {
            // SAFETY: AVX2 presence verified by `simd_active`.
            return unsafe { avx::max(x) };
        }
    }
    max_scalar(x)
}

/// `out[i] += a · x[i]` (lengths must match).
pub fn axpy(out: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd_active() {
            // SAFETY: AVX2 presence verified by `simd_active`.
            unsafe { avx::axpy(out, a, x) };
            return;
        }
    }
    axpy_scalar(out, a, x);
}

/// `out[i] += x[i]` (lengths must match).
pub fn add_assign(out: &mut [f32], x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd_active() {
            // SAFETY: AVX2 presence verified by `simd_active`.
            unsafe { avx::add_assign(out, x) };
            return;
        }
    }
    add_assign_scalar(out, x);
}

/// `out[i] *= x[i]` (lengths must match).
pub fn mul_assign(out: &mut [f32], x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd_active() {
            // SAFETY: AVX2 presence verified by `simd_active`.
            unsafe { avx::mul_assign(out, x) };
            return;
        }
    }
    mul_assign_scalar(out, x);
}

/// `out[i] += c`.
pub fn add_scalar(out: &mut [f32], c: f32) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd_active() {
            // SAFETY: AVX2 presence verified by `simd_active`.
            unsafe { avx::add_scalar(out, c) };
            return;
        }
    }
    add_scalar_scalar(out, c);
}

/// `out[i] *= c`.
pub fn mul_scalar(out: &mut [f32], c: f32) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd_active() {
            // SAFETY: AVX2 presence verified by `simd_active`.
            unsafe { avx::mul_scalar(out, c) };
            return;
        }
    }
    mul_scalar_scalar(out, c);
}

// ---------------------------------------------------------------------------
// Expression-VM elementwise slice kernels
// ---------------------------------------------------------------------------
//
// The batched expression VM (`ir::exprvm`) runs every op of a compiled
// elementwise expression over a whole slice through these kernels. Each is
// per-element identical to the operation `CompiledExpr::eval_with` applies,
// so the VM stays bit-identical to the scalar interpreter on every path —
// AVX2 or portable, runtime switch on or off.

/// `out[i] -= x[i]` (lengths must match).
pub fn ew_sub(out: &mut [f32], x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd_active() {
            // SAFETY: AVX2 presence verified by `simd_active`.
            unsafe { avx::ew_sub(out, x) };
            return;
        }
    }
    ew_sub_scalar_impl(out, x);
}

/// `out[i] /= x[i]` (lengths must match).
pub fn ew_div(out: &mut [f32], x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd_active() {
            // SAFETY: AVX2 presence verified by `simd_active`.
            unsafe { avx::ew_div(out, x) };
            return;
        }
    }
    ew_div_scalar_impl(out, x);
}

/// `out[i] -= c`.
pub fn ew_sub_c(out: &mut [f32], c: f32) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd_active() {
            // SAFETY: AVX2 presence verified by `simd_active`.
            unsafe { avx::ew_sub_c(out, c) };
            return;
        }
    }
    ew_sub_c_scalar_impl(out, c);
}

/// `out[i] /= c` (a real division — not a `* (1/c)` rewrite, which would
/// change rounding).
pub fn ew_div_c(out: &mut [f32], c: f32) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd_active() {
            // SAFETY: AVX2 presence verified by `simd_active`.
            unsafe { avx::ew_div_c(out, c) };
            return;
        }
    }
    ew_div_c_scalar_impl(out, c);
}

/// `out[i] = -out[i]` (sign-bit flip, NaN payloads included).
pub fn ew_neg(out: &mut [f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd_active() {
            // SAFETY: AVX2 presence verified by `simd_active`.
            unsafe { avx::ew_neg(out) };
            return;
        }
    }
    ew_neg_scalar_impl(out);
}

/// `out[i] = |out[i]|` (sign-bit clear, NaN payloads included).
pub fn ew_abs(out: &mut [f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd_active() {
            // SAFETY: AVX2 presence verified by `simd_active`.
            unsafe { avx::ew_abs(out) };
            return;
        }
    }
    ew_abs_scalar_impl(out);
}

/// `out[i] = sqrt(out[i])` (correctly rounded on every path).
pub fn ew_sqrt(out: &mut [f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd_active() {
            // SAFETY: AVX2 presence verified by `simd_active`.
            unsafe { avx::ew_sqrt(out) };
            return;
        }
    }
    ew_sqrt_scalar_impl(out);
}

/// `out[i] = 1 / out[i]` (a real division — `rcp_ps` is approximate and
/// never used).
pub fn ew_recip(out: &mut [f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd_active() {
            // SAFETY: AVX2 presence verified by `simd_active`.
            unsafe { avx::ew_recip(out) };
            return;
        }
    }
    ew_recip_scalar_impl(out);
}

/// `out[i] = exp(out[i])`. One libm call per element on every path — there
/// is no bit-identical vector exp offline, so batching here means one call
/// per *slice*, with the loop body free of stack-machine dispatch.
pub fn ew_exp(out: &mut [f32]) {
    for o in out.iter_mut() {
        *o = o.exp();
    }
}

/// `out[i] = ln(out[i])` (see [`ew_exp`] on why this is a scalar loop).
pub fn ew_ln(out: &mut [f32]) {
    for o in out.iter_mut() {
        *o = o.ln();
    }
}

/// `out[i] = out[i].powf(y[i])` (lengths must match; libm per element).
pub fn ew_pow(out: &mut [f32], y: &[f32]) {
    debug_assert_eq!(out.len(), y.len());
    for (o, &e) in out.iter_mut().zip(y) {
        *o = o.powf(e);
    }
}

/// `out[i] = out[i].powf(c)`.
pub fn ew_pow_c(out: &mut [f32], c: f32) {
    for o in out.iter_mut() {
        *o = o.powf(c);
    }
}

/// `out[i] = f32::max(out[i], y[i])` — exactly `f32::max` (IEEE maxNum:
/// a NaN operand yields the other operand), which AVX `max_ps` does *not*
/// implement, so this stays a scalar-call loop on every path.
pub fn ew_max(out: &mut [f32], y: &[f32]) {
    debug_assert_eq!(out.len(), y.len());
    for (o, &v) in out.iter_mut().zip(y) {
        *o = o.max(v);
    }
}

/// `out[i] = f32::max(out[i], c)` (see [`ew_max`]).
pub fn ew_max_c(out: &mut [f32], c: f32) {
    for o in out.iter_mut() {
        *o = o.max(c);
    }
}

/// `out[i] = f32::min(out[i], y[i])` (see [`ew_max`]).
pub fn ew_min(out: &mut [f32], y: &[f32]) {
    debug_assert_eq!(out.len(), y.len());
    for (o, &v) in out.iter_mut().zip(y) {
        *o = o.min(v);
    }
}

/// `out[i] = f32::min(out[i], c)` (see [`ew_max`]).
pub fn ew_min_c(out: &mut [f32], c: f32) {
    for o in out.iter_mut() {
        *o = o.min(c);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Straight-line oracle of the canonical dot order, written
    /// independently of `dot_scalar`'s chunking helpers.
    fn dot_oracle(a: &[f32], b: &[f32]) -> f32 {
        let mut lanes = [0.0f32; LANES];
        let full = a.len() - a.len() % LANES;
        let mut i = 0;
        while i < full {
            lanes[i % LANES] += a[i] * b[i];
            i += 1;
        }
        let mut s = combine_add(&lanes);
        while i < a.len() {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    #[test]
    fn scalar_dot_matches_canonical_order() {
        let a: Vec<f32> = (0..37).map(|i| (i as f32 * 0.7).sin()).collect();
        let b: Vec<f32> = (0..37).map(|i| (i as f32 * 1.3).cos()).collect();
        for len in [0usize, 1, 7, 8, 9, 16, 23, 37] {
            let s = dot_scalar(&a[..len], &b[..len]);
            let o = dot_oracle(&a[..len], &b[..len]);
            assert_eq!(s.to_bits(), o.to_bits(), "len {len}");
        }
    }

    #[test]
    fn max_select_ignores_nan_and_handles_empty() {
        assert_eq!(max_scalar(&[]), f32::NEG_INFINITY);
        let v = [1.0, f32::NAN, 3.0, f32::NEG_INFINITY, 2.0];
        assert_eq!(max_scalar(&v), 3.0);
        let all_nan = [f32::NAN; 11];
        assert_eq!(max_scalar(&all_nan), f32::NEG_INFINITY);
        let with_inf = [0.0, f32::INFINITY, -1.0];
        assert_eq!(max_scalar(&with_inf), f32::INFINITY);
    }

    #[test]
    fn elementwise_scalar_kernels() {
        let mut o = vec![1.0f32, 2.0, 3.0];
        add_assign_scalar(&mut o, &[10.0, 20.0, 30.0]);
        assert_eq!(o, vec![11.0, 22.0, 33.0]);
        mul_assign_scalar(&mut o, &[2.0, 2.0, 2.0]);
        assert_eq!(o, vec![22.0, 44.0, 66.0]);
        axpy_scalar(&mut o, 0.5, &[2.0, 2.0, 2.0]);
        assert_eq!(o, vec![23.0, 45.0, 67.0]);
        add_scalar_scalar(&mut o, 1.0);
        mul_scalar_scalar(&mut o, 0.0);
        assert_eq!(o, vec![0.0, 0.0, 0.0]);
    }

    /// The expression-VM slice kernels reproduce the scalar operation on
    /// every element, special values included — compared via `to_bits` so
    /// NaN signs/payloads count.
    #[test]
    fn ew_kernels_match_scalar_ops_bitwise() {
        let specials = [
            0.0f32,
            -0.0,
            1.0,
            -1.5,
            f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            0.5,
            -2.0,
            3.25,
            1e-30,
        ];
        // 27 elements: three full 8-lanes plus a tail
        let base: Vec<f32> = (0..27)
            .map(|i| specials[i % specials.len()] * if i % 2 == 0 { 1.0 } else { -1.0 })
            .collect();
        let rhs: Vec<f32> = (0..27)
            .map(|i| specials[(i * 7 + 3) % specials.len()])
            .collect();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        let check_un = |name: &str, kernel: &dyn Fn(&mut [f32]), op: &dyn Fn(f32) -> f32| {
            let mut got = base.clone();
            kernel(&mut got);
            let want: Vec<f32> = base.iter().map(|&x| op(x)).collect();
            assert_eq!(bits(&got), bits(&want), "{name}");
        };
        check_un("neg", &|o| ew_neg(o), &|x| -x);
        check_un("abs", &|o| ew_abs(o), &|x| x.abs());
        check_un("sqrt", &|o| ew_sqrt(o), &|x| x.sqrt());
        check_un("recip", &|o| ew_recip(o), &|x| 1.0 / x);
        check_un("exp", &|o| ew_exp(o), &|x| x.exp());
        check_un("ln", &|o| ew_ln(o), &|x| x.ln());
        let check_bin =
            |name: &str, kernel: &dyn Fn(&mut [f32], &[f32]), op: &dyn Fn(f32, f32) -> f32| {
                let mut got = base.clone();
                kernel(&mut got, &rhs);
                let want: Vec<f32> = base.iter().zip(&rhs).map(|(&x, &y)| op(x, y)).collect();
                assert_eq!(bits(&got), bits(&want), "{name}");
            };
        check_bin("sub", &|o, x| ew_sub(o, x), &|a, b| a - b);
        check_bin("div", &|o, x| ew_div(o, x), &|a, b| a / b);
        check_bin("pow", &|o, x| ew_pow(o, x), &|a, b| a.powf(b));
        check_bin("max", &|o, x| ew_max(o, x), &|a, b| a.max(b));
        check_bin("min", &|o, x| ew_min(o, x), &|a, b| a.min(b));
        for c in [0.0f32, -0.0, 2.5, f32::NAN, f32::INFINITY] {
            check_un(&format!("sub_c {c}"), &|o| ew_sub_c(o, c), &|x| x - c);
            check_un(&format!("div_c {c}"), &|o| ew_div_c(o, c), &|x| x / c);
            check_un(&format!("pow_c {c}"), &|o| ew_pow_c(o, c), &|x| x.powf(c));
            check_un(&format!("max_c {c}"), &|o| ew_max_c(o, c), &|x| x.max(c));
            check_un(&format!("min_c {c}"), &|o| ew_min_c(o, c), &|x| x.min(c));
        }
    }

    /// Dispatch and scalar paths agree bitwise on this machine, whichever
    /// path `simd_active()` selects (the cross-mode sweep lives in
    /// `tests/simd_parity.rs`).
    #[test]
    fn dispatch_matches_scalar_here() {
        let a: Vec<f32> = (0..53).map(|i| (i as f32 * 0.11).sin()).collect();
        let b: Vec<f32> = (0..53).map(|i| (i as f32 * 0.37).cos()).collect();
        assert_eq!(dot(&a, &b).to_bits(), dot_scalar(&a, &b).to_bits());
        assert_eq!(sum(&a).to_bits(), sum_scalar(&a).to_bits());
        assert_eq!(max(&a).to_bits(), max_scalar(&a).to_bits());
        let mut o1 = a.clone();
        let mut o2 = a.clone();
        axpy(&mut o1, 1.5, &b);
        axpy_scalar(&mut o2, 1.5, &b);
        assert_eq!(o1, o2);
    }
}
