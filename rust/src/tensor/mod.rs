//! Minimal dense f32 tensor substrate.
//!
//! The offline build environment ships no linear-algebra crates, so the
//! executor's numeric substrate is built here from scratch: a row-major
//! matrix type, the Table-1 block operations, and the [`Val`] sum type the
//! interpreter passes around (scalar / vector / block — the three local-
//! memory item kinds of §2.1).
//!
//! The hot kernels (`dot_bt`, `matmul`, `add`, `hadamard`, row ops) are
//! built on the explicit-width SIMD layer in [`simd`]: every reduction
//! follows one canonical 8-lane order, so the AVX2 and portable scalar
//! paths — and therefore both execution backends — are bit-identical.

pub mod simd;

use std::fmt;

/// Row-major dense f32 matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "Mat::from_vec: size mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// `self @ other.T` — the paper's `dot` block operator.
    /// Constraint (Table 1): `self.cols == other.cols`.
    ///
    /// Dispatches to [`simd::dot_bt_into`]: an AVX2 4-row register-tiled
    /// micro-kernel streaming both operands row-contiguously (both already
    /// iterate along `k`, so no transpose is needed), or the portable
    /// scalar fallback. Per output element the reduction follows the
    /// canonical [`simd::LANES`]-lane order, so every path is
    /// bit-identical to every other.
    pub fn dot_bt(&self, other: &Mat) -> Mat {
        assert_eq!(
            self.cols, other.cols,
            "dot: inner dims differ ({}x{} vs {}x{})",
            self.rows, self.cols, other.rows, other.cols
        );
        let (m, n, k) = (self.rows, other.rows, self.cols);
        let mut out = Mat::zeros(m, n);
        simd::dot_bt_into(&self.data, &other.data, &mut out.data, m, n, k);
        out
    }

    /// Plain `self @ other` (used by reference paths and tests).
    ///
    /// `i-k-j` loop whose inner axpy walks both the output row and the
    /// `other` row contiguously ([`simd::axpy`] vectorizes across output
    /// columns, so each output element still reduces in ascending `k`
    /// order). There is deliberately no `a == 0.0` skip — it silently
    /// turned `0·NaN`/`0·inf` contributions into nothing, so references
    /// could disagree with the blocked executor on non-finite inputs.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul: inner dims differ");
        let (m, kdim, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let orow = &mut out.data[i * n..(i + 1) * n];
            for k in 0..kdim {
                let a = self.data[i * kdim + k];
                let brow = &other.data[k * n..(k + 1) * n];
                simd::axpy(orow, a, brow);
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self.at(j, i))
    }

    /// Elementwise add (Table 1 `add`), one flat [`simd::add_assign`].
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!(
            (self.rows, self.cols),
            (other.rows, other.cols),
            "add: shape mismatch"
        );
        let mut out = self.clone();
        simd::add_assign(&mut out.data, &other.data);
        out
    }

    /// Hadamard product (Table 1 `mul`), one flat [`simd::mul_assign`].
    pub fn hadamard(&self, other: &Mat) -> Mat {
        assert_eq!(
            (self.rows, self.cols),
            (other.rows, other.cols),
            "mul: shape mismatch"
        );
        let mut out = self.clone();
        simd::mul_assign(&mut out.data, &other.data);
        out
    }

    pub fn zip(&self, other: &Mat, f: impl Fn(f32, f32) -> f32) -> Mat {
        assert_eq!(
            (self.rows, self.cols),
            (other.rows, other.cols),
            "zip: shape mismatch"
        );
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| f(*a, *b))
                .collect(),
        }
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| f(*x)).collect(),
        }
    }

    /// `self + c[:,newaxis]` (Table 1 `row_shift`); `c.len() == rows`.
    pub fn row_shift(&self, c: &[f32]) -> Mat {
        assert_eq!(c.len(), self.rows, "row_shift: vector len != rows");
        let mut out = self.clone();
        for (i, &ci) in c.iter().enumerate() {
            simd::add_scalar(&mut out.data[i * self.cols..(i + 1) * self.cols], ci);
        }
        out
    }

    /// `self * c[:,newaxis]` (Table 1 `row_scale`); `c.len() == rows`.
    pub fn row_scale(&self, c: &[f32]) -> Mat {
        assert_eq!(c.len(), self.rows, "row_scale: vector len != rows");
        let mut out = self.clone();
        for (i, &ci) in c.iter().enumerate() {
            simd::mul_scalar(&mut out.data[i * self.cols..(i + 1) * self.cols], ci);
        }
        out
    }

    /// Sum of each row (see DESIGN.md on the Table-1 `row_sum` erratum),
    /// in the canonical [`simd::LANES`]-lane order: 8 stride-8 partial
    /// sums, fixed-tree combine, ascending tail.
    pub fn row_sum(&self) -> Vec<f32> {
        (0..self.rows).map(|i| simd::sum(self.row(i))).collect()
    }

    /// Max of each row (numerical-safety pass), via [`simd::max`]'s
    /// deterministic `>`-select (NaN elements are ignored — a NaN is
    /// never `>` the running max, matching the previous `f32::max`-over-
    /// `-inf` behavior; an empty row yields `-inf`).
    pub fn row_max(&self) -> Vec<f32> {
        (0..self.rows).map(|i| simd::max(self.row(i))).collect()
    }

    /// Outer product of two vectors (Table 1 `outer`).
    pub fn outer(a: &[f32], b: &[f32]) -> Mat {
        Mat::from_fn(a.len(), b.len(), |i, j| a[i] * b[j])
    }

    /// Extract the sub-block `[r0..r0+h, c0..c0+w]`.
    pub fn slice(&self, r0: usize, c0: usize, h: usize, w: usize) -> Mat {
        assert!(r0 + h <= self.rows && c0 + w <= self.cols, "slice oob");
        Mat::from_fn(h, w, |i, j| self.at(r0 + i, c0 + j))
    }

    /// Write `block` at offset `[r0, c0]`.
    pub fn place(&mut self, r0: usize, c0: usize, block: &Mat) {
        assert!(
            r0 + block.rows <= self.rows && c0 + block.cols <= self.cols,
            "place oob"
        );
        for i in 0..block.rows {
            for j in 0..block.cols {
                *self.at_mut(r0 + i, c0 + j) = block.at(i, j);
            }
        }
    }

    /// Maximum absolute difference (numeric comparisons in tests).
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:9.4} ", self.at(i, j))?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

/// A local-memory value: the three §2.1 item kinds.
#[derive(Clone, PartialEq, Debug)]
pub enum Val {
    Scalar(f32),
    Vector(Vec<f32>),
    Block(Mat),
}

impl Val {
    pub fn bytes(&self) -> usize {
        match self {
            Val::Scalar(_) => 4,
            Val::Vector(v) => v.len() * 4,
            Val::Block(m) => m.bytes(),
        }
    }

    pub fn as_block(&self) -> &Mat {
        match self {
            Val::Block(m) => m,
            other => panic!("expected block, got {other:?}"),
        }
    }

    pub fn as_vector(&self) -> &[f32] {
        match self {
            Val::Vector(v) => v,
            other => panic!("expected vector, got {other:?}"),
        }
    }

    pub fn as_scalar(&self) -> f32 {
        match self {
            Val::Scalar(s) => *s,
            other => panic!("expected scalar, got {other:?}"),
        }
    }

    /// Elementwise sum — the [`Val::zip`] `+` fast path. Vector and block
    /// operands run on [`simd::add_assign`] instead of a per-element
    /// closure; scalars (and kind mismatches, which panic) fall back to
    /// `zip`. Bit-identical to `zip(other, |a, b| a + b)`.
    pub fn add(&self, other: &Val) -> Val {
        match (self, other) {
            (Val::Block(a), Val::Block(b)) => Val::Block(a.add(b)),
            (Val::Vector(a), Val::Vector(b)) => {
                assert_eq!(a.len(), b.len(), "Val::add: vector length mismatch");
                let mut out = a.clone();
                simd::add_assign(&mut out, b);
                Val::Vector(out)
            }
            _ => self.zip(other, |x, y| x + y),
        }
    }

    /// Elementwise product — the [`Val::zip`] `*` fast path (see
    /// [`Val::add`]).
    pub fn mul(&self, other: &Val) -> Val {
        match (self, other) {
            (Val::Block(a), Val::Block(b)) => Val::Block(a.hadamard(b)),
            (Val::Vector(a), Val::Vector(b)) => {
                assert_eq!(a.len(), b.len(), "Val::mul: vector length mismatch");
                let mut out = a.clone();
                simd::mul_assign(&mut out, b);
                Val::Vector(out)
            }
            _ => self.zip(other, |x, y| x * y),
        }
    }

    /// Elementwise combine of same-shaped values.
    pub fn zip(&self, other: &Val, f: impl Fn(f32, f32) -> f32) -> Val {
        match (self, other) {
            (Val::Scalar(a), Val::Scalar(b)) => Val::Scalar(f(*a, *b)),
            (Val::Vector(a), Val::Vector(b)) => {
                assert_eq!(a.len(), b.len(), "Val::zip: vector length mismatch");
                Val::Vector(a.iter().zip(b).map(|(x, y)| f(*x, *y)).collect())
            }
            (Val::Block(a), Val::Block(b)) => Val::Block(a.zip(b, f)),
            (a, b) => panic!("Val::zip: item kind mismatch: {a:?} vs {b:?}"),
        }
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Val {
        match self {
            Val::Scalar(a) => Val::Scalar(f(*a)),
            Val::Vector(a) => Val::Vector(a.iter().map(|x| f(*x)).collect()),
            Val::Block(a) => Val::Block(a.map(f)),
        }
    }

    pub fn max_abs_diff(&self, other: &Val) -> f32 {
        match (self, other) {
            (Val::Scalar(a), Val::Scalar(b)) => (a - b).abs(),
            (Val::Vector(a), Val::Vector(b)) => {
                assert_eq!(a.len(), b.len());
                a.iter()
                    .zip(b)
                    .map(|(x, y)| (x - y).abs())
                    .fold(0.0, f32::max)
            }
            (Val::Block(a), Val::Block(b)) => a.max_abs_diff(b),
            (a, b) => panic!("max_abs_diff: item kind mismatch: {a:?} vs {b:?}"),
        }
    }
}

/// Simple deterministic PRNG (SplitMix64) for synthetic data — the offline
/// environment has no `rand` crate.
#[derive(Clone, Debug)]
pub struct Rng(pub u64);

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng(seed)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [-1, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32 * 2.0 - 1.0
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f32() + 1.0) / 2.0 * (hi - lo)
    }

    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    pub fn mat(&mut self, rows: usize, cols: usize) -> Mat {
        Mat::from_fn(rows, cols, |_, _| self.f32())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_bt_matches_matmul_transpose() {
        let mut rng = Rng::new(7);
        let a = rng.mat(3, 5);
        let b = rng.mat(4, 5);
        let d = a.dot_bt(&b);
        let m = a.matmul(&b.transpose());
        assert!(d.max_abs_diff(&m) < 1e-5);
        assert_eq!((d.rows, d.cols), (3, 4));
    }

    /// The tiled micro-kernel and the remainder paths must agree on every
    /// tile-boundary combination (full tiles, row tail, lane tail).
    #[test]
    fn dot_bt_tiled_agrees_on_awkward_shapes() {
        // straight-line oracle of the documented canonical reduction
        // order: 8 stride-8 lanes, fixed combine tree, ascending tail
        fn dot_oracle(a: &[f32], b: &[f32]) -> f32 {
            let n = a.len();
            let full = n - n % 8;
            let mut lanes = [0.0f32; 8];
            for i in 0..full {
                lanes[i % 8] += a[i] * b[i];
            }
            let mut s = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
                + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
            for i in full..n {
                s += a[i] * b[i];
            }
            s
        }
        let mut rng = Rng::new(11);
        for (m, n, k) in [(1, 1, 1), (4, 4, 8), (5, 7, 3), (9, 6, 13), (8, 8, 1), (3, 12, 32)] {
            let a = rng.mat(m, k);
            let b = rng.mat(n, k);
            let fast = a.dot_bt(&b);
            let mut want = Mat::zeros(m, n);
            for i in 0..m {
                for j in 0..n {
                    *want.at_mut(i, j) = dot_oracle(a.row(i), b.row(j));
                }
            }
            // bit-identical: every path reduces in the canonical order
            assert_eq!(fast.data, want.data, "shape {m}x{n}x{k}");
        }
    }

    /// Regression: `matmul` used to skip `a == 0.0` terms, silently turning
    /// `0·NaN` and `0·inf` contributions into nothing, so references could
    /// disagree with the blocked executor on non-finite inputs.
    #[test]
    fn matmul_propagates_nan_and_inf_through_zero() {
        let a = Mat::from_vec(1, 2, vec![0.0, 2.0]);
        let b = Mat::from_vec(2, 2, vec![f32::NAN, f32::INFINITY, 3.0, 4.0]);
        let c = a.matmul(&b);
        assert!(c.at(0, 0).is_nan(), "0*NaN + 2*3 must be NaN, got {}", c.at(0, 0));
        assert!(c.at(0, 1).is_nan(), "0*inf + 2*4 must be NaN, got {}", c.at(0, 1));
        // finite inputs are unaffected by the fix
        let f = Mat::from_vec(1, 2, vec![0.0, 2.0]);
        let g = Mat::from_vec(2, 1, vec![5.0, 7.0]);
        assert_eq!(f.matmul(&g).data, vec![14.0]);
    }

    #[test]
    fn row_ops() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.row_sum(), vec![6., 15.]);
        assert_eq!(a.row_max(), vec![3., 6.]);
        let s = a.row_shift(&[10., 20.]);
        assert_eq!(s.at(0, 0), 11.);
        assert_eq!(s.at(1, 2), 26.);
        let c = a.row_scale(&[2., 3.]);
        assert_eq!(c.at(0, 2), 6.);
        assert_eq!(c.at(1, 0), 12.);
    }

    #[test]
    fn outer_product() {
        let o = Mat::outer(&[1., 2.], &[3., 4., 5.]);
        assert_eq!((o.rows, o.cols), (2, 3));
        assert_eq!(o.at(1, 2), 10.);
    }

    #[test]
    fn slice_place_roundtrip() {
        let mut rng = Rng::new(3);
        let a = rng.mat(6, 8);
        let s = a.slice(2, 4, 3, 2);
        let mut b = Mat::zeros(6, 8);
        b.place(2, 4, &s);
        assert_eq!(b.at(3, 5), a.at(3, 5));
        assert_eq!(b.at(0, 0), 0.0);
    }

    #[test]
    fn val_zip_and_map() {
        let a = Val::Vector(vec![1., 2.]);
        let b = Val::Vector(vec![3., 4.]);
        assert_eq!(a.zip(&b, |x, y| x + y), Val::Vector(vec![4., 6.]));
        assert_eq!(a.map(|x| x * 2.), Val::Vector(vec![2., 4.]));
    }

    /// The `Val::add`/`Val::mul` fast paths are bit-identical to the
    /// closure `zip` they replace, on every item kind.
    #[test]
    fn val_fast_paths_match_zip() {
        let mut rng = Rng::new(21);
        let vals = [
            Val::Scalar(rng.f32()),
            Val::Vector((0..11).map(|_| rng.f32()).collect()),
            Val::Block(rng.mat(5, 9)),
        ];
        for v in &vals {
            let w = v.map(|x| x * 0.5 + 0.25);
            assert_eq!(v.add(&w), v.zip(&w, |x, y| x + y));
            assert_eq!(v.mul(&w), v.zip(&w, |x, y| x * y));
        }
    }

    #[test]
    fn rng_deterministic_and_in_range() {
        let mut r1 = Rng::new(42);
        let mut r2 = Rng::new(42);
        for _ in 0..10 {
            assert_eq!(r1.next_u64(), r2.next_u64());
        }
        for _ in 0..100 {
            let x = r1.f32();
            assert!((-1.0..1.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn hadamard_and_add() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::from_vec(2, 2, vec![5., 6., 7., 8.]);
        assert_eq!(a.hadamard(&b).data, vec![5., 12., 21., 32.]);
        assert_eq!(a.add(&b).data, vec![6., 8., 10., 12.]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(9);
        let a = rng.mat(4, 7);
        assert_eq!(a.transpose().transpose(), a);
    }
}
