//! Rule 4: Linearity of Matmul — Swap Scale/Dot.
//!
//! Pattern: a mapped `row_scale` over the contraction dim `k` whose sole
//! consumer is the left operand of a block matmul. Mathematically
//! `diag(c)·I1·I2 = diag(c)·(I1·I2)`, so scaling can move after the
//! multiplication, where it maps over the *output* dim `a` instead of `k` —
//! unblocking the matmul (it no longer waits for `c`) and aligning map
//! dimensions for Rules 1/2.

use super::matmul::{all_matmuls, MatmulMatch};
use crate::ir::dim::Dim;
use crate::ir::func::FuncOp;
use crate::ir::graph::{map_over, port, ArgMode, Graph, NodeId, NodeKind, OutMode, Port};

/// A map over `dim` whose inner graph is a single `row_scale`/`row_shift`:
/// returns (data source port, vector source port).
pub fn match_norm_map(g: &Graph, id: NodeId, op: &FuncOp) -> Option<(Port, Port, Dim)> {
    let m = g.node(id).as_map()?;
    if m.skip_first || m.inputs.len() != 2 || m.outputs.len() != 1 {
        return None;
    }
    if !matches!(m.outputs[0].mode, OutMode::Collect) {
        return None;
    }
    let inner = &m.inner;
    let mut func = None;
    for nid in inner.node_ids() {
        match &inner.node(nid).kind {
            NodeKind::Input { .. } | NodeKind::Output => {}
            NodeKind::Func(f) if f == op => {
                if func.replace(nid).is_some() {
                    return None;
                }
            }
            _ => return None,
        }
    }
    let func = func?;
    let x_src = inner.producer(port(func, 0))?;
    let c_src = inner.producer(port(func, 1))?;
    // arg0 from the mapped input, arg1 from the broadcast input
    let x_pos = m.inputs.iter().position(|mi| mi.inner_input == x_src.node)?;
    let c_pos = m.inputs.iter().position(|mi| mi.inner_input == c_src.node)?;
    if m.inputs[x_pos].mode != ArgMode::Mapped || m.inputs[c_pos].mode != ArgMode::Bcast {
        return None;
    }
    // func must feed the single output
    let out_node = m.outputs[0].inner_output;
    if inner.consumers(port(func, 0)) != vec![port(out_node, 0)] {
        return None;
    }
    let x_outer = g.producer(port(id, x_pos))?;
    let c_outer = g.producer(port(id, c_pos))?;
    Some((x_outer, c_outer, m.dim.clone()))
}

/// Find (scale map, matmul) where the scale's collect output feeds exactly
/// the matmul's left port and nothing else.
pub fn find(g: &Graph) -> Option<(NodeId, Port, Port, MatmulMatch)> {
    let matmuls = all_matmuls(g);
    if matmuls.is_empty() {
        return None;
    }
    for s in super::map_ids(g) {
        let Some((x_src, c_src, s_dim)) = match_norm_map(g, s, &FuncOp::RowScale) else {
            continue;
        };
        let consumers = g.consumers(port(s, 0));
        if consumers.len() != 1 {
            continue; // "no other outgoing edges" (Rule 8 handles fan-out)
        }
        for mm in &matmuls {
            if consumers[0] == port(mm.pmap, mm.left_port) && mm.k_dim == s_dim {
                return Some((s, x_src, c_src, mm.clone()));
            }
        }
    }
    None
}

pub fn try_rule4(g: &mut Graph) -> Option<String> {
    let (s, x_src, c_src, mm) = find(g)?;
    apply_swap(g, s, x_src, c_src, &mm, FuncOp::RowScale);
    Some(format!(
        "swapped {}-scale n{s} after matmul n{} (now a {}-map)",
        mm.k_dim, mm.pmap, mm.a_dim
    ))
}

/// Shared with Rule 4's apply: feed the matmul the un-normalized operand and
/// re-apply the normalization over the output dim.
pub(super) fn apply_swap(
    g: &mut Graph,
    s: NodeId,
    x_src: Port,
    c_src: Port,
    mm: &MatmulMatch,
    op: FuncOp,
) {
    // 1. matmul consumes the raw operand
    g.connect(x_src, port(mm.pmap, mm.left_port));
    // 2. drop the scale map
    g.remove_node(s);
    // 3. re-scale the matmul's output, mapped over the output dim
    let old_consumers = g.consumers(port(mm.pmap, 0));
    let ns = map_over(
        g,
        mm.a_dim.clone(),
        &[
            (port(mm.pmap, 0), ArgMode::Mapped),
            (c_src, ArgMode::Bcast),
        ],
        |mb, ins| {
            let r = mb.g.func(op, &[ins[0], ins[1]]);
            mb.collect(r);
        },
    );
    for c in old_consumers {
        g.connect(ns[0], c);
    }
}

#[cfg(test)]
pub mod tests {
    use super::*;
    use crate::ir::func::ReduceOp;
    use crate::ir::types::Ty;
    use crate::ir::validate::assert_valid;
    use crate::rules::matmul::build_matmul;

    /// scale(I1 by c) then matmul with I2 — the paper's Rule-4 pattern.
    pub fn scale_matmul_program() -> (Graph, crate::ir::graph::Port) {
        let mut g = Graph::new();
        let i1 = g.input("I1", Ty::blocks(&["K"]));
        let i2 = g.input("I2T", Ty::blocks(&["N", "K"]));
        // c: a vector computed in local memory (reduce of row sums)
        let pre = map_over(&mut g, "K", &[(i1, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.func(FuncOp::RowSum, &[ins[0]]);
            mb.reduce_out(r, ReduceOp::Add);
        });
        let c = g.ew1(crate::ir::expr::Expr::var(0).recip(), pre[0]);
        let scaled = map_over(
            &mut g,
            "K",
            &[(i1, ArgMode::Mapped), (c, ArgMode::Bcast)],
            |mb, ins| {
                let r = mb.g.func(FuncOp::RowScale, &[ins[0], ins[1]]);
                mb.collect(r);
            },
        );
        let o = build_matmul(&mut g, scaled[0], i2, "N", "K");
        g.output("I3", o);
        (g, o)
    }

    #[test]
    fn matches_and_swaps() {
        let (mut g, _) = scale_matmul_program();
        assert!(find(&g).is_some());
        let msg = try_rule4(&mut g).unwrap();
        assert!(msg.contains("swapped"));
        assert_valid(&g);
        assert!(find(&g).is_none(), "pattern gone after apply");
        // the new scale map is over N now
        let n_scale = super::super::map_ids(&g)
            .into_iter()
            .filter(|&id| match_norm_map(&g, id, &FuncOp::RowScale).is_some())
            .count();
        assert_eq!(n_scale, 1);
        let id = super::super::map_ids(&g)
            .into_iter()
            .find(|&id| match_norm_map(&g, id, &FuncOp::RowScale).is_some())
            .unwrap();
        assert_eq!(g.node(id).as_map().unwrap().dim.name(), "N");
    }

    #[test]
    fn fanout_blocks_rule4() {
        let (mut g, _) = scale_matmul_program();
        // add a second consumer of the scaled list
        let sid = super::super::map_ids(&g)
            .into_iter()
            .find(|&id| match_norm_map(&g, id, &FuncOp::RowScale).is_some())
            .unwrap();
        g.output("scaled_too", port(sid, 0));
        assert!(find(&g).is_none());
    }
}
