//! Rule 2: Fuse Sibling Maps.
//!
//! Pattern: two maps over the same dimension that share a common parent
//! (some output port feeds an input port of each, with the same access
//! mode) and are not reachable from each other. Fusing merges the shared
//! inputs, so each shared block is copied from global to local memory once
//! instead of twice.

use super::merge::fuse_maps;
use crate::ir::graph::{port, Graph, NodeId};

pub fn find(g: &Graph) -> Option<(NodeId, NodeId)> {
    let maps = super::map_ids(g);
    for (a, &u) in maps.iter().enumerate() {
        let um = g.node(u).as_map().unwrap();
        if um.skip_first {
            continue;
        }
        for &v in &maps[a + 1..] {
            let vm = g.node(v).as_map().unwrap();
            if vm.dim != um.dim || vm.skip_first {
                continue;
            }
            // any direct edge => Rule 1 territory
            if g.edges().iter().any(|e| {
                (e.src.node == u && e.dst.node == v) || (e.src.node == v && e.dst.node == u)
            }) {
                continue;
            }
            // shared parent with identical mode
            let shared = (0..um.inputs.len()).any(|i| {
                let Some(s) = g.producer(port(u, i)) else {
                    return false;
                };
                (0..vm.inputs.len()).any(|j| {
                    g.producer(port(v, j)) == Some(s) && vm.inputs[j].mode == um.inputs[i].mode
                })
            });
            if !shared {
                continue;
            }
            if g.reaches(u, v) || g.reaches(v, u) {
                continue;
            }
            return Some((u, v));
        }
    }
    None
}

pub fn try_rule2(g: &mut Graph) -> Option<String> {
    let (u, v) = find(g)?;
    let dim = g.node(u).as_map().unwrap().dim.clone();
    let fused = fuse_maps(g, u, v);
    Some(format!("fused sibling {dim}-maps n{u}+n{v} -> n{fused}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::expr::Expr;
    use crate::ir::func::FuncOp;
    use crate::ir::graph::{map_over, ArgMode, Graph};
    use crate::ir::types::Ty;
    use crate::ir::validate::assert_valid;

    #[test]
    fn fuses_siblings_sharing_parent() {
        let mut g = Graph::new();
        let x = g.input("X", Ty::blocks(&["K"]));
        let o1 = map_over(&mut g, "K", &[(x, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.func(FuncOp::RowSum, &[ins[0]]);
            mb.collect(r);
        });
        let o2 = map_over(&mut g, "K", &[(x, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.ew1(Expr::var(0).pow(Expr::cst(2.0)), ins[0]);
            mb.collect(r);
        });
        g.output("S", o1[0]);
        g.output("Q", o2[0]);
        assert!(find(&g).is_some());
        try_rule2(&mut g).unwrap();
        assert_valid(&g);
        let maps = super::super::map_ids(&g);
        assert_eq!(maps.len(), 1);
        let m = g.node(maps[0]).as_map().unwrap();
        assert_eq!(m.inputs.len(), 1, "X loaded once");
        assert_eq!(m.outputs.len(), 2);
    }

    #[test]
    fn no_shared_parent_blocks() {
        let mut g = Graph::new();
        let x = g.input("X", Ty::blocks(&["K"]));
        let y = g.input("Y", Ty::blocks(&["K"]));
        let o1 = map_over(&mut g, "K", &[(x, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.func(FuncOp::RowSum, &[ins[0]]);
            mb.collect(r);
        });
        let o2 = map_over(&mut g, "K", &[(y, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.func(FuncOp::RowSum, &[ins[0]]);
            mb.collect(r);
        });
        g.output("S", o1[0]);
        g.output("Q", o2[0]);
        assert!(find(&g).is_none());
    }

    #[test]
    fn reachable_siblings_block() {
        // u -> reduce -> v, both consume X: still blocked (path would loop).
        let mut g = Graph::new();
        let x = g.input("X", Ty::blocks(&["K"]));
        let o1 = map_over(&mut g, "K", &[(x, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.func(FuncOp::RowSum, &[ins[0]]);
            mb.collect(r);
        });
        let red = g.reduce(crate::ir::func::ReduceOp::Add, o1[0]);
        let o2 = map_over(
            &mut g,
            "K",
            &[(x, ArgMode::Mapped), (red, ArgMode::Bcast)],
            |mb, ins| {
                let r = mb.g.func(FuncOp::RowScale, &[ins[0], ins[1]]);
                mb.collect(r);
            },
        );
        g.output("Z", o2[0]);
        assert!(find(&g).is_none());
    }
}
