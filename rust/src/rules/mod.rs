//! The paper's §3 substitution rules.
//!
//! Each rule is a logic-preserving rewrite: a `try_ruleN(g)` function
//! searches graph `g` (one level of the hierarchy — rules never look across
//! levels except *into* map nodes that are part of their own pattern),
//! applies the first match found in deterministic node-id order, and returns
//! a human-readable detail string, or `None` if no match exists.
//!
//! Fusion rules (1, 2, 3) remove buffered edges directly; companion rules
//! (4, 5, 6, 7, 8) expose hidden opportunities — some by replicating work —
//! and Rule 9 fuses elementwise chains.

pub mod matmul;
pub mod rule1;
pub mod rule2;
pub mod rule3;
pub mod rule4;
pub mod rule5;
pub mod rule6;
pub mod rule7;
pub mod rule8;
pub mod rule9;

mod merge;

pub use merge::fuse_maps;

use crate::ir::graph::{Graph, NodeId};
use std::fmt;

/// Identifies one of the paper's nine substitution rules.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub enum RuleId {
    R1,
    R2,
    R3,
    R4,
    R5,
    R6,
    R7,
    R8,
    R9,
}

impl RuleId {
    pub fn name(&self) -> &'static str {
        match self {
            RuleId::R1 => "Rule 1: Fuse Consecutive Maps",
            RuleId::R2 => "Rule 2: Fuse Sibling Maps",
            RuleId::R3 => "Rule 3: Fuse Map with Reduction",
            RuleId::R4 => "Rule 4: Swap Scale/Dot",
            RuleId::R5 => "Rule 5: Swap Shift/Dot",
            RuleId::R6 => "Rule 6: Extend Map to the Entire Graph",
            RuleId::R7 => "Rule 7: Peel Off First Iteration",
            RuleId::R8 => "Rule 8: Duplicate Mapped Scale",
            RuleId::R9 => "Rule 9: Fuse Consecutive Elementwise",
        }
    }

    pub fn short(&self) -> u8 {
        match self {
            RuleId::R1 => 1,
            RuleId::R2 => 2,
            RuleId::R3 => 3,
            RuleId::R4 => 4,
            RuleId::R5 => 5,
            RuleId::R6 => 6,
            RuleId::R7 => 7,
            RuleId::R8 => 8,
            RuleId::R9 => 9,
        }
    }
}

impl fmt::Display for RuleId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Live map node ids of `g`, in id order (the deterministic match order).
pub fn map_ids(g: &Graph) -> Vec<NodeId> {
    g.node_ids().filter(|&i| g.node(i).as_map().is_some()).collect()
}

/// Apply one rule by id; used by the fusion driver.
pub fn try_rule(g: &mut Graph, r: RuleId) -> Option<String> {
    match r {
        RuleId::R1 => rule1::try_rule1(g),
        RuleId::R2 => rule2::try_rule2(g),
        RuleId::R3 => rule3::try_rule3(g),
        RuleId::R4 => rule4::try_rule4(g),
        RuleId::R5 => rule5::try_rule5(g),
        RuleId::R6 => rule6::try_rule6(g),
        RuleId::R7 => rule7::try_rule7(g),
        RuleId::R8 => rule8::try_rule8(g),
        RuleId::R9 => rule9::try_rule9(g),
    }
}
