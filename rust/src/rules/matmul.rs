//! Composite recognizer for the Table-2 block matmul subgraph.
//!
//! In the block program representation, a matrix multiplication
//! `I1 (1×K blocks) · I2 (K×A blocks)` at some graph level is the map node
//!
//! ```text
//! Map(a) {                         // one iteration per output block-column
//!   L : Input [k]   (bcast at a)   // the row of K blocks of I1
//!   R : Input [k]   (mapped at a)  // column a of I2ᵀ's blocks
//!   Map(k){ dot(l, r) } -> [k]     // per-k partial products
//!   Reduce(k)                      // summed into one block
//! } -> Collect [a]
//! ```
//!
//! (fully unfused, "even when a straightforward fusion opportunity is
//! evident" — Rule 3 and Rule 1 fuse the inside later). Rules 4, 5, and 8
//! need to recognize this shape to swap normalizations across it.

use crate::ir::dim::Dim;
use crate::ir::func::FuncOp;
use crate::ir::graph::{port, ArgMode, Graph, NodeId, NodeKind, OutMode};

/// A recognized matmul map node at the current graph level.
#[derive(Clone, Debug)]
pub struct MatmulMatch {
    /// The outer map node (over the output dim `a`).
    pub pmap: NodeId,
    pub a_dim: Dim,
    pub k_dim: Dim,
    /// pmap's input port carrying the left operand (broadcast, ty `[k]`).
    pub left_port: usize,
    /// pmap's input port carrying the right operand (mapped over `a`).
    pub right_port: usize,
}

/// Try to recognize node `id` of `g` as a block matmul.
pub fn match_matmul(g: &Graph, id: NodeId) -> Option<MatmulMatch> {
    let m = g.node(id).as_map()?;
    if m.skip_first || m.inputs.len() != 2 || m.outputs.len() != 1 {
        return None;
    }
    if !matches!(m.outputs[0].mode, OutMode::Collect) {
        return None;
    }
    let inner = &m.inner;

    // Inner structure: exactly one k-map and one reduce besides I/O.
    let mut kmap = None;
    let mut red = None;
    for nid in inner.node_ids() {
        match &inner.node(nid).kind {
            NodeKind::Input { .. } | NodeKind::Output => {}
            NodeKind::Map(_) => {
                if kmap.replace(nid).is_some() {
                    return None;
                }
            }
            NodeKind::Reduce(crate::ir::func::ReduceOp::Add) => {
                if red.replace(nid).is_some() {
                    return None;
                }
            }
            _ => return None,
        }
    }
    let (kmap, red) = (kmap?, red?);
    let km = inner.node(kmap).as_map()?;
    if km.skip_first || km.inputs.len() != 2 || km.outputs.len() != 1 {
        return None;
    }
    if !matches!(km.outputs[0].mode, OutMode::Collect) {
        return None;
    }
    let k_dim = km.dim.clone();

    // kmap's inner: a single Dot over the two mapped inputs.
    let ki = &km.inner;
    let mut dot = None;
    for nid in ki.node_ids() {
        match &ki.node(nid).kind {
            NodeKind::Input { .. } | NodeKind::Output => {}
            NodeKind::Func(FuncOp::Dot) => {
                if dot.replace(nid).is_some() {
                    return None;
                }
            }
            _ => return None,
        }
    }
    let dot = dot?;
    if km.inputs.iter().any(|mi| mi.mode != ArgMode::Mapped) {
        return None;
    }
    // dot args must come straight from kmap's two inner inputs
    let dot_l = ki.producer(port(dot, 0))?;
    let dot_r = ki.producer(port(dot, 1))?;
    let kin0 = km.inputs[0].inner_input;
    let kin1 = km.inputs[1].inner_input;
    let (l_kport, r_kport) = if dot_l.node == kin0 && dot_r.node == kin1 {
        (0usize, 1usize)
    } else if dot_l.node == kin1 && dot_r.node == kin0 {
        (1, 0)
    } else {
        return None;
    };

    // kmap's collect must feed the reduce, and the reduce must feed pmap's
    // inner output.
    let kmap_consumers = inner.consumers(port(kmap, 0));
    if kmap_consumers != vec![port(red, 0)] {
        return None;
    }
    let red_consumers = inner.consumers(port(red, 0));
    if red_consumers.len() != 1 {
        return None;
    }
    let out_node = m.outputs[0].inner_output;
    if red_consumers[0] != port(out_node, 0) {
        return None;
    }

    // Map the kmap's dot operands back to pmap's ports: the left operand is
    // pmap-broadcast, the right is pmap-mapped.
    let trace_to_pmap_port = |k_port: usize| -> Option<usize> {
        let src = inner.producer(port(kmap, k_port))?;
        // must be one of pmap's inner inputs
        m.inputs
            .iter()
            .position(|mi| mi.inner_input == src.node)
    };
    let p_for_dot_left = trace_to_pmap_port(l_kport)?;
    let p_for_dot_right = trace_to_pmap_port(r_kport)?;
    let (left_port, right_port) = (p_for_dot_left, p_for_dot_right);
    if m.inputs[left_port].mode != ArgMode::Bcast
        || m.inputs[right_port].mode != ArgMode::Mapped
    {
        return None;
    }
    // left operand must be a single-level list [k] at the outer level
    let left_src = g.producer(port(id, left_port))?;
    let lt = g.out_ty(left_src);
    if lt.dims.len() != 1 || lt.dims[0] != k_dim {
        return None;
    }

    Some(MatmulMatch {
        pmap: id,
        a_dim: m.dim.clone(),
        k_dim,
        left_port,
        right_port,
    })
}

/// All matmuls at this level, in node-id order.
pub fn all_matmuls(g: &Graph) -> Vec<MatmulMatch> {
    super::map_ids(g)
        .into_iter()
        .filter_map(|id| match_matmul(g, id))
        .collect()
}

/// Build the Table-2 matmul subgraph at the current level:
/// `left` is a `[k]` list of blocks, `right` an `[a,k]`-or-`[k,a]` list of
/// lists; returns the collect-`[a]` output port.
pub fn build_matmul(
    g: &mut Graph,
    left: crate::ir::graph::Port,
    right: crate::ir::graph::Port,
    a_dim: &str,
    k_dim: &str,
) -> crate::ir::graph::Port {
    use crate::ir::graph::map_over;
    let outs = map_over(
        g,
        a_dim,
        &[(left, ArgMode::Bcast), (right, ArgMode::Mapped)],
        |mb, ins| {
            let k = map_over(
                &mut mb.g,
                k_dim,
                &[(ins[0], ArgMode::Mapped), (ins[1], ArgMode::Mapped)],
                |mb2, i2| {
                    let d = mb2.g.func(FuncOp::Dot, &[i2[0], i2[1]]);
                    mb2.collect(d);
                },
            );
            let r = mb.g.reduce(crate::ir::func::ReduceOp::Add, k[0]);
            mb.collect(r);
        },
    );
    outs[0]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::graph::Graph;
    use crate::ir::types::Ty;
    use crate::ir::validate::assert_valid;

    #[test]
    fn recognizes_built_matmul() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["K"]));
        let b = g.input("BT", Ty::blocks(&["N", "K"]));
        let o = build_matmul(&mut g, a, b, "N", "K");
        g.output("C", o);
        assert_valid(&g);
        let mm = match_matmul(&g, o.node).expect("should match");
        assert_eq!(mm.a_dim.name(), "N");
        assert_eq!(mm.k_dim.name(), "K");
        assert_eq!(g.out_ty(o), Ty::blocks(&["N"]));
        assert_eq!(all_matmuls(&g).len(), 1);
    }

    #[test]
    fn rejects_plain_map() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let o = crate::ir::graph::map_over(
            &mut g,
            "N",
            &[(a, ArgMode::Mapped)],
            |mb, ins| {
                let r = mb.g.ew1(crate::ir::expr::Expr::var(0).exp(), ins[0]);
                mb.collect(r);
            },
        );
        g.output("B", o[0]);
        assert!(match_matmul(&g, o[0].node).is_none());
    }
}
