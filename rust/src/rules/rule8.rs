//! Rule 8: Duplicate Mapped Scale.
//!
//! A mapped `row_scale` feeding the left operands of *two or more* matmuls
//! blocks Rule 4 (which requires a single consumer). Duplicating the scale
//! map gives each matmul a private copy, unlocking Rule 4 for each — the
//! first move of the paper's RMSNorm+FFN-SwiGLU trace (the RMS normalization
//! feeds both the W and V projections).

use super::matmul::all_matmuls;
use crate::ir::func::FuncOp;
use crate::ir::graph::{port, Graph, NodeId, NodeKind, Port};

/// Find a scale map whose collect output feeds ≥2 matmul left ports.
/// Returns (scale map, one matmul-left consumer port to peel off).
pub fn find(g: &Graph) -> Option<(NodeId, Port)> {
    let matmuls = all_matmuls(g);
    if matmuls.len() < 2 {
        return None;
    }
    for s in super::map_ids(g) {
        if super::rule4::match_norm_map(g, s, &FuncOp::RowScale).is_none() {
            continue;
        }
        let consumers = g.consumers(port(s, 0));
        let mm_left: Vec<Port> = consumers
            .iter()
            .copied()
            .filter(|c| {
                matmuls
                    .iter()
                    .any(|mm| *c == port(mm.pmap, mm.left_port))
            })
            .collect();
        if mm_left.len() >= 2 {
            return Some((s, mm_left[0]));
        }
    }
    None
}

pub fn try_rule8(g: &mut Graph) -> Option<String> {
    let (s, peel) = find(g)?;
    // Deep-clone the scale map node.
    let node = g.node(s).clone();
    let NodeKind::Map(m) = &node.kind else {
        unreachable!()
    };
    let sources: Vec<Port> = (0..m.inputs.len())
        .map(|i| g.producer(port(s, i)).expect("scale input unconnected"))
        .collect();
    let clone_id = g.add_node(node.kind.clone(), format!("{}'", node.label));
    for (i, src) in sources.iter().enumerate() {
        g.connect(*src, port(clone_id, i));
    }
    // Peel one matmul consumer off to the clone.
    g.connect(port(clone_id, 0), peel);
    Some(format!(
        "duplicated scale map n{s} -> n{clone_id} for matmul input at n{}",
        peel.node
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::func::ReduceOp;
    use crate::ir::graph::{map_over, ArgMode};
    use crate::ir::types::Ty;
    use crate::ir::validate::assert_valid;
    use crate::rules::matmul::build_matmul;

    fn two_matmul_program() -> Graph {
        let mut g = Graph::new();
        let x = g.input("X", Ty::blocks(&["D"]));
        let wt = g.input("WT", Ty::blocks(&["K", "D"]));
        let vt = g.input("VT", Ty::blocks(&["K", "D"]));
        let pre = map_over(&mut g, "D", &[(x, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.func(FuncOp::RowSum, &[ins[0]]);
            mb.reduce_out(r, ReduceOp::Add);
        });
        let c = g.ew1(crate::ir::expr::Expr::var(0).recip().sqrt(), pre[0]);
        let scaled = map_over(
            &mut g,
            "D",
            &[(x, ArgMode::Mapped), (c, ArgMode::Bcast)],
            |mb, ins| {
                let r = mb.g.func(FuncOp::RowScale, &[ins[0], ins[1]]);
                mb.collect(r);
            },
        );
        let o1 = build_matmul(&mut g, scaled[0], wt, "K", "D");
        let o2 = build_matmul(&mut g, scaled[0], vt, "K", "D");
        g.output("W_OUT", o1);
        g.output("V_OUT", o2);
        g
    }

    #[test]
    fn duplicates_shared_scale() {
        let mut g = two_matmul_program();
        // Rule 4 is blocked by fan-out…
        assert!(super::super::rule4::find(&g).is_none());
        // …until rule 8 duplicates.
        assert!(find(&g).is_some());
        try_rule8(&mut g).unwrap();
        assert_valid(&g);
        assert!(find(&g).is_none(), "each matmul now has its own scale");
        assert!(super::super::rule4::find(&g).is_some());
        // Rule 4 applies twice, then never again.
        assert!(super::super::rule4::try_rule4(&mut g).is_some());
        assert!(super::super::rule4::try_rule4(&mut g).is_some());
        assert!(super::super::rule4::try_rule4(&mut g).is_none());
        assert_valid(&g);
    }

    #[test]
    fn single_matmul_no_match() {
        let (g, _) = super::super::rule4::tests::scale_matmul_program();
        assert!(find(&g).is_none());
    }
}
