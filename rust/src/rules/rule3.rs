//! Rule 3: Fuse Map with Reduction.
//!
//! Pattern: a map's collected output (a list of items over the map's own
//! dimension) whose sole consumer is a reduction operator at the same graph
//! level. Instead of materializing the list in global memory and reading it
//! back, the reduction happens on the fly while the map executes: the map
//! output becomes a `Reduce`-mode output (lowering to the paper's serial
//! `for` loop with an accumulator).

use crate::ir::graph::{port, Graph, NodeId, NodeKind, OutMode};

/// Find (map id, output port, reduce node id).
pub fn find(g: &Graph) -> Option<(NodeId, usize, NodeId)> {
    for u in super::map_ids(g) {
        let um = g.node(u).as_map().unwrap();
        for (i, uo) in um.outputs.iter().enumerate() {
            if !matches!(uo.mode, OutMode::Collect) {
                continue;
            }
            // collected elements must be items (single-level list)
            let ty = g.out_ty(port(u, i));
            if ty.dims.len() != 1 {
                continue;
            }
            let consumers = g.consumers(port(u, i));
            if consumers.len() != 1 {
                continue;
            }
            let c = consumers[0];
            if let NodeKind::Reduce(_) = g.node(c.node).kind {
                return Some((u, i, c.node));
            }
        }
    }
    None
}

pub fn try_rule3(g: &mut Graph) -> Option<String> {
    let (u, i, r) = find(g)?;
    let op = match g.node(r).kind {
        NodeKind::Reduce(op) => op,
        _ => unreachable!(),
    };
    let dim = g.node(u).as_map().unwrap().dim.clone();
    // Flip the output mode and splice out the reduction node.
    g.node_mut(u).as_map_mut().unwrap().outputs[i].mode = OutMode::Reduce(op);
    g.rewire_consumers(port(r, 0), port(u, i));
    g.remove_node(r);
    Some(format!(
        "fused {dim}-map n{u} output {i} with reduction n{r}"
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::func::{FuncOp, ReduceOp};
    use crate::ir::graph::{map_over, ArgMode, Graph};
    use crate::ir::types::Ty;
    use crate::ir::validate::assert_valid;
    use crate::loopir::{lower::lower, print::render};

    #[test]
    fn fuses_map_reduce_to_serial_loop() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let o = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.func(FuncOp::RowSum, &[ins[0]]);
            mb.collect(r);
        });
        let red = g.reduce(ReduceOp::Add, o[0]);
        g.output("c", red);
        assert!(find(&g).is_some());
        try_rule3(&mut g).unwrap();
        assert_valid(&g);
        assert!(find(&g).is_none());
        // the paper's fused listing: one serial loop, no temp buffer
        let code = render(&lower(&g));
        let want = "\
for n in range(N):
  t1 = load(A[n])
  t2 += row_sum(t1)
store(t2, c)
";
        assert_eq!(code, want);
    }

    #[test]
    fn multi_consumer_blocks() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let o = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.func(FuncOp::RowSum, &[ins[0]]);
            mb.collect(r);
        });
        let red = g.reduce(ReduceOp::Add, o[0]);
        g.output("c", red);
        g.output("partials", o[0]); // second consumer of the list
        assert!(find(&g).is_none());
    }

    #[test]
    fn multilevel_list_blocks() {
        // Map(M){Map(N){..}} collect is [M,N]; a reduce over M at top level
        // is NOT rule-3 fusible (elements are lists, not items).
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["M", "N"]));
        let o = map_over(&mut g, "M", &[(a, ArgMode::Mapped)], |mb, ins| {
            let inner = map_over(&mut mb.g, "N", &[(ins[0], ArgMode::Mapped)], |mb2, i2| {
                let r = mb2.g.ew1(crate::ir::expr::Expr::var(0).exp(), i2[0]);
                mb2.collect(r);
            });
            mb.collect(inner[0]);
        });
        // no reduce attached; just confirm the census is stable
        g.output("B", o[0]);
        assert!(find(&g).is_none());
    }
}
