//! Rule 6: Extend Map to the Entire Graph.
//!
//! The aggressive companion rule: when a terminal map `X` (its outputs feed
//! only the graph's output nodes) contains an inner `y`-map, and the rest of
//! the graph also contains a `y`-map that `X` depends on, pull *everything
//! else* into `X`'s inner graph. The moved work is replicated once per `X`
//! iteration — a real cost — but both `y`-maps now live in the same graph,
//! where Rules 1/2 can fuse them and eliminate the buffered edge between
//! them. The fusion driver snapshots the program before every application so
//! the selection layer can roll back unprofitable replication.

use crate::ir::graph::{port, ArgMode, Graph, MapIn, MapNode, NodeId, NodeKind, Port};
use std::collections::HashSet;

/// Find an extendable terminal map. Returns (x, moved nodes).
pub fn find(g: &Graph) -> Option<(NodeId, Vec<NodeId>)> {
    let output_ids: HashSet<NodeId> = g.output_ids().into_iter().collect();
    for x in super::map_ids(g) {
        let xm = g.node(x).as_map().unwrap();
        if xm.skip_first {
            continue;
        }
        // X is terminal: every consumer of X is an Output node.
        if !g
            .node_consumers(x)
            .iter()
            .all(|c| output_ids.contains(&c.node))
        {
            continue;
        }
        // moved = all other non-I/O nodes
        let moved: Vec<NodeId> = g
            .node_ids()
            .filter(|&i| i != x && !g.node(i).is_io())
            .collect();
        if moved.is_empty() {
            continue;
        }
        // no moved node may feed an Output node (its value must not need
        // materialization at this level)
        if moved.iter().any(|&m| {
            g.node_consumers(m)
                .iter()
                .any(|c| output_ids.contains(&c.node))
        }) {
            continue;
        }
        // no moved node may iterate X's own dimension (the extension would
        // nest two loops over one dim), and no value consumed inside may
        // still carry X's dim
        if moved
            .iter()
            .any(|&m| g.node(m).as_map().is_some_and(|mm| mm.dim == xm.dim))
        {
            continue;
        }
        let feeds_moved_with_xdim = moved.iter().any(|&m| {
            (0..g.node(m).in_arity()).any(|j| {
                g.producer(port(m, j))
                    .map(|s| g.out_ty(s).has_dim(&xm.dim))
                    .unwrap_or(false)
            })
        });
        if feeds_moved_with_xdim {
            continue;
        }
        // X's mapped ports must be fed by Input nodes (a moved producer can
        // only replace a broadcast binding)
        let mapped_ok = xm.inputs.iter().enumerate().all(|(i, mi)| {
            if mi.mode != ArgMode::Mapped {
                return true;
            }
            match g.producer(port(x, i)) {
                Some(s) => matches!(g.node(s.node).kind, NodeKind::Input { .. }),
                None => false,
            }
        });
        if !mapped_ok {
            continue;
        }
        // gate: a dim shared between X's inner top-level maps and moved maps
        let inner_dims: HashSet<String> = super::map_ids(&xm.inner)
            .into_iter()
            .map(|i| xm.inner.node(i).as_map().unwrap().dim.name().to_string())
            .collect();
        let moved_dims: HashSet<String> = moved
            .iter()
            .filter_map(|&i| g.node(i).as_map())
            .map(|m| m.dim.name().to_string())
            .collect();
        if inner_dims.is_disjoint(&moved_dims) {
            continue;
        }
        return Some((x, moved));
    }
    None
}

pub fn try_rule6(g: &mut Graph) -> Option<String> {
    let (x, moved) = find(g)?;
    let moved_set: HashSet<NodeId> = moved.iter().copied().collect();
    let xm = g.node(x).as_map().unwrap().clone();
    let mut inner = xm.inner.clone();

    // Build the moved subgraph preserving node ids, then absorb.
    let mut mg = Graph::new();
    let max_id = moved.iter().copied().max().unwrap();
    for i in 0..=max_id {
        if moved_set.contains(&i) {
            let id = mg.add_node(g.node(i).kind.clone(), g.node(i).label.clone());
            debug_assert_eq!(id, i);
        } else {
            // placeholder slot to keep ids aligned
            let id = mg.add_node(NodeKind::Output, "__slot__");
            debug_assert_eq!(id, i);
        }
    }
    for i in 0..=max_id {
        if !moved_set.contains(&i) {
            mg.remove_node(i);
        }
    }
    for e in g.edges() {
        if moved_set.contains(&e.src.node) && moved_set.contains(&e.dst.node) {
            mg.connect(e.src, e.dst);
        }
    }
    let remap = inner.absorb(mg);

    // New input list: keep ports fed from outside the moved set; drop ports
    // fed by moved producers (rewired internally).
    let mut kept: Vec<(Port, ArgMode, NodeId)> = Vec::new();
    for (i, mi) in xm.inputs.iter().enumerate() {
        let s = g.producer(port(x, i)).expect("map input unconnected");
        if moved_set.contains(&s.node) {
            assert_eq!(
                mi.mode,
                ArgMode::Bcast,
                "rule 6: moved producer must feed a broadcast port"
            );
            let new_src = port(remap[&s.node], s.port);
            inner.rewire_consumers(port(mi.inner_input, 0), new_src);
            inner.remove_node(mi.inner_input);
        } else {
            kept.push((s, mi.mode, mi.inner_input));
        }
    }

    // Wire moved nodes' outside inputs through (possibly new) bcast ports.
    for &m_id in &moved {
        let n_in = g.node(m_id).in_arity();
        for j in 0..n_in {
            let s = g.producer(port(m_id, j)).expect("moved input unconnected");
            if moved_set.contains(&s.node) {
                continue; // edge preserved by absorb
            }
            let existing = kept
                .iter()
                .find(|(ks, km, _)| *ks == s && *km == ArgMode::Bcast)
                .map(|(_, _, inner_in)| *inner_in);
            let inner_in = match existing {
                Some(n) => n,
                None => {
                    let ty = g.out_ty(s);
                    let n = inner.add_node(
                        NodeKind::Input { ty },
                        g.node(s.node).label.clone(),
                    );
                    kept.push((s, ArgMode::Bcast, n));
                    n
                }
            };
            inner.connect(port(inner_in, 0), port(remap[&m_id], j));
        }
    }

    // Rebuild the map node.
    let inputs: Vec<MapIn> = kept
        .iter()
        .map(|(_, mode, inner_input)| MapIn {
            inner_input: *inner_input,
            mode: *mode,
        })
        .collect();
    let out_consumers: Vec<Vec<Port>> = (0..xm.outputs.len())
        .map(|j| g.consumers(port(x, j)))
        .collect();
    let dim = xm.dim.clone();
    let new_id = g.add_node(
        NodeKind::Map(Box::new(MapNode {
            dim: dim.clone(),
            inner,
            inputs,
            outputs: xm.outputs.clone(),
            skip_first: false,
        })),
        format!("map{dim}"),
    );
    for (k, (s, _, _)) in kept.iter().enumerate() {
        g.connect(*s, port(new_id, k));
    }
    for (j, consumers) in out_consumers.iter().enumerate() {
        for c in consumers {
            g.connect(port(new_id, j), *c);
        }
    }
    g.remove_node(x);
    for &m_id in &moved {
        g.remove_node(m_id);
    }
    Some(format!(
        "extended {dim}-map n{x} over {} replicated node(s) -> n{new_id}",
        moved.len()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::expr::Expr;
    use crate::ir::func::{FuncOp, ReduceOp};
    use crate::ir::graph::map_over;
    use crate::ir::types::Ty;
    use crate::ir::validate::assert_valid;

    /// Miniature of the FA step-16 situation: an N-map producing a list
    /// consumed (broadcast) inside an L-map that contains its own N-map.
    fn extendable_program() -> Graph {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let vt = g.input("VT", Ty::blocks(&["L", "N"]));
        let u = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.ew1(Expr::var(0).exp(), ins[0]);
            mb.collect(r);
        });
        let x = map_over(
            &mut g,
            "L",
            &[(u[0], ArgMode::Bcast), (vt, ArgMode::Mapped)],
            |mb, ins| {
                let inner = map_over(
                    &mut mb.g,
                    "N",
                    &[(ins[0], ArgMode::Mapped), (ins[1], ArgMode::Mapped)],
                    |mb2, i2| {
                        let d = mb2.g.func(FuncOp::Dot, &[i2[0], i2[1]]);
                        mb2.collect(d);
                    },
                );
                let red = mb.g.reduce(ReduceOp::Add, inner[0]);
                mb.collect(red);
            },
        );
        g.output("O", x[0]);
        g
    }

    #[test]
    fn extends_and_enables_rule1() {
        let mut g = extendable_program();
        assert!(find(&g).is_some());
        let msg = try_rule6(&mut g).unwrap();
        assert!(msg.contains("extended L-map"));
        assert_valid(&g);
        // only the L-map remains at top level
        assert_eq!(super::super::map_ids(&g).len(), 1);
        // and inside it, the two N-maps are now rule-1 fusible
        let x = super::super::map_ids(&g)[0];
        let inner = &g.node(x).as_map().unwrap().inner;
        assert!(super::super::rule1::find(inner).is_some());
    }

    #[test]
    fn no_gate_no_match() {
        // moved map over K, inner map over N: dims disjoint -> no extension
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["K"]));
        let vt = g.input("VT", Ty::blocks(&["L", "N"]));
        let u = map_over(&mut g, "K", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.func(FuncOp::RowSum, &[ins[0]]);
            mb.reduce_out(r, ReduceOp::Add);
        });
        let x = map_over(
            &mut g,
            "L",
            &[(vt, ArgMode::Mapped), (u[0], ArgMode::Bcast)],
            |mb, ins| {
                let inner = map_over(&mut mb.g, "N", &[(ins[0], ArgMode::Mapped)], |mb2, i2| {
                    let r = mb2.g.func(FuncOp::RowScale, &[i2[0], {
                        // use broadcast vector inside: rewire via outer input
                        i2[0]
                    }]);
                    let _ = r;
                    mb2.collect(r);
                });
                let _ = ins;
                mb.collect(inner[0]);
            },
        );
        let _ = x;
        // The construction above is deliberately not type-perfect; the point
        // is only that find() must bail because K ∉ inner dims {N}.
        assert!(find(&g).is_none());
    }

    #[test]
    fn nonterminal_map_not_extended() {
        let mut g = extendable_program();
        // make the N-map's output also a program output: X no longer the
        // unique sink, moved node feeds an Output -> no match
        let u = super::super::map_ids(&g)
            .into_iter()
            .find(|&i| g.node(i).as_map().unwrap().dim.name() == "N")
            .unwrap();
        g.output("EXP", port(u, 0));
        assert!(find(&g).is_none());
    }
}
