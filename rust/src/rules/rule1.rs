//! Rule 1: Fuse Consecutive Maps.
//!
//! Pattern: two maps `U -> V` over the same dimension, connected only by
//! direct edges, each of shape (Collect output of U) -> (Mapped input of V).
//! An indirect path `U -> W -> V` would make fusion create a cycle, so it
//! blocks the match. An edge that is not collect->mapped (e.g. a reduced
//! output consumed as broadcast, or a whole list consumed as broadcast)
//! also blocks: `V`'s iterations would need values `U` only finishes
//! producing after *all* of its iterations.

use super::merge::fuse_maps;
use crate::ir::graph::{ArgMode, Edge, Graph, NodeId, OutMode};

/// Find the lowest-id fusible consecutive pair (u, v).
pub fn find(g: &Graph) -> Option<(NodeId, NodeId)> {
    let maps = super::map_ids(g);
    for &u in &maps {
        let um = g.node(u).as_map().unwrap();
        if um.skip_first {
            continue;
        }
        for &v in &maps {
            if v == u {
                continue;
            }
            let vm = g.node(v).as_map().unwrap();
            if vm.dim != um.dim || vm.skip_first {
                continue;
            }
            let direct: Vec<Edge> = g
                .edges()
                .iter()
                .copied()
                .filter(|e| e.src.node == u && e.dst.node == v)
                .collect();
            if direct.is_empty() {
                continue;
            }
            let all_ok = direct.iter().all(|e| {
                let collect = matches!(um.outputs[e.src.port].mode, OutMode::Collect);
                let mapped = vm.inputs[e.dst.port].mode == ArgMode::Mapped;
                collect && mapped
            });
            if !all_ok {
                continue;
            }
            if g.reaches_excluding(u, v, &direct) {
                continue; // indirect path: fusing would create a loop
            }
            return Some((u, v));
        }
    }
    None
}

pub fn try_rule1(g: &mut Graph) -> Option<String> {
    let (u, v) = find(g)?;
    let dim = g.node(u).as_map().unwrap().dim.clone();
    let fused = fuse_maps(g, u, v);
    Some(format!("fused consecutive {dim}-maps n{u}+n{v} -> n{fused}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::expr::Expr;
    use crate::ir::func::ReduceOp;
    use crate::ir::graph::{map_over, ArgMode, Graph};
    use crate::ir::types::Ty;
    use crate::ir::validate::assert_valid;

    fn chain(g: &mut Graph) -> (crate::ir::graph::Port, crate::ir::graph::Port) {
        let a = g.input("A", Ty::blocks(&["N"]));
        let o1 = map_over(g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.ew1(Expr::var(0).exp(), ins[0]);
            mb.collect(r);
        });
        let o2 = map_over(g, "N", &[(o1[0], ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.ew1(Expr::var(0).neg(), ins[0]);
            mb.collect(r);
        });
        (o1[0], o2[0])
    }

    #[test]
    fn fuses_simple_chain() {
        let mut g = Graph::new();
        let (_, o2) = chain(&mut g);
        g.output("B", o2);
        assert!(find(&g).is_some());
        let msg = try_rule1(&mut g).unwrap();
        assert!(msg.contains("N-maps"));
        assert_valid(&g);
        assert_eq!(g.interior_buffered_count_recursive(), 0);
        assert!(find(&g).is_none());
    }

    #[test]
    fn dim_mismatch_blocks() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N", "K"]));
        let o1 = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let inner = map_over(&mut mb.g, "K", &[(ins[0], ArgMode::Mapped)], |mb2, i2| {
                let r = mb2.g.ew1(Expr::var(0).exp(), i2[0]);
                mb2.collect(r);
            });
            mb.collect(inner[0]);
        });
        // consume over K at top level: map over K (strips K, dims [N, K] -> first K)
        let o2 = map_over(&mut g, "K", &[(o1[0], ArgMode::Mapped)], |mb, ins| {
            let inner = map_over(&mut mb.g, "N", &[(ins[0], ArgMode::Mapped)], |mb2, i2| {
                let r = mb2.g.ew1(Expr::var(0).neg(), i2[0]);
                mb2.collect(r);
            });
            mb.collect(inner[0]);
        });
        g.output("B", o2[0]);
        assert!(find(&g).is_none());
    }

    #[test]
    fn indirect_path_blocks() {
        // U -> W -> V and U -> V: fusing U,V would create a cycle.
        // W = reduce of U's output; V consumes U mapped and W broadcast.
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let u = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.func(crate::ir::func::FuncOp::RowSum, &[ins[0]]);
            mb.collect(r);
        });
        let w = g.reduce(ReduceOp::Add, u[0]); // W on the indirect path
        let v = map_over(
            &mut g,
            "N",
            &[(u[0], ArgMode::Mapped), (w, ArgMode::Bcast)],
            |mb, ins| {
                let r = mb.g.ew2(Expr::var(0).add(Expr::var(1)), ins[0], ins[1]);
                mb.collect(r);
            },
        );
        g.output("B", v[0]);
        assert!(find(&g).is_none(), "indirect path must block rule 1");
    }

    #[test]
    fn reduced_output_edge_blocks() {
        // U's reduced (item) output consumed by V broadcast: not fusible.
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let u = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.func(crate::ir::func::FuncOp::RowSum, &[ins[0]]);
            mb.reduce_out(r, ReduceOp::Add);
        });
        let v = map_over(
            &mut g,
            "N",
            &[(a, ArgMode::Mapped), (u[0], ArgMode::Bcast)],
            |mb, ins| {
                let r = mb.g.func(crate::ir::func::FuncOp::RowScale, &[ins[0], ins[1]]);
                mb.collect(r);
            },
        );
        g.output("B", v[0]);
        assert!(find(&g).is_none());
    }
}
