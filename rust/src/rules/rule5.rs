//! Rule 5: Linearity of Matmul — Swap Shift/Dot.
//!
//! Pattern: a mapped `row_shift` over the contraction dim feeding a matmul's
//! left operand. By distributivity,
//!
//! ```text
//! (I1 + c·1ᵀ)·I2 = I1·I2 + c·(1ᵀ·I2)
//! ```
//!
//! so the substitution computes the raw matmul, a column-sum of `I2`
//! (`row_sum` of the stored transposed blocks), an outer product with `c`,
//! and an add — all mapped over the output dim `a`, aligning dimensions for
//! later fusion. The paper's LayerNorm+Matmul example rides on this rule.

use super::matmul::{all_matmuls, MatmulMatch};
use crate::ir::func::{FuncOp, ReduceOp};
use crate::ir::graph::{map_over, port, ArgMode, Graph, NodeId, Port};

pub fn find(g: &Graph) -> Option<(NodeId, Port, Port, MatmulMatch)> {
    let matmuls = all_matmuls(g);
    if matmuls.is_empty() {
        return None;
    }
    for s in super::map_ids(g) {
        let Some((x_src, c_src, s_dim)) = super::rule4::match_norm_map(g, s, &FuncOp::RowShift)
        else {
            continue;
        };
        let consumers = g.consumers(port(s, 0));
        if consumers.len() != 1 {
            continue;
        }
        for mm in &matmuls {
            if consumers[0] == port(mm.pmap, mm.left_port) && mm.k_dim == s_dim {
                return Some((s, x_src, c_src, mm.clone()));
            }
        }
    }
    None
}

pub fn try_rule5(g: &mut Graph) -> Option<String> {
    let (s, x_src, c_src, mm) = find(g)?;
    let right_src = g.producer(port(mm.pmap, mm.right_port)).unwrap();

    // 1. matmul consumes the raw operand; drop the shift map.
    g.connect(x_src, port(mm.pmap, mm.left_port));
    g.remove_node(s);
    let old_consumers = g.consumers(port(mm.pmap, 0));

    // 2. column sums of I2 (= row sums of the stored I2ᵀ blocks), unfused:
    //    Map(a){ Map(k){row_sum} -> Reduce(k) } — later rules fuse inside.
    let a = mm.a_dim.clone();
    let k = mm.k_dim.clone();
    let colsum = map_over(g, a.clone(), &[(right_src, ArgMode::Mapped)], |mb, ins| {
        let kk = map_over(&mut mb.g, k.clone(), &[(ins[0], ArgMode::Mapped)], |mb2, i2| {
            let r = mb2.g.func(FuncOp::RowSum, &[i2[0]]);
            mb2.collect(r);
        });
        let red = mb.g.reduce(ReduceOp::Add, kk[0]);
        mb.collect(red);
    });

    // 3. outer(c, colsum) per output block (its own a-map, as in the paper's
    //    step-9 listing).
    let omap = map_over(
        g,
        a.clone(),
        &[(c_src, ArgMode::Bcast), (colsum[0], ArgMode::Mapped)],
        |mb, ins| {
            let o = mb.g.func(FuncOp::Outer, &[ins[0], ins[1]]);
            mb.collect(o);
        },
    );

    // 4. add the correction to the raw matmul result.
    let amap = map_over(
        g,
        a.clone(),
        &[
            (omap[0], ArgMode::Mapped),
            (port(mm.pmap, 0), ArgMode::Mapped),
        ],
        |mb, ins| {
            let r = mb.g.func(FuncOp::Add, &[ins[0], ins[1]]);
            mb.collect(r);
        },
    );
    for cns in old_consumers {
        g.connect(amap[0], cns);
    }
    Some(format!(
        "swapped {k}-shift n{s} across matmul n{} (colsum + outer + add over {a})",
        mm.pmap
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::types::Ty;
    use crate::ir::validate::assert_valid;
    use crate::rules::matmul::build_matmul;

    fn shift_matmul_program() -> Graph {
        let mut g = Graph::new();
        let i1 = g.input("I1", Ty::blocks(&["K"]));
        let i2 = g.input("I2T", Ty::blocks(&["N", "K"]));
        let pre = map_over(&mut g, "K", &[(i1, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.func(FuncOp::RowSum, &[ins[0]]);
            mb.reduce_out(r, ReduceOp::Add);
        });
        let c = g.ew1(crate::ir::expr::Expr::var(0).neg(), pre[0]);
        let shifted = map_over(
            &mut g,
            "K",
            &[(i1, ArgMode::Mapped), (c, ArgMode::Bcast)],
            |mb, ins| {
                let r = mb.g.func(FuncOp::RowShift, &[ins[0], ins[1]]);
                mb.collect(r);
            },
        );
        let o = build_matmul(&mut g, shifted[0], i2, "N", "K");
        g.output("I3", o);
        g
    }

    #[test]
    fn matches_and_substitutes() {
        let mut g = shift_matmul_program();
        assert!(find(&g).is_some());
        let before_maps = super::super::map_ids(&g).len();
        try_rule5(&mut g).unwrap();
        assert_valid(&g);
        assert!(find(&g).is_none());
        // shift map gone; colsum + outer + add added
        assert_eq!(super::super::map_ids(&g).len(), before_maps - 1 + 3);
        // all new maps are over the output dim N
        let n_maps = super::super::map_ids(&g)
            .into_iter()
            .filter(|&id| g.node(id).as_map().unwrap().dim.name() == "N")
            .count();
        assert_eq!(n_maps, 4); // matmul + colsum + outer + add
    }
}
