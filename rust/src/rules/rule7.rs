//! Rule 7: Peel Off First Iteration.
//!
//! The redundancy-free alternative to Rule 6: instead of replicating the
//! whole graph per iteration of the terminal map, peel the first iteration
//! (`x = 0`) out of the map and run it at the current graph level; the map
//! then iterates `1..X`. Collected outputs are reassembled with a `Concat`
//! node; reduced outputs combine the peeled value with the rest via the
//! reduction op.
//!
//! None of the paper's three examples uses this rule (their traces all go
//! through Rule 6), so the fusion driver exposes it only through the
//! ablation benches and the public API.

use crate::ir::expr::Expr;
use crate::ir::func::{FuncOp, ReduceOp};
use crate::ir::graph::{port, ArgMode, Graph, NodeId, NodeKind, OutMode, Port};
use std::collections::HashMap;

/// Find a peelable map: terminal, not already peeled, collect outputs with
/// item elements, mapped inputs fed by single-level lists.
pub fn find(g: &Graph) -> Option<NodeId> {
    let output_ids: Vec<NodeId> = g.output_ids();
    for x in super::map_ids(g) {
        let xm = g.node(x).as_map().unwrap();
        if xm.skip_first {
            continue;
        }
        if !g
            .node_consumers(x)
            .iter()
            .all(|c| output_ids.contains(&c.node))
        {
            continue;
        }
        let collect_ok = xm.outputs.iter().enumerate().all(|(j, o)| {
            !matches!(o.mode, OutMode::Collect) || g.out_ty(port(x, j)).dims.len() == 1
        });
        if !collect_ok {
            continue;
        }
        // mapped inputs must be indexed by `x.dim` at the *outermost* level
        // so the peeled copy can take their head element
        let mapped_ok = xm.inputs.iter().enumerate().all(|(i, mi)| {
            mi.mode != ArgMode::Mapped
                || g.producer(port(x, i))
                    .map(|s| g.out_ty(s).dims.first() == Some(&xm.dim))
                    .unwrap_or(false)
        });
        if !mapped_ok {
            continue;
        }
        return Some(x);
    }
    None
}

pub fn try_rule7(g: &mut Graph) -> Option<String> {
    let x = find(g)?;
    let xm = g.node(x).as_map().unwrap().clone();
    let dim = xm.dim.clone();

    // --- peeled copy of the inner graph at this level (x = 0) -------------
    let remap = {
        let inner = xm.inner.clone();
        g.absorb(inner)
    };
    // bind cloned inner Inputs
    for (i, mi) in xm.inputs.iter().enumerate() {
        let s = g.producer(port(x, i)).expect("map input unconnected");
        let cloned_in = remap[&mi.inner_input];
        let replacement: Port = match mi.mode {
            ArgMode::Mapped => {
                let h = g.add_node(NodeKind::Head, "head");
                g.connect(s, port(h, 0));
                port(h, 0)
            }
            ArgMode::Bcast => s,
        };
        g.rewire_consumers(port(cloned_in, 0), replacement);
        g.remove_node(cloned_in);
    }
    // peel out cloned Output nodes, keeping their producer ports
    let mut head_vals: Vec<Port> = Vec::with_capacity(xm.outputs.len());
    for mo in &xm.outputs {
        let cloned_out = remap[&mo.inner_output];
        let p = g
            .producer(port(cloned_out, 0))
            .expect("inner output unconnected");
        g.remove_node(cloned_out);
        head_vals.push(p);
    }

    // --- the rest: the same map over 1..X ----------------------------------
    let mut rest = xm.clone();
    rest.skip_first = true;
    let sources: Vec<Port> = (0..xm.inputs.len())
        .map(|i| g.producer(port(x, i)).unwrap())
        .collect();
    let out_consumers: Vec<Vec<Port>> = (0..xm.outputs.len())
        .map(|j| g.consumers(port(x, j)))
        .collect();
    let rest_id = g.add_node(NodeKind::Map(Box::new(rest)), format!("map{dim}[1:]"));
    for (i, s) in sources.iter().enumerate() {
        g.connect(*s, port(rest_id, i));
    }

    // --- recombine outputs ---------------------------------------------------
    let mut combined: HashMap<usize, Port> = HashMap::new();
    for (j, mo) in xm.outputs.iter().enumerate() {
        let out = match &mo.mode {
            OutMode::Collect => {
                let c = g.add_node(
                    NodeKind::Concat { dim: dim.clone() },
                    format!("concat{dim}"),
                );
                g.connect(head_vals[j], port(c, 0));
                g.connect(port(rest_id, j), port(c, 1));
                port(c, 0)
            }
            OutMode::Reduce(ReduceOp::Add) => {
                g.func(FuncOp::Add, &[head_vals[j], port(rest_id, j)])
            }
            OutMode::Reduce(ReduceOp::Max) => g.ew2(
                Expr::var(0).max(Expr::var(1)),
                head_vals[j],
                port(rest_id, j),
            ),
        };
        combined.insert(j, out);
    }
    for (j, consumers) in out_consumers.iter().enumerate() {
        for c in consumers {
            g.connect(combined[&j], *c);
        }
    }
    g.remove_node(x);
    Some(format!(
        "peeled first {dim}-iteration of n{x} (rest -> n{rest_id})"
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::dim::DimSizes;
    use crate::ir::graph::map_over;
    use crate::ir::types::Ty;
    use crate::ir::validate::assert_valid;
    use crate::loopir::interp::{exec, BufVal, ExecConfig};
    use crate::loopir::lower::lower;
    use crate::tensor::{Rng, Val};

    fn program() -> Graph {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let o = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let e = mb.g.ew1(Expr::var(0).exp(), ins[0]);
            let s = mb.g.func(FuncOp::RowSum, &[ins[0]]);
            mb.collect(e);
            mb.reduce_out(s, ReduceOp::Add);
        });
        g.output("B", o[0]);
        g.output("S", o[1]);
        g
    }

    #[test]
    fn peel_preserves_semantics() {
        let g0 = program();
        let mut g1 = g0.clone();
        assert!(find(&g1).is_some());
        try_rule7(&mut g1).unwrap();
        assert_valid(&g1);

        let mut rng = Rng::new(11);
        let mut input = BufVal::new(vec![4]);
        for i in 0..4 {
            input.set(&[i], Val::Block(rng.mat(2, 3)));
        }
        let run = |g: &Graph| {
            let mut cfg = ExecConfig::new(DimSizes::of(&[("N", 4)]));
            cfg.inputs.insert("A".into(), input.clone());
            exec(&lower(g), &cfg)
        };
        let r0 = run(&g0);
        let r1 = run(&g1);
        for i in 0..4 {
            assert!(
                r0.outputs["B"]
                    .get(&[i])
                    .max_abs_diff(r1.outputs["B"].get(&[i]))
                    < 1e-6
            );
        }
        assert!(
            r0.outputs["S"]
                .get(&[])
                .max_abs_diff(r1.outputs["S"].get(&[]))
                < 1e-5
        );
    }
}
