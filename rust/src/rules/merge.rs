//! Shared map-fusion machinery for Rules 1 and 2.
//!
//! `fuse_maps(g, u, v)` replaces two same-dimension map nodes with a single
//! map whose inner graph is the concatenation of the two inner graphs:
//!
//! * every outer edge `u.out -> v.in` of shape (Collect output, Mapped
//!   input) becomes a direct unbuffered edge in the fused inner graph — this
//!   is the buffered-edge removal that is the whole point of fusion;
//! * inputs with the same outer source and mode are merged into one port
//!   (Rule 2's shared-parent merge; also applied during Rule 1, which is how
//!   the paper's fused listings load a shared block once);
//! * `u` outputs whose only consumer was `v` disappear; all other ports
//!   carry over.
//!
//! The caller (the rule's matcher) is responsible for the legality
//! conditions (same dim, no indirect paths, collect->mapped edges only).

use crate::ir::graph::{port, ArgMode, Graph, MapIn, MapNode, NodeId, NodeKind, OutMode, Port};

/// Fuse map `v_id` into map `u_id`. Returns the fused node id.
pub fn fuse_maps(g: &mut Graph, u_id: NodeId, v_id: NodeId) -> NodeId {
    let u = g.node(u_id).as_map().expect("u not a map").clone();
    let v = g.node(v_id).as_map().expect("v not a map").clone();
    assert_eq!(u.dim, v.dim, "fuse_maps: dim mismatch");
    assert!(
        !u.skip_first && !v.skip_first,
        "fuse_maps: peeled maps not fusible"
    );

    let mut inner = u.inner.clone();
    let remap = inner.absorb(v.inner.clone());

    // --- inputs ------------------------------------------------------------
    // (source port, mode, inner input node) for the fused map.
    let mut fused_inputs: Vec<(Port, ArgMode, NodeId)> = Vec::new();
    for (i, mi) in u.inputs.iter().enumerate() {
        let src = g
            .producer(port(u_id, i))
            .unwrap_or_else(|| panic!("u input {i} unconnected"));
        fused_inputs.push((src, mi.mode, mi.inner_input));
    }
    for (j, mj) in v.inputs.iter().enumerate() {
        let src = g
            .producer(port(v_id, j))
            .unwrap_or_else(|| panic!("v input {j} unconnected"));
        let v_inner_in = remap[&mj.inner_input];
        if src.node == u_id {
            // Internal edge: u's collect output feeds v's mapped input.
            let uo = &u.outputs[src.port];
            assert!(
                matches!(uo.mode, OutMode::Collect) && mj.mode == ArgMode::Mapped,
                "fuse_maps: only collect->mapped edges can be internalized"
            );
            let u_inner_src = inner
                .producer(port(uo.inner_output, 0))
                .expect("u inner output unconnected");
            inner.rewire_consumers(port(v_inner_in, 0), u_inner_src);
            inner.remove_node(v_inner_in);
        } else if let Some((_, _, existing)) = fused_inputs
            .iter()
            .find(|(s, m, _)| *s == src && *m == mj.mode)
        {
            // Shared parent: merge ports, one load per iteration.
            let existing = *existing;
            inner.rewire_consumers(port(v_inner_in, 0), port(existing, 0));
            inner.remove_node(v_inner_in);
        } else {
            fused_inputs.push((src, mj.mode, v_inner_in));
        }
    }

    // --- outputs -----------------------------------------------------------
    // u outputs survive unless their only outer consumers were v.
    let mut fused_outputs: Vec<(NodeId, OutMode, Vec<Port>)> = Vec::new(); // (inner out, mode, outer consumers)
    for (i, uo) in u.outputs.iter().enumerate() {
        let consumers: Vec<Port> = g
            .consumers(port(u_id, i))
            .into_iter()
            .filter(|c| c.node != v_id)
            .collect();
        if consumers.is_empty() {
            // Dead once v is fused in: drop the port and its inner Output.
            inner.remove_node(uo.inner_output);
        } else {
            fused_outputs.push((uo.inner_output, uo.mode.clone(), consumers));
        }
    }
    for (j, vo) in v.outputs.iter().enumerate() {
        let consumers = g.consumers(port(v_id, j));
        fused_outputs.push((remap[&vo.inner_output], vo.mode.clone(), consumers));
    }

    // --- build the fused node ------------------------------------------------
    let inputs: Vec<MapIn> = fused_inputs
        .iter()
        .map(|(_, mode, inner_input)| MapIn {
            inner_input: *inner_input,
            mode: *mode,
        })
        .collect();
    let outputs: Vec<crate::ir::graph::MapOut> = fused_outputs
        .iter()
        .map(|(inner_output, mode, _)| crate::ir::graph::MapOut {
            inner_output: *inner_output,
            mode: mode.clone(),
        })
        .collect();
    let label = format!("map{}", u.dim);
    let fused_id = g.add_node(
        NodeKind::Map(Box::new(MapNode {
            dim: u.dim.clone(),
            inner,
            inputs,
            outputs,
            skip_first: false,
        })),
        label,
    );
    for (k, (src, _, _)) in fused_inputs.iter().enumerate() {
        g.connect(*src, port(fused_id, k));
    }
    for (k, (_, _, consumers)) in fused_outputs.iter().enumerate() {
        for c in consumers {
            g.connect(port(fused_id, k), *c);
        }
    }
    g.remove_node(u_id);
    g.remove_node(v_id);
    fused_id
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::expr::Expr;
    use crate::ir::graph::{map_over, ArgMode, Graph};
    use crate::ir::types::Ty;
    use crate::ir::validate::assert_valid;

    #[test]
    fn fuse_consecutive_removes_interior_buffer() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let o1 = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.ew1(Expr::var(0).exp(), ins[0]);
            mb.collect(r);
        });
        let o2 = map_over(&mut g, "N", &[(o1[0], ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.ew1(Expr::var(0).neg(), ins[0]);
            mb.collect(r);
        });
        g.output("B", o2[0]);
        assert_eq!(g.interior_buffered_edges().len(), 1);
        let fused = fuse_maps(&mut g, o1[0].node, o2[0].node);
        assert_valid(&g);
        assert_eq!(g.interior_buffered_edges().len(), 0);
        assert_eq!(g.node_count(), 3);
        let m = g.node(fused).as_map().unwrap();
        assert_eq!(m.inputs.len(), 1);
        assert_eq!(m.outputs.len(), 1);
    }

    #[test]
    fn fuse_siblings_merges_shared_parent() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let o1 = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.ew1(Expr::var(0).exp(), ins[0]);
            mb.collect(r);
        });
        let o2 = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.ew1(Expr::var(0).neg(), ins[0]);
            mb.collect(r);
        });
        g.output("B1", o1[0]);
        g.output("B2", o2[0]);
        let fused = fuse_maps(&mut g, o1[0].node, o2[0].node);
        assert_valid(&g);
        let m = g.node(fused).as_map().unwrap();
        assert_eq!(m.inputs.len(), 1, "shared parent A merged into one port");
        assert_eq!(m.outputs.len(), 2);
    }

    #[test]
    fn fused_output_kept_when_other_consumers_exist() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let o1 = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.ew1(Expr::var(0).exp(), ins[0]);
            mb.collect(r);
        });
        let o2 = map_over(&mut g, "N", &[(o1[0], ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.ew1(Expr::var(0).neg(), ins[0]);
            mb.collect(r);
        });
        g.output("EXP", o1[0]); // I1 is also a program output
        g.output("B", o2[0]);
        let fused = fuse_maps(&mut g, o1[0].node, o2[0].node);
        assert_valid(&g);
        let m = g.node(fused).as_map().unwrap();
        assert_eq!(m.outputs.len(), 2, "exp output still materialized");
    }
}
