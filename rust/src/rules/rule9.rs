//! Rule 9: Fuse Consecutive Elementwise.
//!
//! Two elementwise operators connected by an unbuffered edge, where the
//! intermediate has no other consumers, compose into a single elementwise
//! operator (the scalar expressions compose symbolically). This removes a
//! kernel invocation rather than a materialized intermediate; in Flash
//! Attention it turns `t7 = t6*(DD**-0.5); t9 = exp(t7)` into
//! `exp(t6*(DD**-0.5))`.

use crate::ir::expr::Expr;
use crate::ir::func::FuncOp;
use crate::ir::graph::{port, Graph, NodeId, NodeKind, Port};

/// Find (producer EW node, consumer EW node).
pub fn find(g: &Graph) -> Option<(NodeId, NodeId)> {
    for u in g.node_ids() {
        let NodeKind::Func(FuncOp::Ew(_)) = &g.node(u).kind else {
            continue;
        };
        let consumers = g.consumers(port(u, 0));
        if consumers.is_empty() {
            continue;
        }
        // all uses must be by one EW node
        let v = consumers[0].node;
        if !consumers.iter().all(|c| c.node == v) {
            continue;
        }
        if let NodeKind::Func(FuncOp::Ew(_)) = &g.node(v).kind {
            return Some((u, v));
        }
    }
    None
}

pub fn try_rule9(g: &mut Graph) -> Option<String> {
    let (u, v) = find(g)?;
    let (NodeKind::Func(FuncOp::Ew(ue)), NodeKind::Func(FuncOp::Ew(ve))) =
        (&g.node(u).kind, &g.node(v).kind)
    else {
        unreachable!()
    };
    let (ue, ve) = (ue.clone(), ve.clone());

    // Collect argument sources, deduplicating by port.
    let u_srcs: Vec<Port> = (0..ue.arity().max(1))
        .filter(|i| *i < g.node(u).in_arity())
        .map(|i| g.producer(port(u, i)).expect("ew input unconnected"))
        .collect();
    let v_srcs: Vec<Port> = (0..g.node(v).in_arity())
        .map(|i| g.producer(port(v, i)).expect("ew input unconnected"))
        .collect();

    let mut new_args: Vec<Port> = Vec::new();
    let pos_of = |p: Port, new_args: &mut Vec<Port>| -> usize {
        if let Some(i) = new_args.iter().position(|x| *x == p) {
            i
        } else {
            new_args.push(p);
            new_args.len() - 1
        }
    };

    // u's expr rewritten onto the merged argument list
    let u_map: Vec<usize> = u_srcs
        .iter()
        .map(|s| pos_of(*s, &mut new_args))
        .collect();
    let u_expr = if u_map.is_empty() {
        ue.clone()
    } else {
        ue.remap_vars(&u_map)
    };

    // v's expr: slots fed by u become u_expr, others map to merged args
    let subs: Vec<Expr> = v_srcs
        .iter()
        .map(|s| {
            if s.node == u {
                u_expr.clone()
            } else {
                Expr::Var(pos_of(*s, &mut new_args))
            }
        })
        .collect();
    let fused = ve.substitute(&subs);

    let consumers = g.consumers(port(v, 0));
    let new = g.func(FuncOp::Ew(fused), &new_args);
    for c in consumers {
        g.connect(new, c);
    }
    g.remove_node(u);
    g.remove_node(v);
    Some(format!("fused elementwise n{u}∘n{v} -> n{}", new.node))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::types::Ty;
    use crate::ir::validate::assert_valid;
    use crate::loopir::{lower::lower, print::render};

    #[test]
    fn composes_scale_then_exp() {
        // the FA step-13 fusion: x*(DD**-0.5) then exp
        let mut g = Graph::new();
        let a = g.input("A", Ty::block());
        let s = g.ew1(
            Expr::var(0).mul(Expr::param("DD").pow(Expr::cst(-0.5))),
            a,
        );
        let e = g.ew1(Expr::var(0).exp(), s);
        g.output("B", e);
        try_rule9(&mut g).unwrap();
        assert_valid(&g);
        assert!(find(&g).is_none());
        let code = render(&lower(&g));
        assert!(code.contains("exp(t1*DD**(-0.5))"), "{code}");
    }

    #[test]
    fn shared_arg_dedup() {
        // u = x+1 consumed twice by v = u*u → (x+1)*(x+1) over ONE arg
        let mut g = Graph::new();
        let a = g.input("A", Ty::block());
        let u = g.ew1(Expr::var(0).add(Expr::cst(1.0)), a);
        let v = g.ew2(Expr::var(0).mul(Expr::var(1)), u, u);
        g.output("B", v);
        try_rule9(&mut g).unwrap();
        assert_valid(&g);
        let id = g
            .node_ids()
            .find(|&i| matches!(g.node(i).kind, NodeKind::Func(FuncOp::Ew(_))))
            .unwrap();
        assert_eq!(g.node(id).in_arity(), 1);
    }

    #[test]
    fn other_consumer_blocks() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::block());
        let u = g.ew1(Expr::var(0).add(Expr::cst(1.0)), a);
        let v = g.ew1(Expr::var(0).exp(), u);
        g.output("B", v);
        g.output("U_TOO", u);
        assert!(find(&g).is_none());
    }

    #[test]
    fn non_ew_blocks() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::block());
        let u = g.ew1(Expr::var(0).exp(), a);
        let v = g.func(FuncOp::RowSum, &[u]);
        g.output("B", v);
        assert!(find(&g).is_none());
    }
}
