//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them on the XLA CPU client.
//!
//! The interchange format is HLO **text** (`HloModuleProto::from_text_file`);
//! see DESIGN.md and the aot docstring for why serialized protos are
//! rejected by this XLA version. One compiled executable per model variant,
//! cached after first use; Python is never on this path.

use crate::tensor::Mat;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One entry of `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub file: String,
    pub inputs: Vec<(String, Vec<usize>)>,
    pub outputs: Vec<Vec<usize>>,
}

/// The artifact registry.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: HashMap<String, ModelInfo>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let mut models = HashMap::new();
        for (name, m) in j.as_obj().ok_or_else(|| anyhow!("manifest not an object"))? {
            let file = m
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| anyhow!("{name}: missing file"))?
                .to_string();
            let inputs = m
                .get("inputs")
                .and_then(|i| i.as_arr())
                .ok_or_else(|| anyhow!("{name}: missing inputs"))?
                .iter()
                .map(|inp| {
                    let n = inp.get("name").and_then(|x| x.as_str()).unwrap_or("?");
                    let shape: Vec<usize> = inp
                        .get("shape")
                        .and_then(|s| s.as_arr())
                        .map(|a| a.iter().filter_map(|x| x.as_num()).map(|x| x as usize).collect())
                        .unwrap_or_default();
                    (n.to_string(), shape)
                })
                .collect();
            let outputs = m
                .get("outputs")
                .and_then(|o| o.as_arr())
                .map(|a| {
                    a.iter()
                        .map(|s| {
                            s.as_arr()
                                .map(|d| {
                                    d.iter()
                                        .filter_map(|x| x.as_num())
                                        .map(|x| x as usize)
                                        .collect()
                                })
                                .unwrap_or_default()
                        })
                        .collect()
                })
                .unwrap_or_default();
            models.insert(
                name.clone(),
                ModelInfo {
                    name: name.clone(),
                    file,
                    inputs,
                    outputs,
                },
            );
        }
        Ok(Manifest { dir, models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models.get(name).ok_or_else(|| {
            anyhow!(
                "model {name} not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }
}

/// PJRT client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            manifest,
            exes: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) a model's executable.
    pub fn prepare(&mut self, model: &str) -> Result<()> {
        if self.exes.contains_key(model) {
            return Ok(());
        }
        let info = self.manifest.model(model)?.clone();
        let path = self.manifest.dir.join(&info.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.exes.insert(model.to_string(), exe);
        Ok(())
    }

    /// Execute a model on full matrices, in manifest input order.
    pub fn execute(&mut self, model: &str, inputs: &[&Mat]) -> Result<Vec<Mat>> {
        self.prepare(model)?;
        let info = self.manifest.model(model)?.clone();
        if inputs.len() != info.inputs.len() {
            bail!(
                "{model}: {} inputs given, manifest wants {}",
                inputs.len(),
                info.inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (m, (name, shape)) in inputs.iter().zip(&info.inputs) {
            if shape.len() == 2 && (m.rows != shape[0] || m.cols != shape[1]) {
                bail!(
                    "{model}: input {name} is {}x{}, artifact expects {}x{}",
                    m.rows,
                    m.cols,
                    shape[0],
                    shape[1]
                );
            }
            let lit = xla::Literal::vec1(&m.data)
                .reshape(&[m.rows as i64, m.cols as i64])?;
            literals.push(lit);
        }
        let exe = self.exes.get(model).unwrap();
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot lowers with return_tuple=True
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for (i, p) in parts.into_iter().enumerate() {
            let data = p.to_vec::<f32>()?;
            let shape = info
                .outputs
                .get(i)
                .cloned()
                .unwrap_or_else(|| vec![data.len(), 1]);
            let (r, c) = match shape.as_slice() {
                [r, c] => (*r, *c),
                [n] => (*n, 1),
                _ => (data.len(), 1),
            };
            out.push(Mat::from_vec(r, c, data));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parse_roundtrip() {
        let dir = std::env::temp_dir().join("bb_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"m": {"file": "m.hlo.txt",
                 "inputs": [{"name": "A", "shape": [4, 4]}],
                 "outputs": [[4, 4]]}}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        let info = m.model("m").unwrap();
        assert_eq!(info.inputs[0].0, "A");
        assert_eq!(info.inputs[0].1, vec![4, 4]);
        assert_eq!(info.outputs[0], vec![4, 4]);
        assert!(m.model("nope").is_err());
    }
}
