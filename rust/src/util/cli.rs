//! Tiny CLI argument helper (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, and positional arguments, which is all
//! the `blockbuster` binary needs.

use std::collections::HashMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: Vec<String>,
    pub options: HashMap<String, String>,
}

impl Args {
    /// Parse an iterator of arguments (excluding argv[0]).
    /// `takes_value` lists option names that consume the next argument.
    pub fn parse(argv: impl Iterator<Item = String>, takes_value: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = argv.peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if takes_value.contains(&name) {
                    match it.next() {
                        Some(v) => {
                            out.options.insert(name.to_string(), v);
                        }
                        None => {
                            out.flags.push(name.to_string());
                        }
                    }
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name} expects a number")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str], takes: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()), takes)
    }

    #[test]
    fn positional_flags_options() {
        let a = parse(
            &["trace", "attention", "--verbose", "--seed", "7", "--m=4"],
            &["seed"],
        );
        assert_eq!(a.positional, vec!["trace", "attention"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.opt("seed"), Some("7"));
        assert_eq!(a.opt("m"), Some("4"));
        assert_eq!(a.opt_usize("seed", 0), 7);
        assert_eq!(a.opt_usize("missing", 3), 3);
    }
}
