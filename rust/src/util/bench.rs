//! Statistical micro-benchmark harness (criterion is unavailable offline).
//!
//! Warmup + N timed iterations; reports mean / median / p95 / stddev and
//! prints aligned table rows so every `cargo bench` target regenerates one
//! of the paper's tables or series.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
}

impl Stats {
    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Time `f` with automatic warmup. `min_iters`/`max_time` bound the run.
pub fn bench<T>(min_iters: usize, max_time: Duration, mut f: impl FnMut() -> T) -> Stats {
    // warmup: a few runs or 10% of budget
    let warm_start = Instant::now();
    let mut warmups = 0;
    while warmups < 3 && warm_start.elapsed() < max_time / 10 {
        std::hint::black_box(f());
        warmups += 1;
    }
    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || (start.elapsed() < max_time && samples.len() < 10_000) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
        if start.elapsed() >= max_time && samples.len() >= min_iters {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
    Stats {
        iters: n,
        mean_ns: mean,
        median_ns: samples[n / 2],
        p95_ns: samples[(n as f64 * 0.95) as usize % n.max(1)],
        stddev_ns: var.sqrt(),
        min_ns: samples[0],
    }
}

/// Default bench: at least 10 iterations within ~1.5s.
pub fn quick<T>(f: impl FnMut() -> T) -> Stats {
    bench(10, Duration::from_millis(1500), f)
}

/// Table printing helpers shared by the bench binaries.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let line = |cells: &[String]| {
            let joined: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            println!("  {}", joined.join("  "));
        };
        line(&self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
        println!("  {}", "-".repeat(total));
        for r in &self.rows {
            line(r);
        }
    }
}

/// Human formatting used across benches.
pub fn fmt_stat(s: &Stats) -> String {
    format!("{} ±{}", fmt_ns(s.median_ns), fmt_ns(s.stddev_ns))
}

/// Persist a machine-readable bench report (`BENCH_*.json` files track the
/// perf trajectory across PRs; the JSON writer is `util::json`).
pub fn write_json_report(path: &str, j: &crate::util::json::Json) -> std::io::Result<()> {
    std::fs::write(path, format!("{j}\n"))
}

/// Nearest-rank p-th percentile (`p` in 0..=100) of an unsorted sample
/// set; 0 on an empty set. Used for the serving layer's latency
/// summaries (`serve::ProgramStats`) and the serve bench rows.
pub fn percentile(samples: &[u128], p: f64) -> u128 {
    if samples.is_empty() {
        return 0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_unstable();
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

pub fn fmt_bytes(b: u64) -> String {
    if b < 1024 {
        format!("{b}B")
    } else if b < 1024 * 1024 {
        format!("{:.1}KiB", b as f64 / 1024.0)
    } else {
        format!("{:.2}MiB", b as f64 / (1024.0 * 1024.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let s = bench(5, Duration::from_millis(50), || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(s.iters >= 5);
        assert!(s.mean_ns > 0.0);
        assert!(s.median_ns <= s.p95_ns);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new("demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print(); // just must not panic
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.0KiB");
        assert!(fmt_ns(1500.0).contains("µs"));
    }

    #[test]
    fn percentile_nearest_rank() {
        assert_eq!(percentile(&[], 95.0), 0);
        assert_eq!(percentile(&[7], 50.0), 7);
        let s: Vec<u128> = (1..=100).collect();
        assert_eq!(percentile(&s, 50.0), 50);
        assert_eq!(percentile(&s, 95.0), 95);
        assert_eq!(percentile(&s, 100.0), 100);
        assert_eq!(percentile(&s, 0.0), 1);
        // unsorted input is handled
        assert_eq!(percentile(&[30, 10, 20], 50.0), 20);
    }
}
