//! Seeded fault injection for the serving layer's chaos tests.
//!
//! The daemon's robustness claims (panic isolation, worker respawn,
//! shed/reject accounting) are only testable if faults can be *made to
//! happen* on demand. This module is that switch: a process-global,
//! seeded, lock-free fault source that instrumented sites query via
//! [`injected`]. Production runs never pay more than one relaxed atomic
//! load per site (the rate defaults to 0 and the fast path is a single
//! compare against 0).
//!
//! Determinism model: the underlying LCG stream is fully determined by
//! `(rate, seed)`, but *which* concurrent consumer observes the n-th
//! draw depends on thread interleaving. Chaos tests therefore assert
//! invariants (containment, accounting, bit-identical survivors), never
//! exact victim identities.
//!
//! Environment hooks (read once by [`init_from_env`], called from
//! `main`): `BB_FAULT_RATE` (fault probability in [0,1]) and
//! `BB_FAULT_SEED` (u64 stream seed, default `0xb10c_fa17`).

use std::sync::atomic::{AtomicU64, Ordering};

/// Instrumented fault sites. Keeping the site explicit lets tests (and
/// future per-site rates) distinguish compute-path panics from pool
/// worker deaths and network-edge misbehavior.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Site {
    /// A batch's compute task (serve's `run_batch` launch body).
    Compute,
    /// A pool worker thread (dies after job check-in; pool respawns it).
    PoolWorker,
    /// A network client tears a frame write in half and vanishes
    /// mid-frame (`serve::net::client` request path) — the server must
    /// time the torn frame out or reject it, never hang or panic.
    NetTornWrite,
    /// A network client stalls before reading a queued response
    /// (`serve::net::client` receive path) — the server's reply path
    /// must tolerate a reader that is arbitrarily slow.
    NetStallRead,
    /// A network client drops its connection after submitting but
    /// before collecting replies — the server must resolve the orphaned
    /// in-flight tickets as disconnects, not leak them.
    NetDisconnect,
}

/// Fault probability in parts-per-million (0 = disabled, the default).
static RATE_PPM: AtomicU64 = AtomicU64::new(0);
/// LCG state; advanced with a compare-exchange loop so every consumer
/// takes a distinct draw from one deterministic stream.
static STATE: AtomicU64 = AtomicU64::new(0xb10c_fa17);

const LCG_MUL: u64 = 6364136223846793005;
const LCG_ADD: u64 = 1442695040888963407;

/// Enable fault injection at `rate` (clamped to [0,1]) with a seed.
pub fn set(rate: f64, seed: u64) {
    let ppm = (rate.clamp(0.0, 1.0) * 1_000_000.0).round() as u64;
    STATE.store(seed, Ordering::SeqCst);
    RATE_PPM.store(ppm, Ordering::SeqCst);
}

/// Disable fault injection (rate back to 0).
pub fn off() {
    RATE_PPM.store(0, Ordering::SeqCst);
}

/// The currently configured fault probability in [0,1].
pub fn rate() -> f64 {
    RATE_PPM.load(Ordering::Relaxed) as f64 / 1_000_000.0
}

/// Read `BB_FAULT_RATE` / `BB_FAULT_SEED` and arm the injector if a
/// nonzero rate is configured. Called once from `main`; tests call
/// [`set`] directly instead.
pub fn init_from_env() {
    let rate = std::env::var("BB_FAULT_RATE")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(0.0);
    if rate > 0.0 {
        let seed = std::env::var("BB_FAULT_SEED")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .unwrap_or(0xb10c_fa17);
        set(rate, seed);
    }
}

/// Should this site fault now? One deterministic LCG draw per call when
/// armed; a single relaxed load (and no draw) when disabled.
pub fn injected(_site: Site) -> bool {
    let ppm = RATE_PPM.load(Ordering::Relaxed);
    if ppm == 0 {
        return false;
    }
    let mut cur = STATE.load(Ordering::Relaxed);
    loop {
        let next = cur.wrapping_mul(LCG_MUL).wrapping_add(LCG_ADD);
        match STATE.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => {
                // Top bits of an LCG are the well-mixed ones.
                let draw = next >> 40; // 24 bits: 0..16_777_216
                return draw % 1_000_000 < ppm;
            }
            Err(observed) => cur = observed,
        }
    }
}

// NOTE: lib unit tests here deliberately never *arm* the injector —
// `cargo test` runs the lib suite multi-threaded in one process, and an
// armed global rate would bleed injected panics into concurrently
// running serve/pool tests. Armed behavior (rate adherence, seeded
// determinism, containment) is pinned by `tests/serve_chaos.rs`, whose
// binary serializes every armed section behind a lock.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_by_default() {
        assert_eq!(RATE_PPM.load(Ordering::Relaxed), 0);
        for _ in 0..100 {
            assert!(!injected(Site::Compute));
            assert!(!injected(Site::PoolWorker));
        }
        assert_eq!(rate(), 0.0);
    }
}
