//! Minimal JSON parser and writer (serde is unavailable offline).
//!
//! Supports the full JSON value grammar minus exotic number forms; good
//! enough for `artifacts/manifest.json` and report emission.
//!
//! The parser is hardened for untrusted input — bench/stats files now
//! cross process boundaries (CI artifacts, the serving CLI), so it must
//! degrade to typed errors, never panics or stack overflows: trailing
//! garbage is rejected, nesting is capped at [`MAX_DEPTH`], truncated
//! escapes are bounds-checked, and [`Json::parse_bytes`] validates
//! UTF-8 before the grammar ever sees the bytes. The seeded fuzz tests
//! below pin all of that.

use std::collections::BTreeMap;
use std::fmt;

/// Maximum container nesting the parser accepts. Deeper input is a
/// typed error instead of unbounded recursion (each level is one
/// [`Parser::value`] stack frame).
pub const MAX_DEPTH: usize = 128;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value(0)?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Parse raw bytes (a file or socket payload): UTF-8 is validated
    /// up front, so malformed encodings are a typed error before the
    /// grammar ever runs.
    pub fn parse_bytes(b: &[u8]) -> Result<Json, String> {
        let s = std::str::from_utf8(b)
            .map_err(|e| format!("invalid UTF-8 at byte {}", e.valid_up_to()))?;
        Json::parse(s)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_num(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|x| x as char)
            ))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        if depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH} at byte {}", self.i));
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            // `.get` (not a slice): a `\u` cut off by
                            // end-of-input must error, not panic.
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or("truncated \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // collect one UTF-8 scalar
                    let s = &self.b[self.i..];
                    let len = utf8_len(c);
                    out.push_str(
                        std::str::from_utf8(&s[..len.min(s.len())])
                            .map_err(|_| "bad utf8")?,
                    );
                    self.i += len;
                }
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value(depth + 1)?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("expected , or ] found {other:?}")),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value(depth + 1)?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected , or }} found {other:?}")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => f.write_str("\\\"")?,
                        '\\' => f.write_str("\\\\")?,
                        '\n' => f.write_str("\\n")?,
                        '\t' => f.write_str("\\t")?,
                        '\r' => f.write_str("\\r")?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let s = r#"{"attention_naive": {"file": "a.hlo.txt",
            "inputs": [{"name": "Q", "shape": [32, 16]}],
            "outputs": [[32, 16]]}}"#;
        let j = Json::parse(s).unwrap();
        let model = j.get("attention_naive").unwrap();
        assert_eq!(model.get("file").unwrap().as_str(), Some("a.hlo.txt"));
        let inputs = model.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs[0].get("name").unwrap().as_str(), Some("Q"));
        let shape = inputs[0].get("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape[0].as_num(), Some(32.0));
        // print -> parse stability
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn scalars_and_escapes() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse(r#""a\nb\"c""#).unwrap(),
            Json::Str("a\nb\"c".into())
        );
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn error_cases() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn truncated_unicode_escape_is_an_error_not_a_panic() {
        // Regression: the escape used to slice `b[i+1..i+5]` and panic
        // when the input ended inside the escape.
        assert!(Json::parse("\"\\u12").is_err());
        assert!(Json::parse("\"\\u").is_err());
        assert!(Json::parse("\"\\uZZZZ\"").is_err());
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
        // Unpaired surrogates degrade to the replacement character.
        assert_eq!(Json::parse("\"\\uD800\"").unwrap(), Json::Str("\u{FFFD}".into()));
    }

    #[test]
    fn deep_nesting_is_a_typed_error_not_a_stack_overflow() {
        let deep = "[".repeat(4096);
        let err = Json::parse(&deep).unwrap_err();
        assert!(err.contains("nesting deeper"), "got: {err}");
        let mixed = "{\"a\":".repeat(4096);
        assert!(Json::parse(&mixed).unwrap_err().contains("nesting deeper"));
        // At or under the cap still parses.
        let ok = format!("{}1{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn parse_bytes_rejects_non_utf8_gracefully() {
        let err = Json::parse_bytes(b"{\"a\": \xff\xfe}").unwrap_err();
        assert!(err.contains("invalid UTF-8"), "got: {err}");
        assert_eq!(Json::parse_bytes(b"[1, 2]").unwrap().as_arr().unwrap().len(), 2);
    }

    /// Seeded fuzz: the parser must return (Ok or Err) on arbitrary
    /// garbage — never panic, never overflow the stack. Two streams:
    /// token soup assembled from JSON-ish fragments, and byte-level
    /// mutations/truncations of a valid document. `BB_FUZZ_ITERS`
    /// scales the effort (CI raises it).
    #[test]
    fn fuzz_malformed_inputs_never_panic() {
        let iters: u64 = std::env::var("BB_FUZZ_ITERS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(200);
        let mut state: u64 = 0x6a50_4a51;
        let mut next = move || {
            state = state
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            state >> 33
        };
        let fragments: &[&str] = &[
            "{", "}", "[", "]", ",", ":", "\"", "\\", "\\u", "\\u12", "null", "true", "false",
            "tru", "-", "1.5e", "e+3", "9", "0.0", " ", "\n", "\"k\"", "\u{2603}",
        ];
        let valid = r#"{"rows":[{"name":"serve","ns":[1,2,3]},{"name":"net","ns":[4.5e1,-0]}]}"#;
        for _ in 0..iters {
            // Token soup.
            let n = 1 + (next() % 24) as usize;
            let soup: String = (0..n)
                .map(|_| fragments[next() as usize % fragments.len()])
                .collect();
            let _ = Json::parse(&soup);
            // Mutate one byte of a valid doc and truncate it somewhere.
            let mut bytes = valid.as_bytes().to_vec();
            let flip = next() as usize % bytes.len();
            bytes[flip] ^= (1 + next() % 255) as u8;
            bytes.truncate(1 + next() as usize % bytes.len());
            let _ = Json::parse_bytes(&bytes);
        }
    }
}
