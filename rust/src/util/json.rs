//! Minimal JSON parser and writer (serde is unavailable offline).
//!
//! Supports the full JSON value grammar minus exotic number forms; good
//! enough for `artifacts/manifest.json` and report emission.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_num(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|x| x as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // collect one UTF-8 scalar
                    let s = &self.b[self.i..];
                    let len = utf8_len(c);
                    out.push_str(
                        std::str::from_utf8(&s[..len.min(s.len())])
                            .map_err(|_| "bad utf8")?,
                    );
                    self.i += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("expected , or ] found {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected , or }} found {other:?}")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => f.write_str("\\\"")?,
                        '\\' => f.write_str("\\\\")?,
                        '\n' => f.write_str("\\n")?,
                        '\t' => f.write_str("\\t")?,
                        '\r' => f.write_str("\\r")?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let s = r#"{"attention_naive": {"file": "a.hlo.txt",
            "inputs": [{"name": "Q", "shape": [32, 16]}],
            "outputs": [[32, 16]]}}"#;
        let j = Json::parse(s).unwrap();
        let model = j.get("attention_naive").unwrap();
        assert_eq!(model.get("file").unwrap().as_str(), Some("a.hlo.txt"));
        let inputs = model.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs[0].get("name").unwrap().as_str(), Some("Q"));
        let shape = inputs[0].get("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape[0].as_num(), Some(32.0));
        // print -> parse stability
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn scalars_and_escapes() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse(r#""a\nb\"c""#).unwrap(),
            Json::Str("a\nb\"c".into())
        );
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn error_cases() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }
}
