//! Small self-built substrates the offline environment lacks crates for:
//! a minimal JSON parser/writer ([`json`]), a statistical micro-benchmark
//! harness ([`bench`]), a tiny CLI argument helper ([`cli`]), and a
//! seeded fault injector for the chaos suite ([`fault`]).

pub mod bench;
pub mod cli;
pub mod fault;
pub mod json;
