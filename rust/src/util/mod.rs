//! Small self-built substrates the offline environment lacks crates for:
//! a minimal JSON parser/writer ([`json`]), a statistical micro-benchmark
//! harness ([`bench`]), and a tiny CLI argument helper ([`cli`]).

pub mod bench;
pub mod cli;
pub mod json;
