//! Numerical safety — the paper's Appendix.
//!
//! Exponentiated values are represented as significand–exponent pairs
//! `x = s·eᵗ`: a software floating point on top of hardware floats. The
//! Appendix defines three sharing granularities — per-element, **row-wise**
//! (what Flash Attention calls *online softmax*), and block-shared — all
//! equally safe, trading precision against cost. This module implements the
//! pair arithmetic at each granularity plus a stabilized executor for the
//! fused attention kernel, applied *after* fusion exactly as the paper
//! prescribes ("a separate compiler pass, which comes after all the fusion
//! passes").

use crate::tensor::Mat;

/// A block of significands sharing one exponent: `S · e^t`.
#[derive(Clone, Debug)]
pub struct BlockExp {
    pub sig: Mat,
    pub exp: f32,
}

impl BlockExp {
    /// Represent a plain block: `(X, 0)`.
    pub fn from_block(x: Mat) -> BlockExp {
        BlockExp { sig: x, exp: 0.0 }
    }

    /// Represent `e^X` safely: `(e^(X−z), z)` with `z = max(X)`.
    pub fn exp_of(x: &Mat) -> BlockExp {
        let z = x.data.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        BlockExp {
            sig: x.map(|v| (v - z).exp()),
            exp: z,
        }
    }

    /// `(S₁,t₁) + (S₂,t₂) = (S₁e^{t₁−z} + S₂e^{t₂−z}, z)`, `z = max(t₁,t₂)`.
    pub fn add(&self, other: &BlockExp) -> BlockExp {
        let z = self.exp.max(other.exp);
        let a = self.sig.map(|v| v * (self.exp - z).exp());
        let b = other.sig.map(|v| v * (other.exp - z).exp());
        BlockExp {
            sig: a.add(&b),
            exp: z,
        }
    }

    /// `(S₁,t₁) · (S₂,t₂) = (S₁·S₂, t₁+t₂)` (matmul of significands).
    pub fn dot_bt(&self, other: &BlockExp) -> BlockExp {
        BlockExp {
            sig: self.sig.dot_bt(&other.sig),
            exp: self.exp + other.exp,
        }
    }

    /// Collapse to a plain block (may overflow if the value really is huge).
    pub fn to_block(&self) -> Mat {
        let e = self.exp.exp();
        self.sig.map(|v| v * e)
    }
}

/// Row-wise significand–exponent pairs: one exponent per row — the
/// granularity Flash Attention uses (*online softmax*).
#[derive(Clone, Debug)]
pub struct RowExp {
    pub sig: Mat,
    pub exp: Vec<f32>,
}

impl RowExp {
    pub fn zeros(rows: usize, cols: usize) -> RowExp {
        RowExp {
            sig: Mat::zeros(rows, cols),
            exp: vec![f32::NEG_INFINITY; rows],
        }
    }

    /// Represent `e^X` with per-row max subtraction.
    pub fn exp_of(x: &Mat) -> RowExp {
        let z = x.row_max();
        let sig = Mat::from_fn(x.rows, x.cols, |i, j| (x.at(i, j) - z[i]).exp());
        RowExp { sig, exp: z }
    }

    /// Row-wise pair addition (the online-softmax accumulator update).
    pub fn add(&self, other: &RowExp) -> RowExp {
        assert_eq!(self.sig.rows, other.sig.rows);
        let mut exp = Vec::with_capacity(self.exp.len());
        let mut sig = Mat::zeros(self.sig.rows, self.sig.cols);
        for i in 0..self.sig.rows {
            let z = self.exp[i].max(other.exp[i]);
            let (a, b) = ((self.exp[i] - z).exp(), (other.exp[i] - z).exp());
            for j in 0..self.sig.cols {
                *sig.at_mut(i, j) = self.sig.at(i, j) * a + other.sig.at(i, j) * b;
            }
            exp.push(z);
        }
        RowExp { sig, exp }
    }

    /// Row sums as pairs `(vector of sums, per-row exponents)`.
    pub fn row_sum(&self) -> (Vec<f32>, Vec<f32>) {
        (self.sig.row_sum(), self.exp.clone())
    }
}

/// Numerically safe fused attention: the Example-1 kernel with the
/// Appendix's row-wise stabilization, streaming KV blocks like the derived
/// single-pass program (and the Pallas kernel). `kt (s_kv, d)`,
/// `vt (d_v, s_kv)`.
pub fn safe_attention(q: &Mat, kt: &Mat, vt: &Mat, block_kv: usize) -> Mat {
    let scale = (q.cols as f32).powf(-0.5);
    let s_kv = kt.rows;
    assert_eq!(s_kv % block_kv, 0);
    let n_blocks = s_kv / block_kv;

    let mut m_run = vec![f32::NEG_INFINITY; q.rows];
    let mut l_run = vec![0.0f32; q.rows];
    let mut acc = Mat::zeros(q.rows, vt.rows);
    for b in 0..n_blocks {
        let k = kt.slice(b * block_kv, 0, block_kv, kt.cols);
        let v = vt.slice(0, b * block_kv, vt.rows, block_kv);
        let s = q.dot_bt(&k).map(|x| x * scale); // (rows, bkv)
        for i in 0..q.rows {
            let row_max = s.row(i).iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let m_new = m_run[i].max(row_max);
            let alpha = (m_run[i] - m_new).exp();
            let p: Vec<f32> = s.row(i).iter().map(|x| (x - m_new).exp()).collect();
            l_run[i] = l_run[i] * alpha + p.iter().sum::<f32>();
            for j in 0..acc.cols {
                let pv: f32 = p
                    .iter()
                    .enumerate()
                    .map(|(t, pt)| pt * v.at(j, t))
                    .sum();
                *acc.at_mut(i, j) = acc.at(i, j) * alpha + pv;
            }
            m_run[i] = m_new;
        }
    }
    let inv: Vec<f32> = l_run.iter().map(|l| 1.0 / l).collect();
    acc.row_scale(&inv)
}

/// The *unsafe* body-of-paper softmax numerator/denominator (for contrast in
/// tests): overflows for large logits.
pub fn unsafe_softmax(x: &Mat) -> Mat {
    let e = x.map(f32::exp);
    let d: Vec<f32> = e.row_sum().iter().map(|s| 1.0 / s).collect();
    e.row_scale(&d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::reference;
    use crate::tensor::Rng;

    #[test]
    fn block_pair_identities() {
        let mut rng = Rng::new(1);
        let x = rng.mat(3, 4);
        let y = rng.mat(3, 4);
        // (X,0) + (Y,0) == X+Y
        let s = BlockExp::from_block(x.clone()).add(&BlockExp::from_block(y.clone()));
        assert!(s.to_block().max_abs_diff(&x.add(&y)) < 1e-6);
        // exp_of is exact for moderate values
        let e = BlockExp::exp_of(&x);
        assert!(e.to_block().max_abs_diff(&x.map(f32::exp)) < 1e-5);
    }

    #[test]
    fn block_pair_mul_adds_exponents() {
        let mut rng = Rng::new(2);
        let a = rng.mat(3, 5);
        let b = rng.mat(4, 5);
        let pa = BlockExp {
            sig: a.clone(),
            exp: 3.0,
        };
        let pb = BlockExp {
            sig: b.clone(),
            exp: -1.0,
        };
        let prod = pa.dot_bt(&pb);
        assert_eq!(prod.exp, 2.0);
        assert!(prod.sig.max_abs_diff(&a.dot_bt(&b)) < 1e-5);
    }

    #[test]
    fn row_pair_addition_is_safe_for_huge_exponents() {
        // e^500 overflows f32; pairs don't.
        let x = Mat::from_vec(1, 2, vec![500.0, 499.0]);
        let y = Mat::from_vec(1, 2, vec![498.0, 500.0]);
        let p = RowExp::exp_of(&x).add(&RowExp::exp_of(&y));
        assert!(p.sig.data.iter().all(|v| v.is_finite()));
        // ratio of the two entries: (1 + e^-2) / (e^-1 + 1)
        let want = (1.0f32 + (-2.0f32).exp()) / ((-1.0f32).exp() + 1.0);
        let got = p.sig.at(0, 0) / p.sig.at(0, 1);
        assert!((got - want).abs() < 1e-5);
    }

    #[test]
    fn safe_attention_matches_reference_small() {
        let mut rng = Rng::new(3);
        let (q, kt, vt) = (rng.mat(6, 8), rng.mat(8, 8), rng.mat(5, 8));
        let safe = safe_attention(&q, &kt, &vt, 4);
        let want = reference::attention_ref(&q, &kt, &vt, 8.0);
        assert!(safe.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn safe_attention_survives_large_logits() {
        // logits ~ 60*sqrt(8)*8 >> 88 (f32 exp overflow threshold)
        let mut rng = Rng::new(4);
        let q = rng.mat(4, 8).map(|v| v * 60.0);
        let kt = rng.mat(8, 8).map(|v| v * 60.0);
        let vt = rng.mat(3, 8);
        // the unsafe formula overflows...
        let scores = q.dot_bt(&kt).map(|v| v * 8.0f32.powf(-0.5));
        let unsafe_out = unsafe_softmax(&scores).dot_bt(&vt);
        assert!(
            unsafe_out.data.iter().any(|v| !v.is_finite()),
            "expected the unsafe path to overflow"
        );
        // ...the stabilized kernel does not
        let safe = safe_attention(&q, &kt, &vt, 4);
        assert!(safe.data.iter().all(|v| v.is_finite()));
        // rows remain convex combinations of V's columns
        let v = vt.transpose();
        for j in 0..safe.cols {
            let lo = (0..v.rows).map(|i| v.at(i, j)).fold(f32::MAX, f32::min);
            let hi = (0..v.rows).map(|i| v.at(i, j)).fold(f32::MIN, f32::max);
            for i in 0..safe.rows {
                assert!(safe.at(i, j) >= lo - 1e-4 && safe.at(i, j) <= hi + 1e-4);
            }
        }
    }

    #[test]
    fn block_vs_row_granularity_precision() {
        // block-shared exponents are safe but coarser than row-wise: both
        // finite, row-wise closer to the exact softmax
        let mut rng = Rng::new(5);
        let x = Mat::from_fn(4, 6, |i, _| 20.0 * i as f32 + rng.f32());
        let row = RowExp::exp_of(&x);
        let block = BlockExp::exp_of(&x);
        assert!(row.sig.data.iter().all(|v| v.is_finite()));
        assert!(block.sig.data.iter().all(|v| v.is_finite()));
        // block-shared underflows the small rows entirely
        let small_row_max_block = block.sig.row(0).iter().fold(0.0f32, |a, b| a.max(*b));
        let small_row_max_row = row.sig.row(0).iter().fold(0.0f32, |a, b| a.max(*b));
        assert!(small_row_max_row > small_row_max_block);
    }
}
