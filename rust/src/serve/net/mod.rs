//! Hardened TCP ingress for the serving daemon.
//!
//! [`NetServer`] is a std-only threaded front end over
//! [`DaemonClient`]: an accept loop plus two threads per connection (a
//! frame reader and a response writer), speaking the versioned
//! length-prefixed protocol of [`proto`]. The build is offline — no
//! async runtime exists here by design; connection counts in this
//! system are bounded by the in-flight cap long before thread-per-
//! connection becomes the limit.
//!
//! **Robustness contract.** The network edge must uphold the daemon's
//! ledger discipline against everything a real client can do to it:
//!
//! * *Malformed bytes* — bad magic, wrong version, unknown frame kinds,
//!   oversized length prefixes, checksum mismatches, truncated or
//!   over-long payloads — get a typed [`Frame::Error`] and a
//!   connection close. Never a panic, never a hang, never an
//!   allocation driven by an attacker-controlled length (the frame cap
//!   is enforced from the 10-byte header alone).
//! * *Slow clients* — a client that trickles a frame byte-by-byte is
//!   bounded by `frame_timeout` from the frame's first byte
//!   (slowloris defense); a fully quiet connection is reaped after
//!   `idle_timeout` (the idle clock pauses while responses are still
//!   owed, so a client waiting on its replies is not "idle"); a client
//!   that stops *reading* is bounded by `write_timeout` on the reply
//!   path.
//! * *Vanished clients* — a disconnect with requests in flight resolves
//!   those tickets as `disconnected` (the daemon side is unaffected:
//!   routing a response to a dropped ticket receiver is a no-op).
//!   `requests_in == delivered + disconnected` reconciles exactly, at
//!   all times, per server.
//! * *Connection storms* — a global in-flight cap turns overload into
//!   immediate typed [`Frame::Reject`]`(QueueFull)` frames at the
//!   network edge instead of unbounded queue growth.
//!
//! **Shutdown ordering.** Graceful drain is a three-step dance with the
//! daemon, in this order:
//!
//! ```text
//! net.begin_shutdown();            // 1. stop accepting; readers wind down
//! let server = daemon.shutdown();  // 2. daemon drains -> every ticket resolves
//! let stats = net.shutdown();      // 3. writers flush replies + Shutdown frame
//! ```
//!
//! Step 2 between 1 and 3 is what makes 3 prompt: writers block on
//! [`Ticket::wait`], and the daemon's drain is what resolves those
//! tickets. [`NetServer::shutdown`] performs step 1 itself if the
//! caller has not, so the worst misuse is a slow join, not a deadlock.

pub mod client;
pub mod proto;

use self::proto::{
    ErrorCode, Frame, WireHealth, WireRequest, WireResponse, HEADER_LEN, PREAMBLE_LEN,
};
use super::daemon::{DaemonClient, Ticket};
use super::{Rejected, Request, Response};
use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Tuning knobs of one [`NetServer`]. The defaults suit a trusted LAN;
/// tests shrink every timeout to keep the chaos suite fast.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Hard cap on one frame's payload bytes; enforced from the header
    /// alone, before any payload allocation.
    pub max_frame: u32,
    /// Global cap on requests in flight through this ingress (admitted
    /// to the daemon, response not yet resolved). Arrivals over the cap
    /// get an immediate [`Frame::Reject`]`(QueueFull)`.
    pub max_inflight: usize,
    /// Reap a connection that has been fully quiet this long (no frame
    /// in progress *and* no response owed).
    pub idle_timeout: Duration,
    /// A frame, once started, must arrive in full within this bound —
    /// the slowloris defense.
    pub frame_timeout: Duration,
    /// Socket write timeout per reply write: bounds a client that stops
    /// reading its responses.
    pub write_timeout: Duration,
    /// Poll slice for interruptible reads and the accept loop: the
    /// granularity at which shutdown and deadlines are noticed.
    pub poll: Duration,
}

impl Default for NetConfig {
    fn default() -> NetConfig {
        NetConfig {
            max_frame: proto::DEFAULT_MAX_FRAME,
            max_inflight: 256,
            idle_timeout: Duration::from_secs(30),
            frame_timeout: Duration::from_secs(2),
            write_timeout: Duration::from_secs(2),
            poll: Duration::from_millis(25),
        }
    }
}

/// Monotonic counters of one ingress, snapshot via [`NetServer::stats`].
/// The ledger invariant: `requests_in == delivered + disconnected` once
/// the server has shut down (transiently, the difference is the
/// requests still in flight).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct NetStats {
    /// Connections accepted (past the TCP accept, before the handshake).
    pub accepted: u64,
    /// Connections dropped during the preamble exchange (bad magic,
    /// wrong version, timeout, immediate disconnect).
    pub handshake_failures: u64,
    /// Well-formed frames decoded (all kinds).
    pub frames_in: u64,
    /// Requests admitted into the daemon.
    pub requests_in: u64,
    /// Responses written back to their clients in full.
    pub delivered: u64,
    /// Admitted requests whose client was gone by reply time (ticket
    /// resolved as a disconnect).
    pub disconnected: u64,
    /// Requests refused at the network edge by the in-flight cap
    /// (typed `Reject` frames; these never reached the daemon).
    pub rejected_inflight: u64,
    /// Frames refused for protocol violations (checksum, truncation,
    /// unknown kinds, trailing bytes, client-sent server frames).
    pub malformed: u64,
    /// Frames refused from the header alone for exceeding `max_frame`.
    pub oversized: u64,
    /// Connections reaped by the idle timeout.
    pub idle_closed: u64,
    /// Connections closed by the slowloris bound (a started frame that
    /// did not complete within `frame_timeout`).
    pub frame_timeouts: u64,
    /// Health probes answered.
    pub health_probes: u64,
    /// Shutdown frames sent (graceful connection closes).
    pub shutdown_frames: u64,
    /// Requests currently in flight (gauge, not a counter).
    pub inflight: u64,
}

impl NetStats {
    /// The edge ledger: every admitted request resolved exactly once.
    pub fn reconciles(&self) -> bool {
        self.inflight == 0 && self.requests_in == self.delivered + self.disconnected
    }
}

#[derive(Default)]
struct Counters {
    accepted: AtomicU64,
    handshake_failures: AtomicU64,
    frames_in: AtomicU64,
    requests_in: AtomicU64,
    delivered: AtomicU64,
    disconnected: AtomicU64,
    rejected_inflight: AtomicU64,
    malformed: AtomicU64,
    oversized: AtomicU64,
    idle_closed: AtomicU64,
    frame_timeouts: AtomicU64,
    health_probes: AtomicU64,
    shutdown_frames: AtomicU64,
    inflight: AtomicU64,
}

impl Counters {
    fn snapshot(&self) -> NetStats {
        NetStats {
            accepted: self.accepted.load(Ordering::Relaxed),
            handshake_failures: self.handshake_failures.load(Ordering::Relaxed),
            frames_in: self.frames_in.load(Ordering::Relaxed),
            requests_in: self.requests_in.load(Ordering::Relaxed),
            delivered: self.delivered.load(Ordering::Relaxed),
            disconnected: self.disconnected.load(Ordering::Relaxed),
            rejected_inflight: self.rejected_inflight.load(Ordering::Relaxed),
            malformed: self.malformed.load(Ordering::Relaxed),
            oversized: self.oversized.load(Ordering::Relaxed),
            idle_closed: self.idle_closed.load(Ordering::Relaxed),
            frame_timeouts: self.frame_timeouts.load(Ordering::Relaxed),
            health_probes: self.health_probes.load(Ordering::Relaxed),
            shutdown_frames: self.shutdown_frames.load(Ordering::Relaxed),
            inflight: self.inflight.load(Ordering::Relaxed),
        }
    }
}

/// A running TCP ingress: accept loop + per-connection threads, all
/// feeding one [`DaemonClient`]. See the module docs for the shutdown
/// ordering.
pub struct NetServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    counters: Arc<Counters>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start accepting. The daemon stays owned by the caller; the
    /// server only holds a cheap submission handle.
    pub fn start(addr: &str, client: DaemonClient, cfg: NetConfig) -> std::io::Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let counters = Arc::new(Counters::default());
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = std::thread::Builder::new()
            .name("bb-net-accept".to_string())
            .spawn({
                let shutdown = Arc::clone(&shutdown);
                let counters = Arc::clone(&counters);
                let conns = Arc::clone(&conns);
                move || accept_loop(listener, client, cfg, shutdown, counters, conns)
            })?;
        Ok(NetServer { addr: local, shutdown, counters, accept: Some(accept), conns })
    }

    /// The bound address (resolves the ephemeral port of `:0` binds).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// A point-in-time snapshot of the ingress counters.
    pub fn stats(&self) -> NetStats {
        self.counters.snapshot()
    }

    /// Whether the server is draining.
    pub fn is_draining(&self) -> bool {
        self.shutdown.load(Ordering::Relaxed)
    }

    /// Step 1 of the drain: stop accepting, tell every connection
    /// reader to wind down. Idempotent. Call `daemon.shutdown()` after
    /// this and [`NetServer::shutdown`] after that.
    pub fn begin_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    /// Step 3 of the drain: join the accept loop and every connection.
    /// Writers flush any resolved responses, send each open connection
    /// a [`Frame::Shutdown`], and close. Returns the final counters.
    pub fn shutdown(mut self) -> NetStats {
        self.begin_shutdown();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> = {
            let mut v = self.conns.lock().unwrap_or_else(|p| p.into_inner());
            v.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
        self.counters.snapshot()
    }
}

fn accept_loop(
    listener: TcpListener,
    client: DaemonClient,
    cfg: NetConfig,
    shutdown: Arc<AtomicBool>,
    counters: Arc<Counters>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
) {
    while !shutdown.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                counters.accepted.fetch_add(1, Ordering::Relaxed);
                let handle = std::thread::Builder::new().name("bb-net-conn".to_string()).spawn({
                    let client = client.clone();
                    let cfg = cfg.clone();
                    let shutdown = Arc::clone(&shutdown);
                    let counters = Arc::clone(&counters);
                    move || conn_loop(stream, client, cfg, shutdown, counters)
                });
                match handle {
                    Ok(h) => {
                        let mut v = conns.lock().unwrap_or_else(|p| p.into_inner());
                        // Reap finished connections so a long-lived server
                        // does not accumulate dead JoinHandles.
                        let mut i = 0;
                        while i < v.len() {
                            if v[i].is_finished() {
                                let _ = v.remove(i).join();
                            } else {
                                i += 1;
                            }
                        }
                        v.push(h);
                    }
                    Err(_) => {
                        // Thread spawn failed (resource exhaustion): drop
                        // the connection rather than the server.
                        let _ = stream.shutdown(Shutdown::Both);
                    }
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => std::thread::sleep(cfg.poll),
            // Transient accept errors (e.g. EMFILE under storm): back
            // off a slice and keep the listener alive.
            Err(_) => std::thread::sleep(cfg.poll),
        }
    }
}

/// What a bounded, interruptible exact read ended as.
enum ReadEnd {
    /// The buffer was filled.
    Done,
    /// The peer closed its write half after `got` of the wanted bytes.
    Eof { got: usize },
    /// The deadline passed first.
    TimedOut,
    /// The stop flag was observed before any byte arrived.
    Stopped,
    /// A hard socket error (peer vanished).
    Gone,
}

/// Read exactly `buf.len()` bytes in poll slices, honoring a deadline —
/// and, when `stop` is given, aborting cleanly if the flag is raised
/// before the first byte lands. The stream's read timeout is the poll
/// slice, so each loop turn is short.
fn read_full(
    stream: &mut TcpStream,
    buf: &mut [u8],
    deadline: Instant,
    stop: Option<&AtomicBool>,
) -> ReadEnd {
    let mut got = 0;
    while got < buf.len() {
        if got == 0 {
            if let Some(s) = stop {
                if s.load(Ordering::Relaxed) {
                    return ReadEnd::Stopped;
                }
            }
        }
        if Instant::now() >= deadline {
            return ReadEnd::TimedOut;
        }
        match stream.read(&mut buf[got..]) {
            Ok(0) => return ReadEnd::Eof { got },
            Ok(n) => got += n,
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut
                    || e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return ReadEnd::Gone,
        }
    }
    ReadEnd::Done
}

fn write_frame(stream: &mut TcpStream, f: &Frame) -> std::io::Result<()> {
    stream.write_all(&proto::encode_frame(f))
}

/// Messages from a connection's reader to its writer. `Hangup` is
/// always the final message.
enum WMsg {
    /// An admitted request: wait the ticket, write the response.
    Ticket { corr: u64, ticket: Ticket },
    /// An immediate frame (reject, health reply, error).
    Frame(Frame),
    /// Last message: `graceful` closes with a `Shutdown` frame,
    /// non-graceful closes cold.
    Hangup { graceful: bool },
}

/// One connection: handshake, then read frames until EOF, error,
/// timeout, or server drain. Spawns the writer thread and joins it
/// before returning, so the connection's JoinHandle covers both.
fn conn_loop(
    mut stream: TcpStream,
    client: DaemonClient,
    cfg: NetConfig,
    shutdown: Arc<AtomicBool>,
    c: Arc<Counters>,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(cfg.poll));
    let _ = stream.set_write_timeout(Some(cfg.write_timeout));

    // Handshake: the client leads with the preamble; we echo it back.
    let mut pre = [0u8; PREAMBLE_LEN];
    match read_full(&mut stream, &mut pre, Instant::now() + cfg.idle_timeout, Some(&shutdown)) {
        ReadEnd::Done => {}
        _ => {
            c.handshake_failures.fetch_add(1, Ordering::Relaxed);
            let _ = stream.shutdown(Shutdown::Both);
            return;
        }
    }
    if let Err((code, msg)) = proto::check_preamble(&pre) {
        c.handshake_failures.fetch_add(1, Ordering::Relaxed);
        let _ = write_frame(&mut stream, &Frame::Error { code, msg });
        let _ = stream.shutdown(Shutdown::Both);
        return;
    }
    if stream.write_all(&proto::encode_preamble()).is_err() {
        c.handshake_failures.fetch_add(1, Ordering::Relaxed);
        return;
    }

    let wstream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => {
            let _ = stream.shutdown(Shutdown::Both);
            return;
        }
    };
    let (wtx, wrx) = channel::<WMsg>();
    // Raised by the reader when the client is known gone or the
    // connection is protocol-dead: the writer then resolves remaining
    // tickets as disconnects instead of writing into the void.
    let gone = Arc::new(AtomicBool::new(false));
    // Responses owed on this connection — while nonzero, the idle
    // reaper leaves a quiet (reading-only) client alone.
    let owed = Arc::new(AtomicU64::new(0));
    let writer = std::thread::Builder::new()
        .name("bb-net-writer".to_string())
        .spawn({
            let c = Arc::clone(&c);
            let gone = Arc::clone(&gone);
            let owed = Arc::clone(&owed);
            move || writer_loop(wstream, wrx, c, gone, owed)
        });
    let writer = match writer {
        Ok(w) => w,
        Err(_) => {
            let _ = stream.shutdown(Shutdown::Both);
            return;
        }
    };

    read_frames(&mut stream, &client, &cfg, &shutdown, &c, &wtx, &gone, &owed);

    drop(wtx);
    let _ = writer.join();
    let _ = stream.shutdown(Shutdown::Both);
}

/// The reader's frame loop. Terminal paths send their error frame (if
/// any) and the final `Hangup`; the caller joins the writer.
#[allow(clippy::too_many_arguments)]
fn read_frames(
    stream: &mut TcpStream,
    client: &DaemonClient,
    cfg: &NetConfig,
    shutdown: &Arc<AtomicBool>,
    c: &Arc<Counters>,
    wtx: &Sender<WMsg>,
    gone: &Arc<AtomicBool>,
    owed: &Arc<AtomicU64>,
) {
    let fail = |frame: Option<Frame>| {
        gone.store(true, Ordering::SeqCst);
        if let Some(f) = frame {
            let _ = wtx.send(WMsg::Frame(f));
        }
        let _ = wtx.send(WMsg::Hangup { graceful: false });
    };
    loop {
        // Await the next frame's first byte. The idle clock only runs
        // while nothing is owed: a client waiting on responses is not
        // idle, it is reading.
        let mut hdr = [0u8; HEADER_LEN];
        let first = loop {
            let idle = Instant::now() + cfg.idle_timeout;
            let r = read_full(stream, &mut hdr[..1], idle, Some(shutdown));
            if matches!(r, ReadEnd::TimedOut) && owed.load(Ordering::Relaxed) > 0 {
                continue;
            }
            break r;
        };
        match first {
            ReadEnd::Done => {}
            ReadEnd::Eof { .. } | ReadEnd::Stopped => {
                // Clean client EOF, or server drain: deliver what is
                // owed, then a Shutdown frame.
                let _ = wtx.send(WMsg::Hangup { graceful: true });
                return;
            }
            ReadEnd::TimedOut => {
                c.idle_closed.fetch_add(1, Ordering::Relaxed);
                let msg = format!("idle for {:?}", cfg.idle_timeout);
                fail(Some(Frame::Error { code: ErrorCode::IdleTimeout, msg }));
                return;
            }
            ReadEnd::Gone => {
                fail(None);
                return;
            }
        }

        // A frame has started: everything else about it — header rest,
        // payload — must land within frame_timeout (slowloris bound).
        let frame_deadline = Instant::now() + cfg.frame_timeout;
        match read_full(stream, &mut hdr[1..], frame_deadline, None) {
            ReadEnd::Done => {}
            ReadEnd::TimedOut => {
                c.frame_timeouts.fetch_add(1, Ordering::Relaxed);
                let msg = format!("frame header incomplete after {:?}", cfg.frame_timeout);
                fail(Some(Frame::Error { code: ErrorCode::FrameTimeout, msg }));
                return;
            }
            ReadEnd::Eof { .. } => {
                c.malformed.fetch_add(1, Ordering::Relaxed);
                fail(Some(Frame::Error {
                    code: ErrorCode::Malformed,
                    msg: "connection closed mid-header".to_string(),
                }));
                return;
            }
            ReadEnd::Stopped | ReadEnd::Gone => {
                fail(None);
                return;
            }
        }
        let header = match proto::decode_header(&hdr, cfg.max_frame) {
            Ok(h) => h,
            Err(e) => {
                let len = u32::from_le_bytes([hdr[2], hdr[3], hdr[4], hdr[5]]);
                let code = if len > cfg.max_frame {
                    c.oversized.fetch_add(1, Ordering::Relaxed);
                    ErrorCode::Oversized
                } else {
                    c.malformed.fetch_add(1, Ordering::Relaxed);
                    ErrorCode::Malformed
                };
                fail(Some(Frame::Error { code, msg: e.0 }));
                return;
            }
        };
        let mut payload = vec![0u8; header.payload_len as usize];
        match read_full(stream, &mut payload, frame_deadline, None) {
            ReadEnd::Done => {}
            ReadEnd::TimedOut => {
                c.frame_timeouts.fetch_add(1, Ordering::Relaxed);
                let msg = format!(
                    "frame payload ({} bytes) incomplete after {:?}",
                    header.payload_len, cfg.frame_timeout
                );
                fail(Some(Frame::Error { code: ErrorCode::FrameTimeout, msg }));
                return;
            }
            ReadEnd::Eof { .. } => {
                c.malformed.fetch_add(1, Ordering::Relaxed);
                fail(Some(Frame::Error {
                    code: ErrorCode::Malformed,
                    msg: "connection closed mid-payload (torn frame)".to_string(),
                }));
                return;
            }
            ReadEnd::Stopped | ReadEnd::Gone => {
                fail(None);
                return;
            }
        }
        let frame = match proto::decode_frame(&header, &payload) {
            Ok(f) => f,
            Err(e) => {
                c.malformed.fetch_add(1, Ordering::Relaxed);
                let code = if e.0.contains("checksum") {
                    ErrorCode::BadChecksum
                } else {
                    ErrorCode::Malformed
                };
                fail(Some(Frame::Error { code, msg: e.0 }));
                return;
            }
        };
        c.frames_in.fetch_add(1, Ordering::Relaxed);

        match frame {
            Frame::Request(wr) => {
                // Global in-flight cap: overload surfaces as a typed
                // edge rejection, never as memory growth.
                let cur = c.inflight.fetch_add(1, Ordering::SeqCst);
                if cur >= cfg.max_inflight as u64 {
                    c.inflight.fetch_sub(1, Ordering::SeqCst);
                    c.rejected_inflight.fetch_add(1, Ordering::Relaxed);
                    let _ = wtx.send(WMsg::Frame(Frame::Reject {
                        corr: wr.corr,
                        reason: Rejected::QueueFull,
                    }));
                    continue;
                }
                let WireRequest { corr, workload, deadline_ms, inputs } = wr;
                let mut req = Request::new(workload, inputs.into_iter().collect());
                if deadline_ms > 0 {
                    req = req
                        .with_deadline(Instant::now() + Duration::from_millis(deadline_ms as u64));
                }
                c.requests_in.fetch_add(1, Ordering::Relaxed);
                owed.fetch_add(1, Ordering::SeqCst);
                let ticket = client.submit(req);
                let _ = wtx.send(WMsg::Ticket { corr, ticket });
            }
            Frame::Health => {
                c.health_probes.fetch_add(1, Ordering::Relaxed);
                let _ = wtx.send(WMsg::Frame(Frame::HealthReply(WireHealth {
                    inflight: c.inflight.load(Ordering::Relaxed),
                    requests_in: c.requests_in.load(Ordering::Relaxed),
                    delivered: c.delivered.load(Ordering::Relaxed),
                    draining: shutdown.load(Ordering::Relaxed),
                })));
            }
            Frame::Shutdown => {
                // Client-initiated half-close: no more requests, still
                // reading. Drain what is owed and close politely.
                let _ = wtx.send(WMsg::Hangup { graceful: true });
                return;
            }
            Frame::Error { .. } => {
                // The client is aborting; nothing further to say.
                fail(None);
                return;
            }
            Frame::Response(_) | Frame::Reject { .. } | Frame::HealthReply(_) => {
                c.malformed.fetch_add(1, Ordering::Relaxed);
                fail(Some(Frame::Error {
                    code: ErrorCode::Malformed,
                    msg: "client sent a server-only frame kind".to_string(),
                }));
                return;
            }
        }
    }
}

/// The writer drains its channel in order: tickets resolve FIFO (so
/// pipelined responses arrive in submission order), immediate frames go
/// straight out, and the final `Hangup` decides between a `Shutdown`
/// frame and a cold close. Every ticket decrements the global in-flight
/// gauge exactly once, delivered or not.
fn writer_loop(
    mut stream: TcpStream,
    rx: Receiver<WMsg>,
    c: Arc<Counters>,
    gone: Arc<AtomicBool>,
    owed: Arc<AtomicU64>,
) {
    let mut broken = false;
    let mut graceful = false;
    for msg in rx {
        match msg {
            WMsg::Ticket { corr, ticket } => {
                if broken || gone.load(Ordering::Relaxed) {
                    // Client is not coming back: resolve as a disconnect
                    // without waiting (dropping the ticket is safe — the
                    // daemon routes into a dropped receiver as a no-op).
                    drop(ticket);
                    owed.fetch_sub(1, Ordering::SeqCst);
                    c.inflight.fetch_sub(1, Ordering::SeqCst);
                    c.disconnected.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                let resp = ticket.wait();
                owed.fetch_sub(1, Ordering::SeqCst);
                c.inflight.fetch_sub(1, Ordering::SeqCst);
                let frame = response_frame(corr, resp);
                if write_frame(&mut stream, &frame).is_ok() {
                    c.delivered.fetch_add(1, Ordering::Relaxed);
                } else {
                    broken = true;
                    c.disconnected.fetch_add(1, Ordering::Relaxed);
                }
            }
            WMsg::Frame(f) => {
                if !broken && write_frame(&mut stream, &f).is_err() {
                    broken = true;
                }
            }
            WMsg::Hangup { graceful: g } => {
                graceful = g;
            }
        }
    }
    if graceful && !broken && write_frame(&mut stream, &Frame::Shutdown).is_ok() {
        c.shutdown_frames.fetch_add(1, Ordering::Relaxed);
    }
    let _ = stream.shutdown(Shutdown::Both);
}

/// Convert a daemon [`Response`] to its wire form. Outputs are sorted
/// by name so the byte encoding is deterministic (the chaos suite's
/// bit-identical comparisons hold across the socket).
fn response_frame(corr: u64, resp: Response) -> Frame {
    let mut outputs: Vec<(String, crate::tensor::Mat)> = resp.outputs.into_iter().collect();
    outputs.sort_by(|a, b| a.0.cmp(&b.0));
    Frame::Response(Box::new(WireResponse {
        corr,
        verdict: resp.verdict,
        batch_size: resp.batch_size as u32,
        coalesced: resp.coalesced,
        queue_ns: resp.queue_ns.min(u64::MAX as u128) as u64,
        exec_ns: resp.exec_ns.min(u64::MAX as u128) as u64,
        mem: resp.mem,
        outputs,
    }))
}

#[cfg(test)]
mod tests {
    use super::client::{ClientConfig, NetClient};
    use super::*;
    use crate::serve::daemon::Daemon;
    use crate::serve::{ModelServer, ServerConfig};

    fn test_cfg() -> NetConfig {
        NetConfig {
            idle_timeout: Duration::from_millis(400),
            frame_timeout: Duration::from_millis(200),
            write_timeout: Duration::from_millis(200),
            poll: Duration::from_millis(5),
            ..NetConfig::default()
        }
    }

    fn start_stack() -> (Daemon, NetServer) {
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            threads: Some(1),
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        let daemon = Daemon::start(s, None);
        let net = NetServer::start("127.0.0.1:0", daemon.client(), test_cfg()).unwrap();
        (daemon, net)
    }

    fn drain(daemon: Daemon, net: NetServer) -> NetStats {
        net.begin_shutdown();
        daemon.shutdown();
        net.shutdown()
    }

    #[test]
    fn loopback_roundtrip_serves_and_reconciles() {
        let (daemon, net) = start_stack();
        let addr = net.local_addr().to_string();
        let mut cli = NetClient::connect(&addr, ClientConfig::default()).unwrap();
        for i in 0..3u64 {
            let resp = cli.call_synthetic("quickstart", i, i).unwrap();
            assert_eq!(resp.corr, i);
            assert_eq!(resp.verdict, crate::serve::Verdict::Ok);
            assert!(!resp.outputs.is_empty());
        }
        drop(cli);
        let stats = drain(daemon, net);
        assert_eq!(stats.requests_in, 3);
        assert_eq!(stats.delivered, 3);
        assert!(stats.reconciles(), "{stats:?}");
    }

    #[test]
    fn bad_magic_is_rejected_at_the_handshake() {
        let (daemon, net) = start_stack();
        let mut raw = TcpStream::connect(net.local_addr()).unwrap();
        raw.write_all(b"NOTBBP1!").unwrap();
        let mut buf = Vec::new();
        let _ = raw.read_to_end(&mut buf); // server errors (maybe) and closes
        drop(raw);
        // The server survives: a well-behaved client still gets served.
        let addr = net.local_addr().to_string();
        let mut cli = NetClient::connect(&addr, ClientConfig::default()).unwrap();
        assert!(cli.call_synthetic("quickstart", 0, 9).is_ok());
        drop(cli);
        let stats = drain(daemon, net);
        assert_eq!(stats.handshake_failures, 1);
        assert!(stats.reconciles(), "{stats:?}");
    }

    #[test]
    fn drain_sends_shutdown_frame_to_open_connections() {
        let (daemon, net) = start_stack();
        let addr = net.local_addr().to_string();
        let mut cli = NetClient::connect(&addr, ClientConfig::default()).unwrap();
        assert!(cli.call_synthetic("quickstart", 0, 1).is_ok());
        net.begin_shutdown();
        daemon.shutdown();
        // The open, idle connection is told the server is going away.
        let f = cli.recv().unwrap();
        assert_eq!(f, Frame::Shutdown);
        let stats = net.shutdown();
        assert_eq!(stats.shutdown_frames, 1);
        assert!(stats.reconciles(), "{stats:?}");
    }
}
