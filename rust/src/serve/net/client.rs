//! A blocking client for the daemon's TCP ingress: preamble handshake,
//! pipelined framed requests, reconnect with capped exponential
//! backoff — and the network fault sites the chaos suite injects
//! ([`Site::NetTornWrite`], [`Site::NetStallRead`],
//! [`Site::NetDisconnect`]) so slow/torn/vanishing clients can be
//! manufactured deterministically against a real socket.
//!
//! Error-kind contract (what a failed call tells the caller):
//!
//! * `BrokenPipe` from [`NetClient::send`] — the frame did **not** reach
//!   the server whole (torn write); the request was never admitted.
//! * `ConnectionAborted` from [`NetClient::send`] — the frame was
//!   written in full, then the connection dropped; the request may be
//!   in flight server-side (it will resolve as a disconnect there).
//! * Any error from [`NetClient::recv`] — the response's fate is
//!   unknown; reconnect and treat the request as lost.

use super::proto::{self, Frame, WireHealth, WireRequest, WireResponse, HEADER_LEN, PREAMBLE_LEN};
use super::{read_full, ReadEnd};
use crate::coordinator::workloads;
use crate::serve::{ModelServer, ServerConfig, Verdict};
use crate::tensor::Mat;
use crate::util::fault::{self, Site};
use std::io::{self, ErrorKind, Write};
use std::net::{Shutdown, TcpStream};
use std::time::{Duration, Instant};

/// Reconnect policy: `attempts` tries, sleeping `min(base * 2^i, cap)`
/// between consecutive failures.
#[derive(Clone, Debug)]
pub struct BackoffConfig {
    pub attempts: u32,
    pub base: Duration,
    pub cap: Duration,
}

impl Default for BackoffConfig {
    fn default() -> BackoffConfig {
        BackoffConfig {
            attempts: 5,
            base: Duration::from_millis(50),
            cap: Duration::from_secs(2),
        }
    }
}

impl BackoffConfig {
    /// The sleep before retry `i` (0-based), exponentially grown from
    /// `base` and clamped at `cap`.
    pub fn delay(&self, i: u32) -> Duration {
        let factor = 1u32.checked_shl(i.min(16)).unwrap_or(u32::MAX);
        self.base.saturating_mul(factor).min(self.cap)
    }
}

/// Client knobs. `stall` is only consumed by the injected
/// [`Site::NetStallRead`] fault.
#[derive(Clone, Debug)]
pub struct ClientConfig {
    /// Overall bound on one [`NetClient::recv`].
    pub read_timeout: Duration,
    /// Socket write timeout for request frames.
    pub write_timeout: Duration,
    /// Largest response frame this client will accept.
    pub max_frame: u32,
    pub backoff: BackoffConfig,
    /// How long an injected stalled-read fault sleeps before reading.
    pub stall: Duration,
}

impl Default for ClientConfig {
    fn default() -> ClientConfig {
        ClientConfig {
            read_timeout: Duration::from_secs(30),
            write_timeout: Duration::from_secs(2),
            max_frame: proto::DEFAULT_MAX_FRAME,
            backoff: BackoffConfig::default(),
            stall: Duration::from_millis(50),
        }
    }
}

/// Poll slice for the client's interruptible reads.
const POLL: Duration = Duration::from_millis(25);

/// A connected client. Requests pipeline freely: [`NetClient::send`]
/// any number of frames, then [`NetClient::recv`] the responses — the
/// server resolves one connection's responses in submission order.
pub struct NetClient {
    addr: String,
    cfg: ClientConfig,
    stream: TcpStream,
}

fn ioerr(kind: ErrorKind, msg: impl Into<String>) -> io::Error {
    io::Error::new(kind, msg.into())
}

impl NetClient {
    /// Connect and handshake, retrying per [`BackoffConfig`]. The
    /// backoff matters in practice: a client racing a server's bind
    /// (CI's loopback smoke does exactly this) connects on a later
    /// attempt instead of failing the run.
    pub fn connect(addr: &str, cfg: ClientConfig) -> io::Result<NetClient> {
        let mut last: Option<io::Error> = None;
        for i in 0..cfg.backoff.attempts.max(1) {
            if i > 0 {
                std::thread::sleep(cfg.backoff.delay(i - 1));
            }
            match connect_once(addr, &cfg) {
                Ok(stream) => {
                    return Ok(NetClient {
                        addr: addr.to_string(),
                        cfg,
                        stream,
                    })
                }
                Err(e) => last = Some(e),
            }
        }
        Err(last
            .unwrap_or_else(|| ioerr(ErrorKind::NotConnected, "no connection attempts configured")))
    }

    /// Drop the current connection and dial again (same backoff).
    pub fn reconnect(&mut self) -> io::Result<()> {
        let _ = self.stream.shutdown(Shutdown::Both);
        let mut last: Option<io::Error> = None;
        for i in 0..self.cfg.backoff.attempts.max(1) {
            if i > 0 {
                std::thread::sleep(self.cfg.backoff.delay(i - 1));
            }
            match connect_once(&self.addr, &self.cfg) {
                Ok(stream) => {
                    self.stream = stream;
                    return Ok(());
                }
                Err(e) => last = Some(e),
            }
        }
        Err(last
            .unwrap_or_else(|| ioerr(ErrorKind::NotConnected, "no connection attempts configured")))
    }

    /// Write one request frame. Consumes the torn-write and disconnect
    /// fault sites (see the module docs for the error-kind contract).
    pub fn send(&mut self, req: &WireRequest) -> io::Result<()> {
        let bytes = proto::encode_frame(&Frame::Request(req.clone()));
        if fault::injected(Site::NetTornWrite) {
            // Write half the frame and vanish: the server must time the
            // torn frame out, never hang on it.
            let half = bytes.len() / 2;
            let _ = self.stream.write_all(&bytes[..half]);
            let _ = self.stream.flush();
            let _ = self.stream.shutdown(Shutdown::Both);
            return Err(ioerr(ErrorKind::BrokenPipe, "injected torn write"));
        }
        self.stream.write_all(&bytes)?;
        if fault::injected(Site::NetDisconnect) {
            // The request reached the server; the client vanishes before
            // collecting the reply — server-side it resolves as a
            // disconnect, not a leak.
            let _ = self.stream.shutdown(Shutdown::Both);
            return Err(ioerr(ErrorKind::ConnectionAborted, "injected disconnect"));
        }
        Ok(())
    }

    /// Read one frame (any kind), bounded by
    /// [`ClientConfig::read_timeout`]. Consumes the stalled-read fault
    /// site.
    pub fn recv(&mut self) -> io::Result<Frame> {
        if fault::injected(Site::NetStallRead) {
            // A deliberately slow reader: the server's reply path must
            // tolerate this (bounded by its write timeout), not block
            // other connections.
            std::thread::sleep(self.cfg.stall);
        }
        let deadline = Instant::now() + self.cfg.read_timeout;
        let mut hdr = [0u8; HEADER_LEN];
        read_end(read_full(&mut self.stream, &mut hdr, deadline, None))?;
        let header = proto::decode_header(&hdr, self.cfg.max_frame)
            .map_err(|e| ioerr(ErrorKind::InvalidData, e.0))?;
        let mut payload = vec![0u8; header.payload_len as usize];
        read_end(read_full(&mut self.stream, &mut payload, deadline, None))?;
        proto::decode_frame(&header, &payload).map_err(|e| ioerr(ErrorKind::InvalidData, e.0))
    }

    /// Send one request and wait for its resolution. Edge rejections
    /// ([`Frame::Reject`]) are folded into a [`WireResponse`] with the
    /// matching [`Verdict::Rejected`], so callers handle one shape.
    pub fn call(&mut self, req: &WireRequest) -> io::Result<WireResponse> {
        self.send(req)?;
        match self.recv()? {
            Frame::Response(r) => Ok(*r),
            Frame::Reject { corr, reason } => Ok(WireResponse {
                corr,
                verdict: Verdict::Rejected(reason),
                batch_size: 0,
                coalesced: false,
                queue_ns: 0,
                exec_ns: 0,
                mem: Default::default(),
                outputs: vec![],
            }),
            Frame::Shutdown => Err(ioerr(ErrorKind::ConnectionAborted, "server draining")),
            Frame::Error { code, msg } => {
                Err(ioerr(ErrorKind::InvalidData, format!("server error {code:?}: {msg}")))
            }
            other => Err(ioerr(
                ErrorKind::InvalidData,
                format!("unexpected frame {:?} awaiting a response", frame_name(&other)),
            )),
        }
    }

    /// [`NetClient::call`] with deterministic synthetic inputs for one
    /// of the canonical demo workloads.
    pub fn call_synthetic(
        &mut self,
        workload: &str,
        corr: u64,
        seed: u64,
    ) -> io::Result<WireResponse> {
        let req = synthetic_request(workload, corr, seed)
            .ok_or_else(|| ioerr(ErrorKind::InvalidInput, format!("unknown workload {workload}")))?;
        self.call(&req)
    }

    /// [`NetClient::call`] with *ragged* synthetic inputs: `trip` blocks
    /// along the workload's stackable grid dim instead of the full
    /// registered extent (see [`synthetic_ragged_request`]).
    pub fn call_synthetic_ragged(
        &mut self,
        workload: &str,
        corr: u64,
        seed: u64,
        trip: usize,
    ) -> io::Result<WireResponse> {
        let req = synthetic_ragged_request(workload, corr, seed, trip).ok_or_else(|| {
            ioerr(
                ErrorKind::InvalidInput,
                format!("unknown or non-stackable workload {workload} (trip {trip})"),
            )
        })?;
        self.call(&req)
    }

    /// Probe server liveness.
    pub fn health(&mut self) -> io::Result<WireHealth> {
        let bytes = proto::encode_frame(&Frame::Health);
        self.stream.write_all(&bytes)?;
        match self.recv()? {
            Frame::HealthReply(h) => Ok(h),
            other => Err(ioerr(
                ErrorKind::InvalidData,
                format!("unexpected frame {:?} awaiting a health reply", frame_name(&other)),
            )),
        }
    }

    /// Politely announce end-of-requests (the server drains what is
    /// owed, sends `Shutdown`, and closes).
    pub fn finish(&mut self) -> io::Result<()> {
        let bytes = proto::encode_frame(&Frame::Shutdown);
        self.stream.write_all(&bytes)
    }
}

/// Build a deterministic synthetic [`WireRequest`] for a canonical demo
/// workload: full-shape inputs from `seed`, sorted by name so the wire
/// bytes are reproducible.
pub fn synthetic_request(workload: &str, corr: u64, seed: u64) -> Option<WireRequest> {
    let (_program, _cfg, _params, inputs) = workloads::by_name(workload, seed)?;
    let mut inputs: Vec<(String, Mat)> = inputs.into_iter().collect();
    inputs.sort_by(|a, b| a.0.cmp(&b.0));
    Some(WireRequest {
        corr,
        workload: workload.to_string(),
        deadline_ms: 0,
        inputs,
    })
}

/// Build a deterministic *ragged* synthetic [`WireRequest`]: stack-dim
/// carrying inputs at `trip` blocks (`1..=` the workload's registered
/// trip), weight-like inputs from the fixed per-workload stream — so
/// ragged wire traffic coalesces server-side with full-shape synthetic
/// requests regardless of seed. This *is* the server's generator
/// ([`ModelServer::synthetic_inputs_ragged`]), run against a throwaway
/// local registration, so the bytes on the wire match what a local
/// server would enqueue. The registration compiles the workload once
/// per call: generate requests outside timed loops.
pub fn synthetic_ragged_request(
    workload: &str,
    corr: u64,
    seed: u64,
    trip: usize,
) -> Option<WireRequest> {
    let mut server = ModelServer::new(ServerConfig::default());
    server.register(workload).ok()?;
    let inputs = server.synthetic_inputs_ragged(workload, seed, trip).ok()?;
    let mut inputs: Vec<(String, Mat)> = inputs.into_iter().collect();
    inputs.sort_by(|a, b| a.0.cmp(&b.0));
    Some(WireRequest {
        corr,
        workload: workload.to_string(),
        deadline_ms: 0,
        inputs,
    })
}

fn frame_name(f: &Frame) -> &'static str {
    match f {
        Frame::Request(_) => "Request",
        Frame::Response(_) => "Response",
        Frame::Reject { .. } => "Reject",
        Frame::Health => "Health",
        Frame::HealthReply(_) => "HealthReply",
        Frame::Error { .. } => "Error",
        Frame::Shutdown => "Shutdown",
    }
}

fn read_end(end: ReadEnd) -> io::Result<()> {
    match end {
        ReadEnd::Done => Ok(()),
        ReadEnd::Eof { .. } => Err(ioerr(ErrorKind::UnexpectedEof, "server closed mid-frame")),
        ReadEnd::TimedOut => Err(ioerr(ErrorKind::TimedOut, "response read timed out")),
        ReadEnd::Stopped => unreachable!("client reads pass no stop flag"),
        ReadEnd::Gone => Err(ioerr(ErrorKind::ConnectionReset, "connection lost mid-frame")),
    }
}

fn connect_once(addr: &str, cfg: &ClientConfig) -> io::Result<TcpStream> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(POLL))?;
    stream.set_write_timeout(Some(cfg.write_timeout))?;
    stream.write_all(&proto::encode_preamble())?;
    let mut echo = [0u8; PREAMBLE_LEN];
    read_end(read_full(&mut stream, &mut echo, Instant::now() + cfg.read_timeout, None))?;
    if proto::check_preamble(&echo).is_err() {
        return Err(ioerr(
            ErrorKind::InvalidData,
            "handshake rejected (magic/version mismatch)",
        ));
    }
    Ok(stream)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_exponentially_and_caps() {
        let b = BackoffConfig {
            attempts: 8,
            base: Duration::from_millis(50),
            cap: Duration::from_millis(400),
        };
        assert_eq!(b.delay(0), Duration::from_millis(50));
        assert_eq!(b.delay(1), Duration::from_millis(100));
        assert_eq!(b.delay(2), Duration::from_millis(200));
        assert_eq!(b.delay(3), Duration::from_millis(400));
        assert_eq!(b.delay(4), Duration::from_millis(400), "capped");
        assert_eq!(b.delay(63), Duration::from_millis(400), "shift-safe");
    }

    #[test]
    fn connect_to_nothing_exhausts_backoff_quickly() {
        // A port from the ephemeral range with (almost certainly) no
        // listener; tiny backoff so the test is fast either way.
        let cfg = ClientConfig {
            backoff: BackoffConfig {
                attempts: 2,
                base: Duration::from_millis(1),
                cap: Duration::from_millis(2),
            },
            ..ClientConfig::default()
        };
        let t0 = Instant::now();
        let r = NetClient::connect("127.0.0.1:1", cfg);
        assert!(r.is_err());
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn synthetic_requests_are_deterministic() {
        let a = synthetic_request("quickstart", 1, 7).unwrap();
        let b = synthetic_request("quickstart", 2, 7).unwrap();
        assert_eq!(a.inputs, b.inputs, "same seed, same inputs");
        assert_ne!(a.corr, b.corr);
        let c = synthetic_request("quickstart", 1, 8).unwrap();
        assert_ne!(a.inputs, c.inputs, "different seed, different inputs");
        assert!(synthetic_request("no_such_workload", 0, 0).is_none());
    }

    #[test]
    fn ragged_synthetic_requests_scale_the_stack_dim() {
        let full = synthetic_ragged_request("quickstart", 0, 7, 4).unwrap();
        let half = synthetic_ragged_request("quickstart", 1, 7, 2).unwrap();
        let a_full = &full.inputs.iter().find(|(n, _)| n == "A").unwrap().1;
        let a_half = &half.inputs.iter().find(|(n, _)| n == "A").unwrap().1;
        assert_eq!(a_full.rows, 32);
        assert_eq!(a_half.rows, 16, "half the registered trip, half the rows");
        assert_eq!(a_full.cols, a_half.cols);
        // weights ride the fixed stream: bit-identical across seeds, so
        // ragged wire traffic coalesces with any other synthetic request
        let bt_full = &full.inputs.iter().find(|(n, _)| n == "BT").unwrap().1;
        let bt_half = &half.inputs.iter().find(|(n, _)| n == "BT").unwrap().1;
        assert_eq!(bt_full, bt_half);
        assert!(
            synthetic_ragged_request("quickstart", 0, 0, 9).is_none(),
            "trip above the registered trip"
        );
    }
}
