//! The versioned, length-prefixed binary wire protocol of the serving
//! daemon's TCP ingress (`serve::net`).
//!
//! **Connection preamble.** A client opens with 8 bytes — [`MAGIC`]
//! (`u32` LE), [`VERSION`] (`u16` LE), two reserved zero bytes — and the
//! server echoes the same 8 bytes back on acceptance. A bad magic or an
//! unsupported version gets a typed [`Frame::Error`] and a close; random
//! port scanners never reach the frame layer.
//!
//! **Frames.** Everything after the preamble is length-prefixed frames:
//!
//! ```text
//! [kind u8][reserved u8][payload_len u32 LE][checksum u32 LE] payload…
//! ```
//!
//! The checksum is FNV-1a over the payload, so a torn or corrupted
//! frame is detected before any payload byte is interpreted. Payloads
//! above the connection's frame-size cap are rejected from the header
//! alone (the payload is never read into memory). Decoding is strict:
//! every decoder must consume its payload exactly — trailing bytes,
//! truncated fields, and unknown tags are all typed errors, never
//! panics ([`ProtoError`]).
//!
//! Frame kinds: `Request` (client → server, one inference request),
//! `Response` (server → client, the daemon's verdict + outputs +
//! traffic counters), `Reject` (server → client, a network-edge
//! rejection that never reached the daemon — e.g. the global in-flight
//! cap), `Health`/`HealthReply` (liveness probe), `Error` (fatal
//! protocol violation; the connection closes after), and `Shutdown`
//! (server → client: graceful drain — no further responses follow).

use crate::loopir::interp::MemSim;
use crate::serve::{Rejected, Verdict};
use crate::tensor::Mat;
use std::fmt;

/// `"BBP1"` — Blockbuster protocol, generation 1.
pub const MAGIC: u32 = 0x4231_5042;
/// Bumped on any incompatible frame-layout change; the preamble
/// handshake rejects mismatches before any frame is parsed.
pub const VERSION: u16 = 3;
/// Connection preamble length: magic + version + 2 reserved bytes.
pub const PREAMBLE_LEN: usize = 8;
/// Frame header length: kind + reserved + payload len + checksum.
pub const HEADER_LEN: usize = 10;
/// Default hard cap on one frame's payload (16 MiB) — an adversarial
/// length prefix must not be able to make the server allocate.
pub const DEFAULT_MAX_FRAME: u32 = 16 * 1024 * 1024;

/// A decode/validation failure. Always a typed error — the protocol
/// layer never panics on wire bytes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ProtoError(pub String);

impl fmt::Display for ProtoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "protocol error: {}", self.0)
    }
}

impl std::error::Error for ProtoError {}

fn perr<T>(msg: impl Into<String>) -> Result<T, ProtoError> {
    Err(ProtoError(msg.into()))
}

/// FNV-1a over `bytes` — the frame payload checksum. Not cryptographic;
/// it exists to catch torn writes and corruption, not tampering.
pub fn checksum(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// Why the server is terminating a connection (carried in
/// [`Frame::Error`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    BadMagic,
    BadVersion,
    BadChecksum,
    Oversized,
    Malformed,
    IdleTimeout,
    FrameTimeout,
    TooManyConnections,
    Internal,
}

impl ErrorCode {
    fn to_u8(self) -> u8 {
        match self {
            ErrorCode::BadMagic => 1,
            ErrorCode::BadVersion => 2,
            ErrorCode::BadChecksum => 3,
            ErrorCode::Oversized => 4,
            ErrorCode::Malformed => 5,
            ErrorCode::IdleTimeout => 6,
            ErrorCode::FrameTimeout => 7,
            ErrorCode::TooManyConnections => 8,
            ErrorCode::Internal => 9,
        }
    }

    fn from_u8(b: u8) -> Result<ErrorCode, ProtoError> {
        Ok(match b {
            1 => ErrorCode::BadMagic,
            2 => ErrorCode::BadVersion,
            3 => ErrorCode::BadChecksum,
            4 => ErrorCode::Oversized,
            5 => ErrorCode::Malformed,
            6 => ErrorCode::IdleTimeout,
            7 => ErrorCode::FrameTimeout,
            8 => ErrorCode::TooManyConnections,
            9 => ErrorCode::Internal,
            other => return perr(format!("unknown error code {other}")),
        })
    }
}

/// One inference request on the wire. `corr` is the client's own
/// correlation id, echoed verbatim on the matching [`WireResponse`] /
/// [`Frame::Reject`]; the server's internal request ids never leak.
#[derive(Clone, Debug, PartialEq)]
pub struct WireRequest {
    pub corr: u64,
    pub workload: String,
    /// Relative deadline in milliseconds from server-side admission
    /// (0 = none) — wall-clock instants do not cross machines.
    pub deadline_ms: u32,
    /// Named program inputs, in the order the client wrote them.
    pub inputs: Vec<(String, Mat)>,
}

/// One served response on the wire: the daemon's verdict plus outputs
/// and the request's own traffic counters (the serving layer's
/// sequential-parity contract crosses the socket intact).
#[derive(Clone, Debug, PartialEq)]
pub struct WireResponse {
    pub corr: u64,
    pub verdict: Verdict,
    pub batch_size: u32,
    pub coalesced: bool,
    pub queue_ns: u64,
    pub exec_ns: u64,
    pub mem: MemSim,
    pub outputs: Vec<(String, Mat)>,
}

/// The [`Frame::HealthReply`] payload: a cheap liveness probe.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WireHealth {
    /// Requests currently in flight through this ingress.
    pub inflight: u64,
    /// Requests admitted into the daemon since the server started.
    pub requests_in: u64,
    /// Responses delivered to clients since the server started.
    pub delivered: u64,
    /// Whether the server is draining (shutdown in progress).
    pub draining: bool,
}

/// Every frame the protocol can carry. See the module docs for the
/// direction and lifecycle of each kind.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    Request(WireRequest),
    Response(Box<WireResponse>),
    /// A network-edge rejection that never reached the daemon (e.g. the
    /// global in-flight cap): the request identified by `corr` was shed
    /// with this typed reason.
    Reject { corr: u64, reason: Rejected },
    Health,
    HealthReply(WireHealth),
    /// Fatal, connection-scoped: the peer violated the protocol (or
    /// timed out); the sender closes the connection after this frame.
    Error { code: ErrorCode, msg: String },
    /// Graceful drain: no further responses will be sent.
    Shutdown,
}

impl Frame {
    fn kind(&self) -> u8 {
        match self {
            Frame::Request(_) => 1,
            Frame::Response(_) => 2,
            Frame::Reject { .. } => 3,
            Frame::Health => 4,
            Frame::HealthReply(_) => 5,
            Frame::Error { .. } => 6,
            Frame::Shutdown => 7,
        }
    }
}

fn rejected_to_u8(r: Rejected) -> u8 {
    match r {
        Rejected::QueueFull => 1,
        Rejected::Shutdown => 2,
        Rejected::DeadlineExpired => 3,
    }
}

fn rejected_from_u8(b: u8) -> Result<Rejected, ProtoError> {
    Ok(match b {
        1 => Rejected::QueueFull,
        2 => Rejected::Shutdown,
        3 => Rejected::DeadlineExpired,
        other => return perr(format!("unknown rejection tag {other}")),
    })
}

// ---------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn new() -> Enc {
        Enc { buf: Vec::new() }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        let b = s.as_bytes();
        // Length-capped at u16: workload names and error messages are
        // short; anything longer is truncated rather than rejected.
        let n = b.len().min(u16::MAX as usize);
        self.u16(n as u16);
        self.buf.extend_from_slice(&b[..n]);
    }

    fn mat(&mut self, m: &Mat) {
        self.u32(m.rows as u32);
        self.u32(m.cols as u32);
        for v in &m.data {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    fn mem(&mut self, m: &MemSim) {
        self.u64(m.loaded_bytes);
        self.u64(m.stored_bytes);
        self.u64(m.n_loads);
        self.u64(m.n_stores);
        self.u64(m.peak_local_bytes);
        self.u64(m.kernel_launches);
        self.u64(m.flops);
        self.u64(m.padded_loaded_bytes);
        self.u64(m.padded_stored_bytes);
        self.u64(m.padded_flops);
        self.u64(m.state_appended_bytes);
        self.u64(m.state_appends);
    }
}

// ---------------------------------------------------------------------
// Decoding (strict: bounds-checked, and the frame decoder verifies the
// payload was consumed exactly)
// ---------------------------------------------------------------------

struct Dec<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Dec<'a> {
    fn new(b: &'a [u8]) -> Dec<'a> {
        Dec { b, i: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], ProtoError> {
        match self.b.get(self.i..self.i + n) {
            Some(s) => {
                self.i += n;
                Ok(s)
            }
            None => perr(format!(
                "truncated payload: wanted {n} bytes at offset {}, have {}",
                self.i,
                self.b.len()
            )),
        }
    }

    fn u8(&mut self) -> Result<u8, ProtoError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, ProtoError> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self) -> Result<u32, ProtoError> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64, ProtoError> {
        let s = self.take(8)?;
        let mut b = [0u8; 8];
        b.copy_from_slice(s);
        Ok(u64::from_le_bytes(b))
    }

    fn str(&mut self) -> Result<String, ProtoError> {
        let n = self.u16()? as usize;
        let s = self.take(n)?;
        match std::str::from_utf8(s) {
            Ok(s) => Ok(s.to_string()),
            Err(_) => perr("non-UTF8 string field"),
        }
    }

    fn mat(&mut self) -> Result<Mat, ProtoError> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        // The element count is validated against the *remaining* payload
        // before allocating, so a lying rows/cols pair cannot force a
        // huge allocation: the frame-size cap already bounded the bytes.
        let n = rows.checked_mul(cols).ok_or_else(|| ProtoError("matrix size overflow".into()))?;
        let need = n.checked_mul(4).ok_or_else(|| ProtoError("matrix size overflow".into()))?;
        if self.b.len() - self.i < need {
            return perr(format!(
                "matrix claims {rows}x{cols} ({need} bytes) but only {} remain",
                self.b.len() - self.i
            ));
        }
        let s = self.take(need)?;
        let mut data = Vec::with_capacity(n);
        for chunk in s.chunks_exact(4) {
            data.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }
        Ok(Mat { rows, cols, data })
    }

    fn mem(&mut self) -> Result<MemSim, ProtoError> {
        Ok(MemSim {
            loaded_bytes: self.u64()?,
            stored_bytes: self.u64()?,
            n_loads: self.u64()?,
            n_stores: self.u64()?,
            peak_local_bytes: self.u64()?,
            kernel_launches: self.u64()?,
            flops: self.u64()?,
            padded_loaded_bytes: self.u64()?,
            padded_stored_bytes: self.u64()?,
            padded_flops: self.u64()?,
            state_appended_bytes: self.u64()?,
            state_appends: self.u64()?,
        })
    }

    fn finish(self) -> Result<(), ProtoError> {
        if self.i != self.b.len() {
            return perr(format!(
                "trailing payload bytes: consumed {}, frame carried {}",
                self.i,
                self.b.len()
            ));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Preamble
// ---------------------------------------------------------------------

/// The 8-byte connection preamble both sides exchange.
pub fn encode_preamble() -> [u8; PREAMBLE_LEN] {
    let mut b = [0u8; PREAMBLE_LEN];
    b[0..4].copy_from_slice(&MAGIC.to_le_bytes());
    b[4..6].copy_from_slice(&VERSION.to_le_bytes());
    b
}

/// Validate a received preamble. Distinguishes bad magic (not our
/// protocol at all) from a version mismatch (our protocol, wrong
/// generation) so the error frame can say which.
pub fn check_preamble(b: &[u8; PREAMBLE_LEN]) -> Result<(), (ErrorCode, String)> {
    let magic = u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
    if magic != MAGIC {
        return Err((ErrorCode::BadMagic, format!("bad magic {magic:#010x}")));
    }
    let version = u16::from_le_bytes([b[4], b[5]]);
    if version != VERSION {
        return Err((
            ErrorCode::BadVersion,
            format!("unsupported protocol version {version} (want {VERSION})"),
        ));
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Frame encode/decode
// ---------------------------------------------------------------------

/// Encode one frame (header + checksummed payload) into a byte vector.
pub fn encode_frame(f: &Frame) -> Vec<u8> {
    let mut e = Enc::new();
    match f {
        Frame::Request(r) => {
            e.u64(r.corr);
            e.u32(r.deadline_ms);
            e.str(&r.workload);
            e.u16(r.inputs.len().min(u16::MAX as usize) as u16);
            for (name, m) in &r.inputs {
                e.str(name);
                e.mat(m);
            }
        }
        Frame::Response(r) => {
            e.u64(r.corr);
            match &r.verdict {
                Verdict::Ok => e.u8(0),
                Verdict::Rejected(rej) => e.u8(rejected_to_u8(*rej)),
                Verdict::Failed(msg) => {
                    e.u8(4);
                    e.str(msg);
                }
            }
            e.u32(r.batch_size);
            e.u8(r.coalesced as u8);
            e.u64(r.queue_ns);
            e.u64(r.exec_ns);
            e.mem(&r.mem);
            e.u16(r.outputs.len().min(u16::MAX as usize) as u16);
            for (name, m) in &r.outputs {
                e.str(name);
                e.mat(m);
            }
        }
        Frame::Reject { corr, reason } => {
            e.u64(*corr);
            e.u8(rejected_to_u8(*reason));
        }
        Frame::Health => {}
        Frame::HealthReply(h) => {
            e.u64(h.inflight);
            e.u64(h.requests_in);
            e.u64(h.delivered);
            e.u8(h.draining as u8);
        }
        Frame::Error { code, msg } => {
            e.u8(code.to_u8());
            e.str(msg);
        }
        Frame::Shutdown => {}
    }
    let payload = e.buf;
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.push(f.kind());
    out.push(0); // reserved
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&checksum(&payload).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// A parsed frame header: the payload length/checksum still pending.
#[derive(Clone, Copy, Debug)]
pub struct Header {
    pub kind: u8,
    pub payload_len: u32,
    pub checksum: u32,
}

/// Parse and validate a frame header against the frame-size cap. The
/// payload has not been read yet — an oversized frame is rejected here,
/// before any allocation.
pub fn decode_header(b: &[u8; HEADER_LEN], max_frame: u32) -> Result<Header, ProtoError> {
    let kind = b[0];
    if !(1..=7).contains(&kind) {
        return perr(format!("unknown frame kind {kind}"));
    }
    let payload_len = u32::from_le_bytes([b[2], b[3], b[4], b[5]]);
    if payload_len > max_frame {
        return perr(format!("frame payload {payload_len} exceeds cap {max_frame}"));
    }
    let cks = u32::from_le_bytes([b[6], b[7], b[8], b[9]]);
    Ok(Header { kind, payload_len, checksum: cks })
}

/// Decode one frame body. The payload must checksum-match the header
/// and every decoder must consume it exactly.
pub fn decode_frame(h: &Header, payload: &[u8]) -> Result<Frame, ProtoError> {
    if payload.len() != h.payload_len as usize {
        return perr("payload length mismatch");
    }
    if checksum(payload) != h.checksum {
        return perr("payload checksum mismatch (torn or corrupted frame)");
    }
    let mut d = Dec::new(payload);
    let f = match h.kind {
        1 => {
            let corr = d.u64()?;
            let deadline_ms = d.u32()?;
            let workload = d.str()?;
            let n = d.u16()? as usize;
            let mut inputs = Vec::with_capacity(n.min(1024));
            for _ in 0..n {
                let name = d.str()?;
                let m = d.mat()?;
                inputs.push((name, m));
            }
            Frame::Request(WireRequest { corr, workload, deadline_ms, inputs })
        }
        2 => {
            let corr = d.u64()?;
            let verdict = match d.u8()? {
                0 => Verdict::Ok,
                4 => Verdict::Failed(d.str()?),
                tag => Verdict::Rejected(rejected_from_u8(tag)?),
            };
            let batch_size = d.u32()?;
            let coalesced = d.u8()? != 0;
            let queue_ns = d.u64()?;
            let exec_ns = d.u64()?;
            let mem = d.mem()?;
            let n = d.u16()? as usize;
            let mut outputs = Vec::with_capacity(n.min(1024));
            for _ in 0..n {
                let name = d.str()?;
                let m = d.mat()?;
                outputs.push((name, m));
            }
            Frame::Response(Box::new(WireResponse {
                corr,
                verdict,
                batch_size,
                coalesced,
                queue_ns,
                exec_ns,
                mem,
                outputs,
            }))
        }
        3 => {
            let corr = d.u64()?;
            let reason = rejected_from_u8(d.u8()?)?;
            Frame::Reject { corr, reason }
        }
        4 => Frame::Health,
        5 => {
            let inflight = d.u64()?;
            let requests_in = d.u64()?;
            let delivered = d.u64()?;
            let draining = d.u8()? != 0;
            Frame::HealthReply(WireHealth { inflight, requests_in, delivered, draining })
        }
        6 => {
            let code = ErrorCode::from_u8(d.u8()?)?;
            let msg = d.str()?;
            Frame::Error { code, msg }
        }
        7 => Frame::Shutdown,
        other => return perr(format!("unknown frame kind {other}")),
    };
    d.finish()?;
    Ok(f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn roundtrip(f: Frame) {
        let bytes = encode_frame(&f);
        let mut hdr = [0u8; HEADER_LEN];
        hdr.copy_from_slice(&bytes[..HEADER_LEN]);
        let h = decode_header(&hdr, DEFAULT_MAX_FRAME).unwrap();
        let got = decode_frame(&h, &bytes[HEADER_LEN..]).unwrap();
        assert_eq!(got, f);
    }

    #[test]
    fn frames_roundtrip_bit_exact() {
        let mut rng = Rng::new(7);
        let m = rng.mat(3, 5);
        roundtrip(Frame::Request(WireRequest {
            corr: 42,
            workload: "quickstart".into(),
            deadline_ms: 250,
            inputs: vec![("A".into(), m.clone()), ("B".into(), rng.mat(2, 2))],
        }));
        roundtrip(Frame::Response(Box::new(WireResponse {
            corr: 42,
            verdict: Verdict::Ok,
            batch_size: 4,
            coalesced: true,
            queue_ns: 123,
            exec_ns: 456,
            mem: MemSim {
                loaded_bytes: 1,
                stored_bytes: 2,
                n_loads: 3,
                n_stores: 4,
                peak_local_bytes: 5,
                kernel_launches: 6,
                flops: 7,
                padded_loaded_bytes: 8,
                padded_stored_bytes: 9,
                padded_flops: 10,
                state_appended_bytes: 11,
                state_appends: 12,
            },
            outputs: vec![("Y".into(), m)],
        })));
        roundtrip(Frame::Response(Box::new(WireResponse {
            corr: 1,
            verdict: Verdict::Failed("injected compute fault".into()),
            batch_size: 0,
            coalesced: false,
            queue_ns: 0,
            exec_ns: 0,
            mem: MemSim::default(),
            outputs: vec![],
        })));
        roundtrip(Frame::Reject { corr: 9, reason: Rejected::QueueFull });
        roundtrip(Frame::Health);
        roundtrip(Frame::HealthReply(WireHealth {
            inflight: 3,
            requests_in: 10,
            delivered: 7,
            draining: false,
        }));
        roundtrip(Frame::Error { code: ErrorCode::BadChecksum, msg: "torn".into() });
        roundtrip(Frame::Shutdown);
    }

    #[test]
    fn nan_and_inf_survive_the_wire_bit_exact() {
        let m = Mat { rows: 1, cols: 4, data: vec![f32::NAN, f32::INFINITY, -0.0, 1.5e-42] };
        let f = Frame::Request(WireRequest {
            corr: 0,
            workload: "w".into(),
            deadline_ms: 0,
            inputs: vec![("X".into(), m.clone())],
        });
        let bytes = encode_frame(&f);
        let mut hdr = [0u8; HEADER_LEN];
        hdr.copy_from_slice(&bytes[..HEADER_LEN]);
        let h = decode_header(&hdr, DEFAULT_MAX_FRAME).unwrap();
        let Frame::Request(r) = decode_frame(&h, &bytes[HEADER_LEN..]).unwrap() else {
            panic!("wrong frame kind");
        };
        let got = &r.inputs[0].1;
        assert_eq!(got.rows, 1);
        for (a, b) in got.data.iter().zip(&m.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "wire transport must be bit-exact");
        }
    }

    #[test]
    fn preamble_checks_magic_and_version() {
        let good = encode_preamble();
        assert!(check_preamble(&good).is_ok());
        let mut bad = good;
        bad[0] ^= 0xff;
        assert_eq!(check_preamble(&bad).unwrap_err().0, ErrorCode::BadMagic);
        let mut wrong_ver = good;
        wrong_ver[4] = 99;
        assert_eq!(check_preamble(&wrong_ver).unwrap_err().0, ErrorCode::BadVersion);
    }

    #[test]
    fn oversized_frames_rejected_from_the_header_alone() {
        let mut hdr = [0u8; HEADER_LEN];
        hdr[0] = 1; // Request
        hdr[2..6].copy_from_slice(&(1_000_000u32).to_le_bytes());
        assert!(decode_header(&hdr, 1_000_000).is_ok());
        let err = decode_header(&hdr, 999_999).unwrap_err();
        assert!(err.0.contains("exceeds cap"), "got: {}", err.0);
    }

    #[test]
    fn corrupted_and_truncated_frames_are_typed_errors() {
        let f = Frame::Reject { corr: 5, reason: Rejected::QueueFull };
        let bytes = encode_frame(&f);
        let mut hdr = [0u8; HEADER_LEN];
        hdr.copy_from_slice(&bytes[..HEADER_LEN]);
        let h = decode_header(&hdr, DEFAULT_MAX_FRAME).unwrap();

        // checksum mismatch (one flipped payload bit)
        let mut torn = bytes[HEADER_LEN..].to_vec();
        torn[0] ^= 1;
        let err = decode_frame(&h, &torn).unwrap_err();
        assert!(err.0.contains("checksum"), "got: {}", err.0);

        // truncated payload
        let err = decode_frame(&h, &bytes[HEADER_LEN..bytes.len() - 1]).unwrap_err();
        assert!(err.0.contains("length mismatch"), "got: {}", err.0);

        // unknown kind
        let mut bad_kind = hdr;
        bad_kind[0] = 200;
        assert!(decode_header(&bad_kind, DEFAULT_MAX_FRAME).is_err());
    }

    #[test]
    fn trailing_payload_bytes_are_rejected() {
        // A Shutdown frame carries no payload; hand-build one that does.
        let payload = vec![0u8; 3];
        let h = Header { kind: 7, payload_len: 3, checksum: checksum(&payload) };
        let err = decode_frame(&h, &payload).unwrap_err();
        assert!(err.0.contains("trailing"), "got: {}", err.0);
    }

    #[test]
    fn lying_matrix_dims_cannot_force_allocation() {
        // A request whose matrix header claims 1e9 elements but whose
        // payload holds none: rejected by the remaining-bytes check.
        let mut e = Vec::new();
        e.extend_from_slice(&0u64.to_le_bytes()); // corr
        e.extend_from_slice(&0u32.to_le_bytes()); // deadline
        e.extend_from_slice(&1u16.to_le_bytes()); // workload len
        e.push(b'w');
        e.extend_from_slice(&1u16.to_le_bytes()); // one input
        e.extend_from_slice(&1u16.to_le_bytes()); // name len
        e.push(b'X');
        e.extend_from_slice(&1_000_000_000u32.to_le_bytes()); // rows
        e.extend_from_slice(&1_000_000_000u32.to_le_bytes()); // cols
        let h = Header { kind: 1, payload_len: e.len() as u32, checksum: checksum(&e) };
        let err = decode_frame(&h, &e).unwrap_err();
        assert!(
            err.0.contains("overflow") || err.0.contains("remain"),
            "got: {}",
            err.0
        );
    }

    #[test]
    fn decoder_survives_seeded_random_bytes() {
        // The decoder must return typed errors on arbitrary input, never
        // panic: fully random headers+payloads, and single-bit mutations
        // of a valid frame (which exercise the deep payload parsers past
        // the checksum only when the flip lands in the header).
        let mut rng = Rng::new(0xf4a3);
        let iters = std::env::var("BB_FUZZ_ITERS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(500);
        let valid = encode_frame(&Frame::Reject { corr: 1, reason: Rejected::QueueFull });
        for _ in 0..iters {
            let mut hdr = [0u8; HEADER_LEN];
            for b in hdr.iter_mut() {
                *b = rng.next_u64() as u8;
            }
            if let Ok(h) = decode_header(&hdr, 4096) {
                let payload: Vec<u8> =
                    (0..h.payload_len as usize).map(|_| rng.next_u64() as u8).collect();
                let _ = decode_frame(&h, &payload);
            }

            let mut mutated = valid.clone();
            let i = rng.below(mutated.len());
            mutated[i] ^= 1 << rng.below(8);
            let mut hdr = [0u8; HEADER_LEN];
            hdr.copy_from_slice(&mutated[..HEADER_LEN]);
            if let Ok(h) = decode_header(&hdr, DEFAULT_MAX_FRAME) {
                let _ = decode_frame(&h, &mutated[HEADER_LEN..]);
            }
        }
    }
}
