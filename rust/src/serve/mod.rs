//! Compile-once / execute-many serving layer with dynamic batching.
//!
//! Every `blockbuster run` invocation recompiles its plan and executes
//! exactly one request. This module is the inference-server shape the
//! paper positions Blockbuster for: a [`ModelServer`] that compiles each
//! registered workload **once** through [`crate::coordinator::compile`],
//! holds its [`PreparedPlan`] (segments lowered once, tape skeletons
//! pulled from a shared [`TapeCache`] and bound once per `DimSizes`),
//! and then drains a submission queue of [`Request`]s with zero
//! per-request compilation.
//!
//! **Dynamic batching.** Requests are queued per workload; a workload's
//! queue flushes when it reaches [`ServerConfig::max_batch`] requests or
//! its oldest entry has waited [`ServerConfig::max_wait`] (the classic
//! throughput/latency trade-off knobs). A flushed batch becomes **one**
//! submission to the persistent worker pool
//! ([`crate::exec::pool::WorkerPool::run_tasks`]): each pool task
//! executes one request's full multi-segment plan against the shared
//! `PreparedPlan`, so the batch pays one job handoff instead of one
//! spawn/join per request, and mixed-program traffic is scheduled
//! round-robin across workloads so no queue starves.
//!
//! **Determinism.** Batching changes *where* a request executes (a pool
//! worker instead of the caller) and *when* (coalesced with its batch),
//! never *what*: outputs and [`MemSim`] traffic counters are
//! bit-identical to a sequential
//! [`crate::coordinator::execute_plan_opts`] run on the same inputs
//! (all but the `peak_local_bytes` estimate, which no execution path
//! pins across worker fan-outs) — pinned by `tests/serve_parity.rs`
//! across thread counts and SIMD modes.
//!
//! ```
//! use blockbuster::serve::{ModelServer, ServerConfig};
//!
//! let mut server = ModelServer::new(ServerConfig::default());
//! server.register("quickstart").unwrap();
//! let id = server.submit_synthetic("quickstart", 7).unwrap();
//! let responses = server.drain();
//! assert_eq!(responses.len(), 1);
//! assert_eq!(responses[0].id, id);
//! assert_eq!(server.stats().per_program["quickstart"].compiles, 1);
//! ```

use crate::array::ArrayProgram;
use crate::autotune::{autotune_measured_cached, MeasuredPoint};
use crate::coordinator::{
    compile, execute_prepared, prepare_plan, workloads, CompileConfig, PlanRun, PreparedPlan,
};
use crate::cost::CostModel;
use crate::exec::{pool, ExecBackend, TapeCache};
use crate::fusion::fuse;
use crate::ir::graph::Graph;
use crate::loopir::interp::MemSim;
use crate::tensor::{Mat, Rng};
use anyhow::{anyhow, bail};
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Serving configuration: executor backend, worker cap, and the dynamic
/// batching knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Backend every registered plan is prepared for.
    pub backend: ExecBackend,
    /// Worker cap shared by batch fan-out and the engine's parallel grid
    /// loops (`None` = one per available core; `Some(1)` never touches
    /// the pool).
    pub threads: Option<usize>,
    /// Flush a workload's queue as soon as it holds this many requests.
    pub max_batch: usize,
    /// Flush a workload's queue (on [`ModelServer::poll`]) once its
    /// oldest request has waited this long, even if the batch is not
    /// full — the latency bound.
    pub max_wait: Duration,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            backend: ExecBackend::Compiled,
            threads: None,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// One inference request: a registered workload name plus a full matrix
/// per program input (shapes must match the registered `full_shapes`).
#[derive(Clone, Debug)]
pub struct Request {
    pub workload: String,
    pub inputs: HashMap<String, Mat>,
}

/// One served request: the plan outputs, the request's own (simulated)
/// memory-traffic counters, and latency telemetry.
pub struct Response {
    /// The id [`ModelServer::submit`] returned for this request.
    pub id: u64,
    pub workload: String,
    pub outputs: HashMap<String, Mat>,
    /// This request's traffic counters — loads/stores, launches, and
    /// flops bit-identical to a sequential
    /// [`crate::coordinator::execute_plan_opts`] run on the same inputs.
    /// (`peak_local_bytes` is the one exception: a peak *estimate* the
    /// engine does not pin across worker fan-outs.)
    pub mem: MemSim,
    /// How many requests shared this request's batched launch.
    pub batch_size: usize,
    /// Time spent queued before the batch launched.
    pub queue_ns: u128,
    /// Wall-clock of the whole batched launch this request rode in
    /// (shared across the batch, not divided by it).
    pub exec_ns: u128,
}

/// Latency samples retained per workload: the summaries window over the
/// most recent this-many requests, so a long-lived server's telemetry
/// stays bounded no matter how much traffic flows.
pub const LATENCY_SAMPLE_CAP: usize = 4096;

/// Per-workload serving counters.
#[derive(Clone, Debug, Default)]
pub struct ProgramStats {
    /// [`crate::coordinator::compile`] invocations — compile-once means
    /// this stays at 1 no matter how many requests are served.
    pub compiles: u64,
    /// Tape-skeleton binds performed at registration (== plan segments
    /// on the compiled backend); serving performs none.
    pub binds: u64,
    /// Requests served.
    pub served: u64,
    /// Batched launches performed.
    pub batches: u64,
    /// Largest batch coalesced so far.
    pub peak_batch: usize,
    /// Per-request end-to-end latency (queue + batched launch) of the
    /// most recent [`LATENCY_SAMPLE_CAP`] requests (a ring buffer — the
    /// latency summaries describe that window).
    pub latency_ns: Vec<u128>,
    /// Ring cursor into `latency_ns` once the cap is reached.
    latency_next: usize,
}

impl ProgramStats {
    /// Record one request's end-to-end latency, overwriting the oldest
    /// sample once [`LATENCY_SAMPLE_CAP`] are held.
    fn record_latency(&mut self, ns: u128) {
        if self.latency_ns.len() < LATENCY_SAMPLE_CAP {
            self.latency_ns.push(ns);
        } else {
            self.latency_ns[self.latency_next] = ns;
        }
        self.latency_next = (self.latency_next + 1) % LATENCY_SAMPLE_CAP;
    }
    /// Mean occupancy of this workload's batched launches.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.served as f64 / self.batches as f64
        }
    }

    pub fn mean_latency_ns(&self) -> f64 {
        if self.latency_ns.is_empty() {
            0.0
        } else {
            self.latency_ns.iter().sum::<u128>() as f64 / self.latency_ns.len() as f64
        }
    }

    /// Nearest-rank p-th percentile of the end-to-end latencies.
    pub fn percentile_latency_ns(&self, p: f64) -> u128 {
        crate::util::bench::percentile(&self.latency_ns, p)
    }
}

/// Aggregate serving telemetry. Throughput is deliberately *not* a
/// method here: a meaningful req/s figure needs a serving window chosen
/// by the caller (the CLI times its submit→drain span; dividing by
/// server uptime would dilute the number with registration/compile and
/// idle time).
#[derive(Debug)]
pub struct ServerStats {
    pub per_program: BTreeMap<String, ProgramStats>,
    /// When the server was created (uptime reference).
    pub started: Instant,
}

impl ServerStats {
    pub fn total_served(&self) -> u64 {
        self.per_program.values().map(|s| s.served).sum()
    }
}

/// A registered workload: its prepared plan plus everything needed to
/// validate and synthesize requests (and to re-tune block shapes).
struct Served {
    prepared: PreparedPlan,
    /// The initial (unfused) block program, kept for [`ModelServer::tune`].
    block: Graph,
    full_shapes: HashMap<String, (usize, usize)>,
    model: CostModel,
    queue: VecDeque<Pending>,
}

struct Pending {
    id: u64,
    inputs: HashMap<String, Mat>,
    enqueued: Instant,
}

/// The compile-once model server (see module docs).
pub struct ModelServer {
    cfg: ServerConfig,
    programs: BTreeMap<String, Served>,
    /// Registration order — the round-robin schedule for mixed traffic.
    order: Vec<String>,
    /// Next round-robin offset into `order`.
    rr: usize,
    /// Skeleton cache shared across all registered workloads (and with
    /// [`ModelServer::tune`]'s measured trials).
    cache: TapeCache,
    next_id: u64,
    stats: ServerStats,
}

impl ModelServer {
    pub fn new(cfg: ServerConfig) -> ModelServer {
        ModelServer {
            cfg,
            programs: BTreeMap::new(),
            order: Vec::new(),
            rr: 0,
            cache: TapeCache::new(),
            next_id: 0,
            stats: ServerStats {
                per_program: BTreeMap::new(),
                started: Instant::now(),
            },
        }
    }

    /// Register one of the canonical demo workloads
    /// ([`crate::coordinator::workloads`]) by CLI name — compiling and
    /// preparing its plan exactly once.
    pub fn register(&mut self, name: &str) -> anyhow::Result<()> {
        let (program, cfg, params, _inputs) = workloads::by_name(name, 0).ok_or_else(|| {
            anyhow!(
                "unknown workload {name}; have {}",
                workloads::NAMES.join(", ")
            )
        })?;
        self.register_program(name, &program, cfg, params)
    }

    /// Register an arbitrary array program under `name`: runs the full
    /// compilation pipeline once, then lowers and binds every plan
    /// segment once. All subsequent requests reuse that work.
    pub fn register_program(
        &mut self,
        name: &str,
        program: &ArrayProgram,
        cfg: CompileConfig,
        params: BTreeMap<String, f32>,
    ) -> anyhow::Result<()> {
        if self.programs.contains_key(name) {
            bail!("workload {name} already registered");
        }
        let full_shapes = cfg.full_shapes.clone();
        let model = cfg.model;
        let sizes = cfg.sizes.clone();
        let compiled = compile(program, cfg);
        let prepared = prepare_plan(
            &compiled.plan,
            &sizes,
            &params,
            self.cfg.backend,
            &mut self.cache,
        );
        let st = self.stats.per_program.entry(name.to_string()).or_default();
        st.compiles += 1;
        st.binds += prepared.binds;
        self.programs.insert(
            name.to_string(),
            Served {
                prepared,
                block: compiled.block,
                full_shapes,
                model,
                queue: VecDeque::new(),
            },
        );
        self.order.push(name.to_string());
        Ok(())
    }

    /// Enqueue a request; returns its id. The request is validated (the
    /// workload must be registered, every program input present at its
    /// registered full shape) but not executed until a batch flushes.
    pub fn submit(&mut self, req: Request) -> anyhow::Result<u64> {
        let served = self
            .programs
            .get_mut(&req.workload)
            .ok_or_else(|| anyhow!("unknown workload {}", req.workload))?;
        for (input, &(r, c)) in &served.full_shapes {
            let m = req
                .inputs
                .get(input)
                .ok_or_else(|| anyhow!("request for {} missing input {input}", req.workload))?;
            if (m.rows, m.cols) != (r, c) {
                bail!(
                    "request for {}: input {input} is {}x{}, registered shape is {r}x{c}",
                    req.workload,
                    m.rows,
                    m.cols
                );
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        served.queue.push_back(Pending {
            id,
            inputs: req.inputs,
            enqueued: Instant::now(),
        });
        Ok(id)
    }

    /// The synthetic inputs [`Self::submit_synthetic`] generates for
    /// `(workload, seed)` — exposed so callers can reproduce a request
    /// for verification (input names are generated in sorted order, so
    /// the mapping is deterministic).
    pub fn synthetic_inputs(
        &self,
        workload: &str,
        seed: u64,
    ) -> anyhow::Result<HashMap<String, Mat>> {
        let served = self
            .programs
            .get(workload)
            .ok_or_else(|| anyhow!("unknown workload {workload}"))?;
        let mut names: Vec<&String> = served.full_shapes.keys().collect();
        names.sort();
        let mut rng = Rng::new(seed);
        Ok(names
            .into_iter()
            .map(|n| {
                let (r, c) = served.full_shapes[n];
                (n.clone(), rng.mat(r, c))
            })
            .collect())
    }

    /// Enqueue a request with deterministic random inputs derived from
    /// `seed` at the workload's registered shapes.
    pub fn submit_synthetic(&mut self, workload: &str, seed: u64) -> anyhow::Result<u64> {
        let inputs = self.synthetic_inputs(workload, seed)?;
        self.submit(Request {
            workload: workload.to_string(),
            inputs,
        })
    }

    /// Requests currently queued across all workloads.
    pub fn pending(&self) -> usize {
        self.programs.values().map(|s| s.queue.len()).sum()
    }

    /// Flush every workload whose queue is due — full
    /// ([`ServerConfig::max_batch`]) or latency-bound (oldest entry
    /// older than [`ServerConfig::max_wait`]) — visiting workloads
    /// round-robin.
    /// Returns the responses of every batch launched; an empty vec means
    /// nothing was due.
    pub fn poll(&mut self) -> Vec<Response> {
        let now = Instant::now();
        let mut out = Vec::new();
        let n = self.order.len();
        for k in 0..n {
            let name = self.order[(self.rr + k) % n].clone();
            let due = {
                let s = &self.programs[&name];
                s.queue.len() >= self.cfg.max_batch.max(1)
                    || s.queue
                        .front()
                        .is_some_and(|p| now.duration_since(p.enqueued) >= self.cfg.max_wait)
            };
            if due {
                out.extend(self.flush_one(&name));
            }
        }
        if n > 0 {
            self.rr = (self.rr + 1) % n;
        }
        out
    }

    /// Flush until every queue is empty, taking at most `max_batch`
    /// requests per workload per round-robin turn (so mixed traffic
    /// interleaves instead of one workload draining first).
    pub fn drain(&mut self) -> Vec<Response> {
        let mut out = Vec::new();
        let n = self.order.len();
        if n == 0 {
            return out;
        }
        loop {
            let mut any = false;
            for k in 0..n {
                let name = self.order[(self.rr + k) % n].clone();
                if !self.programs[&name].queue.is_empty() {
                    out.extend(self.flush_one(&name));
                    any = true;
                }
            }
            self.rr = (self.rr + 1) % n;
            if !any {
                return out;
            }
        }
    }

    /// Take up to `max_batch` queued requests of `name` and launch them
    /// as one batch.
    fn flush_one(&mut self, name: &str) -> Vec<Response> {
        let take = {
            let q = &self.programs[name].queue;
            q.len().min(self.cfg.max_batch.max(1))
        };
        if take == 0 {
            return Vec::new();
        }
        let batch: Vec<Pending> = self
            .programs
            .get_mut(name)
            .expect("flush_one: registered workload")
            .queue
            .drain(..take)
            .collect();
        self.run_batch(name, batch)
    }

    /// Execute one coalesced batch: a single pool submission whose tasks
    /// each run one request's full plan against the shared
    /// [`PreparedPlan`]. With one request (or a worker cap of 1) the
    /// batch runs inline on the caller — the exact serial path.
    fn run_batch(&mut self, name: &str, batch: Vec<Pending>) -> Vec<Response> {
        let bs = batch.len();
        let workers = effective_workers(self.cfg.threads, bs);
        let threads = self.cfg.threads;
        let (runs, launched, finished) = {
            let prepared = &self.programs[name].prepared;
            let t0 = Instant::now();
            let runs: Vec<PlanRun> = if workers <= 1 || bs == 1 {
                // Serial path: intra-request grid parallelism still
                // applies under the caller's thread budget.
                batch
                    .iter()
                    .map(|p| execute_prepared(prepared, &p.inputs, threads))
                    .collect()
            } else {
                // One heterogeneous pool job for the whole batch. Each
                // task runs its request serially (threads=1): the batch
                // itself is the parallelism, and nested fan-out from
                // inside a pool worker would run inline anyway.
                let slots: Vec<Mutex<Option<PlanRun>>> =
                    (0..bs).map(|_| Mutex::new(None)).collect();
                pool::global().run_tasks(workers, bs, &|t| {
                    let run = execute_prepared(prepared, &batch[t].inputs, Some(1));
                    *slots[t].lock().unwrap() = Some(run);
                });
                slots
                    .into_iter()
                    .map(|s| {
                        s.into_inner()
                            .expect("batch slot lock")
                            .expect("batch task completed")
                    })
                    .collect()
            };
            (runs, t0, Instant::now())
        };
        let exec_ns = finished.duration_since(launched).as_nanos();

        let st = self.stats.per_program.entry(name.to_string()).or_default();
        st.served += bs as u64;
        st.batches += 1;
        st.peak_batch = st.peak_batch.max(bs);
        let mut out = Vec::with_capacity(bs);
        for (p, run) in batch.into_iter().zip(runs) {
            st.record_latency(finished.duration_since(p.enqueued).as_nanos());
            out.push(Response {
                id: p.id,
                workload: name.to_string(),
                outputs: run.outputs,
                mem: run.mem,
                batch_size: bs,
                queue_ns: launched.duration_since(p.enqueued).as_nanos(),
                exec_ns,
            });
        }
        out
    }

    /// Measured block-shape autotuning for a registered workload,
    /// sharing the server's skeleton cache (so trials re-bind the same
    /// skeletons serving uses instead of recompiling). Returns the
    /// candidates best-first by measured wall-clock; the server keeps
    /// serving at its registered sizes — re-register to adopt a winner.
    pub fn tune(
        &mut self,
        name: &str,
        local_capacity: u64,
        trials: usize,
        seed: u64,
    ) -> anyhow::Result<Vec<MeasuredPoint>> {
        let inputs = self.synthetic_inputs(name, seed)?;
        let served = self
            .programs
            .get(name)
            .ok_or_else(|| anyhow!("unknown workload {name}"))?;
        let fused = fuse(served.block.clone())
            .snapshots
            .pop()
            .expect("fusion produces at least the initial snapshot");
        Ok(autotune_measured_cached(
            &fused,
            &served.full_shapes,
            local_capacity,
            &served.model,
            &served.prepared.params,
            &inputs,
            self.cfg.backend,
            trials,
            self.cfg.threads,
            &mut self.cache,
        ))
    }

    pub fn stats(&self) -> &ServerStats {
        &self.stats
    }

    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    /// Registered workload names, in registration (round-robin) order.
    pub fn workloads(&self) -> &[String] {
        &self.order
    }

    /// Skeleton-cache misses so far. Stable across any amount of serving
    /// traffic — recompiles would show up here (see `tests/serve_parity.rs`).
    pub fn cache_misses(&self) -> u64 {
        self.cache.misses
    }

    /// Skeleton-cache hits so far (structure sharing across workloads
    /// and [`Self::tune`] trials).
    pub fn cache_hits(&self) -> u64 {
        self.cache.hits
    }
}

/// Worker budget for a batch of `tasks` requests: the engine's own
/// budget resolution ([`crate::exec::engine::worker_budget`]), further
/// capped by the batch size.
fn effective_workers(threads: Option<usize>, tasks: usize) -> usize {
    crate::exec::engine::worker_budget(threads).min(tasks)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_rejects_unknown_and_duplicate() {
        let mut s = ModelServer::new(ServerConfig::default());
        assert!(s.register("no_such_program").is_err());
        s.register("quickstart").unwrap();
        let err = s.register("quickstart").unwrap_err().to_string();
        assert!(err.contains("already registered"), "got: {err}");
    }

    #[test]
    fn submit_validates_workload_and_shapes() {
        let mut s = ModelServer::new(ServerConfig::default());
        s.register("quickstart").unwrap();
        assert!(s.submit_synthetic("attention", 0).is_err());
        // wrong shape for a known input
        let mut inputs = s.synthetic_inputs("quickstart", 0).unwrap();
        let a = inputs.get_mut("A").unwrap();
        *a = Mat::zeros(a.rows + 1, a.cols);
        let err = s
            .submit(Request {
                workload: "quickstart".into(),
                inputs,
            })
            .unwrap_err()
            .to_string();
        assert!(err.contains("registered shape"), "got: {err}");
        // missing input
        let err = s
            .submit(Request {
                workload: "quickstart".into(),
                inputs: HashMap::new(),
            })
            .unwrap_err()
            .to_string();
        assert!(err.contains("missing input"), "got: {err}");
    }

    #[test]
    fn size_and_latency_bound_flushes() {
        // size-triggered: nothing flushes until max_batch requests queue
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 3,
            max_wait: Duration::from_secs(3600),
            threads: Some(1),
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        s.submit_synthetic("quickstart", 0).unwrap();
        s.submit_synthetic("quickstart", 1).unwrap();
        assert!(s.poll().is_empty(), "batch not full, wait not exceeded");
        assert_eq!(s.pending(), 2);
        s.submit_synthetic("quickstart", 2).unwrap();
        let r = s.poll();
        assert_eq!(r.len(), 3);
        assert!(r.iter().all(|r| r.batch_size == 3));
        assert_eq!(s.pending(), 0);

        // latency-triggered: max_wait zero flushes a lone request at once
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 100,
            max_wait: Duration::ZERO,
            threads: Some(1),
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        s.submit_synthetic("quickstart", 0).unwrap();
        let r = s.poll();
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].batch_size, 1);
        assert_eq!(s.stats().per_program["quickstart"].peak_batch, 1);
    }

    #[test]
    fn latency_samples_stay_bounded() {
        let mut st = ProgramStats::default();
        for i in 0..(LATENCY_SAMPLE_CAP as u128 + 10) {
            st.record_latency(i);
        }
        assert_eq!(st.latency_ns.len(), LATENCY_SAMPLE_CAP);
        // the ring overwrote the oldest slots with the newest samples
        assert_eq!(st.latency_ns[0], LATENCY_SAMPLE_CAP as u128);
        assert_eq!(st.latency_ns[9], LATENCY_SAMPLE_CAP as u128 + 9);
        assert_eq!(st.latency_ns[10], 10);
    }

    #[test]
    fn tune_shares_the_server_cache() {
        let mut s = ModelServer::new(ServerConfig {
            threads: Some(1),
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        let pts = s.tune("quickstart", 1 << 20, 3, 9).unwrap();
        assert!(!pts.is_empty() && pts.len() <= 3);
        let misses = s.cache_misses();
        // a second tune re-binds cached skeletons, compiling nothing new
        s.tune("quickstart", 1 << 20, 3, 10).unwrap();
        assert_eq!(s.cache_misses(), misses);
    }
}
