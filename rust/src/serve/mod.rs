//! Compile-once / execute-many serving layer with dynamic batching.
//!
//! Every `blockbuster run` invocation recompiles its plan and executes
//! exactly one request. This module is the inference-server shape the
//! paper positions Blockbuster for: a [`ModelServer`] that compiles each
//! registered workload **once** through [`crate::coordinator::compile`],
//! holds its [`PreparedPlan`] (segments lowered once, tape skeletons
//! pulled from a shared [`TapeCache`] and bound once per `DimSizes`),
//! and then drains a submission queue of [`Request`]s with zero
//! per-request compilation.
//!
//! **Dynamic batching.** Requests are queued per workload; a workload's
//! queue flushes when it reaches [`ServerConfig::max_batch`] requests or
//! its oldest entry has waited [`ServerConfig::max_wait`] (the classic
//! throughput/latency trade-off knobs), and an over-full queue keeps
//! flushing while it still holds a full batch (bursts drain in one
//! poll). A flushed batch becomes **one** submission to the persistent
//! worker pool
//! ([`crate::exec::pool::WorkerPool::run_tasks`]): each pool task
//! executes one request's full multi-segment plan against the shared
//! `PreparedPlan`, so the batch pays one job handoff instead of one
//! spawn/join per request, and mixed-program traffic is scheduled
//! round-robin across workloads so no queue starves.
//!
//! **Cross-request kernel coalescing** ([`ServerConfig::coalesce`]).
//! Fanning a batch across the pool still launches every plan segment
//! once *per request*. When the plan's segments all grid over one
//! stackable dimension (the row-block dim `M` on every canonical
//! workload — see `loopir::compile::stackable_grid_dim`), a coalesced
//! batch instead stacks the requests' activations along that grid axis,
//! binds the enlarged `DimSizes` against the same cached tape skeletons
//! ([`crate::coordinator::bind_stacked`]), and runs **one stacked tape
//! launch** across the full worker budget
//! ([`crate::coordinator::execute_prepared_stacked`]): per-segment
//! launch overhead is paid once per batch instead of once per request
//! ([`ProgramStats::launches`] is where the win shows). Weight-like
//! inputs (no stack dim) are bound once; a batch whose weights are not
//! bit-identical — or a plan with no stackable grid dim — falls back to
//! the fan-out path, per batch, automatically.
//!
//! **Determinism.** Batching changes *where* a request executes (a pool
//! worker instead of the caller) and *when* (coalesced with its batch),
//! never *what*: outputs and [`MemSim`] traffic counters are
//! bit-identical to a sequential
//! [`crate::coordinator::execute_plan_opts`] run on the same inputs
//! (all but the `peak_local_bytes` estimate, which no execution path
//! pins across worker fan-outs) — pinned by `tests/serve_parity.rs`
//! across thread counts, SIMD modes, and coalescing on/off. Stacked
//! launches keep the contract through per-slice attribution: the
//! executor splits its counters by grid-slice ownership, so each
//! response reports exactly what its request would have charged alone.
//!
//! ```
//! use blockbuster::serve::{ModelServer, ServerConfig};
//!
//! let mut server = ModelServer::new(ServerConfig::default());
//! server.register("quickstart").unwrap();
//! let id = server.submit_synthetic("quickstart", 7).unwrap();
//! let responses = server.drain();
//! assert_eq!(responses.len(), 1);
//! assert_eq!(responses[0].id, id);
//! assert_eq!(server.stats().per_program["quickstart"].compiles, 1);
//! ```

use crate::array::ArrayProgram;
use crate::autotune::{autotune_measured_cached, MeasuredPoint};
use crate::coordinator::{
    bind_stacked, compile, execute_prepared, execute_prepared_stacked, plan_stack_info,
    prepare_plan, unstacked_inputs, workloads, CompileConfig, PlanRun, PreparedPlan, StackInfo,
    StackedPlan,
};
use crate::cost::CostModel;
use crate::exec::{pool, ExecBackend, TapeCache};
use crate::fusion::fuse;
use crate::ir::graph::Graph;
use crate::loopir::interp::MemSim;
use crate::tensor::{Mat, Rng};
use anyhow::{anyhow, bail};
use std::collections::{BTreeMap, BTreeSet, HashMap, VecDeque};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Serving configuration: executor backend, worker cap, and the dynamic
/// batching knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Backend every registered plan is prepared for.
    pub backend: ExecBackend,
    /// Worker cap shared by batch fan-out and the engine's parallel grid
    /// loops (`None` = one per available core; `Some(1)` never touches
    /// the pool).
    pub threads: Option<usize>,
    /// Flush a workload's queue as soon as it holds this many requests.
    /// Normalized to at least 1 at server construction — 0 would mean no
    /// batch could ever fill, so no flush call site needs its own clamp.
    pub max_batch: usize,
    /// Flush a workload's queue (on [`ModelServer::poll`]) once its
    /// oldest request has waited this long, even if the batch is not
    /// full — the latency bound.
    pub max_wait: Duration,
    /// Cross-request kernel coalescing: execute a same-shape batch as
    /// **one stacked tape launch** (requests stacked along the plan's
    /// row-block grid dim) instead of fanning one plan execution per
    /// request across the pool. Falls back to fan-out per batch when
    /// the plan has no stackable grid dim or the batch's shared weight
    /// operands are not bit-identical. Per-request outputs and traffic
    /// counters are unchanged either way (the parity contract); only
    /// the *actual* launch count ([`ProgramStats::launches`]) shrinks.
    pub coalesce: bool,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            backend: ExecBackend::Compiled,
            threads: None,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            coalesce: false,
        }
    }
}

impl ServerConfig {
    /// Normalize degenerate knobs once, at server construction:
    /// `max_batch == 0` becomes 1, so no flush/queue call site ever
    /// needs a per-site clamp (and a future call site cannot forget
    /// one).
    fn normalized(mut self) -> ServerConfig {
        self.max_batch = self.max_batch.max(1);
        self
    }
}

/// One inference request: a registered workload name plus a full matrix
/// per program input (shapes must match the registered `full_shapes`).
#[derive(Clone, Debug)]
pub struct Request {
    pub workload: String,
    pub inputs: HashMap<String, Mat>,
}

/// One served request: the plan outputs, the request's own (simulated)
/// memory-traffic counters, and latency telemetry.
pub struct Response {
    /// The id [`ModelServer::submit`] returned for this request.
    pub id: u64,
    pub workload: String,
    pub outputs: HashMap<String, Mat>,
    /// This request's traffic counters — loads/stores, launches, and
    /// flops bit-identical to a sequential
    /// [`crate::coordinator::execute_plan_opts`] run on the same inputs.
    /// (`peak_local_bytes` is the one exception: a peak *estimate* the
    /// engine does not pin across worker fan-outs.) Coalesced launches
    /// report per-request counters via grid-slice attribution, so the
    /// contract holds there too — including `kernel_launches`, which
    /// stays what this request would have paid alone.
    pub mem: MemSim,
    /// How many requests shared this request's batched launch.
    pub batch_size: usize,
    /// Whether this request rode a stacked (coalesced) launch rather
    /// than a per-request fan-out.
    pub coalesced: bool,
    /// Time spent queued before the batch launched.
    pub queue_ns: u128,
    /// Wall-clock of the whole batched launch this request rode in
    /// (shared across the batch, not divided by it).
    pub exec_ns: u128,
}

/// Latency samples retained per workload: the summaries window over the
/// most recent this-many requests, so a long-lived server's telemetry
/// stays bounded no matter how much traffic flows.
pub const LATENCY_SAMPLE_CAP: usize = 4096;

/// Per-workload serving counters.
#[derive(Clone, Debug, Default)]
pub struct ProgramStats {
    /// [`crate::coordinator::compile`] invocations — compile-once means
    /// this stays at 1 no matter how many requests are served.
    pub compiles: u64,
    /// Tape-skeleton binds performed: plan segments once at
    /// registration (on the compiled backend), plus one per segment for
    /// each first-seen coalesced batch size (stacked re-binds — the
    /// cheap phase only; skeletons are never recompiled while serving).
    pub binds: u64,
    /// Requests served.
    pub served: u64,
    /// Batched launches performed.
    pub batches: u64,
    /// Largest batch coalesced so far.
    pub peak_batch: usize,
    /// Requests served via stacked (coalesced) launches.
    pub coalesced: u64,
    /// Stacked launches performed (each serving a whole batch).
    pub stacked_batches: u64,
    /// Kernel launches **actually executed** for this workload: a
    /// stacked batch contributes one request's worth regardless of its
    /// size; a fanned batch contributes every request's. This is the
    /// coalescing win the per-response [`Response::mem`] counters
    /// deliberately do not show (they keep the sequential-parity
    /// contract).
    pub launches: u64,
    /// Per-request end-to-end latency (queue + batched launch) of the
    /// most recent [`LATENCY_SAMPLE_CAP`] requests (a ring buffer — the
    /// latency summaries describe that window).
    pub latency_ns: Vec<u128>,
    /// Ring cursor into `latency_ns` once the cap is reached.
    latency_next: usize,
}

impl ProgramStats {
    /// Record one request's end-to-end latency, overwriting the oldest
    /// sample once [`LATENCY_SAMPLE_CAP`] are held.
    fn record_latency(&mut self, ns: u128) {
        if self.latency_ns.len() < LATENCY_SAMPLE_CAP {
            self.latency_ns.push(ns);
        } else {
            self.latency_ns[self.latency_next] = ns;
        }
        self.latency_next = (self.latency_next + 1) % LATENCY_SAMPLE_CAP;
    }
    /// Mean occupancy of this workload's batched launches.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.served as f64 / self.batches as f64
        }
    }

    pub fn mean_latency_ns(&self) -> f64 {
        if self.latency_ns.is_empty() {
            0.0
        } else {
            self.latency_ns.iter().sum::<u128>() as f64 / self.latency_ns.len() as f64
        }
    }

    /// Nearest-rank p-th percentile of the end-to-end latencies.
    pub fn percentile_latency_ns(&self, p: f64) -> u128 {
        crate::util::bench::percentile(&self.latency_ns, p)
    }
}

/// Aggregate serving telemetry. Throughput is deliberately *not* a
/// method here: a meaningful req/s figure needs a serving window chosen
/// by the caller (the CLI times its submit→drain span; dividing by
/// server uptime would dilute the number with registration/compile and
/// idle time).
#[derive(Debug)]
pub struct ServerStats {
    pub per_program: BTreeMap<String, ProgramStats>,
    /// When the server was created (uptime reference).
    pub started: Instant,
}

impl ServerStats {
    pub fn total_served(&self) -> u64 {
        self.per_program.values().map(|s| s.served).sum()
    }
}

/// A registered workload: its prepared plan plus everything needed to
/// validate and synthesize requests (and to re-tune block shapes).
struct Served {
    prepared: PreparedPlan,
    /// The initial (unfused) block program, kept for [`ModelServer::tune`].
    block: Graph,
    full_shapes: HashMap<String, (usize, usize)>,
    model: CostModel,
    queue: VecDeque<Pending>,
    /// `Some` iff the plan can coalesce same-shape batches into one
    /// stacked launch (every segment's top-level nests grid over the
    /// same dim) — computed once at registration.
    stack: Option<StackInfo>,
    /// Program inputs that do not carry the stack dim (weight-like,
    /// bound once per stacked launch): a batch only coalesces when
    /// these are bit-identical across its requests.
    shared_inputs: BTreeSet<String>,
    /// Stacked re-binds of the prepared plan, one per batch size seen
    /// (bounded by `max_batch`; each is only the cheap bind phase).
    stacked: HashMap<usize, StackedPlan>,
}

struct Pending {
    id: u64,
    inputs: HashMap<String, Mat>,
    enqueued: Instant,
}

/// The compile-once model server (see module docs).
pub struct ModelServer {
    cfg: ServerConfig,
    programs: BTreeMap<String, Served>,
    /// Registration order — the round-robin schedule for mixed traffic.
    order: Vec<String>,
    /// Next round-robin offset into `order`.
    rr: usize,
    /// Skeleton cache shared across all registered workloads (and with
    /// [`ModelServer::tune`]'s measured trials).
    cache: TapeCache,
    next_id: u64,
    stats: ServerStats,
}

impl ModelServer {
    pub fn new(cfg: ServerConfig) -> ModelServer {
        ModelServer {
            cfg: cfg.normalized(),
            programs: BTreeMap::new(),
            order: Vec::new(),
            rr: 0,
            cache: TapeCache::new(),
            next_id: 0,
            stats: ServerStats {
                per_program: BTreeMap::new(),
                started: Instant::now(),
            },
        }
    }

    /// Register one of the canonical demo workloads
    /// ([`crate::coordinator::workloads`]) by CLI name — compiling and
    /// preparing its plan exactly once.
    pub fn register(&mut self, name: &str) -> anyhow::Result<()> {
        let (program, cfg, params, _inputs) = workloads::by_name(name, 0).ok_or_else(|| {
            anyhow!(
                "unknown workload {name}; have {}",
                workloads::NAMES.join(", ")
            )
        })?;
        self.register_program(name, &program, cfg, params)
    }

    /// Register an arbitrary array program under `name`: runs the full
    /// compilation pipeline once, then lowers and binds every plan
    /// segment once. All subsequent requests reuse that work.
    pub fn register_program(
        &mut self,
        name: &str,
        program: &ArrayProgram,
        cfg: CompileConfig,
        params: BTreeMap<String, f32>,
    ) -> anyhow::Result<()> {
        if self.programs.contains_key(name) {
            bail!("workload {name} already registered");
        }
        let full_shapes = cfg.full_shapes.clone();
        let model = cfg.model;
        let sizes = cfg.sizes.clone();
        let compiled = compile(program, cfg);
        let prepared = prepare_plan(
            &compiled.plan,
            &sizes,
            &params,
            self.cfg.backend,
            &mut self.cache,
        );
        let stack = plan_stack_info(&prepared);
        let shared_inputs = stack
            .as_ref()
            .map(|info| unstacked_inputs(&prepared, info))
            .unwrap_or_default();
        let st = self.stats.per_program.entry(name.to_string()).or_default();
        st.compiles += 1;
        st.binds += prepared.binds;
        self.programs.insert(
            name.to_string(),
            Served {
                prepared,
                block: compiled.block,
                full_shapes,
                model,
                queue: VecDeque::new(),
                stack,
                shared_inputs,
                stacked: HashMap::new(),
            },
        );
        self.order.push(name.to_string());
        Ok(())
    }

    /// Enqueue a request; returns its id. The request is validated (the
    /// workload must be registered, every program input present at its
    /// registered full shape) but not executed until a batch flushes.
    pub fn submit(&mut self, req: Request) -> anyhow::Result<u64> {
        let served = self
            .programs
            .get_mut(&req.workload)
            .ok_or_else(|| anyhow!("unknown workload {}", req.workload))?;
        for (input, &(r, c)) in &served.full_shapes {
            let m = req
                .inputs
                .get(input)
                .ok_or_else(|| anyhow!("request for {} missing input {input}", req.workload))?;
            if (m.rows, m.cols) != (r, c) {
                bail!(
                    "request for {}: input {input} is {}x{}, registered shape is {r}x{c}",
                    req.workload,
                    m.rows,
                    m.cols
                );
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        served.queue.push_back(Pending {
            id,
            inputs: req.inputs,
            enqueued: Instant::now(),
        });
        Ok(id)
    }

    /// The synthetic inputs [`Self::submit_synthetic`] generates for
    /// `(workload, seed)` — exposed so callers can reproduce a request
    /// for verification (input names are generated in sorted order, so
    /// the mapping is deterministic).
    ///
    /// Weight-like inputs — those that do not carry the plan's stackable
    /// grid dim — are drawn from a **fixed** per-workload stream instead
    /// of `seed`: synthetic traffic then models a served model (fixed
    /// weights, per-request activations), and any two synthetic requests
    /// of one workload share their weights bit-for-bit, which is exactly
    /// the condition a coalesced batch needs.
    pub fn synthetic_inputs(
        &self,
        workload: &str,
        seed: u64,
    ) -> anyhow::Result<HashMap<String, Mat>> {
        let served = self
            .programs
            .get(workload)
            .ok_or_else(|| anyhow!("unknown workload {workload}"))?;
        let mut names: Vec<&String> = served.full_shapes.keys().collect();
        names.sort();
        let mut rng = Rng::new(seed);
        let mut weight_rng = Rng::new(SYNTHETIC_WEIGHT_SEED);
        Ok(names
            .into_iter()
            .map(|n| {
                let (r, c) = served.full_shapes[n];
                let m = if served.shared_inputs.contains(n) {
                    weight_rng.mat(r, c)
                } else {
                    rng.mat(r, c)
                };
                (n.clone(), m)
            })
            .collect())
    }

    /// Enqueue a request with deterministic random inputs derived from
    /// `seed` at the workload's registered shapes.
    pub fn submit_synthetic(&mut self, workload: &str, seed: u64) -> anyhow::Result<u64> {
        let inputs = self.synthetic_inputs(workload, seed)?;
        self.submit(Request {
            workload: workload.to_string(),
            inputs,
        })
    }

    /// Requests currently queued across all workloads.
    pub fn pending(&self) -> usize {
        self.programs.values().map(|s| s.queue.len()).sum()
    }

    /// Whether `name`'s queue is due a flush as of `now`: holds a full
    /// batch ([`ServerConfig::max_batch`]) or its oldest entry has
    /// waited past [`ServerConfig::max_wait`] (the latency bound).
    fn queue_due(&self, name: &str, now: Instant) -> bool {
        let s = &self.programs[name];
        s.queue.len() >= self.cfg.max_batch
            || s.queue
                .front()
                .is_some_and(|p| now.duration_since(p.enqueued) >= self.cfg.max_wait)
    }

    /// Repeated round-robin sweeps, one batch per eligible workload per
    /// sweep (so mixed traffic interleaves instead of one workload's
    /// backlog blocking the others), until a full sweep flushes
    /// nothing. The cursor advances once per sweep. Terminates: every
    /// sweep that continues flushed at least one request, and the
    /// eligibility predicates only shrink as queues drain.
    fn sweep_flush(&mut self, eligible: impl Fn(&ModelServer, &str) -> bool) -> Vec<Response> {
        let mut out = Vec::new();
        let n = self.order.len();
        if n == 0 {
            return out;
        }
        loop {
            let mut any = false;
            for k in 0..n {
                let name = self.order[(self.rr + k) % n].clone();
                if eligible(self, &name) {
                    out.extend(self.flush_one(&name));
                    any = true;
                }
            }
            self.rr = (self.rr + 1) % n;
            if !any {
                return out;
            }
        }
    }

    /// Flush every workload whose queue is due — full
    /// ([`ServerConfig::max_batch`]) or latency-bound (oldest entry
    /// older than [`ServerConfig::max_wait`]) — in round-robin sweeps
    /// that repeat **while anything stays due**: a burst that queued
    /// several `max_batch` fulls drains in this one poll (instead of
    /// leaking backlog at one batch per poll), and a latency-due
    /// partial remainder flushes here too rather than aging another
    /// poll cycle.
    /// Returns the responses of every batch launched; an empty vec means
    /// nothing was due.
    pub fn poll(&mut self) -> Vec<Response> {
        let now = Instant::now();
        self.sweep_flush(move |s, name| s.queue_due(name, now))
    }

    /// Flush until every queue is empty, taking at most `max_batch`
    /// requests per workload per round-robin turn (so mixed traffic
    /// interleaves instead of one workload draining first).
    pub fn drain(&mut self) -> Vec<Response> {
        self.sweep_flush(|s, name| !s.programs[name].queue.is_empty())
    }

    /// Take up to `max_batch` queued requests of `name` and launch them
    /// as one batch.
    fn flush_one(&mut self, name: &str) -> Vec<Response> {
        let take = {
            let q = &self.programs[name].queue;
            q.len().min(self.cfg.max_batch)
        };
        if take == 0 {
            return Vec::new();
        }
        let batch: Vec<Pending> = self
            .programs
            .get_mut(name)
            .expect("flush_one: registered workload")
            .queue
            .drain(..take)
            .collect();
        self.run_batch(name, batch)
    }

    /// Execute one batch. With coalescing on and an eligible batch
    /// (stackable plan, ≥2 requests, shared weights bit-identical) the
    /// whole batch becomes **one stacked tape launch** across the full
    /// worker budget ([`crate::coordinator::execute_prepared_stacked`]):
    /// per-segment launch overhead is paid once instead of once per
    /// request. Otherwise the batch fans out as one pool submission
    /// whose tasks each run one request's plan. With one request (or a
    /// worker cap of 1) the fan-out runs inline on the caller — the
    /// exact serial path.
    fn run_batch(&mut self, name: &str, batch: Vec<Pending>) -> Vec<Response> {
        let bs = batch.len();
        let threads = self.cfg.threads;
        let workers = effective_workers(threads, bs);
        let served = self
            .programs
            .get_mut(name)
            .expect("run_batch: registered workload");
        let stack_ok = self.cfg.coalesce
            && bs >= 2
            && served.stack.is_some()
            && shared_inputs_identical(&served.shared_inputs, &batch);
        let (runs, agg_launches, coalesced, new_binds, launched, finished) = if stack_ok {
            let info = served.stack.clone().expect("stack_ok implies stack info");
            let mut new_binds = 0;
            if !served.stacked.contains_key(&bs) {
                let sp = bind_stacked(&served.prepared, &info, bs);
                new_binds = sp.binds;
                served.stacked.insert(bs, sp);
            }
            let stacked = &served.stacked[&bs];
            let input_refs: Vec<&HashMap<String, Mat>> = batch.iter().map(|p| &p.inputs).collect();
            let t0 = Instant::now();
            let br = execute_prepared_stacked(&served.prepared, stacked, &input_refs, threads);
            (br.runs, br.agg.kernel_launches, true, new_binds, t0, Instant::now())
        } else {
            let prepared = &served.prepared;
            let t0 = Instant::now();
            let rs: Vec<PlanRun> = if workers <= 1 || bs == 1 {
                // Serial path: intra-request grid parallelism still
                // applies under the caller's thread budget.
                batch
                    .iter()
                    .map(|p| execute_prepared(prepared, &p.inputs, threads))
                    .collect()
            } else {
                // One heterogeneous pool job for the whole batch. Each
                // task runs its request serially (threads=1): the batch
                // itself is the parallelism, and nested fan-out from
                // inside a pool worker would run inline anyway.
                let slots: Vec<Mutex<Option<PlanRun>>> =
                    (0..bs).map(|_| Mutex::new(None)).collect();
                pool::global().run_tasks(workers, bs, &|t| {
                    let run = execute_prepared(prepared, &batch[t].inputs, Some(1));
                    *slots[t].lock().unwrap() = Some(run);
                });
                slots
                    .into_iter()
                    .map(|s| {
                        s.into_inner()
                            .expect("batch slot lock")
                            .expect("batch task completed")
                    })
                    .collect()
            };
            let launches = rs.iter().map(|r| r.mem.kernel_launches).sum();
            (rs, launches, false, 0, t0, Instant::now())
        };
        let exec_ns = finished.duration_since(launched).as_nanos();

        let st = self.stats.per_program.entry(name.to_string()).or_default();
        st.served += bs as u64;
        st.batches += 1;
        st.peak_batch = st.peak_batch.max(bs);
        st.launches += agg_launches;
        st.binds += new_binds;
        if coalesced {
            st.coalesced += bs as u64;
            st.stacked_batches += 1;
        }
        let mut out = Vec::with_capacity(bs);
        for (p, run) in batch.into_iter().zip(runs) {
            st.record_latency(finished.duration_since(p.enqueued).as_nanos());
            out.push(Response {
                id: p.id,
                workload: name.to_string(),
                outputs: run.outputs,
                mem: run.mem,
                batch_size: bs,
                coalesced,
                queue_ns: launched.duration_since(p.enqueued).as_nanos(),
                exec_ns,
            });
        }
        out
    }

    /// Measured block-shape autotuning for a registered workload,
    /// sharing the server's skeleton cache (so trials re-bind the same
    /// skeletons serving uses instead of recompiling). Returns the
    /// candidates best-first by measured wall-clock; the server keeps
    /// serving at its registered sizes — re-register to adopt a winner.
    pub fn tune(
        &mut self,
        name: &str,
        local_capacity: u64,
        trials: usize,
        seed: u64,
    ) -> anyhow::Result<Vec<MeasuredPoint>> {
        let inputs = self.synthetic_inputs(name, seed)?;
        let served = self
            .programs
            .get(name)
            .ok_or_else(|| anyhow!("unknown workload {name}"))?;
        let fused = fuse(served.block.clone())
            .snapshots
            .pop()
            .expect("fusion produces at least the initial snapshot");
        Ok(autotune_measured_cached(
            &fused,
            &served.full_shapes,
            local_capacity,
            &served.model,
            &served.prepared.params,
            &inputs,
            self.cfg.backend,
            trials,
            self.cfg.threads,
            &mut self.cache,
        ))
    }

    pub fn stats(&self) -> &ServerStats {
        &self.stats
    }

    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    /// Registered workload names, in registration (round-robin) order.
    pub fn workloads(&self) -> &[String] {
        &self.order
    }

    /// Skeleton-cache misses so far. Stable across any amount of serving
    /// traffic — recompiles would show up here (see `tests/serve_parity.rs`).
    pub fn cache_misses(&self) -> u64 {
        self.cache.misses
    }

    /// Skeleton-cache hits so far (structure sharing across workloads
    /// and [`Self::tune`] trials).
    pub fn cache_hits(&self) -> u64 {
        self.cache.hits
    }
}

/// Worker budget for a batch of `tasks` requests: the engine's own
/// budget resolution ([`crate::exec::engine::worker_budget`]), further
/// capped by the batch size.
fn effective_workers(threads: Option<usize>, tasks: usize) -> usize {
    crate::exec::engine::worker_budget(threads).min(tasks)
}

/// Seed of the fixed weight stream behind [`ModelServer::synthetic_inputs`]
/// (weight-like inputs are shared across all synthetic requests of a
/// workload; activations vary with the request seed).
const SYNTHETIC_WEIGHT_SEED: u64 = 0x5eed_b10c;

/// Bitwise equality of every shared (weight-like) input across a batch.
/// Value equality (`==`) is not enough — `-0.0 == 0.0` and NaN never
/// compares equal — and a stacked launch binds request 0's copy for the
/// whole batch, so anything short of bit-identity would break the
/// per-request parity contract. The scan is O(batch · weight bytes) per
/// flush, deliberately: a hash pre-check could only *reject* cheaply
/// (matching hashes would still need this confirm scan to keep the
/// bit-identical guarantee), and one linear pass over the weights is
/// noise next to the launch itself, which re-reads them many times.
fn shared_inputs_identical(shared: &BTreeSet<String>, batch: &[Pending]) -> bool {
    shared.iter().all(|name| {
        let m0 = batch[0]
            .inputs
            .get(name)
            .expect("validated request has every program input");
        batch[1..].iter().all(|p| {
            let m = p
                .inputs
                .get(name)
                .expect("validated request has every program input");
            m.rows == m0.rows
                && m.cols == m0.cols
                && m.data
                    .iter()
                    .zip(&m0.data)
                    .all(|(a, b)| a.to_bits() == b.to_bits())
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_rejects_unknown_and_duplicate() {
        let mut s = ModelServer::new(ServerConfig::default());
        assert!(s.register("no_such_program").is_err());
        s.register("quickstart").unwrap();
        let err = s.register("quickstart").unwrap_err().to_string();
        assert!(err.contains("already registered"), "got: {err}");
    }

    #[test]
    fn submit_validates_workload_and_shapes() {
        let mut s = ModelServer::new(ServerConfig::default());
        s.register("quickstart").unwrap();
        assert!(s.submit_synthetic("attention", 0).is_err());
        // wrong shape for a known input
        let mut inputs = s.synthetic_inputs("quickstart", 0).unwrap();
        let a = inputs.get_mut("A").unwrap();
        *a = Mat::zeros(a.rows + 1, a.cols);
        let err = s
            .submit(Request {
                workload: "quickstart".into(),
                inputs,
            })
            .unwrap_err()
            .to_string();
        assert!(err.contains("registered shape"), "got: {err}");
        // missing input
        let err = s
            .submit(Request {
                workload: "quickstart".into(),
                inputs: HashMap::new(),
            })
            .unwrap_err()
            .to_string();
        assert!(err.contains("missing input"), "got: {err}");
    }

    #[test]
    fn size_and_latency_bound_flushes() {
        // size-triggered: nothing flushes until max_batch requests queue
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 3,
            max_wait: Duration::from_secs(3600),
            threads: Some(1),
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        s.submit_synthetic("quickstart", 0).unwrap();
        s.submit_synthetic("quickstart", 1).unwrap();
        assert!(s.poll().is_empty(), "batch not full, wait not exceeded");
        assert_eq!(s.pending(), 2);
        s.submit_synthetic("quickstart", 2).unwrap();
        let r = s.poll();
        assert_eq!(r.len(), 3);
        assert!(r.iter().all(|r| r.batch_size == 3));
        assert_eq!(s.pending(), 0);

        // latency-triggered: max_wait zero flushes a lone request at once
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 100,
            max_wait: Duration::ZERO,
            threads: Some(1),
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        s.submit_synthetic("quickstart", 0).unwrap();
        let r = s.poll();
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].batch_size, 1);
        assert_eq!(s.stats().per_program["quickstart"].peak_batch, 1);
    }

    /// Regression (burst under-drain): a queue holding several
    /// `max_batch`-fulls must flush them all in ONE poll — the old
    /// one-flush-per-poll behavior grew unbounded backlog whenever
    /// arrival bursts outpaced the poll rate.
    #[test]
    fn poll_drains_overfull_queue_in_one_call() {
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 2,
            max_wait: Duration::from_secs(3600),
            threads: Some(1),
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        for i in 0..7u64 {
            s.submit_synthetic("quickstart", i).unwrap();
        }
        let r = s.poll();
        assert_eq!(r.len(), 6, "three full batches flush in one poll");
        assert_eq!(s.pending(), 1, "the partial batch stays queued");
        assert_eq!(s.stats().per_program["quickstart"].batches, 3);
        // the remainder is below max_batch and not yet latency-due
        assert!(s.poll().is_empty());
    }

    /// `max_batch == 0` normalizes to 1 at construction — no call site
    /// clamps it anymore, so the server must behave exactly like
    /// `max_batch == 1`.
    #[test]
    fn max_batch_zero_normalizes_to_one() {
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 0,
            max_wait: Duration::from_secs(3600),
            threads: Some(1),
            ..ServerConfig::default()
        });
        assert_eq!(s.config().max_batch, 1);
        s.register("quickstart").unwrap();
        s.submit_synthetic("quickstart", 0).unwrap();
        s.submit_synthetic("quickstart", 1).unwrap();
        let r = s.poll();
        assert_eq!(r.len(), 2, "two single-request batches");
        assert!(r.iter().all(|r| r.batch_size == 1));
    }

    /// Coalescing smoke: a full same-shape batch rides one stacked
    /// launch, and the actual launch count is one request's worth — the
    /// per-response counters still report the sequential values.
    #[test]
    fn coalesced_batch_is_one_stacked_launch() {
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 4,
            max_wait: Duration::from_secs(3600),
            threads: Some(2),
            coalesce: true,
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        for i in 0..4u64 {
            s.submit_synthetic("quickstart", i).unwrap();
        }
        let r = s.poll();
        assert_eq!(r.len(), 4);
        assert!(r.iter().all(|r| r.coalesced && r.batch_size == 4));
        let st = &s.stats().per_program["quickstart"];
        assert_eq!(st.coalesced, 4);
        assert_eq!(st.stacked_batches, 1);
        let per_req = r[0].mem.kernel_launches;
        assert!(per_req > 0);
        assert!(
            r.iter().all(|x| x.mem.kernel_launches == per_req),
            "same plan, same per-request launch charge"
        );
        assert_eq!(
            st.launches, per_req,
            "the stacked launch paid one request's worth of kernel launches for the whole batch"
        );
    }

    #[test]
    fn latency_samples_stay_bounded() {
        let mut st = ProgramStats::default();
        for i in 0..(LATENCY_SAMPLE_CAP as u128 + 10) {
            st.record_latency(i);
        }
        assert_eq!(st.latency_ns.len(), LATENCY_SAMPLE_CAP);
        // the ring overwrote the oldest slots with the newest samples
        assert_eq!(st.latency_ns[0], LATENCY_SAMPLE_CAP as u128);
        assert_eq!(st.latency_ns[9], LATENCY_SAMPLE_CAP as u128 + 9);
        assert_eq!(st.latency_ns[10], 10);
    }

    #[test]
    fn tune_shares_the_server_cache() {
        let mut s = ModelServer::new(ServerConfig {
            threads: Some(1),
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        let pts = s.tune("quickstart", 1 << 20, 3, 9).unwrap();
        assert!(!pts.is_empty() && pts.len() <= 3);
        let misses = s.cache_misses();
        // a second tune re-binds cached skeletons, compiling nothing new
        s.tune("quickstart", 1 << 20, 3, 10).unwrap();
        assert_eq!(s.cache_misses(), misses);
    }
}
