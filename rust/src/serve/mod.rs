//! Compile-once / execute-many serving layer with dynamic batching.
//!
//! Every `blockbuster run` invocation recompiles its plan and executes
//! exactly one request. This module is the inference-server shape the
//! paper positions Blockbuster for: a [`ModelServer`] that compiles each
//! registered workload **once** through [`crate::coordinator::compile`],
//! holds its [`PreparedPlan`] (segments lowered once, tape skeletons
//! pulled from a shared [`TapeCache`] and bound once per `DimSizes`),
//! and then drains a submission queue of [`Request`]s with zero
//! per-request compilation.
//!
//! **Dynamic batching.** Requests are queued per workload; a workload's
//! queue flushes when it reaches [`ServerConfig::max_batch`] requests or
//! its oldest entry has waited [`ServerConfig::max_wait`] (the classic
//! throughput/latency trade-off knobs), and an over-full queue keeps
//! flushing while it still holds a full batch (bursts drain in one
//! poll). A flushed batch becomes **one** submission to the persistent
//! worker pool
//! ([`crate::exec::pool::WorkerPool::run_tasks`]): each pool task
//! executes one request's full multi-segment plan against the shared
//! `PreparedPlan`, so the batch pays one job handoff instead of one
//! spawn/join per request, and mixed-program traffic is scheduled
//! round-robin across workloads so no queue starves.
//!
//! **Cross-request kernel coalescing** ([`ServerConfig::coalesce`]).
//! Fanning a batch across the pool still launches every plan segment
//! once *per request*. When the plan's segments all grid over one
//! stackable dimension (the row-block dim `M` on every canonical
//! workload — see `loopir::compile::stackable_grid_dim`), a coalesced
//! batch instead stacks the requests' activations along that grid axis,
//! binds the enlarged `DimSizes` against the same cached tape skeletons
//! ([`crate::coordinator::bind_stacked`]), and runs **one stacked tape
//! launch** across the full worker budget
//! ([`crate::coordinator::execute_prepared_stacked`]): per-segment
//! launch overhead is paid once per batch instead of once per request
//! ([`ProgramStats::launches`] is where the win shows). Weight-like
//! inputs (no stack dim) are bound once; a batch whose weights are not
//! bit-identical — or a plan with no stackable grid dim — falls back to
//! the fan-out path, per batch, automatically.
//!
//! **Ragged traffic: shape buckets, padding, continuous batching.**
//! Requests of a stackable workload need not arrive at the registered
//! shape: any extent along the stackable grid dim `M` (in whole block
//! units, up to the registered trip) is admitted, and
//! [`ModelServer::submit`] derives the request's *trip* (its block
//! count along `M`) from its input extents. Each workload keeps one
//! queue per **shape bucket** ([`BucketLadder`] — [`ServerConfig::buckets`]):
//! requests whose `DimSizes` differ only in the stackable dim land in
//! the same bucket and share a stacked launch (the legality check is
//! `loopir::compile::bucket_compatible` — any *other* differing dim is
//! rejected at admission, since every non-stack extent must match the
//! registered shape). A ragged batch stacks each request at its own
//! trip (`coordinator::StackSpec`); with [`ServerConfig::pad`] on, each
//! request is padded to its bucket edge with zero blocks so stacked
//! bind sizes stay bounded by the ladder. Pad blocks execute for real,
//! but their traffic is **never** attributed to a request: it lands in
//! the aggregate's `padded_*` counters ([`ProgramStats::padded_flops`]
//! and friends), keeping the reconciliation `launch totals == Σ
//! per-request + padded_*` exact. Batching is *continuous*: a flush
//! takes whatever its bucket holds at launch time, so requests
//! admitted while earlier batches were executing ride the next stacked
//! launch, and [`ModelServer::next_due`] tracks due times per bucket.
//!
//! **Determinism.** Batching changes *where* a request executes (a pool
//! worker instead of the caller) and *when* (coalesced with its batch),
//! never *what*: outputs and [`MemSim`] traffic counters are
//! bit-identical to a sequential
//! [`crate::coordinator::execute_plan_opts`] run on the same inputs
//! (all but the `peak_local_bytes` estimate, which no execution path
//! pins across worker fan-outs) — pinned by `tests/serve_parity.rs`
//! across thread counts, SIMD modes, and coalescing on/off. Stacked
//! launches keep the contract through per-slice attribution: the
//! executor splits its counters by grid-slice ownership, so each
//! response reports exactly what its request would have charged alone.
//!
//! **Robustness.** The server is built to degrade, not die. Admission
//! control bounds every workload queue ([`ServerConfig::queue_cap`]):
//! an over-cap submission is *shed* with a typed
//! [`Verdict::Rejected`] response ([`Rejected::QueueFull`]) instead of
//! growing the backlog — [`ShedPolicy`] picks whether the new request
//! or the oldest queued one pays. Per-request **deadlines**
//! ([`Request::deadline`], defaulted from [`ServerConfig::deadline`])
//! are checked at admission *and again at batch formation*, so expired
//! work is shed ([`Rejected::DeadlineExpired`]) before it burns a
//! launch. **Panic isolation**: a panicking batch launch is caught and
//! converted into [`Verdict::Failed`] error responses for exactly that
//! batch's requests (per *request* on the fan-out path, per batch on a
//! stacked launch); the worker pool respawns dead workers
//! ([`crate::exec::pool`]), lock poisoning is recovered, and every
//! formerly panicking `expect` on the serve path is a recoverable
//! error. The seeded fault injector ([`crate::util::fault`]) makes all
//! of this testable on demand (`tests/serve_chaos.rs`).
//!
//! **Daemon.** [`daemon::Daemon`] wraps a [`ModelServer`] in a
//! channel-fed background flusher thread that honors `max_wait`
//! *without polling* (it sleeps exactly until [`ModelServer::next_due`]),
//! drains gracefully on shutdown (stop admitting → flush in-flight →
//! join), and can re-tune block shapes under live traffic, adopting a
//! measured winner via an atomic `Arc` plan swap between batches
//! ([`ModelServer::adopt_sizes`]).
//!
//! ```
//! use blockbuster::serve::{ModelServer, ServerConfig};
//!
//! let mut server = ModelServer::new(ServerConfig::default());
//! server.register("quickstart").unwrap();
//! let id = server.submit_synthetic("quickstart", 7).unwrap();
//! let responses = server.drain();
//! assert_eq!(responses.len(), 1);
//! assert_eq!(responses[0].id, id);
//! assert!(responses[0].is_ok());
//! assert_eq!(server.stats().per_program["quickstart"].compiles, 1);
//! ```

pub mod daemon;
pub mod net;

use crate::array::ArrayProgram;
use crate::autotune::{autotune_measured_cached, MeasuredPoint};
use crate::coordinator::{
    bind_stacked_sized, bind_stacked_trip, compile, execute_prepared,
    execute_prepared_stacked_extra, execute_prepared_stacked_spec, input_block_grid,
    input_dim_axes, plan_stack_info, prepare_plan, stacked_input_axes, state_input_axes,
    unstacked_inputs, workloads, CompileConfig, PlanRun, PreparedPlan, StackInfo, StackSpec,
    StackedPlan,
};
use crate::cost::CostModel;
use crate::exec::{append_state, pool, ExecBackend, TapeCache};
use crate::fusion::fuse;
use crate::ir::dim::{Dim, DimSizes};
use crate::ir::graph::Graph;
use crate::loopir::interp::MemSim;
use crate::select::{select, SelectCtx};
use crate::tensor::{Mat, Rng};
use crate::util::fault;
use anyhow::{anyhow, bail};
use std::collections::{BTreeMap, BTreeSet, HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Serving configuration: executor backend, worker cap, and the dynamic
/// batching knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Backend every registered plan is prepared for.
    pub backend: ExecBackend,
    /// Worker cap shared by batch fan-out and the engine's parallel grid
    /// loops (`None` = one per available core; `Some(1)` never touches
    /// the pool).
    pub threads: Option<usize>,
    /// Flush a workload's queue as soon as it holds this many requests.
    /// Normalized to at least 1 at server construction — 0 would mean no
    /// batch could ever fill, so no flush call site needs its own clamp.
    pub max_batch: usize,
    /// Flush a workload's queue (on [`ModelServer::poll`]) once its
    /// oldest request has waited this long, even if the batch is not
    /// full — the latency bound.
    pub max_wait: Duration,
    /// Cross-request kernel coalescing: execute a same-shape batch as
    /// **one stacked tape launch** (requests stacked along the plan's
    /// row-block grid dim) instead of fanning one plan execution per
    /// request across the pool. Falls back to fan-out per batch when
    /// the plan has no stackable grid dim or the batch's shared weight
    /// operands are not bit-identical. Per-request outputs and traffic
    /// counters are unchanged either way (the parity contract); only
    /// the *actual* launch count ([`ProgramStats::launches`]) shrinks.
    pub coalesce: bool,
    /// Admission control: cap each workload's queue at this many pending
    /// requests (`None` = unbounded, the pre-daemon behavior). An
    /// over-cap submission sheds per [`ServerConfig::shed_policy`] with
    /// a typed [`Rejected::QueueFull`] response.
    pub queue_cap: Option<usize>,
    /// Default per-request deadline, measured from admission (`None` =
    /// no deadline). A request carrying its own [`Request::deadline`]
    /// overrides this. Expired requests are shed with
    /// [`Rejected::DeadlineExpired`] — at admission if already past due,
    /// or at batch formation if they expired while queued.
    pub deadline: Option<Duration>,
    /// Who pays when a queue is full: the new arrival or the oldest
    /// queued request.
    pub shed_policy: ShedPolicy,
    /// Shape-bucket ladder for ragged traffic: which requests of one
    /// workload may share a stacked launch. The default
    /// ([`BucketLadder::Exact`]) groups only same-trip requests —
    /// full-shape traffic behaves exactly as before this knob existed.
    pub buckets: BucketLadder,
    /// Pad each ragged request to its bucket edge with zero blocks
    /// (default off). Padding bounds the set of stacked bind sizes by
    /// the ladder's edges; the waste is charged to the aggregate
    /// `padded_*` counters, never to a request.
    pub pad: bool,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            backend: ExecBackend::Compiled,
            threads: None,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            coalesce: false,
            queue_cap: None,
            deadline: None,
            shed_policy: ShedPolicy::RejectNew,
            buckets: BucketLadder::Exact,
            pad: false,
        }
    }
}

impl ServerConfig {
    /// Normalize degenerate knobs once, at server construction:
    /// `max_batch == 0` becomes 1 and `queue_cap == Some(0)` becomes
    /// `Some(1)` (a cap of 0 could never admit anything), so no
    /// flush/queue call site ever needs a per-site clamp (and a future
    /// call site cannot forget one).
    fn normalized(mut self) -> ServerConfig {
        self.max_batch = self.max_batch.max(1);
        self.queue_cap = self.queue_cap.map(|c| c.max(1));
        self
    }
}

/// Shape-bucket ladder for ragged traffic: maps a request's trip (its
/// block count along the stackable grid dim) to the **bucket edge** it
/// queues under. Requests sharing an edge share a queue — and thus
/// stacked launches; with [`ServerConfig::pad`] on, each is padded up
/// to the edge.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum BucketLadder {
    /// One bucket per exact trip: only same-trip requests coalesce,
    /// padding is never needed. The default — full-shape traffic
    /// behaves exactly as it did before buckets existed.
    #[default]
    Exact,
    /// Edges at powers of two, clamped to the registered trip:
    /// `1, 2, 4, …, registered`. Bounds pad waste to < 2x per request
    /// while keeping the bucket count logarithmic.
    Pow2,
    /// One bucket for everything, at the registered trip. Maximizes
    /// coalescing opportunity; with padding on, maximizes waste too.
    Max,
    /// Explicit ascending edges; a trip above the last edge buckets at
    /// its own value (no padding).
    Edges(Vec<usize>),
}

impl BucketLadder {
    /// Parse a CLI `--buckets` value: `exact`, `pow2`, `max`, or a
    /// comma-separated ascending edge list like `2,4,8`.
    pub fn from_name(name: &str) -> Option<BucketLadder> {
        match name {
            "exact" => Some(BucketLadder::Exact),
            "pow2" => Some(BucketLadder::Pow2),
            "max" => Some(BucketLadder::Max),
            _ => {
                let edges: Option<Vec<usize>> =
                    name.split(',').map(|s| s.trim().parse().ok()).collect();
                let edges = edges?;
                if edges.is_empty() || edges.contains(&0) || edges.windows(2).any(|w| w[0] >= w[1])
                {
                    return None;
                }
                Some(BucketLadder::Edges(edges))
            }
        }
    }

    /// The bucket edge for a request of `trip` blocks under a plan
    /// whose registered trip is `registered` (`1 <= trip <=
    /// registered`, enforced at admission).
    pub fn edge_for(&self, trip: usize, registered: usize) -> usize {
        match self {
            BucketLadder::Exact => trip,
            BucketLadder::Pow2 => trip.next_power_of_two().min(registered),
            BucketLadder::Max => registered,
            BucketLadder::Edges(edges) => edges
                .iter()
                .copied()
                .find(|&e| e >= trip)
                .map(|e| e.min(registered))
                .unwrap_or(trip),
        }
    }
}

/// What to shed when a queue is at [`ServerConfig::queue_cap`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Reject the arriving request; queued work is never evicted.
    #[default]
    RejectNew,
    /// Evict the oldest queued request (it gets the
    /// [`Rejected::QueueFull`] response) and admit the arrival — keeps
    /// the queue biased toward fresh work under sustained overload.
    DropOldest,
}

impl ShedPolicy {
    /// Parse a CLI `--shed-policy` value.
    pub fn from_name(name: &str) -> Option<ShedPolicy> {
        match name {
            "reject-new" => Some(ShedPolicy::RejectNew),
            "drop-oldest" => Some(ShedPolicy::DropOldest),
            _ => None,
        }
    }
}

/// Why a request was shed without executing. Carried in
/// [`Verdict::Rejected`] responses and tallied per workload in
/// [`ProgramStats`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rejected {
    /// The workload's queue was at [`ServerConfig::queue_cap`].
    QueueFull,
    /// The server is draining ([`ModelServer::begin_shutdown`]) and no
    /// longer admits work.
    Shutdown,
    /// The request's deadline passed — at admission or while queued.
    DeadlineExpired,
}

/// Outcome of one request, carried on every [`Response`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Verdict {
    /// Executed; `outputs`/`mem` hold the parity-contract results.
    Ok,
    /// Shed by admission control or deadline enforcement — never
    /// executed, `outputs` is empty.
    Rejected(Rejected),
    /// Its batch (stacked) or its own task (fan-out) panicked; the
    /// panic was contained and converted into this error message.
    Failed(String),
}

/// One inference request: a registered workload name plus a full matrix
/// per program input (shapes must match the registered `full_shapes`),
/// optionally carrying its own completion deadline.
#[derive(Clone, Debug)]
pub struct Request {
    pub workload: String,
    pub inputs: HashMap<String, Mat>,
    /// Absolute deadline; overrides [`ServerConfig::deadline`] when set.
    /// A request not launched by this instant is shed with
    /// [`Rejected::DeadlineExpired`].
    pub deadline: Option<Instant>,
}

impl Request {
    pub fn new(workload: impl Into<String>, inputs: HashMap<String, Mat>) -> Request {
        Request {
            workload: workload.into(),
            inputs,
            deadline: None,
        }
    }

    /// Builder-style absolute deadline.
    pub fn with_deadline(mut self, deadline: Instant) -> Request {
        self.deadline = Some(deadline);
        self
    }
}

/// One served request: the plan outputs, the request's own (simulated)
/// memory-traffic counters, and latency telemetry.
pub struct Response {
    /// The id [`ModelServer::submit`] returned for this request.
    pub id: u64,
    pub workload: String,
    pub outputs: HashMap<String, Mat>,
    /// This request's traffic counters — loads/stores, launches, and
    /// flops bit-identical to a sequential
    /// [`crate::coordinator::execute_plan_opts`] run on the same inputs.
    /// (`peak_local_bytes` is the one exception: a peak *estimate* the
    /// engine does not pin across worker fan-outs.) Coalesced launches
    /// report per-request counters via grid-slice attribution, so the
    /// contract holds there too — including `kernel_launches`, which
    /// stays what this request would have paid alone.
    pub mem: MemSim,
    /// How many requests shared this request's batched launch.
    pub batch_size: usize,
    /// Whether this request rode a stacked (coalesced) launch rather
    /// than a per-request fan-out.
    pub coalesced: bool,
    /// Time spent queued before the batch launched.
    pub queue_ns: u128,
    /// Wall-clock of the whole batched launch this request rode in
    /// (shared across the batch, not divided by it).
    pub exec_ns: u128,
    /// How this request ended: served, shed, or failed. Only
    /// [`Verdict::Ok`] responses carry outputs and counters.
    pub verdict: Verdict,
}

impl Response {
    /// Whether the request executed successfully.
    pub fn is_ok(&self) -> bool {
        self.verdict == Verdict::Ok
    }

    /// A response for a request that never executed (shed or failed
    /// before launch): empty outputs, zeroed counters.
    fn unserved(id: u64, workload: &str, verdict: Verdict, queue_ns: u128) -> Response {
        Response {
            id,
            workload: workload.to_string(),
            outputs: HashMap::new(),
            mem: MemSim::default(),
            batch_size: 0,
            coalesced: false,
            queue_ns,
            exec_ns: 0,
            verdict,
        }
    }
}

/// Latency samples retained per workload: the summaries window over the
/// most recent this-many requests, so a long-lived server's telemetry
/// stays bounded no matter how much traffic flows.
pub const LATENCY_SAMPLE_CAP: usize = 4096;

/// Per-workload serving counters.
#[derive(Clone, Debug, Default)]
pub struct ProgramStats {
    /// [`crate::coordinator::compile`] invocations — compile-once means
    /// this stays at 1 no matter how many requests are served.
    pub compiles: u64,
    /// Tape-skeleton binds performed: plan segments once at
    /// registration (on the compiled backend), plus one per segment for
    /// each first-seen coalesced batch size (stacked re-binds — the
    /// cheap phase only; skeletons are never recompiled while serving).
    pub binds: u64,
    /// Requests served successfully ([`Verdict::Ok`] responses only).
    pub served: u64,
    /// Admission attempts that passed validation — including ones later
    /// rejected, shed, or failed. When every response has been drained,
    /// `submitted == accounted()` (the chaos suite's reconciliation).
    pub submitted: u64,
    /// Rejected at admission: queue at [`ServerConfig::queue_cap`]
    /// (counts [`ShedPolicy::DropOldest`] evictions too — either way
    /// one request paid for the full queue).
    pub rejected_full: u64,
    /// Rejected at admission: deadline already expired.
    pub rejected_deadline: u64,
    /// Rejected at admission: server draining
    /// ([`ModelServer::begin_shutdown`]).
    pub rejected_shutdown: u64,
    /// Shed at batch formation: deadline expired while queued.
    pub shed_deadline: u64,
    /// Requests whose launch panicked ([`Verdict::Failed`] responses).
    pub failed: u64,
    /// Panicking launches contained (one per poisoned stacked batch,
    /// one per poisoned fan-out task).
    pub panics: u64,
    /// Live plan hot-swaps adopted ([`ModelServer::adopt_sizes`]).
    pub plan_swaps: u64,
    /// Batched launches performed.
    pub batches: u64,
    /// Largest batch coalesced so far.
    pub peak_batch: usize,
    /// Requests served via stacked (coalesced) launches.
    pub coalesced: u64,
    /// Stacked launches performed (each serving a whole batch).
    pub stacked_batches: u64,
    /// Kernel launches **actually executed** for this workload: a
    /// stacked batch contributes one request's worth regardless of its
    /// size; a fanned batch contributes every request's. This is the
    /// coalescing win the per-response [`Response::mem`] counters
    /// deliberately do not show (they keep the sequential-parity
    /// contract).
    pub launches: u64,
    /// Bytes loaded by pad blocks (pad-to-bucket waste) across this
    /// workload's stacked launches. Pad traffic executes for real but
    /// is never attributed to a request's own counters: per launch,
    /// `aggregate loads == Σ per-request loads + padded loads`.
    pub padded_loaded_bytes: u64,
    /// Bytes stored by pad blocks — see
    /// [`ProgramStats::padded_loaded_bytes`].
    pub padded_stored_bytes: u64,
    /// Flops burned on pad blocks — see
    /// [`ProgramStats::padded_loaded_bytes`].
    pub padded_flops: u64,
    /// Decode sessions opened on this workload
    /// ([`ModelServer::open_session`]).
    pub sessions_opened: u64,
    /// Decode steps served successfully — a subset of
    /// [`ProgramStats::served`]; stateless and decode traffic share
    /// every other counter.
    pub decode_steps: u64,
    /// Stateful-buffer block appends performed at decode admission
    /// (each decode step appends one block-slab per stateful input).
    pub state_appends: u64,
    /// Bytes those appends stored. Per step this also rides the step's
    /// own [`Response::mem`] (broken out in
    /// [`MemSim::state_appended_bytes`]); here it aggregates.
    pub state_appended_bytes: u64,
    /// Per-request end-to-end latency (queue + batched launch) of the
    /// most recent [`LATENCY_SAMPLE_CAP`] requests (a ring buffer — the
    /// latency summaries describe that window).
    pub latency_ns: Vec<u128>,
    /// Ring cursor into `latency_ns` once the cap is reached.
    latency_next: usize,
}

impl ProgramStats {
    /// Record one request's end-to-end latency, overwriting the oldest
    /// sample once [`LATENCY_SAMPLE_CAP`] are held.
    fn record_latency(&mut self, ns: u128) {
        if self.latency_ns.len() < LATENCY_SAMPLE_CAP {
            self.latency_ns.push(ns);
        } else {
            self.latency_ns[self.latency_next] = ns;
        }
        self.latency_next = (self.latency_next + 1) % LATENCY_SAMPLE_CAP;
    }
    /// Mean occupancy of this workload's batched launches.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.served as f64 / self.batches as f64
        }
    }

    pub fn mean_latency_ns(&self) -> f64 {
        if self.latency_ns.is_empty() {
            0.0
        } else {
            self.latency_ns.iter().sum::<u128>() as f64 / self.latency_ns.len() as f64
        }
    }

    /// Nearest-rank p-th percentile of the end-to-end latencies; 0 when
    /// no samples have been recorded yet (never NaN — see
    /// [`crate::util::bench::percentile`]).
    pub fn percentile_latency_ns(&self, p: f64) -> u128 {
        crate::util::bench::percentile(&self.latency_ns, p)
    }

    /// Every admission that has been answered: served + rejected + shed
    /// + failed. Once all responses are drained this equals
    /// [`ProgramStats::submitted`] — requests are never silently lost.
    pub fn accounted(&self) -> u64 {
        self.served
            + self.rejected_full
            + self.rejected_deadline
            + self.rejected_shutdown
            + self.shed_deadline
            + self.failed
    }

    /// Requests shed by admission control or deadline enforcement.
    pub fn rejected(&self) -> u64 {
        self.rejected_full + self.rejected_deadline + self.rejected_shutdown + self.shed_deadline
    }
}

/// Aggregate serving telemetry. Throughput is deliberately *not* a
/// method here: a meaningful req/s figure needs a serving window chosen
/// by the caller (the CLI times its submit→drain span; dividing by
/// server uptime would dilute the number with registration/compile and
/// idle time).
#[derive(Debug)]
pub struct ServerStats {
    pub per_program: BTreeMap<String, ProgramStats>,
    /// When the server was created (uptime reference).
    pub started: Instant,
}

impl ServerStats {
    pub fn total_served(&self) -> u64 {
        self.per_program.values().map(|s| s.served).sum()
    }

    pub fn total_submitted(&self) -> u64 {
        self.per_program.values().map(|s| s.submitted).sum()
    }

    /// Requests shed (queue-full + deadline + shutdown) across programs.
    pub fn total_rejected(&self) -> u64 {
        self.per_program.values().map(|s| s.rejected()).sum()
    }

    /// Requests that got [`Verdict::Failed`] responses across programs.
    pub fn total_failed(&self) -> u64 {
        self.per_program.values().map(|s| s.failed).sum()
    }
}

/// A registered workload: its prepared plan plus everything needed to
/// validate and synthesize requests (and to re-tune block shapes).
struct Served {
    /// The live plan, behind an `Arc` so a batch launch holds its own
    /// handle: [`ModelServer::adopt_sizes`] can swap in a re-tuned plan
    /// between batches (atomically, from the one serving thread's point
    /// of view) without invalidating telemetry or racing an in-flight
    /// launch.
    prepared: Arc<PreparedPlan>,
    /// The initial (unfused) block program, kept for [`ModelServer::tune`].
    block: Graph,
    full_shapes: HashMap<String, (usize, usize)>,
    model: CostModel,
    /// One queue per shape bucket, keyed by bucket edge
    /// ([`BucketLadder::edge_for`]; 0 for a non-stackable plan, whose
    /// requests are all full-shape). Requests in one bucket share
    /// stacked launches; buckets flush independently, each with its
    /// own due time.
    queues: BTreeMap<usize, VecDeque<Pending>>,
    /// `Some` iff the plan can coalesce same-bucket batches into one
    /// stacked launch (every segment's top-level nests grid over the
    /// same dim) — computed once at registration.
    stack: Option<StackInfo>,
    /// Program inputs that do not carry the stack dim (weight-like,
    /// bound once per stacked launch): a batch only coalesces when
    /// these are bit-identical across its requests.
    shared_inputs: BTreeSet<String>,
    /// For each stack-dim-carrying program input, the matrix axis it
    /// stacks along — how [`ModelServer::submit`] derives a ragged
    /// request's trip from its extents.
    stack_axes: BTreeMap<String, usize>,
    /// `Some` iff the plan has stateful (KV-cache) inputs — the growth
    /// geometry the session machinery works from. Recomputed on every
    /// hot-swap; open sessions keep the snapshot they pinned at open.
    /// A stateful workload rejects plain [`ModelServer::submit`]:
    /// decode traffic flows through sessions only.
    state: Option<StateMeta>,
    /// Stacked re-binds of the prepared plan, keyed by **total trip**
    /// (uniform batches bind at `batch · trip`; ragged batches at the
    /// sum of their trips plus pads — bounded by the bucket ladder's
    /// edges times `max_batch`). Each is only the cheap bind phase.
    stacked: HashMap<usize, Arc<StackedPlan>>,
    /// Fair-share weight ([`ModelServer::set_weight`], default 1): per
    /// scheduling round this workload may flush up to
    /// `weight * max_batch` requests before yielding the turn.
    weight: u64,
    /// Deficit-round-robin credit carried between rounds, in request
    /// units. Banked when a turn ends mid-batch, zeroed whenever the
    /// workload has nothing eligible (an idle workload must not hoard
    /// credit it would later use to starve the others).
    deficit: u64,
}

struct Pending {
    id: u64,
    inputs: HashMap<String, Mat>,
    enqueued: Instant,
    /// Effective absolute deadline (request's own, else admission time
    /// plus [`ServerConfig::deadline`]); `None` = never expires.
    deadline: Option<Instant>,
    /// Block count along the stackable grid dim, derived from the
    /// request's extents at admission (== the registered trip for a
    /// full-shape request; 0 when the plan is not stackable).
    trip: usize,
    /// The decode session this step belongs to (`None` for stateless
    /// requests). Session steps are batched by
    /// [`ModelServer::run_decode_batch`], never the stateless paths.
    session: Option<u64>,
    /// For a session step: the cache length (in growth blocks,
    /// *including* this step's own append) it executes at. The step
    /// binds the cache **prefix** at this length no matter how much
    /// the session grows while it waits — which is what makes queued
    /// steps order-independent.
    state_len: usize,
    /// For a session step: the admission-time append traffic, folded
    /// into the step's own [`Response::mem`]
    /// ([`MemSim::state_appended_bytes`] breaks it back out).
    append_mem: MemSim,
}

/// Growth geometry of a stateful plan, derived from its `state_dim`
/// marks ([`crate::ir::graph::Graph::mark_state`], threaded through
/// lowering) at registration and on every hot-swap. Sessions snapshot
/// it at open time alongside the plan handle, so a later swap cannot
/// change an open session's cache blocking.
#[derive(Clone)]
struct StateMeta {
    /// The one growth dim every stateful input shares (`N` for decode
    /// attention — the cache/context dim). Sessions support exactly
    /// one growth dim per plan, distinct from the stack dim.
    growth: Dim,
    /// Registered block count of the growth dim — the **context cap**:
    /// a session holds at most this many cache blocks.
    cap: usize,
    /// Stateful input name → how one decode step's append lands.
    state: BTreeMap<String, StateAppend>,
    /// Request inputs that carry the growth dim without being stateful
    /// (the decode mask): name → (matrix axis, element extent of one
    /// growth block along it). They must arrive scaled to the new
    /// cache length.
    scaled: BTreeMap<String, (usize, usize)>,
}

/// How one decode step's append lands in one stateful input's cache.
#[derive(Clone)]
struct StateAppend {
    /// Matrix axis the cache grows along (0 = rows, 1 = cols).
    axis: usize,
    /// Element extent of one appended block-slab along `axis`.
    unit: usize,
    /// Block grid of one append — 1 along the growth axis, the full
    /// registered block count on the other — what
    /// [`crate::exec::append_state`] charges to [`MemSim`].
    blocks: (usize, usize),
}

/// One decode session: the persistent KV blocks plus the plan handle
/// they were opened against.
struct Session {
    workload: String,
    /// The plan pinned at open: every step of this session executes
    /// this exact plan, even across [`ModelServer::adopt_sizes`]
    /// hot-swaps — the session's cache blocking is fixed at open time,
    /// and its decode-vs-prefill parity holds against the pinned plan,
    /// not whatever the live plan has been re-tuned to.
    prepared: Arc<PreparedPlan>,
    /// Stack info of the pinned plan (sessions require a stackable
    /// plan — decode singles coalesce along it).
    info: StackInfo,
    /// Growth geometry snapshotted from the pinned plan.
    meta: StateMeta,
    /// The persistent buffers, one full matrix per stateful input,
    /// grown by [`crate::exec::append_state`] at each step's
    /// admission. A prefix is immutable once appended: a queued step
    /// binds the prefix at its own [`Pending::state_len`], so steps
    /// execute correctly in any order relative to later appends.
    caches: BTreeMap<String, Mat>,
    /// Cache length in growth blocks appended so far.
    len: usize,
}

/// Derive a plan's growth geometry from its state marks. `Ok(None)`
/// when the plan has no stateful inputs; `Err` when it has them but
/// they cannot back sessions (several growth dims, growth dim == stack
/// dim, extents not divisible into growth blocks).
fn state_meta(
    prepared: &PreparedPlan,
    stack: Option<&StackInfo>,
    full_shapes: &HashMap<String, (usize, usize)>,
) -> anyhow::Result<Option<StateMeta>> {
    let marks = state_input_axes(prepared);
    if marks.is_empty() {
        return Ok(None);
    }
    let mut growth: Option<Dim> = None;
    for (name, (dim, _)) in &marks {
        match &growth {
            Some(g) if g != dim => bail!(
                "stateful inputs disagree on the growth dim ({g:?} vs {dim:?} on {name}); \
                 sessions support one growth dim per plan"
            ),
            Some(_) => {}
            None => growth = Some(dim.clone()),
        }
    }
    let growth = growth.expect("marks is non-empty");
    if let Some(info) = stack {
        if info.dim == growth {
            bail!(
                "growth dim {growth:?} is also the stackable grid dim; \
                 sessions need them distinct"
            );
        }
    }
    let cap = prepared.sizes.get(&growth);
    if cap == 0 {
        bail!("growth dim {growth:?} is registered at 0 blocks");
    }
    let mut state = BTreeMap::new();
    for (name, (_, axis)) in &marks {
        let &(r, c) = full_shapes
            .get(name)
            .ok_or_else(|| anyhow!("stateful input {name} has no registered shape"))?;
        let full = if *axis == 0 { r } else { c };
        if full == 0 || full % cap != 0 {
            bail!(
                "stateful input {name}: extent {full} does not split into {cap} growth blocks"
            );
        }
        let (rb, cb) = input_block_grid(prepared, name)
            .ok_or_else(|| anyhow!("stateful input {name} has no block grid"))?;
        let blocks = if *axis == 0 { (1, cb) } else { (rb, 1) };
        state.insert(
            name.clone(),
            StateAppend {
                axis: *axis,
                unit: full / cap,
                blocks,
            },
        );
    }
    let mut scaled = BTreeMap::new();
    for (name, axis) in input_dim_axes(prepared, &growth) {
        if state.contains_key(&name) {
            continue;
        }
        let &(r, c) = full_shapes
            .get(&name)
            .ok_or_else(|| anyhow!("growth-scaled input {name} has no registered shape"))?;
        let full = if axis == 0 { r } else { c };
        if full == 0 || full % cap != 0 {
            bail!(
                "growth-scaled input {name}: extent {full} does not split into {cap} growth \
                 blocks"
            );
        }
        scaled.insert(name, (axis, full / cap));
    }
    Ok(Some(StateMeta {
        growth,
        cap,
        state,
        scaled,
    }))
}

/// The compile-once model server (see module docs).
pub struct ModelServer {
    cfg: ServerConfig,
    programs: BTreeMap<String, Served>,
    /// Registration order — the round-robin schedule for mixed traffic.
    order: Vec<String>,
    /// Next round-robin offset into `order`.
    rr: usize,
    /// Skeleton cache shared across all registered workloads (and with
    /// [`ModelServer::tune`]'s measured trials).
    cache: TapeCache,
    next_id: u64,
    stats: ServerStats,
    /// Set by [`ModelServer::begin_shutdown`]: new submissions are
    /// rejected ([`Rejected::Shutdown`]) while queued work still drains.
    shutting_down: bool,
    /// Responses produced outside a batch flush (admission rejections,
    /// shed evictions) — handed out at the next [`ModelServer::poll`] /
    /// [`ModelServer::drain`] so every admitted id yields exactly one
    /// response through the same channel.
    deferred: Vec<Response>,
    /// Open decode sessions ([`ModelServer::open_session`]), keyed by
    /// session id — a namespace separate from request ids.
    sessions: HashMap<u64, Session>,
    next_session_id: u64,
    /// Stacked binds for decode groups, keyed by (pinned plan pointer,
    /// total stack trip, cache length). Decode binds override the
    /// growth dim to the group's cache length, so they cannot share
    /// [`Served::stacked`] (keyed by total trip alone), and they must
    /// survive hot-swaps (sessions pin plans that outlive the live
    /// one). Each entry keeps its plan's `Arc` alive, so a key's
    /// pointer can never be reused by a different plan while the entry
    /// exists.
    decode_binds: HashMap<(usize, usize, usize), (Arc<PreparedPlan>, Arc<StackedPlan>)>,
}

impl ModelServer {
    pub fn new(cfg: ServerConfig) -> ModelServer {
        ModelServer {
            cfg: cfg.normalized(),
            programs: BTreeMap::new(),
            order: Vec::new(),
            rr: 0,
            cache: TapeCache::new(),
            next_id: 0,
            stats: ServerStats {
                per_program: BTreeMap::new(),
                started: Instant::now(),
            },
            shutting_down: false,
            deferred: Vec::new(),
            sessions: HashMap::new(),
            next_session_id: 0,
            decode_binds: HashMap::new(),
        }
    }

    /// Register one of the canonical demo workloads
    /// ([`crate::coordinator::workloads`]) by CLI name — compiling and
    /// preparing its plan exactly once.
    pub fn register(&mut self, name: &str) -> anyhow::Result<()> {
        let (program, cfg, params, _inputs) = workloads::by_name(name, 0).ok_or_else(|| {
            anyhow!("unknown workload {name}; have {}", workloads::NAMES.join(", "))
        })?;
        self.register_program(name, &program, cfg, params)
    }

    /// Register an arbitrary array program under `name`: runs the full
    /// compilation pipeline once, then lowers and binds every plan
    /// segment once. All subsequent requests reuse that work.
    pub fn register_program(
        &mut self,
        name: &str,
        program: &ArrayProgram,
        cfg: CompileConfig,
        params: BTreeMap<String, f32>,
    ) -> anyhow::Result<()> {
        if self.programs.contains_key(name) {
            bail!("workload {name} already registered");
        }
        let full_shapes = cfg.full_shapes.clone();
        let model = cfg.model;
        let sizes = cfg.sizes.clone();
        let compiled = compile(program, cfg);
        let prepared = prepare_plan(
            &compiled.plan,
            &sizes,
            &params,
            self.cfg.backend,
            &mut self.cache,
        );
        let stack = plan_stack_info(&prepared);
        let shared_inputs = stack
            .as_ref()
            .map(|info| unstacked_inputs(&prepared, info))
            .unwrap_or_default();
        let stack_axes = stack
            .as_ref()
            .map(|info| stacked_input_axes(&prepared, info))
            .unwrap_or_default();
        let state = state_meta(&prepared, stack.as_ref(), &full_shapes)?;
        let st = self.stats.per_program.entry(name.to_string()).or_default();
        st.compiles += 1;
        st.binds += prepared.binds;
        self.programs.insert(
            name.to_string(),
            Served {
                prepared: Arc::new(prepared),
                block: compiled.block,
                full_shapes,
                model,
                queues: BTreeMap::new(),
                stack,
                shared_inputs,
                stack_axes,
                state,
                stacked: HashMap::new(),
                weight: 1,
                deficit: 0,
            },
        );
        self.order.push(name.to_string());
        Ok(())
    }

    /// Set `name`'s fair-share weight: per scheduling round it may
    /// flush up to `weight * max_batch` requests before yielding (see
    /// [`ModelServer::sweep_flush`]'s deficit round-robin). All
    /// workloads default to 1 — plain round-robin. A weight of 0 is
    /// rejected: it would mean "never scheduled", which is starvation
    /// by configuration, not fairness.
    pub fn set_weight(&mut self, name: &str, weight: u64) -> anyhow::Result<()> {
        if weight == 0 {
            bail!("weight must be >= 1 (0 would never be scheduled)");
        }
        let served = self
            .programs
            .get_mut(name)
            .ok_or_else(|| anyhow!("unknown workload {name}"))?;
        served.weight = weight;
        Ok(())
    }

    /// The fair-share weight of a registered workload.
    pub fn weight_of(&self, name: &str) -> Option<u64> {
        self.programs.get(name).map(|s| s.weight)
    }

    /// Enqueue a request; returns its id. The request is validated
    /// first (`Err` on violations, which never consume admission
    /// accounting): the workload must be registered and every program
    /// input present. On a stackable plan the stack-dim-carrying
    /// inputs may be **ragged** — any whole-block extent along the
    /// stackable grid dim up to the registered shape — while every
    /// other extent must match registration exactly; the derived trip
    /// picks the request's shape bucket ([`ServerConfig::buckets`]).
    /// Then admission control: a draining server, an already-expired
    /// deadline, or a workload at [`ServerConfig::queue_cap`] sheds it
    /// with a typed [`Verdict::Rejected`] response delivered by the
    /// next [`ModelServer::poll`]/[`ModelServer::drain`]. Admitted or
    /// shed, every `Ok(id)` yields exactly one response.
    pub fn submit(&mut self, req: Request) -> anyhow::Result<u64> {
        let served = self
            .programs
            .get_mut(&req.workload)
            .ok_or_else(|| anyhow!("unknown workload {}", req.workload))?;
        if served.state.is_some() {
            bail!(
                "workload {} is stateful; open a session ({}) and submit decode steps",
                req.workload,
                "ModelServer::open_session"
            );
        }
        let trip = match &served.stack {
            Some(info) => derive_trip(
                &req.workload,
                info,
                &served.stack_axes,
                &served.full_shapes,
                &req.inputs,
            )?,
            None => {
                // non-stackable plans serve exactly one shape
                for (input, &(r, c)) in &served.full_shapes {
                    let m = req.inputs.get(input).ok_or_else(|| {
                        anyhow!("request for {} missing input {input}", req.workload)
                    })?;
                    if (m.rows, m.cols) != (r, c) {
                        bail!(
                            "request for {}: input {input} is {}x{}, registered shape is {r}x{c}",
                            req.workload,
                            m.rows,
                            m.cols
                        );
                    }
                }
                0
            }
        };
        let bucket = served
            .stack
            .as_ref()
            .map(|info| self.cfg.buckets.edge_for(trip, info.trip))
            .unwrap_or(0);
        let id = self.next_id;
        self.next_id += 1;
        let now = Instant::now();
        let st = self
            .stats
            .per_program
            .entry(req.workload.clone())
            .or_default();
        st.submitted += 1;
        if self.shutting_down {
            st.rejected_shutdown += 1;
            self.deferred.push(Response::unserved(
                id,
                &req.workload,
                Verdict::Rejected(Rejected::Shutdown),
                0,
            ));
            return Ok(id);
        }
        let deadline = match req.deadline {
            Some(d) => Some(d),
            None => self.cfg.deadline.and_then(|d| now.checked_add(d)),
        };
        if deadline.is_some_and(|d| d <= now) {
            st.rejected_deadline += 1;
            self.deferred.push(Response::unserved(
                id,
                &req.workload,
                Verdict::Rejected(Rejected::DeadlineExpired),
                0,
            ));
            return Ok(id);
        }
        if let Some(cap) = self.cfg.queue_cap {
            // the cap bounds the whole workload, across its buckets
            if served.queues.values().map(|q| q.len()).sum::<usize>() >= cap {
                st.rejected_full += 1;
                match self.cfg.shed_policy {
                    ShedPolicy::RejectNew => {
                        self.deferred.push(Response::unserved(
                            id,
                            &req.workload,
                            Verdict::Rejected(Rejected::QueueFull),
                            0,
                        ));
                        return Ok(id);
                    }
                    ShedPolicy::DropOldest => {
                        // evict the oldest head across every bucket
                        let oldest = served
                            .queues
                            .iter()
                            .filter_map(|(k, q)| q.front().map(|p| (p.enqueued, *k)))
                            .min()
                            .map(|(_, k)| k);
                        if let Some(evicted) = oldest
                            .and_then(|k| served.queues.get_mut(&k))
                            .and_then(|q| q.pop_front())
                        {
                            self.deferred.push(Response::unserved(
                                evicted.id,
                                &req.workload,
                                Verdict::Rejected(Rejected::QueueFull),
                                now.duration_since(evicted.enqueued).as_nanos(),
                            ));
                        }
                    }
                }
            }
        }
        served.queues.entry(bucket).or_default().push_back(Pending {
            id,
            inputs: req.inputs,
            enqueued: now,
            deadline,
            trip,
            session: None,
            state_len: 0,
            append_mem: MemSim::default(),
        });
        Ok(id)
    }

    /// Open a decode session on a registered **stateful** workload: the
    /// session owns one persistent buffer per stateful input (initially
    /// empty) and pins the live plan — every step of this session
    /// executes that exact plan, even across
    /// [`ModelServer::retune_and_swap`] hot-swaps, which is what keeps
    /// its cache blocking (and its decode-vs-prefill parity) stable for
    /// its whole life. Fails on unknown, stateless, or non-stackable
    /// workloads, and on a draining server.
    pub fn open_session(&mut self, workload: &str) -> anyhow::Result<u64> {
        if self.shutting_down {
            bail!("server is shutting down");
        }
        let served = self
            .programs
            .get(workload)
            .ok_or_else(|| anyhow!("unknown workload {workload}"))?;
        let meta = served
            .state
            .clone()
            .ok_or_else(|| anyhow!("workload {workload} has no stateful inputs"))?;
        let info = served.stack.clone().ok_or_else(|| {
            anyhow!("workload {workload} has no stackable grid dim; sessions need one")
        })?;
        let mut caches = BTreeMap::new();
        for (name, app) in &meta.state {
            let (r, c) = served.full_shapes[name];
            let empty = if app.axis == 0 {
                Mat::zeros(0, c)
            } else {
                Mat::zeros(r, 0)
            };
            caches.insert(name.clone(), empty);
        }
        let id = self.next_session_id;
        self.next_session_id += 1;
        self.sessions.insert(
            id,
            Session {
                workload: workload.to_string(),
                prepared: Arc::clone(&served.prepared),
                info,
                meta,
                caches,
                len: 0,
            },
        );
        let st = self.stats.per_program.entry(workload.to_string()).or_default();
        st.sessions_opened += 1;
        Ok(id)
    }

    /// Close a decode session, dropping its persistent buffers; returns
    /// its final cache length in growth blocks. Steps of the session
    /// still queued fail at launch with a typed [`Verdict::Failed`]
    /// response (their ids still get exactly one response each).
    pub fn close_session(&mut self, id: u64) -> anyhow::Result<usize> {
        self.sessions
            .remove(&id)
            .map(|s| s.len)
            .ok_or_else(|| anyhow!("unknown session {id}"))
    }

    /// Cache length (growth blocks appended so far) of an open session.
    pub fn session_len(&self, id: u64) -> Option<usize> {
        self.sessions.get(&id).map(|s| s.len)
    }

    /// The workload an open session belongs to.
    pub fn session_workload(&self, id: u64) -> Option<&str> {
        self.sessions.get(&id).map(|s| s.workload.as_str())
    }

    /// Read-only view of one of a session's persistent buffers (a test
    /// and debugging hook — the differential suite checks the cache
    /// bytes are exactly the appended stream).
    pub fn session_cache(&self, id: u64, input: &str) -> Option<&Mat> {
        self.sessions.get(&id).and_then(|s| s.caches.get(input))
    }

    /// Enqueue one decode step for an open session; returns its request
    /// id. The step carries the fresh per-step inputs (the query block,
    /// the mask scaled to the **new** cache length) plus exactly one
    /// new block-slab per stateful input — the K/V blocks this step
    /// appends. Validation errors (`Err`) never consume admission
    /// accounting: the session must exist, the cache must have room
    /// (the registered growth extent is the context cap), appends must
    /// be one block-slab each, and every other input must match its
    /// shape class. Past validation this mirrors
    /// [`ModelServer::submit`]'s admission control (shutdown, default
    /// deadline, queue cap) — and only an actually **enqueued** step
    /// appends to the caches: a shed step leaves the session untouched.
    /// Append traffic is charged to the step's own response counters
    /// ([`MemSim::state_appended_bytes`] breaks it out), and the step
    /// queues under its cache-length bucket, where same-length steps of
    /// different sessions coalesce into one stacked launch.
    pub fn submit_decode(
        &mut self,
        session: u64,
        mut inputs: HashMap<String, Mat>,
    ) -> anyhow::Result<u64> {
        let sess = self
            .sessions
            .get(&session)
            .ok_or_else(|| anyhow!("unknown session {session}"))?;
        let workload = sess.workload.clone();
        let trip = sess.info.trip;
        let t_new = sess.len + 1;
        if t_new > sess.meta.cap {
            bail!(
                "session {session}: cache is full ({} of {} growth blocks)",
                sess.len,
                sess.meta.cap
            );
        }
        let served = self
            .programs
            .get_mut(&workload)
            .ok_or_else(|| anyhow!("session {session}: workload {workload} is not registered"))?;
        for (input, &(r, c)) in &served.full_shapes {
            let m = inputs
                .get(input)
                .ok_or_else(|| anyhow!("decode step for {workload} missing input {input}"))?;
            let want = if let Some(app) = sess.meta.state.get(input) {
                // the one-block append slab
                if app.axis == 0 {
                    (app.unit, c)
                } else {
                    (r, app.unit)
                }
            } else if let Some(&(axis, unit)) = sess.meta.scaled.get(input) {
                // scaled to the new cache length
                if axis == 0 {
                    (unit * t_new, c)
                } else {
                    (r, unit * t_new)
                }
            } else {
                (r, c)
            };
            if (m.rows, m.cols) != want {
                bail!(
                    "decode step for {workload}: input {input} is {}x{}, expected {}x{} at \
                     cache length {t_new}",
                    m.rows,
                    m.cols,
                    want.0,
                    want.1
                );
            }
        }
        let bucket = self.cfg.buckets.edge_for(t_new, sess.meta.cap);
        let id = self.next_id;
        self.next_id += 1;
        let now = Instant::now();
        let st = self.stats.per_program.entry(workload.clone()).or_default();
        st.submitted += 1;
        if self.shutting_down {
            st.rejected_shutdown += 1;
            self.deferred.push(Response::unserved(
                id,
                &workload,
                Verdict::Rejected(Rejected::Shutdown),
                0,
            ));
            return Ok(id);
        }
        let deadline = self.cfg.deadline.and_then(|d| now.checked_add(d));
        if deadline.is_some_and(|d| d <= now) {
            st.rejected_deadline += 1;
            self.deferred.push(Response::unserved(
                id,
                &workload,
                Verdict::Rejected(Rejected::DeadlineExpired),
                0,
            ));
            return Ok(id);
        }
        if let Some(cap) = self.cfg.queue_cap {
            if served.queues.values().map(|q| q.len()).sum::<usize>() >= cap {
                st.rejected_full += 1;
                match self.cfg.shed_policy {
                    ShedPolicy::RejectNew => {
                        self.deferred.push(Response::unserved(
                            id,
                            &workload,
                            Verdict::Rejected(Rejected::QueueFull),
                            0,
                        ));
                        return Ok(id);
                    }
                    ShedPolicy::DropOldest => {
                        let oldest = served
                            .queues
                            .iter()
                            .filter_map(|(k, q)| q.front().map(|p| (p.enqueued, *k)))
                            .min()
                            .map(|(_, k)| k);
                        if let Some(evicted) = oldest
                            .and_then(|k| served.queues.get_mut(&k))
                            .and_then(|q| q.pop_front())
                        {
                            self.deferred.push(Response::unserved(
                                evicted.id,
                                &workload,
                                Verdict::Rejected(Rejected::QueueFull),
                                now.duration_since(evicted.enqueued).as_nanos(),
                            ));
                        }
                    }
                }
            }
        }
        // Admission proper: append this step's K/V blocks. From here on
        // the step owns cache position `t_new` — it binds the prefix at
        // its own length, so later appends cannot disturb it.
        let mut append_mem = MemSim::default();
        let sess = self
            .sessions
            .get_mut(&session)
            .ok_or_else(|| anyhow!("unknown session {session}"))?;
        for (name, app) in &sess.meta.state {
            let part = inputs.remove(name).expect("validated above");
            let cache = sess.caches.get_mut(name).expect("one cache per stateful input");
            append_state(cache, app.axis, &part, app.blocks, &mut append_mem);
        }
        sess.len = t_new;
        st.state_appends += append_mem.state_appends;
        st.state_appended_bytes += append_mem.state_appended_bytes;
        served.queues.entry(bucket).or_default().push_back(Pending {
            id,
            inputs,
            enqueued: now,
            deadline,
            trip,
            session: Some(session),
            state_len: t_new,
            append_mem,
        });
        Ok(id)
    }

    /// The synthetic inputs [`Self::submit_synthetic`] generates for
    /// `(workload, seed)` — exposed so callers can reproduce a request
    /// for verification (input names are generated in sorted order, so
    /// the mapping is deterministic).
    ///
    /// Weight-like inputs — those that do not carry the plan's stackable
    /// grid dim — are drawn from a **fixed** per-workload stream instead
    /// of `seed`: synthetic traffic then models a served model (fixed
    /// weights, per-request activations), and any two synthetic requests
    /// of one workload share their weights bit-for-bit, which is exactly
    /// the condition a coalesced batch needs.
    pub fn synthetic_inputs(
        &self,
        workload: &str,
        seed: u64,
    ) -> anyhow::Result<HashMap<String, Mat>> {
        let served = self
            .programs
            .get(workload)
            .ok_or_else(|| anyhow!("unknown workload {workload}"))?;
        let mut names: Vec<&String> = served.full_shapes.keys().collect();
        names.sort();
        let mut rng = Rng::new(seed);
        let mut weight_rng = Rng::new(SYNTHETIC_WEIGHT_SEED);
        Ok(names
            .into_iter()
            .map(|n| {
                let (r, c) = served.full_shapes[n];
                let m = if served.shared_inputs.contains(n) {
                    weight_rng.mat(r, c)
                } else {
                    rng.mat(r, c)
                };
                (n.clone(), m)
            })
            .collect())
    }

    /// Enqueue a request with deterministic random inputs derived from
    /// `seed` at the workload's registered shapes.
    pub fn submit_synthetic(&mut self, workload: &str, seed: u64) -> anyhow::Result<u64> {
        let inputs = self.synthetic_inputs(workload, seed)?;
        self.submit(Request::new(workload, inputs))
    }

    /// Ragged variant of [`ModelServer::synthetic_inputs`]: stack-dim
    /// carrying inputs are generated at `trip` blocks along their stack
    /// axis (`1..=` the registered trip) instead of the full registered
    /// extent; weight-like inputs still come from the fixed per-workload
    /// stream, so ragged synthetic requests coalesce with full-shape
    /// ones. Errors if the workload has no stackable grid dim.
    pub fn synthetic_inputs_ragged(
        &self,
        workload: &str,
        seed: u64,
        trip: usize,
    ) -> anyhow::Result<HashMap<String, Mat>> {
        let served = self
            .programs
            .get(workload)
            .ok_or_else(|| anyhow!("unknown workload {workload}"))?;
        let info = served
            .stack
            .as_ref()
            .ok_or_else(|| anyhow!("workload {workload} has no stackable grid dim"))?;
        if trip < 1 || trip > info.trip {
            bail!(
                "ragged trip {trip} out of range 1..={} for workload {workload}",
                info.trip
            );
        }
        let mut names: Vec<&String> = served.full_shapes.keys().collect();
        names.sort();
        let mut rng = Rng::new(seed);
        let mut weight_rng = Rng::new(SYNTHETIC_WEIGHT_SEED);
        Ok(names
            .into_iter()
            .map(|n| {
                let (r, c) = served.full_shapes[n];
                let m = if served.shared_inputs.contains(n) {
                    weight_rng.mat(r, c)
                } else {
                    match served.stack_axes.get(n) {
                        Some(0) => rng.mat(r / info.trip * trip, c),
                        Some(_) => rng.mat(r, c / info.trip * trip),
                        None => rng.mat(r, c),
                    }
                };
                (n.clone(), m)
            })
            .collect())
    }

    /// Enqueue a ragged synthetic request: `trip` blocks along the
    /// stackable grid dim (see [`ModelServer::synthetic_inputs_ragged`]).
    pub fn submit_synthetic_ragged(
        &mut self,
        workload: &str,
        seed: u64,
        trip: usize,
    ) -> anyhow::Result<u64> {
        let inputs = self.synthetic_inputs_ragged(workload, seed, trip)?;
        self.submit(Request::new(workload, inputs))
    }

    /// The deterministic inputs [`Self::submit_synthetic_decode`]
    /// generates for `(workload, session_seed, step)` — `step` counts
    /// from 1 and becomes the new cache length. Stateful K/V appends
    /// come from a **fixed per-step stream** shared by every session
    /// (the decode analogue of the fixed weight stream): any two
    /// sessions at the same step hold bit-identical caches, which is
    /// exactly the condition a coalesced decode launch needs. The query
    /// comes from `session_seed`, so outputs still differ per session;
    /// the mask ships zeroed at the new length (each step attends the
    /// whole cache, its own block included).
    pub fn synthetic_decode_inputs(
        &self,
        workload: &str,
        session_seed: u64,
        step: usize,
    ) -> anyhow::Result<HashMap<String, Mat>> {
        let served = self
            .programs
            .get(workload)
            .ok_or_else(|| anyhow!("unknown workload {workload}"))?;
        let meta = served
            .state
            .as_ref()
            .ok_or_else(|| anyhow!("workload {workload} has no stateful inputs"))?;
        if step < 1 || step > meta.cap {
            bail!("decode step {step} out of range 1..={} for {workload}", meta.cap);
        }
        Ok(synth_decode_step(&served.full_shapes, meta, session_seed, step))
    }

    /// Enqueue the session's next synthetic decode step (see
    /// [`ModelServer::synthetic_decode_inputs`]). The step index is the
    /// session's own cache length + 1 — a shed step does not advance
    /// it, so a retry regenerates the same step. Geometry comes from
    /// the session's **pinned** plan, so synthetic steps keep flowing
    /// bit-exactly across live hot-swaps.
    pub fn submit_synthetic_decode(
        &mut self,
        session: u64,
        session_seed: u64,
    ) -> anyhow::Result<u64> {
        let sess = self
            .sessions
            .get(&session)
            .ok_or_else(|| anyhow!("unknown session {session}"))?;
        let workload = sess.workload.clone();
        let step = sess.len + 1;
        if step > sess.meta.cap {
            bail!(
                "session {session}: cache is full ({} of {} growth blocks)",
                sess.len,
                sess.meta.cap
            );
        }
        let served = self
            .programs
            .get(&workload)
            .ok_or_else(|| anyhow!("session {session}: workload {workload} is not registered"))?;
        let inputs = synth_decode_step(&served.full_shapes, &sess.meta, session_seed, step);
        self.submit_decode(session, inputs)
    }

    /// Requests currently queued across all workloads (and buckets).
    pub fn pending(&self) -> usize {
        self.programs
            .values()
            .flat_map(|s| s.queues.values())
            .map(|q| q.len())
            .sum()
    }

    /// Whether any of `name`'s bucket queues is due a flush as of
    /// `now`: a bucket holds a full batch ([`ServerConfig::max_batch`]),
    /// its oldest entry has waited past [`ServerConfig::max_wait`] (the
    /// latency bound), or any of its entries' deadlines has expired (so
    /// the shed happens promptly, not at the next unrelated flush).
    fn queue_due(&self, name: &str, now: Instant) -> bool {
        let Some(s) = self.programs.get(name) else {
            return false;
        };
        s.queues.values().any(|q| {
            q.len() >= self.cfg.max_batch
                || q.front()
                    .is_some_and(|p| now.duration_since(p.enqueued) >= self.cfg.max_wait)
                || q.iter().any(|p| p.deadline.is_some_and(|d| d <= now))
        })
    }

    /// The earliest instant at which any bucket queue becomes due — the
    /// daemon's flusher sleeps exactly until this (or until new work
    /// arrives), which is how `max_wait` is honored *without polling*.
    /// `None` means nothing is queued. A bucket already holding a full
    /// batch returns "now". Each bucket ages independently: a lone
    /// ragged straggler in one bucket wakes the flusher at its own
    /// `max_wait`, not when some other bucket happens to fill.
    pub fn next_due(&self) -> Option<Instant> {
        let mut due: Option<Instant> = None;
        let mut fold = |t: Instant| {
            due = Some(match due {
                Some(d) => d.min(t),
                None => t,
            });
        };
        for s in self.programs.values() {
            for q in s.queues.values() {
                if q.len() >= self.cfg.max_batch {
                    fold(Instant::now());
                    continue;
                }
                if let Some(p) = q.front() {
                    fold(p.enqueued + self.cfg.max_wait);
                }
                for p in q {
                    if let Some(d) = p.deadline {
                        fold(d);
                    }
                }
            }
        }
        due
    }

    /// Repeated weighted-fair sweeps (deficit round-robin), until a
    /// full sweep flushes nothing. Each sweep visits every workload in
    /// registration order starting at the rotating cursor; an eligible
    /// workload banks `weight * max_batch` request units of credit
    /// (capped at twice that, so credit cannot accumulate without
    /// bound) and flushes batches until the credit is spent or nothing
    /// eligible remains. With all weights at 1 and full batches this
    /// degenerates to the previous plain round-robin — one batch per
    /// workload per sweep — while weights let a hot workload take a
    /// proportionally larger (but still *bounded*) share of each round:
    /// the starvation bound is that between two turns of any workload,
    /// every other workload flushes at most `2 * weight * max_batch`
    /// requests.
    ///
    /// Terminates: every sweep that continues flushed at least one
    /// response, queues only shrink, and the eligibility predicates
    /// only shrink as queues drain.
    fn sweep_flush(&mut self, eligible: impl Fn(&ModelServer, &str) -> bool) -> Vec<Response> {
        let mut out = Vec::new();
        let n = self.order.len();
        if n == 0 {
            return out;
        }
        let unit = self.cfg.max_batch as u64;
        loop {
            let mut any = false;
            for k in 0..n {
                let name = self.order[(self.rr + k) % n].clone();
                if !eligible(self, &name) {
                    // No credit hoarding while idle (see `Served::deficit`).
                    if let Some(s) = self.programs.get_mut(&name) {
                        s.deficit = 0;
                    }
                    continue;
                }
                let (weight, banked) = {
                    let s = &self.programs[&name];
                    (s.weight, s.deficit)
                };
                let quantum = weight.saturating_mul(unit);
                let mut deficit = banked.saturating_add(quantum).min(quantum.saturating_mul(2));
                while deficit > 0 && eligible(self, &name) {
                    let flushed = self.flush_one(&name);
                    if flushed.is_empty() {
                        break;
                    }
                    // Only responses that occupied a launch slot debit
                    // the deficit. Deadline-shed rejections never
                    // executed — debiting them (the old `flushed.len()`)
                    // charged a workload for work it didn't get,
                    // shrinking its fair share below its weight
                    // whenever its queue carried expired entries.
                    let occupied = flushed
                        .iter()
                        .filter(|r| !matches!(r.verdict, Verdict::Rejected(_)))
                        .count() as u64;
                    deficit = deficit.saturating_sub(occupied);
                    out.extend(flushed);
                    any = true;
                }
                if let Some(s) = self.programs.get_mut(&name) {
                    s.deficit = deficit;
                }
            }
            self.rr = (self.rr + 1) % n;
            if !any {
                return out;
            }
        }
    }

    /// Flush every workload whose queue is due — full
    /// ([`ServerConfig::max_batch`]) or latency-bound (oldest entry
    /// older than [`ServerConfig::max_wait`]) — in round-robin sweeps
    /// that repeat **while anything stays due**: a burst that queued
    /// several `max_batch` fulls drains in this one poll (instead of
    /// leaking backlog at one batch per poll), and a latency-due
    /// partial remainder flushes here too rather than aging another
    /// poll cycle.
    /// Returns the responses of every batch launched plus any pending
    /// admission-control rejections; an empty vec means nothing was due.
    ///
    /// Due-ness is re-evaluated **per eligibility check**, not once per
    /// poll: an entry that crosses `max_wait` (or its deadline) while a
    /// long burst drains earlier in the same poll is flushed by this
    /// poll, not parked until the next wakeup — which matters to the
    /// daemon, whose flusher would otherwise sleep until the *next*
    /// queue event while an already-due request sat queued.
    pub fn poll(&mut self) -> Vec<Response> {
        let mut out = std::mem::take(&mut self.deferred);
        out.extend(self.sweep_flush(|s, name| s.queue_due(name, Instant::now())));
        out
    }

    /// Flush until every queue is empty, taking at most `max_batch`
    /// requests per workload per round-robin turn (so mixed traffic
    /// interleaves instead of one workload draining first). Pending
    /// admission-control rejections are delivered too.
    pub fn drain(&mut self) -> Vec<Response> {
        let mut out = std::mem::take(&mut self.deferred);
        out.extend(self.sweep_flush(|s, name| {
            s.programs
                .get(name)
                .is_some_and(|p| p.queues.values().any(|q| !q.is_empty()))
        }));
        out
    }

    /// Stop admitting: every later [`ModelServer::submit`] is shed with
    /// [`Rejected::Shutdown`]; queued work still flushes via
    /// [`ModelServer::poll`]/[`ModelServer::drain`]. The daemon calls
    /// this at the head of its graceful drain.
    pub fn begin_shutdown(&mut self) {
        self.shutting_down = true;
    }

    /// Whether [`ModelServer::begin_shutdown`] has been called.
    pub fn is_shutting_down(&self) -> bool {
        self.shutting_down
    }

    /// Take up to `max_batch` queued requests of one of `name`'s
    /// bucket queues and launch them as one batch, first shedding
    /// queued entries whose deadline expired (each gets a
    /// [`Rejected::DeadlineExpired`] response — expired work must not
    /// burn a launch slot). Bucket choice: the due bucket (full,
    /// latency-bound) with the oldest head; if none is due (the drain
    /// path), the oldest head overall. A flush takes whatever its
    /// bucket holds *now* — requests admitted after the previous
    /// launch ride this one (continuous batching).
    ///
    /// The expiry shed is a single retain-style pass per bucket: the
    /// old `VecDeque::remove(i)` loop shifted the queue's tail on
    /// every expired hit — O(n²) on a deeply-expired queue, which is
    /// exactly the queue a deadline storm produces.
    fn flush_one(&mut self, name: &str) -> Vec<Response> {
        let now = Instant::now();
        let mut out = Vec::new();
        let batch: Vec<Pending> = {
            let Some(served) = self.programs.get_mut(name) else {
                // Unregistered mid-flush is unreachable today; degrade to
                // a no-op instead of the old `.expect` panic.
                return out;
            };
            for q in served.queues.values_mut() {
                if !q.iter().any(|p| p.deadline.is_some_and(|d| d <= now)) {
                    continue;
                }
                let mut kept = VecDeque::with_capacity(q.len());
                for p in q.drain(..) {
                    if p.deadline.is_some_and(|d| d <= now) {
                        out.push(Response::unserved(
                            p.id,
                            name,
                            Verdict::Rejected(Rejected::DeadlineExpired),
                            now.duration_since(p.enqueued).as_nanos(),
                        ));
                    } else {
                        kept.push_back(p);
                    }
                }
                *q = kept;
            }
            served.queues.retain(|_, q| !q.is_empty());
            let due_key = |q: &VecDeque<Pending>| {
                q.len() >= self.cfg.max_batch
                    || q.front()
                        .is_some_and(|p| now.duration_since(p.enqueued) >= self.cfg.max_wait)
            };
            let pick = served
                .queues
                .iter()
                .filter(|(_, q)| due_key(q))
                .filter_map(|(k, q)| q.front().map(|p| (p.enqueued, *k)))
                .min()
                .or_else(|| {
                    served
                        .queues
                        .iter()
                        .filter_map(|(k, q)| q.front().map(|p| (p.enqueued, *k)))
                        .min()
                })
                .map(|(_, k)| k);
            match pick.and_then(|k| served.queues.get_mut(&k)) {
                Some(q) => {
                    let take = q.len().min(self.cfg.max_batch);
                    q.drain(..take).collect()
                }
                None => Vec::new(),
            }
        };
        if !out.is_empty() {
            let st = self.stats.per_program.entry(name.to_string()).or_default();
            st.shed_deadline += out.len() as u64;
        }
        if !batch.is_empty() {
            out.extend(self.run_batch(name, batch));
        }
        out
    }

    /// Execute one batch. With coalescing on and an eligible batch
    /// (stackable plan, ≥2 requests, shared weights bit-identical) the
    /// whole batch becomes **one stacked tape launch** across the full
    /// worker budget
    /// ([`crate::coordinator::execute_prepared_stacked_spec`]): each
    /// request rides at its own trip, padded to its bucket edge when
    /// padding is on, and per-segment launch overhead is paid once
    /// instead of once per request. Stacked binds are cached by *total*
    /// trip, so any mix of trips landing on the same total reuses one
    /// bind. Otherwise the batch fans out as one pool submission whose
    /// tasks each run one request's plan — ragged requests via a
    /// single-request stacked bind (the registered-shape plan cannot
    /// execute them), full-shape requests via the plain prepared plan.
    /// With one request (or a worker cap of 1) the fan-out runs inline
    /// on the caller — the exact serial path.
    ///
    /// **Panic isolation.** Every launch body runs under `catch_unwind`:
    /// a panic (real or injected via [`crate::util::fault`]) poisons
    /// only its own scope — the whole batch on the stacked path (one
    /// launch serves everyone), the one task's request on the fan-out
    /// path — and each poisoned request gets a [`Verdict::Failed`]
    /// response carrying the panic message. The server itself never
    /// unwinds.
    fn run_batch(&mut self, name: &str, batch: Vec<Pending>) -> Vec<Response> {
        let bs = batch.len();
        if bs == 0 {
            return Vec::new();
        }
        if batch.iter().any(|p| p.session.is_some()) {
            // Stateful workloads admit only session steps, so a batch
            // holding one holds nothing else.
            return self.run_decode_batch(name, batch);
        }
        let threads = self.cfg.threads;
        let workers = effective_workers(threads, bs);
        let Some(served) = self.programs.get_mut(name) else {
            // Unregistered mid-batch is unreachable today; degrade to
            // error responses instead of the old `.expect` panic.
            let st = self.stats.per_program.entry(name.to_string()).or_default();
            st.failed += bs as u64;
            return batch
                .into_iter()
                .map(|p| {
                    Response::unserved(
                        p.id,
                        name,
                        Verdict::Failed(format!("workload {name} is not registered")),
                        0,
                    )
                })
                .collect();
        };
        // `stack_info.is_some()` replaces the old boolean + `.expect`
        // pair: eligibility and the info travel together.
        let stack_info = if self.cfg.coalesce
            && bs >= 2
            && shared_inputs_identical(&served.shared_inputs, &batch)
        {
            served.stack.clone()
        } else {
            None
        };
        // The batch holds its own plan handle: a concurrent-looking
        // `adopt_sizes` (between batches) swaps `served.prepared`
        // without touching this launch.
        let prepared = Arc::clone(&served.prepared);
        let mut new_binds = 0u64;
        let outcome = if let Some(info) = stack_info {
            // Ragged-aware stack spec: each request at its own trip
            // (all from one bucket, but trips may differ within it),
            // padded to its bucket edge when padding is on. Binds are
            // cached by total trip, so uniform and ragged batches that
            // land on the same total share one bind.
            let spec = StackSpec {
                trips: batch.iter().map(|p| p.trip).collect(),
                pads: if self.cfg.pad {
                    batch
                        .iter()
                        .map(|p| self.cfg.buckets.edge_for(p.trip, info.trip) - p.trip)
                        .collect()
                } else {
                    vec![0; bs]
                },
            };
            let total = spec.total_trip();
            let stacked = match served.stacked.get(&total) {
                Some(sp) => Arc::clone(sp),
                None => {
                    let sp = Arc::new(bind_stacked_trip(&prepared, &info, total));
                    new_binds = sp.binds;
                    served.stacked.insert(total, Arc::clone(&sp));
                    sp
                }
            };
            let input_refs: Vec<&HashMap<String, Mat>> = batch.iter().map(|p| &p.inputs).collect();
            let t0 = Instant::now();
            let run = catch_unwind(AssertUnwindSafe(|| {
                if fault::injected(fault::Site::Compute) {
                    panic!("injected compute fault (stacked batch)");
                }
                execute_prepared_stacked_spec(&prepared, &stacked, &spec, &input_refs, threads)
            }));
            let t1 = Instant::now();
            match run {
                Ok(br) => Flushed {
                    launches: br.agg.kernel_launches,
                    padded: (
                        br.agg.padded_loaded_bytes,
                        br.agg.padded_stored_bytes,
                        br.agg.padded_flops,
                    ),
                    results: br.runs.into_iter().map(Ok).collect(),
                    coalesced: true,
                    contained: 0,
                    launched: t0,
                    finished: t1,
                },
                Err(p) => {
                    // One launch served the whole batch, so one panic
                    // poisons every request in it.
                    let msg = panic_message(p);
                    Flushed {
                        launches: 0,
                        padded: (0, 0, 0),
                        results: (0..bs).map(|_| Err(msg.clone())).collect(),
                        coalesced: false,
                        contained: 1,
                        launched: t0,
                        finished: t1,
                    }
                }
            }
        } else {
            // Fan-out. Full-shape requests run the plain prepared plan;
            // a ragged request rides a single-request stacked bind at
            // its own trip (padded to its bucket edge when padding is
            // on) — the registered-shape bind cannot execute it. Binds
            // happen here, serially, so pool tasks only read.
            let info_opt = served.stack.clone();
            let mut singles: HashMap<usize, (Arc<StackedPlan>, StackSpec)> = HashMap::new();
            if let Some(info) = &info_opt {
                for p in &batch {
                    if p.trip != info.trip && !singles.contains_key(&p.trip) {
                        let pad = if self.cfg.pad {
                            self.cfg.buckets.edge_for(p.trip, info.trip) - p.trip
                        } else {
                            0
                        };
                        let spec = StackSpec {
                            trips: vec![p.trip],
                            pads: vec![pad],
                        };
                        let total = spec.total_trip();
                        let sp = match served.stacked.get(&total) {
                            Some(sp) => Arc::clone(sp),
                            None => {
                                let sp = Arc::new(bind_stacked_trip(&prepared, info, total));
                                new_binds += sp.binds;
                                served.stacked.insert(total, Arc::clone(&sp));
                                sp
                            }
                        };
                        singles.insert(p.trip, (sp, spec));
                    }
                }
            }
            // Each task result carries its pad waste (zero on the plain
            // path) alongside the request's own parity-contract run.
            type TaskResult = Result<(PlanRun, (u64, u64, u64)), String>;
            let exec_one = |p: &Pending, threads: Option<usize>| -> TaskResult {
                match singles.get(&p.trip) {
                    Some((sp, spec)) => catch_unwind(AssertUnwindSafe(|| {
                        if fault::injected(fault::Site::Compute) {
                            panic!("injected compute fault");
                        }
                        let mut br = execute_prepared_stacked_spec(
                            &prepared,
                            sp,
                            spec,
                            &[&p.inputs],
                            threads,
                        );
                        let waste = (
                            br.agg.padded_loaded_bytes,
                            br.agg.padded_stored_bytes,
                            br.agg.padded_flops,
                        );
                        (br.runs.remove(0), waste)
                    }))
                    .map_err(panic_message),
                    None => execute_guarded(&prepared, &p.inputs, threads).map(|r| (r, (0, 0, 0))),
                }
            };
            let t0 = Instant::now();
            let results: Vec<TaskResult> = if workers <= 1 || bs == 1 {
                // Serial path: intra-request grid parallelism still
                // applies under the caller's thread budget.
                batch.iter().map(|p| exec_one(p, threads)).collect()
            } else {
                // One heterogeneous pool job for the whole batch. Each
                // task runs its request serially (threads=1): the batch
                // itself is the parallelism, and nested fan-out from
                // inside a pool worker would run inline anyway. Task
                // bodies guard themselves, so a panicking request fails
                // alone; the outer guard and the poison-recovering slot
                // locks are defense in depth against pool internals.
                let slots: Vec<Mutex<Option<TaskResult>>> =
                    (0..bs).map(|_| Mutex::new(None)).collect();
                let submit = catch_unwind(AssertUnwindSafe(|| {
                    pool::global().run_tasks(workers, bs, &|t| {
                        let run = exec_one(&batch[t], Some(1));
                        *slots[t].lock().unwrap_or_else(|e| e.into_inner()) = Some(run);
                    });
                }));
                let submit_err = submit.err().map(panic_message);
                slots
                    .into_iter()
                    .map(|s| {
                        s.into_inner()
                            .unwrap_or_else(|e| e.into_inner())
                            .unwrap_or_else(|| {
                                Err(submit_err
                                    .clone()
                                    .unwrap_or_else(|| "batch task did not run".to_string()))
                            })
                    })
                    .collect()
            };
            let launches = results
                .iter()
                .filter_map(|r| r.as_ref().ok().map(|(x, _)| x.mem.kernel_launches))
                .sum();
            let padded = results
                .iter()
                .filter_map(|r| r.as_ref().ok())
                .fold((0u64, 0u64, 0u64), |acc, (_, w)| {
                    (acc.0 + w.0, acc.1 + w.1, acc.2 + w.2)
                });
            let contained = results.iter().filter(|r| r.is_err()).count() as u64;
            Flushed {
                launches,
                padded,
                results: results.into_iter().map(|r| r.map(|(run, _)| run)).collect(),
                coalesced: false,
                contained,
                launched: t0,
                finished: Instant::now(),
            }
        };
        let exec_ns = outcome.finished.duration_since(outcome.launched).as_nanos();

        let ok = outcome.results.iter().filter(|r| r.is_ok()).count() as u64;
        let st = self.stats.per_program.entry(name.to_string()).or_default();
        st.served += ok;
        st.failed += bs as u64 - ok;
        st.panics += outcome.contained;
        st.batches += 1;
        st.peak_batch = st.peak_batch.max(bs);
        st.launches += outcome.launches;
        st.binds += new_binds;
        st.padded_loaded_bytes += outcome.padded.0;
        st.padded_stored_bytes += outcome.padded.1;
        st.padded_flops += outcome.padded.2;
        if outcome.coalesced {
            st.coalesced += bs as u64;
            st.stacked_batches += 1;
        }
        let mut out = Vec::with_capacity(bs);
        for (p, run) in batch.into_iter().zip(outcome.results) {
            let queue_ns = outcome.launched.duration_since(p.enqueued).as_nanos();
            match run {
                Ok(run) => {
                    st.record_latency(outcome.finished.duration_since(p.enqueued).as_nanos());
                    out.push(Response {
                        id: p.id,
                        workload: name.to_string(),
                        outputs: run.outputs,
                        mem: run.mem,
                        batch_size: bs,
                        coalesced: outcome.coalesced,
                        queue_ns,
                        exec_ns,
                        verdict: Verdict::Ok,
                    });
                }
                Err(msg) => out.push(Response {
                    id: p.id,
                    workload: name.to_string(),
                    outputs: HashMap::new(),
                    mem: MemSim::default(),
                    batch_size: bs,
                    coalesced: false,
                    queue_ns,
                    exec_ns,
                    verdict: Verdict::Failed(msg),
                }),
            }
        }
        out
    }

    /// Execute one batch of decode steps. Steps are grouped by (pinned
    /// plan, cache length, bit-identical cache prefixes); with
    /// coalescing on each group becomes **one stacked launch** — decode
    /// singles stack along the plan's grid dim exactly like prefill
    /// requests, with the growth dim re-bound to the group's cache
    /// length — else every step launches alone. Each step's response
    /// carries the stateless parity counters for its cache length
    /// *plus* its own admission-time append traffic (broken out in
    /// [`MemSim::state_appended_bytes`]); panic isolation matches
    /// [`ModelServer::run_batch`]'s stacked path (one contained panic
    /// poisons its group only).
    fn run_decode_batch(&mut self, name: &str, batch: Vec<Pending>) -> Vec<Response> {
        struct Group {
            prepared: Arc<PreparedPlan>,
            info: StackInfo,
            growth: Dim,
            t: usize,
            /// The group's cache view: one prefix matrix per stateful
            /// input, sliced at `t` — bound as shared extra inputs.
            extra: HashMap<String, Mat>,
            members: Vec<Pending>,
        }
        let threads = self.cfg.threads;
        let now = Instant::now();
        let mut out = Vec::new();
        let mut groups: Vec<Group> = Vec::new();
        for p in batch {
            let sid = match p.session {
                Some(sid) => sid,
                None => {
                    let st = self.stats.per_program.entry(name.to_string()).or_default();
                    st.failed += 1;
                    out.push(Response::unserved(
                        p.id,
                        name,
                        Verdict::Failed(format!(
                            "stateless request batched with decode steps of {name}"
                        )),
                        now.duration_since(p.enqueued).as_nanos(),
                    ));
                    continue;
                }
            };
            let Some(sess) = self.sessions.get(&sid) else {
                let st = self.stats.per_program.entry(name.to_string()).or_default();
                st.failed += 1;
                out.push(Response::unserved(
                    p.id,
                    name,
                    Verdict::Failed(format!("session {sid} closed with steps still queued")),
                    now.duration_since(p.enqueued).as_nanos(),
                ));
                continue;
            };
            let t = p.state_len;
            let mut extra = HashMap::new();
            for (iname, app) in &sess.meta.state {
                let cache = &sess.caches[iname];
                let m = if app.axis == 0 {
                    cache.slice(0, 0, app.unit * t, cache.cols)
                } else {
                    cache.slice(0, 0, cache.rows, app.unit * t)
                };
                extra.insert(iname.clone(), m);
            }
            let ptr = Arc::as_ptr(&sess.prepared) as usize;
            let slot = groups.iter_mut().find(|g| {
                Arc::as_ptr(&g.prepared) as usize == ptr
                    && g.t == t
                    && caches_identical(&g.extra, &extra)
            });
            match slot {
                Some(g) => g.members.push(p),
                None => groups.push(Group {
                    prepared: Arc::clone(&sess.prepared),
                    info: sess.info.clone(),
                    growth: sess.meta.growth.clone(),
                    t,
                    extra,
                    members: vec![p],
                }),
            }
        }
        for group in groups {
            let Group {
                prepared,
                info,
                growth,
                t,
                extra,
                members,
            } = group;
            // With coalescing off every step launches alone (the
            // stacked machinery still runs it — a batch of one — since
            // only a stacked bind can override the growth dim to `t`).
            let subgroups: Vec<Vec<Pending>> = if self.cfg.coalesce {
                vec![members]
            } else {
                members.into_iter().map(|p| vec![p]).collect()
            };
            for members in subgroups {
                let bs = members.len();
                let spec = StackSpec {
                    trips: vec![info.trip; bs],
                    pads: vec![0; bs],
                };
                let total = spec.total_trip();
                let key = (Arc::as_ptr(&prepared) as usize, total, t);
                let mut new_binds = 0u64;
                let stacked = match self.decode_binds.get(&key) {
                    Some((_, sp)) => Arc::clone(sp),
                    None => {
                        let sp = Arc::new(bind_stacked_sized(
                            &prepared,
                            &info,
                            total,
                            &[(growth.clone(), t)],
                        ));
                        new_binds = sp.binds;
                        self.decode_binds
                            .insert(key, (Arc::clone(&prepared), Arc::clone(&sp)));
                        sp
                    }
                };
                let input_refs: Vec<&HashMap<String, Mat>> =
                    members.iter().map(|p| &p.inputs).collect();
                let t0 = Instant::now();
                let run = catch_unwind(AssertUnwindSafe(|| {
                    if fault::injected(fault::Site::Compute) {
                        panic!("injected compute fault (decode batch)");
                    }
                    execute_prepared_stacked_extra(
                        &prepared,
                        &stacked,
                        &spec,
                        &input_refs,
                        &extra,
                        threads,
                    )
                }));
                let t1 = Instant::now();
                let exec_ns = t1.duration_since(t0).as_nanos();
                let coalesced = self.cfg.coalesce && bs >= 2;
                let st = self.stats.per_program.entry(name.to_string()).or_default();
                st.binds += new_binds;
                st.batches += 1;
                st.peak_batch = st.peak_batch.max(bs);
                match run {
                    Ok(br) => {
                        st.served += bs as u64;
                        st.decode_steps += bs as u64;
                        st.launches += br.agg.kernel_launches;
                        if coalesced {
                            st.coalesced += bs as u64;
                            st.stacked_batches += 1;
                        }
                        for (p, run) in members.into_iter().zip(br.runs) {
                            let mut mem = run.mem;
                            mem.add_counters(&p.append_mem);
                            st.record_latency(t1.duration_since(p.enqueued).as_nanos());
                            out.push(Response {
                                id: p.id,
                                workload: name.to_string(),
                                outputs: run.outputs,
                                mem,
                                batch_size: bs,
                                coalesced,
                                queue_ns: t0.duration_since(p.enqueued).as_nanos(),
                                exec_ns,
                                verdict: Verdict::Ok,
                            });
                        }
                    }
                    Err(payload) => {
                        let msg = panic_message(payload);
                        st.failed += bs as u64;
                        st.panics += 1;
                        for p in members {
                            out.push(Response {
                                id: p.id,
                                workload: name.to_string(),
                                outputs: HashMap::new(),
                                mem: MemSim::default(),
                                batch_size: bs,
                                coalesced: false,
                                queue_ns: t0.duration_since(p.enqueued).as_nanos(),
                                exec_ns,
                                verdict: Verdict::Failed(msg.clone()),
                            });
                        }
                    }
                }
            }
        }
        out
    }

    /// Measured block-shape autotuning for a registered workload,
    /// sharing the server's skeleton cache (so trials re-bind the same
    /// skeletons serving uses instead of recompiling). Returns the
    /// candidates best-first by measured wall-clock; the server keeps
    /// serving at its registered sizes — [`ModelServer::adopt_sizes`]
    /// (or [`ModelServer::retune_and_swap`]) hot-swaps a winner in.
    pub fn tune(
        &mut self,
        name: &str,
        local_capacity: u64,
        trials: usize,
        seed: u64,
    ) -> anyhow::Result<Vec<MeasuredPoint>> {
        let inputs = self.synthetic_inputs(name, seed)?;
        let served = self
            .programs
            .get(name)
            .ok_or_else(|| anyhow!("unknown workload {name}"))?;
        let fused = fuse(served.block.clone())
            .snapshots
            .pop()
            .ok_or_else(|| anyhow!("fusion produced no snapshots for {name}"))?;
        Ok(autotune_measured_cached(
            &fused,
            &served.full_shapes,
            local_capacity,
            &served.model,
            &served.prepared.params,
            &inputs,
            self.cfg.backend,
            trials,
            self.cfg.threads,
            &mut self.cache,
        ))
    }

    /// Re-select and re-prepare `name`'s plan at new block `sizes`, then
    /// hot-swap it in via an atomic `Arc` swap. Queued requests and the
    /// next batch pick up the new plan; a batch already holding its
    /// handle (none can be, on this single serving thread, but the
    /// daemon's flusher calls this *between* batches regardless) keeps
    /// the old one until it finishes. Stacked re-binds are invalidated
    /// (they bound the old plan's skeletons); the shared skeleton cache
    /// makes the re-prepare cheap when the new structure has been seen.
    pub fn adopt_sizes(&mut self, name: &str, sizes: &DimSizes) -> anyhow::Result<()> {
        let (plan, params) = {
            let served = self
                .programs
                .get(name)
                .ok_or_else(|| anyhow!("unknown workload {name}"))?;
            let ctx = SelectCtx {
                sizes: sizes.clone(),
                full_shapes: served.full_shapes.clone(),
                model: served.model,
            };
            (select(&served.block, &ctx), served.prepared.params.clone())
        };
        let prepared = prepare_plan(&plan, sizes, &params, self.cfg.backend, &mut self.cache);
        let stack = plan_stack_info(&prepared);
        let shared_inputs = stack
            .as_ref()
            .map(|info| unstacked_inputs(&prepared, info))
            .unwrap_or_default();
        let stack_axes = stack
            .as_ref()
            .map(|info| stacked_input_axes(&prepared, info))
            .unwrap_or_default();
        let binds = prepared.binds;
        let Some(served) = self.programs.get_mut(name) else {
            bail!("workload {name} disappeared during adopt_sizes");
        };
        let state = state_meta(&prepared, stack.as_ref(), &served.full_shapes)?;
        served.prepared = Arc::new(prepared);
        served.stack = stack;
        served.shared_inputs = shared_inputs;
        served.stack_axes = stack_axes;
        served.state = state;
        served.stacked.clear();
        // Re-bucket queued requests against the new plan: bucket edges
        // are keyed by the plan's registered trip, so both the edges
        // and each entry's derived trip can shift under a swap. An
        // entry whose extents no longer divide the new plan's stack
        // unit cannot execute; it fails out here (as a deferred
        // response) so the submitted/accounted ledger stays exact.
        let queued: Vec<Pending> = served
            .queues
            .values_mut()
            .flat_map(|q| q.drain(..))
            .collect();
        served.queues.clear();
        let mut dropped: Vec<(Pending, String)> = Vec::new();
        for p in queued {
            if let Some(sid) = p.session {
                // A session step executes its *pinned* plan — the swap
                // does not touch it. Re-bucket by cache length against
                // the pinned capacity; a closed session's straggler
                // keeps its old bucket and fails typed at launch.
                let cap = self
                    .sessions
                    .get(&sid)
                    .map(|s| s.meta.cap)
                    .unwrap_or(p.state_len);
                let bucket = self.cfg.buckets.edge_for(p.state_len, cap);
                served.queues.entry(bucket).or_default().push_back(p);
                continue;
            }
            match &served.stack {
                Some(info) => match derive_trip(
                    name,
                    info,
                    &served.stack_axes,
                    &served.full_shapes,
                    &p.inputs,
                ) {
                    Ok(trip) => {
                        let bucket = self.cfg.buckets.edge_for(trip, info.trip);
                        served
                            .queues
                            .entry(bucket)
                            .or_default()
                            .push_back(Pending { trip, ..p });
                    }
                    Err(e) => dropped.push((p, e.to_string())),
                },
                None => {
                    let full = served.full_shapes.iter().all(|(input, &(r, c))| {
                        p.inputs
                            .get(input)
                            .is_some_and(|m| (m.rows, m.cols) == (r, c))
                    });
                    if full {
                        served
                            .queues
                            .entry(0)
                            .or_default()
                            .push_back(Pending { trip: 0, ..p });
                    } else {
                        dropped.push((
                            p,
                            format!(
                                "plan swap for {name}: queued ragged request no longer \
                                 matches a stackable plan"
                            ),
                        ));
                    }
                }
            }
        }
        let st = self.stats.per_program.entry(name.to_string()).or_default();
        st.binds += binds;
        st.plan_swaps += 1;
        st.failed += dropped.len() as u64;
        let now = Instant::now();
        for (p, msg) in dropped {
            self.deferred.push(Response::unserved(
                p.id,
                name,
                Verdict::Failed(msg),
                now.duration_since(p.enqueued).as_nanos(),
            ));
        }
        Ok(())
    }

    /// Measured re-tune + hot-swap: run [`ModelServer::tune`] and, if
    /// the measured winner's sizes differ from the live plan's, adopt
    /// them via [`ModelServer::adopt_sizes`]. Returns the adopted sizes,
    /// or `None` if the live plan already wins (or tuning produced no
    /// candidates). The daemon's flusher calls this between batches
    /// under live traffic (`--retune-every`).
    pub fn retune_and_swap(
        &mut self,
        name: &str,
        local_capacity: u64,
        trials: usize,
        seed: u64,
    ) -> anyhow::Result<Option<DimSizes>> {
        let points = self.tune(name, local_capacity, trials, seed)?;
        let Some(best) = points.first() else {
            return Ok(None);
        };
        let best_sizes = best.sizes.clone();
        let current = self
            .programs
            .get(name)
            .ok_or_else(|| anyhow!("unknown workload {name}"))?
            .prepared
            .sizes
            .clone();
        if best_sizes == current {
            return Ok(None);
        }
        self.adopt_sizes(name, &best_sizes)?;
        Ok(Some(best_sizes))
    }

    /// The live plan handle for `name` — the exact plan the next batch
    /// will execute (tests compare hot-swapped serving against direct
    /// [`crate::coordinator::execute_prepared`] runs of this).
    pub fn live_plan(&self, name: &str) -> Option<Arc<PreparedPlan>> {
        self.programs.get(name).map(|s| Arc::clone(&s.prepared))
    }

    pub fn stats(&self) -> &ServerStats {
        &self.stats
    }

    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    /// Registered workload names, in registration (round-robin) order.
    pub fn workloads(&self) -> &[String] {
        &self.order
    }

    /// Skeleton-cache misses so far. Stable across any amount of serving
    /// traffic — recompiles would show up here (see `tests/serve_parity.rs`).
    pub fn cache_misses(&self) -> u64 {
        self.cache.misses
    }

    /// Skeleton-cache hits so far (structure sharing across workloads
    /// and [`Self::tune`] trials).
    pub fn cache_hits(&self) -> u64 {
        self.cache.hits
    }
}

/// What one batch launch produced: per-request results (an `Err` is a
/// contained panic's message), plus the telemetry `run_batch` folds
/// into [`ProgramStats`].
struct Flushed {
    results: Vec<Result<PlanRun, String>>,
    /// Kernel launches actually executed (0 for a poisoned stacked
    /// batch — nothing completed).
    launches: u64,
    /// Pad-row waste this launch charged to the bucket edge, as
    /// `(loaded_bytes, stored_bytes, flops)` — never part of any
    /// request's own counters.
    padded: (u64, u64, u64),
    /// Whether the batch rode one successful stacked launch.
    coalesced: bool,
    /// Panicking launches contained (1 per poisoned stacked batch, 1
    /// per poisoned fan-out task).
    contained: u64,
    launched: Instant,
    finished: Instant,
}

/// Execute one request's plan under a panic guard, with the seeded
/// fault injector's compute site armed in front of it: a panic becomes
/// an `Err` message instead of unwinding the server.
fn execute_guarded(
    prepared: &PreparedPlan,
    inputs: &HashMap<String, Mat>,
    threads: Option<usize>,
) -> Result<PlanRun, String> {
    catch_unwind(AssertUnwindSafe(|| {
        if fault::injected(fault::Site::Compute) {
            panic!("injected compute fault");
        }
        execute_prepared(prepared, inputs, threads)
    }))
    .map_err(panic_message)
}

/// Best-effort extraction of a panic payload's message (`&str` and
/// `String` payloads cover every `panic!` in this crate).
fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    p.downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| p.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "opaque panic payload".to_string())
}

/// Worker budget for a batch of `tasks` requests: the engine's own
/// budget resolution ([`crate::exec::engine::worker_budget`]), further
/// capped by the batch size.
fn effective_workers(threads: Option<usize>, tasks: usize) -> usize {
    crate::exec::engine::worker_budget(threads).min(tasks)
}

/// Seed of the fixed weight stream behind [`ModelServer::synthetic_inputs`]
/// (weight-like inputs are shared across all synthetic requests of a
/// workload; activations vary with the request seed).
const SYNTHETIC_WEIGHT_SEED: u64 = 0x5eed_b10c;

/// Build one synthetic decode step against an explicit growth geometry
/// (a session's pinned one, or the live plan's). Pure in
/// `(full_shapes, meta, session_seed, step)`: K/V appends from a fixed
/// per-step stream (shared across sessions, drawn in sorted input-name
/// order), the mask zeroed at the new length, everything else from the
/// session stream.
fn synth_decode_step(
    full_shapes: &HashMap<String, (usize, usize)>,
    meta: &StateMeta,
    session_seed: u64,
    step: usize,
) -> HashMap<String, Mat> {
    let mix = (step as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let mut rng = Rng::new(session_seed ^ mix);
    let mut state_rng = Rng::new(SYNTHETIC_WEIGHT_SEED ^ mix);
    let mut names: Vec<&String> = full_shapes.keys().collect();
    names.sort();
    names
        .into_iter()
        .map(|n| {
            let (r, c) = full_shapes[n];
            let m = if let Some(app) = meta.state.get(n) {
                if app.axis == 0 {
                    state_rng.mat(app.unit, c)
                } else {
                    state_rng.mat(r, app.unit)
                }
            } else if let Some(&(axis, unit)) = meta.scaled.get(n) {
                if axis == 0 {
                    Mat::zeros(unit * step, c)
                } else {
                    Mat::zeros(r, unit * step)
                }
            } else {
                rng.mat(r, c)
            };
            (n.clone(), m)
        })
        .collect()
}

/// Bitwise equality of two decode steps' cache views — the decode
/// analogue of [`shared_inputs_identical`]: a stacked decode launch
/// binds one cache prefix for every member, so anything short of
/// bit-identity would break per-step parity.
fn caches_identical(a: &HashMap<String, Mat>, b: &HashMap<String, Mat>) -> bool {
    a.len() == b.len()
        && a.iter().all(|(k, ma)| {
            b.get(k).is_some_and(|mb| {
                ma.rows == mb.rows
                    && ma.cols == mb.cols
                    && ma
                        .data
                        .iter()
                        .zip(&mb.data)
                        .all(|(x, y)| x.to_bits() == y.to_bits())
            })
        })
}

/// Derive a request's trip (block count along the stack dim) from its
/// input extents, validating everything else against the registered
/// shape. Stack-dim-carrying inputs may shrink along their stack axis
/// in whole block units (1..= the registered trip) but must all agree
/// on the trip; every other extent — shared weights entirely, and the
/// non-stack axis of stacked inputs — must match the registered shape
/// exactly (the serving-level mirror of
/// `loopir::compile::bucket_compatible`: only the stackable grid dim
/// may differ).
fn derive_trip(
    workload: &str,
    info: &StackInfo,
    stack_axes: &BTreeMap<String, usize>,
    full_shapes: &HashMap<String, (usize, usize)>,
    inputs: &HashMap<String, Mat>,
) -> anyhow::Result<usize> {
    let mut trip: Option<usize> = None;
    for (input, &(r, c)) in full_shapes {
        let m = inputs
            .get(input)
            .ok_or_else(|| anyhow!("request for {workload} missing input {input}"))?;
        match stack_axes.get(input) {
            Some(&axis) => {
                let (full_stack, got, fixed_ok) = if axis == 0 {
                    (r, m.rows, m.cols == c)
                } else {
                    (c, m.cols, m.rows == r)
                };
                let unit = full_stack / info.trip;
                if !fixed_ok || unit == 0 || got == 0 || got % unit != 0 || got / unit > info.trip
                {
                    bail!(
                        "request for {workload}: input {input} is {}x{}, registered shape is \
                         {r}x{c} (stackable in units of {unit} along axis {axis})",
                        m.rows,
                        m.cols
                    );
                }
                let k = got / unit;
                match trip {
                    Some(prev) if prev != k => bail!(
                        "request for {workload}: inconsistent ragged extents — input {input} \
                         implies {k} block(s) along the stack dim, earlier inputs implied {prev}"
                    ),
                    _ => trip = Some(k),
                }
            }
            None => {
                if (m.rows, m.cols) != (r, c) {
                    bail!(
                        "request for {workload}: input {input} is {}x{}, registered shape is \
                         {r}x{c}",
                        m.rows,
                        m.cols
                    );
                }
            }
        }
    }
    Ok(trip.unwrap_or(info.trip))
}

/// Bitwise equality of every shared (weight-like) input across a batch.
/// Value equality (`==`) is not enough — `-0.0 == 0.0` and NaN never
/// compares equal — and a stacked launch binds request 0's copy for the
/// whole batch, so anything short of bit-identity would break the
/// per-request parity contract. The scan is O(batch · weight bytes) per
/// flush, deliberately: a hash pre-check could only *reject* cheaply
/// (matching hashes would still need this confirm scan to keep the
/// bit-identical guarantee), and one linear pass over the weights is
/// noise next to the launch itself, which re-reads them many times.
fn shared_inputs_identical(shared: &BTreeSet<String>, batch: &[Pending]) -> bool {
    shared.iter().all(|name| {
        // Validation at submit guarantees every input is present; if
        // that invariant ever broke, declining to coalesce (fan-out
        // would surface the real error per request) beats panicking.
        let Some(m0) = batch.first().and_then(|p| p.inputs.get(name)) else {
            return false;
        };
        batch[1..].iter().all(|p| {
            p.inputs.get(name).is_some_and(|m| {
                m.rows == m0.rows
                    && m.cols == m0.cols
                    && m.data
                        .iter()
                        .zip(&m0.data)
                        .all(|(a, b)| a.to_bits() == b.to_bits())
            })
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_rejects_unknown_and_duplicate() {
        let mut s = ModelServer::new(ServerConfig::default());
        assert!(s.register("no_such_program").is_err());
        s.register("quickstart").unwrap();
        let err = s.register("quickstart").unwrap_err().to_string();
        assert!(err.contains("already registered"), "got: {err}");
    }

    #[test]
    fn submit_validates_workload_and_shapes() {
        let mut s = ModelServer::new(ServerConfig::default());
        s.register("quickstart").unwrap();
        assert!(s.submit_synthetic("attention", 0).is_err());
        // wrong shape for a known input
        let mut inputs = s.synthetic_inputs("quickstart", 0).unwrap();
        let a = inputs.get_mut("A").unwrap();
        *a = Mat::zeros(a.rows + 1, a.cols);
        let err = s
            .submit(Request::new("quickstart", inputs))
            .unwrap_err()
            .to_string();
        assert!(err.contains("registered shape"), "got: {err}");
        // missing input
        let err = s
            .submit(Request::new("quickstart", HashMap::new()))
            .unwrap_err()
            .to_string();
        assert!(err.contains("missing input"), "got: {err}");
        // validation failures never consume admission accounting
        assert_eq!(s.stats().per_program["quickstart"].submitted, 0);
    }

    #[test]
    fn size_and_latency_bound_flushes() {
        // size-triggered: nothing flushes until max_batch requests queue
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 3,
            max_wait: Duration::from_secs(3600),
            threads: Some(1),
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        s.submit_synthetic("quickstart", 0).unwrap();
        s.submit_synthetic("quickstart", 1).unwrap();
        assert!(s.poll().is_empty(), "batch not full, wait not exceeded");
        assert_eq!(s.pending(), 2);
        s.submit_synthetic("quickstart", 2).unwrap();
        let r = s.poll();
        assert_eq!(r.len(), 3);
        assert!(r.iter().all(|r| r.batch_size == 3));
        assert_eq!(s.pending(), 0);

        // latency-triggered: max_wait zero flushes a lone request at once
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 100,
            max_wait: Duration::ZERO,
            threads: Some(1),
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        s.submit_synthetic("quickstart", 0).unwrap();
        let r = s.poll();
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].batch_size, 1);
        assert_eq!(s.stats().per_program["quickstart"].peak_batch, 1);
    }

    /// Regression (burst under-drain): a queue holding several
    /// `max_batch`-fulls must flush them all in ONE poll — the old
    /// one-flush-per-poll behavior grew unbounded backlog whenever
    /// arrival bursts outpaced the poll rate.
    #[test]
    fn poll_drains_overfull_queue_in_one_call() {
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 2,
            max_wait: Duration::from_secs(3600),
            threads: Some(1),
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        for i in 0..7u64 {
            s.submit_synthetic("quickstart", i).unwrap();
        }
        let r = s.poll();
        assert_eq!(r.len(), 6, "three full batches flush in one poll");
        assert_eq!(s.pending(), 1, "the partial batch stays queued");
        assert_eq!(s.stats().per_program["quickstart"].batches, 3);
        // the remainder is below max_batch and not yet latency-due
        assert!(s.poll().is_empty());
    }

    /// `max_batch == 0` normalizes to 1 at construction — no call site
    /// clamps it anymore, so the server must behave exactly like
    /// `max_batch == 1`.
    #[test]
    fn max_batch_zero_normalizes_to_one() {
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 0,
            max_wait: Duration::from_secs(3600),
            threads: Some(1),
            ..ServerConfig::default()
        });
        assert_eq!(s.config().max_batch, 1);
        s.register("quickstart").unwrap();
        s.submit_synthetic("quickstart", 0).unwrap();
        s.submit_synthetic("quickstart", 1).unwrap();
        let r = s.poll();
        assert_eq!(r.len(), 2, "two single-request batches");
        assert!(r.iter().all(|r| r.batch_size == 1));
    }

    /// Coalescing smoke: a full same-shape batch rides one stacked
    /// launch, and the actual launch count is one request's worth — the
    /// per-response counters still report the sequential values.
    #[test]
    fn coalesced_batch_is_one_stacked_launch() {
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 4,
            max_wait: Duration::from_secs(3600),
            threads: Some(2),
            coalesce: true,
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        for i in 0..4u64 {
            s.submit_synthetic("quickstart", i).unwrap();
        }
        let r = s.poll();
        assert_eq!(r.len(), 4);
        assert!(r.iter().all(|r| r.coalesced && r.batch_size == 4));
        let st = &s.stats().per_program["quickstart"];
        assert_eq!(st.coalesced, 4);
        assert_eq!(st.stacked_batches, 1);
        let per_req = r[0].mem.kernel_launches;
        assert!(per_req > 0);
        assert!(
            r.iter().all(|x| x.mem.kernel_launches == per_req),
            "same plan, same per-request launch charge"
        );
        assert_eq!(
            st.launches, per_req,
            "the stacked launch paid one request's worth of kernel launches for the whole batch"
        );
    }

    #[test]
    fn latency_samples_stay_bounded() {
        let mut st = ProgramStats::default();
        for i in 0..(LATENCY_SAMPLE_CAP as u128 + 10) {
            st.record_latency(i);
        }
        assert_eq!(st.latency_ns.len(), LATENCY_SAMPLE_CAP);
        // the ring overwrote the oldest slots with the newest samples
        assert_eq!(st.latency_ns[0], LATENCY_SAMPLE_CAP as u128);
        assert_eq!(st.latency_ns[9], LATENCY_SAMPLE_CAP as u128 + 9);
        assert_eq!(st.latency_ns[10], 10);
    }

    #[test]
    fn tune_shares_the_server_cache() {
        let mut s = ModelServer::new(ServerConfig {
            threads: Some(1),
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        let pts = s.tune("quickstart", 1 << 20, 3, 9).unwrap();
        assert!(!pts.is_empty() && pts.len() <= 3);
        let misses = s.cache_misses();
        // a second tune re-binds cached skeletons, compiling nothing new
        s.tune("quickstart", 1 << 20, 3, 10).unwrap();
        assert_eq!(s.cache_misses(), misses);
    }

    /// Satellite: stats summaries on an empty/fresh server are zeros,
    /// never NaN (`mean_batch`/`mean_latency_ns` divide, and a NaN here
    /// would propagate straight into the CLI stats table).
    #[test]
    fn stats_empty_samples_are_zero_not_nan() {
        let st = ProgramStats::default();
        assert_eq!(st.mean_batch(), 0.0);
        assert_eq!(st.mean_latency_ns(), 0.0);
        assert_eq!(st.percentile_latency_ns(50.0), 0);
        assert_eq!(st.percentile_latency_ns(99.0), 0);
        assert!(!st.mean_batch().is_nan());
        assert!(!st.mean_latency_ns().is_nan());
        assert_eq!(st.accounted(), 0);
        assert_eq!(st.rejected(), 0);
    }

    #[test]
    fn queue_cap_sheds_new_arrivals_with_reject_new() {
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 100,
            max_wait: Duration::from_secs(3600),
            threads: Some(1),
            queue_cap: Some(2),
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        let a = s.submit_synthetic("quickstart", 0).unwrap();
        let b = s.submit_synthetic("quickstart", 1).unwrap();
        let c = s.submit_synthetic("quickstart", 2).unwrap();
        assert_eq!(s.pending(), 2, "cap holds");
        // the shed response arrives via the normal poll channel
        let r = s.poll();
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].id, c);
        assert_eq!(r[0].verdict, Verdict::Rejected(Rejected::QueueFull));
        let served: Vec<u64> = s.drain().iter().map(|r| r.id).collect();
        assert_eq!(served, vec![a, b]);
        let st = &s.stats().per_program["quickstart"];
        assert_eq!(st.submitted, 3);
        assert_eq!(st.rejected_full, 1);
        assert_eq!(st.served, 2);
        assert_eq!(st.accounted(), st.submitted);
    }

    #[test]
    fn queue_cap_drop_oldest_evicts_the_queue_head() {
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 100,
            max_wait: Duration::from_secs(3600),
            threads: Some(1),
            queue_cap: Some(2),
            shed_policy: ShedPolicy::DropOldest,
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        let a = s.submit_synthetic("quickstart", 0).unwrap();
        let b = s.submit_synthetic("quickstart", 1).unwrap();
        let c = s.submit_synthetic("quickstart", 2).unwrap();
        let r = s.poll();
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].id, a, "the oldest queued request paid");
        assert_eq!(r[0].verdict, Verdict::Rejected(Rejected::QueueFull));
        let served: Vec<u64> = s.drain().iter().map(|r| r.id).collect();
        assert_eq!(served, vec![b, c], "fresh work survived");
        let st = &s.stats().per_program["quickstart"];
        assert_eq!(st.accounted(), st.submitted);
    }

    #[test]
    fn deadline_rejects_at_admission() {
        // a config-level zero deadline is already expired at admission
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 100,
            max_wait: Duration::from_secs(3600),
            threads: Some(1),
            deadline: Some(Duration::ZERO),
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        let id = s.submit_synthetic("quickstart", 0).unwrap();
        assert_eq!(s.pending(), 0, "never queued");
        let r = s.poll();
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].id, id);
        assert_eq!(r[0].verdict, Verdict::Rejected(Rejected::DeadlineExpired));
        assert_eq!(s.stats().per_program["quickstart"].rejected_deadline, 1);

        // a per-request deadline in the past overrides a generous config
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 100,
            max_wait: Duration::from_secs(3600),
            threads: Some(1),
            deadline: Some(Duration::from_secs(3600)),
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        let inputs = s.synthetic_inputs("quickstart", 0).unwrap();
        let past = Instant::now() - Duration::from_millis(1);
        s.submit(Request::new("quickstart", inputs).with_deadline(past))
            .unwrap();
        let r = s.poll();
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].verdict, Verdict::Rejected(Rejected::DeadlineExpired));
    }

    #[test]
    fn deadline_sheds_at_batch_formation() {
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 100,
            max_wait: Duration::from_secs(3600),
            threads: Some(1),
            deadline: Some(Duration::from_millis(5)),
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        let id = s.submit_synthetic("quickstart", 0).unwrap();
        assert_eq!(s.pending(), 1, "admitted — not yet expired");
        std::thread::sleep(Duration::from_millis(10));
        // an expired queued deadline makes the queue due on its own
        let r = s.poll();
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].id, id);
        assert_eq!(r[0].verdict, Verdict::Rejected(Rejected::DeadlineExpired));
        let st = &s.stats().per_program["quickstart"];
        assert_eq!(st.shed_deadline, 1, "shed at flush, not at admission");
        assert_eq!(st.rejected_deadline, 0);
        assert_eq!(st.batches, 0, "no launch was burned");
    }

    #[test]
    fn shutdown_rejects_new_work_but_drains_queued() {
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 100,
            max_wait: Duration::from_secs(3600),
            threads: Some(1),
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        let a = s.submit_synthetic("quickstart", 0).unwrap();
        s.begin_shutdown();
        assert!(s.is_shutting_down());
        let b = s.submit_synthetic("quickstart", 1).unwrap();
        let r = s.drain();
        assert_eq!(r.len(), 2);
        let rb = r.iter().find(|x| x.id == b).unwrap();
        assert_eq!(rb.verdict, Verdict::Rejected(Rejected::Shutdown));
        let ra = r.iter().find(|x| x.id == a).unwrap();
        assert!(ra.is_ok(), "queued work still drains after shutdown");
        let st = &s.stats().per_program["quickstart"];
        assert_eq!(st.rejected_shutdown, 1);
        assert_eq!(st.accounted(), st.submitted);
    }

    /// Hot-swap smoke: adopting new block sizes swaps the live plan
    /// between batches, serving continues, and the served outputs match
    /// a direct execution of the swapped-in plan bit for bit.
    #[test]
    fn adopt_sizes_hot_swaps_the_live_plan() {
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 1,
            max_wait: Duration::from_secs(3600),
            threads: Some(1),
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        s.submit_synthetic("quickstart", 0).unwrap();
        assert!(s.drain().iter().all(|r| r.is_ok()));

        let old_sizes = s.live_plan("quickstart").unwrap().sizes.clone();
        let mut new_sizes = old_sizes.clone();
        let m = crate::ir::dim::Dim::new("M");
        new_sizes.set(m.clone(), old_sizes.get(&m) / 2);
        s.adopt_sizes("quickstart", &new_sizes).unwrap();
        let live = s.live_plan("quickstart").unwrap();
        assert_eq!(live.sizes, new_sizes, "swap adopted the new sizes");
        assert_eq!(s.stats().per_program["quickstart"].plan_swaps, 1);
        assert_eq!(
            s.stats().per_program["quickstart"].compiles,
            1,
            "hot-swap re-selects and re-binds; it never recompiles from scratch"
        );

        let inputs = s.synthetic_inputs("quickstart", 7).unwrap();
        s.submit(Request::new("quickstart", inputs.clone())).unwrap();
        let r = s.drain();
        assert_eq!(r.len(), 1);
        assert!(r[0].is_ok());
        let direct = execute_prepared(&live, &inputs, Some(1));
        for (name, got) in &r[0].outputs {
            let want = &direct.outputs[name];
            assert_eq!(got.rows, want.rows);
            assert_eq!(got.cols, want.cols);
            assert!(
                got.data
                    .iter()
                    .zip(&want.data)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                "served output {name} must match the live plan bit for bit"
            );
        }
        let st = &s.stats().per_program["quickstart"];
        assert_eq!(st.accounted(), st.submitted);
    }

    #[test]
    fn set_weight_validates_name_and_value() {
        let mut s = ModelServer::new(ServerConfig::default());
        s.register("quickstart").unwrap();
        assert_eq!(s.weight_of("quickstart"), Some(1), "default weight is 1");
        s.set_weight("quickstart", 4).unwrap();
        assert_eq!(s.weight_of("quickstart"), Some(4));
        let err = s.set_weight("quickstart", 0).unwrap_err().to_string();
        assert!(err.contains("weight must be"), "got: {err}");
        assert!(s.set_weight("no_such_program", 2).is_err());
        assert_eq!(s.weight_of("no_such_program"), None);
    }

    /// The acceptance test for weighted fairness: one saturating hot
    /// workload at weight 4 against two weight-1 workloads, all backed
    /// by the same program. Deficit round-robin must give the hot
    /// workload its 4x share *per round* while the cold workloads keep
    /// flushing every round — so the colds finish well before the hot
    /// backlog and no workload's p99 queue wait grows past the hot
    /// tail's.
    #[test]
    fn weighted_fairness_bounds_starvation_under_saturation() {
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 2,
            max_wait: Duration::from_secs(3600),
            threads: Some(1),
            ..ServerConfig::default()
        });
        for name in ["hot", "cold1", "cold2"] {
            let (program, cfg, params, _inputs) = workloads::by_name("quickstart", 0).unwrap();
            s.register_program(name, &program, cfg, params).unwrap();
        }
        s.set_weight("hot", 4).unwrap();

        // Pre-generate all inputs, then enqueue hots strictly before
        // colds: any cold response that waits longer than the hot tail
        // then proves a scheduling failure, not clock noise.
        let hot_inputs: Vec<_> = (0..40)
            .map(|i| s.synthetic_inputs("hot", i).unwrap())
            .collect();
        let cold_inputs: Vec<_> = (0..6)
            .map(|i| {
                (
                    s.synthetic_inputs("cold1", 100 + i).unwrap(),
                    s.synthetic_inputs("cold2", 200 + i).unwrap(),
                )
            })
            .collect();
        for inputs in hot_inputs {
            s.submit(Request::new("hot", inputs)).unwrap();
        }
        for (c1, c2) in cold_inputs {
            s.submit(Request::new("cold1", c1)).unwrap();
            s.submit(Request::new("cold2", c2)).unwrap();
        }

        let responses = s.drain();
        assert_eq!(responses.len(), 52);
        assert!(responses.iter().all(|r| r.is_ok()));

        // Round 1 (cursor starts at "hot"): hot spends its full quantum
        // of 4 batches, then each cold gets its one batch — the 4:1:1
        // weighted share, exactly.
        let first: Vec<&str> = responses[..12].iter().map(|r| r.workload.as_str()).collect();
        let mut want = vec!["hot"; 8];
        want.extend(["cold1", "cold1", "cold2", "cold2"]);
        assert_eq!(first, want, "round 1 must be 8 hot + 2 cold1 + 2 cold2");

        // Starvation bound: the colds (6 requests each, 2 per round)
        // need 3 rounds, so every cold response lands within the first
        // 36 — the hot backlog's tail (16 more requests) cannot push
        // them back.
        let last_cold = responses
            .iter()
            .rposition(|r| r.workload != "hot")
            .expect("cold responses exist");
        assert!(last_cold < 36, "last cold response at {last_cold}, starved past round 3");

        // And in time, not just order: every workload's p99 queue wait
        // is bounded by the hot tail's worst wait (colds were enqueued
        // after every hot, so ordering alone makes this deterministic).
        let waits = |name: &str| -> Vec<u128> {
            responses
                .iter()
                .filter(|r| r.workload == name)
                .map(|r| r.queue_ns)
                .collect()
        };
        let hot_max = *waits("hot").iter().max().unwrap();
        for cold in ["cold1", "cold2"] {
            let p99 = crate::util::bench::percentile(&waits(cold), 99.0);
            assert!(
                p99 <= hot_max,
                "{cold} p99 queue wait {p99}ns exceeds the hot tail's {hot_max}ns"
            );
        }

        for name in ["hot", "cold1", "cold2"] {
            let st = &s.stats().per_program[name];
            assert_eq!(st.accounted(), st.submitted, "{name} ledger");
        }
    }

    /// With every weight at 1, deficit round-robin must degenerate to
    /// the old behavior: one batch per workload per round, strict
    /// interleave.
    #[test]
    fn weight_one_stays_plain_round_robin() {
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 2,
            max_wait: Duration::from_secs(3600),
            threads: Some(1),
            ..ServerConfig::default()
        });
        for name in ["a", "b"] {
            let (program, cfg, params, _inputs) = workloads::by_name("quickstart", 0).unwrap();
            s.register_program(name, &program, cfg, params).unwrap();
        }
        for i in 0..4u64 {
            let inputs = s.synthetic_inputs("a", i).unwrap();
            s.submit(Request::new("a", inputs)).unwrap();
            let inputs = s.synthetic_inputs("b", i).unwrap();
            s.submit(Request::new("b", inputs)).unwrap();
        }
        let order: Vec<String> = s.drain().into_iter().map(|r| r.workload).collect();
        assert_eq!(
            order,
            ["a", "a", "b", "b", "b", "b", "a", "a"],
            "one batch per workload per round (cursor rotates between rounds)"
        );
    }

    #[test]
    fn bucket_ladder_parses_and_maps_trips_to_edges() {
        assert_eq!(BucketLadder::from_name("exact"), Some(BucketLadder::Exact));
        assert_eq!(BucketLadder::from_name("pow2"), Some(BucketLadder::Pow2));
        assert_eq!(BucketLadder::from_name("max"), Some(BucketLadder::Max));
        assert_eq!(
            BucketLadder::from_name("2,4,8"),
            Some(BucketLadder::Edges(vec![2, 4, 8]))
        );
        assert_eq!(BucketLadder::from_name("8,4"), None, "edges must ascend");
        assert_eq!(BucketLadder::from_name("0,4"), None, "zero edge");
        assert_eq!(BucketLadder::from_name("bogus"), None);
        assert_eq!(BucketLadder::from_name(""), None);

        assert_eq!(BucketLadder::Exact.edge_for(3, 8), 3);
        assert_eq!(BucketLadder::Pow2.edge_for(3, 8), 4);
        assert_eq!(BucketLadder::Pow2.edge_for(5, 8), 8);
        assert_eq!(BucketLadder::Pow2.edge_for(5, 6), 6, "clamped to registered");
        assert_eq!(BucketLadder::Max.edge_for(1, 8), 8);
        let edges = BucketLadder::Edges(vec![2, 4]);
        assert_eq!(edges.edge_for(1, 8), 2);
        assert_eq!(edges.edge_for(3, 8), 4);
        assert_eq!(edges.edge_for(5, 8), 5, "past the last edge: exact");
    }

    /// Regression (fairness debit): deadline-shed rejections used to
    /// debit the workload's DRR deficit as if they had been served, so
    /// a workload whose queue carried expired entries got less than its
    /// weighted share of launch slots. Only responses that occupied a
    /// slot may debit.
    #[test]
    fn deadline_sheds_do_not_debit_drr_deficit() {
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 2,
            max_wait: Duration::from_secs(3600),
            threads: Some(1),
            ..ServerConfig::default()
        });
        for name in ["a", "b"] {
            let (program, cfg, params, _inputs) = workloads::by_name("quickstart", 0).unwrap();
            s.register_program(name, &program, cfg, params).unwrap();
        }
        s.set_weight("a", 2).unwrap();
        // four requests that will be dead by drain time...
        let dead = Instant::now() + Duration::from_millis(5);
        for i in 0..4u64 {
            let inputs = s.synthetic_inputs("a", i).unwrap();
            s.submit(Request::new("a", inputs).with_deadline(dead)).unwrap();
        }
        std::thread::sleep(Duration::from_millis(10));
        // ...then live traffic on both workloads
        for i in 4..8u64 {
            let inputs = s.synthetic_inputs("a", i).unwrap();
            s.submit(Request::new("a", inputs)).unwrap();
        }
        for i in 0..2u64 {
            let inputs = s.synthetic_inputs("b", i).unwrap();
            s.submit(Request::new("b", inputs)).unwrap();
        }
        let responses = s.drain();
        assert_eq!(responses.len(), 10);
        let shed = responses
            .iter()
            .filter(|r| r.verdict == Verdict::Rejected(Rejected::DeadlineExpired))
            .count();
        assert_eq!(shed, 4, "the stale requests shed at batch formation");
        let served: Vec<&str> = responses
            .iter()
            .filter(|r| r.is_ok())
            .map(|r| r.workload.as_str())
            .collect();
        // a's full quantum (weight 2 x max_batch 2) serves all four
        // live requests before the cursor moves on; the old debit
        // handed b the round after a had served only two.
        assert_eq!(served, ["a", "a", "a", "a", "b", "b"]);
        for name in ["a", "b"] {
            let st = &s.stats().per_program[name];
            assert_eq!(st.accounted(), st.submitted, "{name} ledger");
        }
    }

    /// Regression (shed complexity): the expiry shed used
    /// `VecDeque::remove(i)` per expired entry — O(n²) on a
    /// deeply-expired queue, exactly what a deadline storm produces. A
    /// 10k-expired backlog must shed in one poll, in one pass.
    #[test]
    fn expired_backlog_sheds_in_one_poll() {
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 4,
            max_wait: Duration::from_secs(3600),
            threads: Some(1),
            deadline: Some(Duration::from_millis(5)),
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        let inputs = s.synthetic_inputs("quickstart", 0).unwrap();
        for _ in 0..10_000 {
            s.submit(Request::new("quickstart", inputs.clone())).unwrap();
        }
        assert_eq!(s.pending(), 10_000);
        std::thread::sleep(Duration::from_millis(10));
        let r = s.poll();
        assert_eq!(r.len(), 10_000, "the whole expired backlog sheds in one poll");
        assert!(r
            .iter()
            .all(|x| x.verdict == Verdict::Rejected(Rejected::DeadlineExpired)));
        assert_eq!(s.pending(), 0);
        let st = &s.stats().per_program["quickstart"];
        assert_eq!(st.shed_deadline, 10_000);
        assert_eq!(st.batches, 0, "no launch burned");
        assert_eq!(st.accounted(), st.submitted);
    }

    /// Regression (stale `now` in poll): a request crossing `max_wait`
    /// while a long burst drains must flush in the *same* poll. The old
    /// code captured `now` once per poll, so the straggler sat through
    /// the whole drain and waited for the next wakeup.
    #[test]
    fn poll_reevaluates_due_ness_per_sweep() {
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(5),
            threads: Some(1),
            ..ServerConfig::default()
        });
        for name in ["heavy", "light"] {
            let (program, cfg, params, _inputs) = workloads::by_name("attention", 0).unwrap();
            s.register_program(name, &program, cfg, params).unwrap();
        }
        for i in 0..16u64 {
            let inputs = s.synthetic_inputs("heavy", i).unwrap();
            s.submit(Request::new("heavy", inputs)).unwrap();
        }
        // submitted immediately before the poll: not yet latency-due
        // when the poll starts, due well before its 16 heavy batches
        // finish draining
        let inputs = s.synthetic_inputs("light", 99).unwrap();
        s.submit(Request::new("light", inputs)).unwrap();
        let r = s.poll();
        assert!(
            r.iter().any(|x| x.workload == "light"),
            "a request crossing max_wait during the drain flushes in the same poll"
        );
        assert_eq!(r.len(), 17);
    }

    /// Ragged coalescing smoke: four distinct trips land in one bucket
    /// under the `max` ladder and ride ONE stacked launch with zero pad
    /// waste; under `pow2` + padding, mixed trips sharing an edge pay
    /// explicit pad counters that never leak into any request's own
    /// MemSim.
    #[test]
    fn ragged_mixed_trips_coalesce_into_one_stacked_launch() {
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 4,
            max_wait: Duration::from_secs(3600),
            threads: Some(2),
            coalesce: true,
            buckets: BucketLadder::Max,
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        for (i, trip) in [1usize, 2, 3, 4].into_iter().enumerate() {
            s.submit_synthetic_ragged("quickstart", i as u64, trip).unwrap();
        }
        let r = s.poll();
        assert_eq!(r.len(), 4);
        assert!(r.iter().all(|x| x.is_ok() && x.coalesced && x.batch_size == 4));
        let st = &s.stats().per_program["quickstart"];
        assert_eq!(st.stacked_batches, 1);
        assert_eq!(st.coalesced, 4);
        assert_eq!(
            (st.padded_loaded_bytes, st.padded_stored_bytes, st.padded_flops),
            (0, 0, 0),
            "max ladder with padding off stacks ragged, never pads"
        );
        assert!(r
            .iter()
            .all(|x| x.mem.padded_loaded_bytes == 0 && x.mem.padded_flops == 0));

        // pow2 ladder + padding: trips 3 and 4 share the 4-edge bucket,
        // the trip-3 request pads by one block — charged to the
        // program's pad counters, invisible in either request's own.
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 2,
            max_wait: Duration::from_secs(3600),
            threads: Some(2),
            coalesce: true,
            buckets: BucketLadder::Pow2,
            pad: true,
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        s.submit_synthetic_ragged("quickstart", 0, 3).unwrap();
        s.submit_synthetic_ragged("quickstart", 1, 4).unwrap();
        let r = s.poll();
        assert_eq!(r.len(), 2);
        assert!(r.iter().all(|x| x.is_ok() && x.coalesced));
        let st = &s.stats().per_program["quickstart"];
        assert_eq!(st.stacked_batches, 1);
        assert!(
            st.padded_loaded_bytes > 0 && st.padded_flops > 0,
            "pad rows charged explicitly"
        );
        assert!(
            r.iter()
                .all(|x| x.mem.padded_loaded_bytes == 0 && x.mem.padded_flops == 0),
            "pad waste never leaks into a request's own counters"
        );
    }

    /// With the default `exact` ladder, a ragged request simply fans
    /// out (its own bucket, its own single-request stacked bind) and
    /// still serves correctly — the pre-bucket behavior for full-shape
    /// traffic, graceful degradation for ragged.
    #[test]
    fn exact_ladder_serves_ragged_without_cross_trip_coalescing() {
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 4,
            max_wait: Duration::from_secs(3600),
            threads: Some(1),
            coalesce: true,
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        s.submit_synthetic_ragged("quickstart", 0, 1).unwrap();
        s.submit_synthetic_ragged("quickstart", 1, 2).unwrap();
        s.submit_synthetic_ragged("quickstart", 2, 3).unwrap();
        let r = s.drain();
        assert_eq!(r.len(), 3);
        assert!(r.iter().all(|x| x.is_ok()));
        let st = &s.stats().per_program["quickstart"];
        assert_eq!(st.stacked_batches, 0, "distinct trips, distinct buckets");
        assert_eq!(
            (st.padded_loaded_bytes, st.padded_flops),
            (0, 0),
            "exact edges never pad"
        );
        // outputs scale with each request's own trip
        let trips: Vec<usize> = r.iter().map(|x| x.outputs["C"].rows).collect();
        let unit = trips.iter().min().copied().unwrap();
        assert!(trips.iter().all(|t| t % unit == 0));
    }

    /// Tiny deterministic generator for the property fuzz below (the
    /// crate's `Rng` draws f32 matrices; these properties need integer
    /// draws).
    struct Lcg(u64);
    impl Lcg {
        fn next(&mut self) -> u64 {
            self.0 = self
                .0
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            self.0 >> 33
        }
        fn below(&mut self, n: u64) -> u64 {
            self.next() % n
        }
    }

    /// Property fuzz (seeded): for every ladder shape and any
    /// `1 <= trip <= registered`, the edge is clamped to
    /// `trip..=registered` and is monotone in the trip — the two facts
    /// bucket routing relies on.
    #[test]
    fn fuzz_bucket_edges_monotone_and_clamped() {
        let mut g = Lcg(0xb10c_1add_e500_0001);
        let mut ladders = vec![BucketLadder::Exact, BucketLadder::Pow2, BucketLadder::Max];
        for _ in 0..32 {
            let mut edges = Vec::new();
            let mut e = 0u64;
            for _ in 0..=g.below(4) {
                e += 1 + g.below(5);
                edges.push(e as usize);
            }
            ladders.push(BucketLadder::Edges(edges));
        }
        for ladder in &ladders {
            for _ in 0..64 {
                let registered = 1 + g.below(16) as usize;
                let mut prev = 0usize;
                for trip in 1..=registered {
                    let edge = ladder.edge_for(trip, registered);
                    assert!(
                        trip <= edge && edge <= registered,
                        "{ladder:?}: edge {edge} for trip {trip}/{registered} escapes the clamp"
                    );
                    assert!(edge >= prev, "{ladder:?}: edge not monotone at trip {trip}");
                    prev = edge;
                }
            }
        }
    }

    /// Property fuzz (seeded): `from_name` accepts exactly the named
    /// ladders and strictly-ascending positive edge lists; every
    /// non-ascending, zero-containing, or junk list is rejected.
    #[test]
    fn fuzz_from_name_rejects_malformed_edge_lists() {
        assert_eq!(BucketLadder::from_name("exact"), Some(BucketLadder::Exact));
        assert_eq!(BucketLadder::from_name("pow2"), Some(BucketLadder::Pow2));
        assert_eq!(BucketLadder::from_name("max"), Some(BucketLadder::Max));
        for bad in ["", "0", "1,1", "4,2", "1,2,2", "2,0,3", "a", "1,b", "-1", "1,,2"] {
            assert_eq!(BucketLadder::from_name(bad), None, "accepted {bad:?}");
        }
        let mut g = Lcg(0x5eed_ed6e_5);
        for _ in 0..256 {
            let n = 1 + g.below(5) as usize;
            let mut edges: Vec<usize> = Vec::new();
            let mut e = 0u64;
            for _ in 0..n {
                e += 1 + g.below(6);
                edges.push(e as usize);
            }
            let name = edges
                .iter()
                .map(|x| x.to_string())
                .collect::<Vec<_>>()
                .join(",");
            assert_eq!(
                BucketLadder::from_name(&name),
                Some(BucketLadder::Edges(edges.clone())),
                "rejected ascending {name}"
            );
            // any mutation that breaks strict ascent must reject
            if edges.len() >= 2 {
                let i = 1 + g.below(edges.len() as u64 - 1) as usize;
                let mut broken = edges.clone();
                broken[i] = broken[i - 1];
                let name = broken
                    .iter()
                    .map(|x| x.to_string())
                    .collect::<Vec<_>>()
                    .join(",");
                assert_eq!(BucketLadder::from_name(&name), None, "accepted {name}");
            }
        }
    }

    /// Property fuzz (seeded) for `derive_trip`: whole-block extents up
    /// to the registered trip derive exactly; full shapes clamp to the
    /// registered trip; unit violations, oversize, cross-input
    /// disagreement, and missing inputs all reject.
    #[test]
    fn fuzz_derive_trip_units_and_clamp() {
        let mut g = Lcg(0x7819_0001);
        for _ in 0..128 {
            let reg = 1 + g.below(6) as usize;
            let unit_q = 4 * (1 + g.below(3) as usize);
            let info = StackInfo {
                dim: Dim::new("M"),
                trip: reg,
            };
            let mut full = HashMap::new();
            full.insert("Q".to_string(), (reg * unit_q, 16));
            full.insert("KT".to_string(), (32, reg * 8));
            full.insert("W".to_string(), (16, 16));
            let mut axes = BTreeMap::new();
            axes.insert("Q".to_string(), 0);
            axes.insert("KT".to_string(), 1);
            let mk = |kq: usize, kk: usize| {
                let mut m = HashMap::new();
                m.insert("Q".to_string(), Mat::zeros(kq, 16));
                m.insert("KT".to_string(), Mat::zeros(32, kk));
                m.insert("W".to_string(), Mat::zeros(16, 16));
                m
            };
            let k = 1 + g.below(reg as u64) as usize;
            let got = derive_trip("w", &info, &axes, &full, &mk(k * unit_q, k * 8)).unwrap();
            assert_eq!(got, k, "exact whole-block extents derive their trip");
            let got = derive_trip("w", &info, &axes, &full, &mk(reg * unit_q, reg * 8)).unwrap();
            assert_eq!(got, reg, "full shapes clamp to the registered trip");
            if unit_q > 1 {
                let r = derive_trip("w", &info, &axes, &full, &mk(k * unit_q - 1, k * 8));
                assert!(r.is_err(), "non-whole-block extent must reject");
            }
            let r = derive_trip("w", &info, &axes, &full, &mk((reg + 1) * unit_q, (reg + 1) * 8));
            assert!(r.is_err(), "oversize must reject");
            if reg >= 2 {
                let k2 = if k == reg { k - 1 } else { k + 1 };
                let r = derive_trip("w", &info, &axes, &full, &mk(k * unit_q, k2 * 8));
                assert!(r.is_err(), "cross-input trip disagreement must reject");
            }
            let mut missing = mk(k * unit_q, k * 8);
            missing.remove("KT");
            assert!(derive_trip("w", &info, &axes, &full, &missing).is_err());
        }
    }

    /// Property (seeded): bucket assignment — and therefore each
    /// request's outputs and coalesced batch size — is stable under
    /// permutation of a burst's arrival order.
    #[test]
    fn fuzz_ladder_assignment_stable_under_permutation() {
        let trips = [1usize, 3, 4, 2, 2, 3, 1, 4, 4, 1];
        let mut orders: Vec<Vec<usize>> = vec![(0..trips.len()).collect()];
        let mut g = Lcg(0xbadc_0ffe_e);
        for _ in 0..3 {
            // Fisher–Yates off the seeded generator
            let mut o: Vec<usize> = (0..trips.len()).collect();
            for i in (1..o.len()).rev() {
                o.swap(i, g.below(i as u64 + 1) as usize);
            }
            orders.push(o);
        }
        let runs: Vec<BTreeMap<usize, (usize, Mat)>> = orders
            .iter()
            .map(|order| {
                let mut s = ModelServer::new(ServerConfig {
                    max_batch: 16,
                    max_wait: Duration::from_secs(3600),
                    threads: Some(1),
                    coalesce: true,
                    buckets: BucketLadder::Pow2,
                    ..ServerConfig::default()
                });
                s.register("attention").unwrap();
                let mut by_req: HashMap<u64, usize> = HashMap::new();
                for &r in order {
                    let id = s
                        .submit_synthetic_ragged("attention", r as u64, trips[r])
                        .unwrap();
                    by_req.insert(id, r);
                }
                let mut out = BTreeMap::new();
                for resp in s.drain() {
                    assert!(resp.is_ok());
                    out.insert(by_req[&resp.id], (resp.batch_size, resp.outputs["O"].clone()));
                }
                out
            })
            .collect();
        for run in &runs[1..] {
            assert_eq!(run.len(), runs[0].len());
            for (k, (bs, m)) in run {
                let (bs0, m0) = &runs[0][k];
                assert_eq!(bs, bs0, "batch size of request {k} depends on arrival order");
                assert_eq!((m.rows, m.cols), (m0.rows, m0.cols));
                assert!(
                    m.data.iter().zip(&m0.data).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "outputs of request {k} depend on arrival order"
                );
            }
        }
    }

    /// Decode sessions end to end inside the server: stateful workloads
    /// reject plain submits, sessions append at admission, same-length
    /// steps of different sessions coalesce into one stacked launch,
    /// and every response carries the append breakout.
    #[test]
    fn decode_sessions_coalesce_and_account_appends() {
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_secs(3600),
            threads: Some(1),
            coalesce: true,
            ..ServerConfig::default()
        });
        s.register("decode_attention").unwrap();
        let err = s
            .submit_synthetic("decode_attention", 0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("stateful"), "got: {err}");
        let a = s.open_session("decode_attention").unwrap();
        let b = s.open_session("decode_attention").unwrap();
        assert_ne!(a, b);
        for step in 0..4 {
            s.submit_synthetic_decode(a, 11).unwrap();
            s.submit_synthetic_decode(b, 22).unwrap();
            let r = s.drain();
            assert_eq!(r.len(), 2);
            for resp in &r {
                assert!(resp.is_ok(), "step {step}: {:?}", resp.verdict);
                assert!(resp.coalesced, "same-length steps share a stacked launch");
                assert_eq!(resp.batch_size, 2);
                assert!(resp.mem.state_appends > 0);
                assert!(resp.mem.state_appended_bytes > 0);
                assert!(resp.mem.stored_bytes >= resp.mem.state_appended_bytes);
            }
            // the two sessions' queries differ, so outputs must too
            assert_ne!(r[0].outputs["O"].data, r[1].outputs["O"].data);
        }
        assert_eq!(s.session_len(a), Some(4));
        // context cap: a fifth step overflows the registered extent
        let err = s.submit_synthetic_decode(a, 11).unwrap_err().to_string();
        assert!(err.contains("full"), "got: {err}");
        let st = &s.stats().per_program["decode_attention"];
        assert_eq!(st.sessions_opened, 2);
        assert_eq!(st.decode_steps, 8);
        assert_eq!(st.stacked_batches, 4);
        assert_eq!(st.state_appends, 8 * 4, "4 blocks per step (2 per cache)");
        assert_eq!(s.close_session(a).unwrap(), 4);
        assert!(s.session_len(a).is_none());
    }

    /// A step queued when its session closes fails typed at launch; the
    /// submitted/accounted ledger still reconciles.
    #[test]
    fn closed_session_straggler_fails_typed() {
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_secs(3600),
            threads: Some(1),
            ..ServerConfig::default()
        });
        s.register("decode_attention").unwrap();
        let sid = s.open_session("decode_attention").unwrap();
        s.submit_synthetic_decode(sid, 7).unwrap();
        s.close_session(sid).unwrap();
        let r = s.drain();
        assert_eq!(r.len(), 1);
        match &r[0].verdict {
            Verdict::Failed(msg) => assert!(msg.contains("closed"), "got: {msg}"),
            v => panic!("expected Failed, got {v:?}"),
        }
        let st = &s.stats().per_program["decode_attention"];
        assert_eq!(st.submitted, st.accounted());
    }

    /// Decode admission mirrors stateless admission: validation errors
    /// consume no accounting, a draining server sheds steps typed and
    /// refuses new sessions, and a shed step never appends.
    #[test]
    fn decode_admission_control_mirrors_submit() {
        let mut s = ModelServer::new(ServerConfig {
            threads: Some(1),
            ..ServerConfig::default()
        });
        s.register("decode_attention").unwrap();
        let sid = s.open_session("decode_attention").unwrap();
        assert!(s.submit_decode(sid, HashMap::new()).is_err());
        assert_eq!(s.stats().per_program["decode_attention"].submitted, 0);
        s.begin_shutdown();
        assert!(s.open_session("decode_attention").is_err());
        let id = s.submit_synthetic_decode(sid, 3).unwrap();
        let r = s.drain();
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].id, id);
        assert_eq!(r[0].verdict, Verdict::Rejected(Rejected::Shutdown));
        assert_eq!(s.session_len(sid), Some(0), "a shed step never appends");
    }
}
