//! The standalone serving daemon: a channel-fed ingest front end plus a
//! background flusher thread around a [`ModelServer`].
//!
//! The library server is passive — `max_wait` only fires when somebody
//! calls [`ModelServer::poll`]. The daemon makes the latency bound
//! self-enforcing *without polling*: its flusher thread sleeps on the
//! ingest channel with a timeout of exactly
//! [`ModelServer::next_due`]`- now`, so it wakes either because new
//! work arrived (a channel send) or because the oldest queued request
//! just crossed `max_wait` (or a deadline) — never on a spin loop.
//! `next_due` is per *bucket queue* (ragged traffic queues per shape
//! bucket), so a lone ragged straggler wakes the flusher at its own
//! `max_wait` even while other buckets idle — the daemon itself needs
//! no bucket awareness.
//!
//! Lifecycle: **ingest → flusher → pool.**
//! [`DaemonClient::submit`] ships a [`Request`] plus a private reply
//! channel to the flusher; the flusher admits it through
//! [`ModelServer::submit`] (admission control, deadlines), flushes due
//! batches to the worker pool, and routes each [`Response`] back over
//! the submitting client's reply channel ([`Ticket::wait`]). Clients
//! are cheap `Sender` clones — any number of threads can submit
//! concurrently.
//!
//! Shutdown is graceful by construction: [`Daemon::shutdown`] sends a
//! stop message; the flusher then (1) stops admitting
//! ([`ModelServer::begin_shutdown`] — stragglers racing the shutdown
//! get typed [`Rejected::Shutdown`](super::Rejected::Shutdown)
//! responses *through the server*, so its counters still reconcile),
//! (2) drains every queued request, (3) routes the final responses, and
//! only then returns the server — which [`Daemon::shutdown`] hands back
//! for stats inspection. A client that submits after the daemon is gone
//! gets an immediate `Rejected::Shutdown` self-reply rather than a
//! hang.
//!
//! Re-tuning under live traffic: with a [`RetuneConfig`], the flusher
//! calls [`ModelServer::retune_and_swap`] between batches once a
//! workload has served `every` more requests — adopting measured block
//! shape winners via the atomic `Arc` plan swap while requests keep
//! flowing.

use super::{ModelServer, Request, Response, Verdict};
use crate::tensor::Mat;
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Sentinel id on responses synthesized outside the server (a submit
/// that never reached admission — daemon already shut down, or a
/// validation failure surfaced as a [`Verdict::Failed`] response).
pub const INVALID_ID: u64 = u64::MAX;

/// How long the flusher sleeps when no queue has a due time (idle
/// server). Purely a liveness backstop — submissions wake it instantly.
const IDLE_TICK: Duration = Duration::from_millis(25);

/// Background re-tuning knobs (`--retune-every`).
#[derive(Clone, Debug)]
pub struct RetuneConfig {
    /// Re-tune a workload after it has served this many more requests
    /// (0 disables).
    pub every: u64,
    /// Local-memory capacity handed to the autotuner's pruner.
    pub local_capacity: u64,
    /// Measured trials per re-tune.
    pub trials: usize,
}

enum Msg {
    Submit(Request, Sender<Response>),
    /// Open a KV-cache session on a stateful workload; replies with the
    /// session id (or the server's admission error).
    OpenSession(String, Sender<anyhow::Result<u64>>),
    /// One decode step for an open session (session id + step inputs).
    SubmitDecode(u64, HashMap<String, Mat>, Sender<Response>),
    Shutdown,
}

/// A running serving daemon. Owns the flusher thread; dropped tickets
/// and clients are harmless (routing to a vanished client is a no-op).
pub struct Daemon {
    tx: Sender<Msg>,
    flusher: JoinHandle<ModelServer>,
}

/// A cheap, cloneable handle for submitting requests to a [`Daemon`].
#[derive(Clone)]
pub struct DaemonClient {
    tx: Sender<Msg>,
}

/// The pending reply to one submitted request.
pub struct Ticket {
    rx: Receiver<Response>,
}

impl Ticket {
    /// Block until this request's [`Response`] arrives (admission
    /// rejections included — every submission yields exactly one
    /// response). If the daemon vanished before routing the reply, a
    /// synthesized [`Verdict::Failed`] response is returned instead of
    /// hanging.
    pub fn wait(self) -> Response {
        self.rx.recv().unwrap_or_else(|_| {
            Response::unserved(
                INVALID_ID,
                "",
                Verdict::Failed("daemon exited before the request was routed".to_string()),
                0,
            )
        })
    }
}

impl Daemon {
    /// Move `server` into a new flusher thread and start serving.
    pub fn start(server: ModelServer, retune: Option<RetuneConfig>) -> Daemon {
        let (tx, rx) = channel();
        let flusher = std::thread::Builder::new()
            .name("bb-serve-flusher".to_string())
            .spawn(move || flusher_loop(server, rx, retune))
            .expect("spawning serve flusher thread");
        Daemon { tx, flusher }
    }

    /// A cloneable submission handle (e.g. one per load-generator
    /// thread).
    pub fn client(&self) -> DaemonClient {
        DaemonClient {
            tx: self.tx.clone(),
        }
    }

    /// Submit a request from the owning thread.
    pub fn submit(&self, req: Request) -> Ticket {
        submit_via(&self.tx, req)
    }

    /// Open a KV-cache session (see [`ModelServer::open_session`]) from
    /// the owning thread. Blocks for the flusher's round-trip.
    pub fn open_session(&self, workload: &str) -> anyhow::Result<u64> {
        open_session_via(&self.tx, workload)
    }

    /// Submit one decode step for an open session from the owning
    /// thread.
    pub fn submit_decode(&self, session: u64, inputs: HashMap<String, Mat>) -> Ticket {
        submit_decode_via(&self.tx, session, inputs)
    }

    /// Graceful drain: stop admitting, flush everything in flight, join
    /// the flusher, and return the server (with its final stats).
    pub fn shutdown(self) -> ModelServer {
        let Daemon { tx, flusher } = self;
        let _ = tx.send(Msg::Shutdown);
        drop(tx);
        // The flusher thread is panic-hardened (every launch body is
        // guarded); a join error would mean a bug in the loop itself and
        // is propagated honestly rather than masked.
        flusher
            .join()
            .unwrap_or_else(|p| std::panic::resume_unwind(p))
    }
}

impl DaemonClient {
    /// Submit a request; returns the [`Ticket`] its response arrives on.
    /// If the daemon has already shut down, the ticket resolves
    /// immediately to a [`Rejected::Shutdown`](super::Rejected::Shutdown)
    /// response.
    pub fn submit(&self, req: Request) -> Ticket {
        submit_via(&self.tx, req)
    }

    /// Open a KV-cache session (see [`ModelServer::open_session`]).
    /// Blocks for the flusher's round-trip; errors (unknown/stateless
    /// workload, shutdown) come back typed instead of hanging.
    pub fn open_session(&self, workload: &str) -> anyhow::Result<u64> {
        open_session_via(&self.tx, workload)
    }

    /// Submit one decode step for an open session; the step's inputs
    /// must match the session's pinned geometry
    /// ([`ModelServer::submit_decode`]).
    pub fn submit_decode(&self, session: u64, inputs: HashMap<String, Mat>) -> Ticket {
        submit_decode_via(&self.tx, session, inputs)
    }
}

fn submit_via(tx: &Sender<Msg>, req: Request) -> Ticket {
    let (rtx, rrx) = channel();
    if let Err(e) = tx.send(Msg::Submit(req, rtx)) {
        // Daemon gone: recover the message from the send error and
        // self-reply a typed rejection so the caller never hangs.
        if let Msg::Submit(req, rtx) = e.0 {
            let _ = rtx.send(Response::unserved(
                INVALID_ID,
                &req.workload,
                Verdict::Rejected(super::Rejected::Shutdown),
                0,
            ));
        }
    }
    Ticket { rx: rrx }
}

fn open_session_via(tx: &Sender<Msg>, workload: &str) -> anyhow::Result<u64> {
    let (rtx, rrx) = channel();
    if tx.send(Msg::OpenSession(workload.to_string(), rtx)).is_err() {
        anyhow::bail!("daemon already shut down");
    }
    rrx.recv()
        .unwrap_or_else(|_| Err(anyhow::anyhow!("daemon exited before opening the session")))
}

fn submit_decode_via(tx: &Sender<Msg>, session: u64, inputs: HashMap<String, Mat>) -> Ticket {
    let (rtx, rrx) = channel();
    if let Err(e) = tx.send(Msg::SubmitDecode(session, inputs, rtx)) {
        if let Msg::SubmitDecode(_, _, rtx) = e.0 {
            let _ = rtx.send(Response::unserved(
                INVALID_ID,
                "decode",
                Verdict::Rejected(super::Rejected::Shutdown),
                0,
            ));
        }
    }
    Ticket { rx: rrx }
}

/// The flusher thread: admit arrivals, sleep exactly until the next
/// queue is due, flush, route responses, and (optionally) re-tune —
/// until a shutdown message or every ingest handle is dropped.
fn flusher_loop(
    mut server: ModelServer,
    rx: Receiver<Msg>,
    retune: Option<RetuneConfig>,
) -> ModelServer {
    let mut waiters: HashMap<u64, Sender<Response>> = HashMap::new();
    let mut last_tuned: HashMap<String, u64> = HashMap::new();
    let mut tune_seed: u64 = 0x7e7e_0001;
    loop {
        let timeout = server
            .next_due()
            .map(|t| t.saturating_duration_since(Instant::now()))
            .unwrap_or(IDLE_TICK);
        match rx.recv_timeout(timeout) {
            Ok(Msg::Shutdown) | Err(RecvTimeoutError::Disconnected) => {
                return drain_and_return(server, rx, waiters);
            }
            Ok(msg) => {
                ingest(&mut server, msg, &mut waiters);
                // Burst drain: admit everything already queued on the
                // channel before flushing, so a burst forms full batches
                // instead of max_batch-1 stragglers.
                loop {
                    match rx.try_recv() {
                        Ok(Msg::Shutdown) | Err(TryRecvError::Disconnected) => {
                            return drain_and_return(server, rx, waiters);
                        }
                        Ok(msg) => ingest(&mut server, msg, &mut waiters),
                        Err(TryRecvError::Empty) => break,
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
        }
        for resp in server.poll() {
            route(resp, &mut waiters);
        }
        if let Some(rt) = &retune {
            maybe_retune(&mut server, rt, &mut last_tuned, &mut tune_seed);
        }
    }
}

/// Dispatch one non-shutdown ingest message. Session opens reply
/// inline (they never enter the request ledger); submits park their
/// reply channel with [`accept`]/[`accept_decode`].
fn ingest(server: &mut ModelServer, msg: Msg, waiters: &mut HashMap<u64, Sender<Response>>) {
    match msg {
        Msg::Submit(req, rtx) => accept(server, req, rtx, waiters),
        Msg::OpenSession(workload, rtx) => {
            let _ = rtx.send(server.open_session(&workload));
        }
        Msg::SubmitDecode(session, inputs, rtx) => {
            accept_decode(server, session, inputs, rtx, waiters)
        }
        Msg::Shutdown => {}
    }
}

/// Admit one arrival. Validation failures (unknown workload, bad
/// shapes) become immediate [`Verdict::Failed`] replies; everything
/// else gets an id and its reply channel parked until the response
/// routes.
fn accept(
    server: &mut ModelServer,
    req: Request,
    rtx: Sender<Response>,
    waiters: &mut HashMap<u64, Sender<Response>>,
) {
    let workload = req.workload.clone();
    match server.submit(req) {
        Ok(id) => {
            waiters.insert(id, rtx);
        }
        Err(e) => {
            let _ = rtx.send(Response::unserved(
                INVALID_ID,
                &workload,
                Verdict::Failed(e.to_string()),
                0,
            ));
        }
    }
}

/// Admit one decode step, mirroring [`accept`]: admission errors
/// (unknown/closed session, shape mismatch, full cache, shutdown)
/// become immediate typed replies; admitted steps park their reply
/// channel until the batched response routes.
fn accept_decode(
    server: &mut ModelServer,
    session: u64,
    inputs: HashMap<String, Mat>,
    rtx: Sender<Response>,
    waiters: &mut HashMap<u64, Sender<Response>>,
) {
    let workload = server
        .session_workload(session)
        .unwrap_or("decode")
        .to_string();
    match server.submit_decode(session, inputs) {
        Ok(id) => {
            waiters.insert(id, rtx);
        }
        Err(e) => {
            let _ = rtx.send(Response::unserved(
                INVALID_ID,
                &workload,
                Verdict::Failed(e.to_string()),
                0,
            ));
        }
    }
}

fn route(resp: Response, waiters: &mut HashMap<u64, Sender<Response>>) {
    if let Some(tx) = waiters.remove(&resp.id) {
        // A client that dropped its ticket is not an error.
        let _ = tx.send(resp);
    }
}

/// Graceful drain (see module docs): stop admitting, flush everything,
/// answer stragglers through the server (typed shutdown rejections),
/// and hand the server back.
fn drain_and_return(
    mut server: ModelServer,
    rx: Receiver<Msg>,
    mut waiters: HashMap<u64, Sender<Response>>,
) -> ModelServer {
    server.begin_shutdown();
    for resp in server.drain() {
        route(resp, &mut waiters);
    }
    // Submissions that raced the shutdown message: run them through the
    // server so they get counted, typed rejections (session opens get
    // the server's shutdown error the same way).
    while let Ok(msg) = rx.try_recv() {
        ingest(&mut server, msg, &mut waiters);
    }
    for resp in server.drain() {
        route(resp, &mut waiters);
    }
    server
}

/// Between-batch re-tuning: once a workload has served
/// [`RetuneConfig::every`] more requests since its last tune, measure
/// and (maybe) hot-swap. Failures are logged, never fatal — the daemon
/// keeps serving on the live plan.
fn maybe_retune(
    server: &mut ModelServer,
    rt: &RetuneConfig,
    last_tuned: &mut HashMap<String, u64>,
    tune_seed: &mut u64,
) {
    if rt.every == 0 {
        return;
    }
    let names: Vec<String> = server.workloads().to_vec();
    for name in names {
        let served = server
            .stats()
            .per_program
            .get(&name)
            .map(|s| s.served)
            .unwrap_or(0);
        let prev = *last_tuned.entry(name.clone()).or_insert(0);
        if served.saturating_sub(prev) < rt.every {
            continue;
        }
        *tune_seed = tune_seed
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        match server.retune_and_swap(&name, rt.local_capacity, rt.trials, *tune_seed) {
            Ok(_) => {}
            Err(e) => eprintln!("serve: re-tune of {name} failed (still serving): {e}"),
        }
        last_tuned.insert(name, served);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{Rejected, ServerConfig};

    #[test]
    fn daemon_serves_and_drains_on_shutdown() {
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            threads: Some(1),
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        let reqs: Vec<Request> = (0..6)
            .map(|i| Request::new("quickstart", s.synthetic_inputs("quickstart", i).unwrap()))
            .collect();
        let daemon = Daemon::start(s, None);
        let client = daemon.client();
        let tickets: Vec<Ticket> = reqs.into_iter().map(|r| client.submit(r)).collect();
        let responses: Vec<Response> = tickets.into_iter().map(|t| t.wait()).collect();
        assert_eq!(responses.len(), 6);
        assert!(responses.iter().all(|r| r.is_ok()));
        let server = daemon.shutdown();
        let st = &server.stats().per_program["quickstart"];
        assert_eq!(st.served, 6);
        assert_eq!(st.accounted(), st.submitted);
    }

    /// The flusher honors `max_wait` without anyone polling: one lone
    /// request (batch never fills) still completes.
    #[test]
    fn flusher_honors_max_wait_without_polling() {
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(20),
            threads: Some(1),
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        let req = Request::new("quickstart", s.synthetic_inputs("quickstart", 3).unwrap());
        let daemon = Daemon::start(s, None);
        let t0 = Instant::now();
        let resp = daemon.submit(req).wait();
        assert!(resp.is_ok());
        assert!(
            t0.elapsed() >= Duration::from_millis(20),
            "a lone request must ride the max_wait latency bound"
        );
        daemon.shutdown();
    }

    /// Regression for the network edge's disconnect path: a client that
    /// vanishes before its reply arrives drops its [`Ticket`] receiver.
    /// Every reply-send site (`route`, `submit_via`, `accept`'s
    /// validation failures) must treat that as a no-op — never panic,
    /// never count the request twice. The requests still *execute* and
    /// the server ledger still reconciles exactly.
    #[test]
    fn dropped_ticket_receivers_never_panic_or_double_count() {
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            threads: Some(1),
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        let inputs: Vec<_> = (0..8)
            .map(|i| s.synthetic_inputs("quickstart", i).unwrap())
            .collect();
        let daemon = Daemon::start(s, None);
        let client = daemon.client();
        let mut kept = Vec::new();
        for (i, input) in inputs.into_iter().enumerate() {
            let ticket = client.submit(Request::new("quickstart", input));
            if i % 2 == 0 {
                // Client gone before the reply: the receiver drops here,
                // possibly while the flusher is mid-route.
                drop(ticket);
            } else {
                kept.push(ticket);
            }
        }
        for t in kept {
            assert!(t.wait().is_ok(), "surviving clients still get replies");
        }
        let server = daemon.shutdown();
        let st = &server.stats().per_program["quickstart"];
        assert_eq!(st.submitted, 8);
        assert_eq!(st.served, 8, "dropped receivers do not cancel execution");
        assert_eq!(st.accounted(), st.submitted, "no double counting");

        // Validation failures route through the same reply channel;
        // dropping that ticket immediately must be just as harmless.
        let mut s2 = ModelServer::new(ServerConfig {
            threads: Some(1),
            ..ServerConfig::default()
        });
        s2.register("quickstart").unwrap();
        let daemon = Daemon::start(s2, None);
        drop(daemon.submit(Request::new("quickstart", HashMap::new())));
        let server = daemon.shutdown();
        let st = &server.stats().per_program["quickstart"];
        assert_eq!(st.submitted, 0, "validation failures never enter the ledger");
    }

    #[test]
    fn submit_after_shutdown_self_replies_rejected() {
        let mut s = ModelServer::new(ServerConfig {
            threads: Some(1),
            ..ServerConfig::default()
        });
        s.register("quickstart").unwrap();
        let req = Request::new("quickstart", s.synthetic_inputs("quickstart", 0).unwrap());
        let daemon = Daemon::start(s, None);
        let client = daemon.client();
        daemon.shutdown();
        let resp = client.submit(req).wait();
        assert_eq!(resp.verdict, Verdict::Rejected(Rejected::Shutdown));
        assert_eq!(resp.id, INVALID_ID);
    }

    /// Decode sessions over the daemon RPC surface: open, step the
    /// cache to length 3, and reconcile the ledger on shutdown. Post-
    /// shutdown session opens and steps fail typed instead of hanging.
    #[test]
    fn daemon_decode_sessions_roundtrip() {
        let mut s = ModelServer::new(ServerConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            threads: Some(1),
            ..ServerConfig::default()
        });
        s.register("decode_attention").unwrap();
        let steps: Vec<_> = (1..=3)
            .map(|t| s.synthetic_decode_inputs("decode_attention", 7, t).unwrap())
            .collect();
        let daemon = Daemon::start(s, None);
        let client = daemon.client();
        assert!(client.open_session("quickstart").is_err(), "unknown workload");
        let sid = client.open_session("decode_attention").unwrap();
        for (i, inputs) in steps.into_iter().enumerate() {
            let resp = client.submit_decode(sid, inputs).wait();
            assert!(resp.is_ok(), "decode step {}: {:?}", i + 1, resp.verdict);
            assert!(resp.outputs.contains_key("O"), "decode steps carry outputs");
        }
        let stray = client.submit_decode(sid + 1, HashMap::new()).wait();
        assert_eq!(stray.id, INVALID_ID);
        assert!(matches!(stray.verdict, Verdict::Failed(_)), "unknown session fails typed");
        let server = daemon.shutdown();
        assert_eq!(server.session_len(sid), Some(3), "cache grew one block per step");
        let st = &server.stats().per_program["decode_attention"];
        assert_eq!(st.decode_steps, 3);
        assert_eq!(st.sessions_opened, 1);
        assert_eq!(st.accounted(), st.submitted);
        assert!(client.open_session("decode_attention").is_err(), "daemon gone");
        let resp = client.submit_decode(sid, HashMap::new()).wait();
        assert_eq!(resp.verdict, Verdict::Rejected(Rejected::Shutdown));
    }
}
