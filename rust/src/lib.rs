//! # Blockbuster — block-level AI operator fusion
//!
//! A production-grade reproduction of *"Blockbuster, Part 1: Block-level AI
//! Operator Fusion"* (Dekel, 2025). The library implements the paper's three
//! pillars plus every substrate they depend on:
//!
//! * [`ir`] — the **block program** representation: a hierarchical DAG whose
//!   nodes are functional / map / reduction / miscellaneous operators and
//!   whose edges are buffered (global memory) or unbuffered (local memory).
//! * [`rules`] — the nine logic-preserving **substitution rules** of §3.
//! * [`fusion`] — the rule-based **fusion algorithm** of §4
//!   (`fuse_no_extend`, breadth-first application, map extension, snapshots).
//! * [`array`] + [`lower`] — the array-program layer and the Table-2 lookup
//!   that converts array operators into block-program subgraphs.
//! * [`select`] — a fusion-candidate selection algorithm implementing the
//!   contract the paper defers to its companion paper.
//! * [`loopir`] — the loop-nest IR used to print the paper's code listings,
//!   to statically analyse memory traffic, and to execute block programs.
//!   `loopir::compile` flattens the loop nest in two phases: a
//!   size-independent **tape skeleton** (trip counts symbolic, elementwise
//!   expressions pre-compiled, every `forall` — top-level or nested —
//!   carrying a parallel-safety annotation) plus a cheap per-`DimSizes`
//!   **bind** of trip counts and stride tables.
//! * [`tensor`] — the dense f32 substrate; its hot kernels sit on
//!   `tensor::simd`, an explicit 8-lane SIMD layer (runtime-dispatched
//!   AVX2 behind the `simd` cargo feature, with a scalar fallback that
//!   follows the identical canonical reduction order — so vector and
//!   scalar results are bit-identical).
//! * [`exec`] — a two-tier-memory execution substrate that runs block
//!   programs on concrete data behind an `ExecBackend` switch:
//!   `Interp` tree-walks the loop nest (the semantic ground truth),
//!   `Compiled` executes the flat tape with SIMD kernels, a batched
//!   elementwise expression VM (`ir::exprvm`, slice-at-a-time instead
//!   of per-element), and a work-stealing grid-loop scheduler
//!   (`exec::sched`) draining a persistent parked worker pool
//!   (`exec::pool`), fanning out nested grids when the top level is
//!   serial — bit-identical outputs and traffic counters, several
//!   times faster. `exec::TapeCache` shares tape skeletons across
//!   executions that differ only in block counts (the autotuner's
//!   measured-trial loop).
//! * [`cost`] + [`autotune`] — the traffic/compute cost model and the block
//!   shape autotuner the paper's epilogues rely on.
//! * [`stabilize`] — the Appendix's numerical-safety pass
//!   (significand–exponent pairs / online softmax).
//! * [`runtime`] — PJRT client wrapper: loads AOT artifacts produced by the
//!   Python build path (`python/compile/aot.py`) and executes them.
//! * [`coordinator`] — the end-to-end compiler driver and CLI plumbing;
//!   `coordinator::prepare_plan` splits plan execution into a
//!   compile-once [`coordinator::PreparedPlan`] and a zero-compilation
//!   per-request `coordinator::execute_prepared` hot path.
//! * [`serve`] — the compile-once/execute-many serving layer:
//!   `serve::ModelServer` holds prepared plans for all registered
//!   workloads, coalesces queued requests into dynamically-sized batches
//!   (size- and latency-bound flushes), and drains mixed-program traffic
//!   round-robin through the persistent worker pool — outputs and
//!   traffic counters bit-identical to sequential execution.
//!
//! Python (JAX + Pallas) exists only on the *build path*: it authors the
//! reference models and fused Pallas kernels and AOT-lowers them to HLO text
//! artifacts; the Rust binary is self-contained afterwards.
//!
//! ---
//!
//! The repository guides are included below verbatim so docs.rs-style
//! output carries them; they live at the repo root as `README.md` and
//! `ARCHITECTURE.md`.
//!
//! # Repository README
#![doc = include_str!("../../README.md")]
//!
//! # Architecture guide
#![doc = include_str!("../../ARCHITECTURE.md")]

pub mod array;
pub mod autotune;
pub mod coordinator;
pub mod cost;
pub mod exec;
pub mod fusion;
pub mod ir;
pub mod lower;
pub mod loopir;
pub mod prop;
pub mod rules;
pub mod runtime;
pub mod select;
pub mod serve;
pub mod stabilize;
pub mod tensor;
pub mod util;

pub use ir::graph::{Graph, Node, NodeId, NodeKind, Port};
pub use ir::types::{Item, Ty};
