//! Fusion-candidate selection.
//!
//! The paper defers its provably-optimal selection algorithm to an
//! unpublished companion paper, but fully specifies the *contract* (§1, §4):
//! the selector picks candidate subgraphs made of standard operators, sends
//! each to the fusion algorithm, receives multiple fused snapshots per
//! candidate, evaluates them, and chooses the optimal set of kernels that
//! implements the whole block program — also guarding against excessive
//! fusion so the fusion algorithm never has to.
//!
//! This module implements that contract with an interval dynamic program:
//! top-level operators are linearized in topological order; every contiguous
//! interval free of miscellaneous operators is a candidate (contiguous topo
//! intervals are convex, so extraction is always legal); each candidate is
//! fused, every snapshot is scored with the static cost model, and a
//! shortest-path DP picks the minimum-cost partition into kernels.

use crate::cost::{analyze, CostModel, ShapeEnv, VShape};
use crate::fusion::fuse;
use crate::ir::graph::{port, Graph, NodeId, NodeKind, Port};
use crate::ir::types::Ty;
use crate::loopir::lower::lower;
use std::collections::HashMap;

/// Where a segment input comes from at execution time.
#[derive(Clone, Debug, PartialEq)]
pub enum ValueRef {
    /// A program input buffer (by name).
    ProgramInput(String),
    /// Output `label` of an earlier segment.
    SegmentOutput { segment: usize, label: String },
}

/// One chosen kernel: a fused standalone block program plus its I/O wiring.
#[derive(Clone, Debug)]
pub struct Segment {
    /// Original top-level node ids covered by this kernel.
    pub node_ids: Vec<NodeId>,
    /// The fused block program (best snapshot).
    pub graph: Graph,
    /// Which fusion snapshot was chosen (0 = least replication).
    pub snapshot_index: usize,
    /// For each graph input label: where its value comes from.
    pub inputs: Vec<(String, ValueRef)>,
    /// For each graph output label: the program output it implements, if any.
    pub outputs: Vec<(String, Option<String>)>,
    pub cost_scalar: f64,
}

/// The selected implementation of a block program.
#[derive(Clone, Debug)]
pub struct SelectionPlan {
    pub segments: Vec<Segment>,
    pub total_cost: f64,
}

/// Context needed to score candidates.
pub struct SelectCtx {
    pub sizes: crate::ir::dim::DimSizes,
    /// Full shapes of program inputs (rows, cols).
    pub full_shapes: HashMap<String, (usize, usize)>,
    pub model: CostModel,
}

impl SelectCtx {
    /// Item shapes of every top-level value of `g` (graph-level inference).
    fn port_shapes(&self, g: &Graph) -> HashMap<Port, VShape> {
        infer_port_shapes(g, &self.input_shapes(g))
    }

    fn input_shapes(&self, g: &Graph) -> HashMap<String, VShape> {
        let mut m = HashMap::new();
        for id in g.input_ids() {
            let name = &g.node(id).label;
            let ty = g.input_ty(id);
            let (rows, cols) = *self
                .full_shapes
                .get(name)
                .unwrap_or_else(|| panic!("no full shape for program input {name}"));
            assert_eq!(ty.dims.len(), 2);
            let rb = self.sizes.get(&ty.dims[0]);
            let cb = self.sizes.get(&ty.dims[1]);
            m.insert(name.clone(), VShape::Block(rows / rb, cols / cb));
        }
        m
    }
}

/// Infer the item shape of every output port at the top level of `g`
/// (recursing through maps; item shapes are invariant under list nesting).
pub fn infer_port_shapes(
    g: &Graph,
    input_shapes: &HashMap<String, VShape>,
) -> HashMap<Port, VShape> {
    fn go(
        g: &Graph,
        in_shapes: &HashMap<NodeId, VShape>,
        out: &mut HashMap<Port, VShape>,
    ) {
        for id in g.topo_order() {
            let n = g.node(id);
            match &n.kind {
                NodeKind::Input { .. } => {
                    out.insert(port(id, 0), in_shapes[&id]);
                }
                NodeKind::Output => {}
                NodeKind::Func(f) => {
                    let args: Vec<VShape> = (0..f.arity())
                        .map(|i| out[&g.producer(port(id, i)).unwrap()])
                        .collect();
                    let (sh, _) =
                        crate::cost::shape_of_func(f, &args);
                    out.insert(port(id, 0), sh);
                }
                NodeKind::Reduce(_) | NodeKind::Head => {
                    let s = out[&g.producer(port(id, 0)).unwrap()];
                    out.insert(port(id, 0), s);
                }
                NodeKind::Concat { .. } => {
                    let s = out[&g.producer(port(id, 0)).unwrap()];
                    out.insert(port(id, 0), s);
                }
                NodeKind::Misc { .. } => {
                    let s = out[&g.producer(port(id, 0)).unwrap()];
                    out.insert(port(id, 0), s);
                }
                NodeKind::Map(m) => {
                    let mut inner_in = HashMap::new();
                    for (i, mi) in m.inputs.iter().enumerate() {
                        let s = out[&g.producer(port(id, i)).unwrap()];
                        inner_in.insert(mi.inner_input, s);
                    }
                    let mut inner_out = HashMap::new();
                    go(&m.inner, &inner_in, &mut inner_out);
                    for (j, mo) in m.outputs.iter().enumerate() {
                        let src = m.inner.producer(port(mo.inner_output, 0)).unwrap();
                        out.insert(port(id, j), inner_out[&src]);
                    }
                }
            }
        }
    }
    let mut in_shapes = HashMap::new();
    for id in g.input_ids() {
        in_shapes.insert(id, input_shapes[&g.node(id).label]);
    }
    let mut out = HashMap::new();
    go(g, &in_shapes, &mut out);
    out
}

/// Extract the contiguous-interval candidate as a standalone block program.
/// Returns (graph, input wiring, output wiring).
#[allow(clippy::type_complexity)]
fn extract_candidate(
    g: &Graph,
    interval: &[NodeId],
) -> (Graph, Vec<(String, Port)>, Vec<(String, Port)>) {
    let inside: std::collections::HashSet<NodeId> = interval.iter().copied().collect();
    let mut cg = Graph::new();
    let mut remap: HashMap<NodeId, NodeId> = HashMap::new();
    for &id in interval {
        let n = g.node(id);
        let nid = cg.add_node(n.kind.clone(), n.label.clone());
        remap.insert(id, nid);
    }
    // inputs: every distinct outside source feeding the interval
    let mut in_wiring: Vec<(String, Port)> = Vec::new();
    let mut in_ports: HashMap<Port, Port> = HashMap::new(); // outer src -> candidate input port
    for &id in interval {
        for i in 0..g.node(id).in_arity() {
            let s = g.producer(port(id, i)).expect("unconnected input");
            let dst = port(remap[&id], i);
            if inside.contains(&s.node) {
                cg.connect(port(remap[&s.node], s.port), dst);
            } else {
                let cin = *in_ports.entry(s).or_insert_with(|| {
                    let label = format!("CIN{}", in_wiring.len());
                    let ty: Ty = g.out_ty(s);
                    let p = cg.input(label.clone(), ty);
                    in_wiring.push((label, s));
                    p
                });
                cg.connect(cin, dst);
            }
        }
    }
    // outputs: every interval value consumed outside (or by program outputs)
    let mut out_wiring: Vec<(String, Port)> = Vec::new();
    for &id in interval {
        for j in 0..g.node(id).out_arity() {
            let consumers = g.consumers(port(id, j));
            let escapes = consumers.iter().any(|c| !inside.contains(&c.node));
            if escapes {
                let label = format!("COUT{}", out_wiring.len());
                cg.output(label.clone(), port(remap[&id], j));
                out_wiring.push((label, port(id, j)));
            }
        }
    }
    (cg, in_wiring, out_wiring)
}

/// Score a standalone candidate: fuse it, cost every snapshot, return the
/// best (cost, snapshot index, fused graph).
fn best_fusion(
    cg: &Graph,
    shapes: &HashMap<String, VShape>,
    ctx: &SelectCtx,
) -> (f64, usize, Graph) {
    let res = fuse(cg.clone());
    let mut best: Option<(f64, usize, Graph)> = None;
    for (i, snap) in res.snapshots.iter().enumerate() {
        let ir = lower(snap);
        let env = ShapeEnv {
            inputs: shapes.clone(),
        };
        let c = analyze(&ir, &ctx.sizes, &env);
        let s = ctx.model.scalar(&c);
        if best.as_ref().map(|(b, _, _)| s < *b).unwrap_or(true) {
            best = Some((s, i, snap.clone()));
        }
    }
    best.expect("fuse returned no snapshots")
}

/// Run selection over the top level of a block program.
pub fn select(g: &Graph, ctx: &SelectCtx) -> SelectionPlan {
    let port_shapes = ctx.port_shapes(g);
    let ops: Vec<NodeId> = g
        .topo_order()
        .into_iter()
        .filter(|&i| !g.node(i).is_io())
        .collect();
    let n = ops.len();
    assert!(n > 0, "select: empty program");

    let splittable = |id: NodeId| matches!(g.node(id).kind, NodeKind::Misc { .. });

    // Score every legal interval [i, j).
    let mut interval: HashMap<(usize, usize), (f64, usize, Graph)> = HashMap::new();
    for i in 0..n {
        for j in i + 1..=n {
            let nodes = &ops[i..j];
            if nodes.iter().any(|&id| splittable(id)) && nodes.len() > 1 {
                continue; // misc ops live in singleton segments only
            }
            if nodes.len() == 1 && splittable(nodes[0]) {
                // a misc op runs as its own (unfusable) kernel
                let (cg, inw, _outw) = extract_candidate(g, nodes);
                let shapes: HashMap<String, VShape> = inw
                    .iter()
                    .map(|(l, s)| (l.clone(), port_shapes[s]))
                    .collect();
                let ir = lower(&cg);
                let env = ShapeEnv { inputs: shapes };
                let c = analyze(&ir, &ctx.sizes, &env);
                interval.insert((i, j), (ctx.model.scalar(&c), 0, cg));
                continue;
            }
            let (cg, inw, _outw) = extract_candidate(g, nodes);
            let shapes: HashMap<String, VShape> = inw
                .iter()
                .map(|(l, s)| (l.clone(), port_shapes[s]))
                .collect();
            let (cost, snap_ix, fused) = best_fusion(&cg, &shapes, ctx);
            interval.insert((i, j), (cost, snap_ix, fused));
        }
    }

    // Shortest-path DP over the linearization.
    let mut dp: Vec<f64> = vec![f64::INFINITY; n + 1];
    let mut back: Vec<usize> = vec![0; n + 1];
    dp[0] = 0.0;
    for j in 1..=n {
        for i in 0..j {
            if let Some((c, _, _)) = interval.get(&(i, j)) {
                if dp[i] + c < dp[j] {
                    dp[j] = dp[i] + c;
                    back[j] = i;
                }
            }
        }
        assert!(dp[j].is_finite(), "no legal segmentation ending at {j}");
    }

    // Reconstruct segments in order.
    let mut cuts = vec![n];
    let mut j = n;
    while j > 0 {
        j = back[j];
        cuts.push(j);
    }
    cuts.reverse();

    // program-output lookup: source port -> output name
    let mut prog_out: HashMap<Port, String> = HashMap::new();
    for oid in g.output_ids() {
        let s = g.producer(port(oid, 0)).unwrap();
        prog_out.insert(s, g.node(oid).label.clone());
    }

    let mut segments: Vec<Segment> = Vec::new();
    let mut produced: HashMap<Port, (usize, String)> = HashMap::new(); // source port -> (segment, label)
    for w in cuts.windows(2) {
        let (i, j) = (w[0], w[1]);
        let nodes = ops[i..j].to_vec();
        let (cg, inw, outw) = extract_candidate(g, &nodes);
        let (cost, snap_ix, mut fused) = interval[&(i, j)].clone();
        let seg_ix = segments.len();
        let inputs: Vec<(String, ValueRef)> = inw
            .iter()
            .map(|(label, src)| {
                let vr = if let Some((seg, out_label)) = produced.get(src) {
                    ValueRef::SegmentOutput {
                        segment: *seg,
                        label: out_label.clone(),
                    }
                } else {
                    let name = g.node(src.node).label.clone();
                    ValueRef::ProgramInput(name)
                };
                (label.clone(), vr)
            })
            .collect();
        let outputs: Vec<(String, Option<String>)> = outw
            .iter()
            .map(|(label, src)| {
                produced.insert(*src, (seg_ix, label.clone()));
                (label.clone(), prog_out.get(src).cloned())
            })
            .collect();
        let _ = cg;
        // Stateful-buffer marks survive fusion: a segment input fed by a
        // stateful *program* input inherits its growth dim under the
        // segment-local label, so `loopir::lower` can tag the `BufDecl`.
        for (label, vr) in &inputs {
            if let ValueRef::ProgramInput(name) = vr {
                if let Some(dim) = g.state_dim(name) {
                    fused.mark_state(label.clone(), dim.clone());
                }
            }
        }
        segments.push(Segment {
            node_ids: nodes,
            graph: fused,
            snapshot_index: snap_ix,
            inputs,
            outputs,
            cost_scalar: cost,
        });
    }

    SelectionPlan {
        segments,
        total_cost: dp[n],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::programs;
    use crate::ir::dim::DimSizes;
    use crate::lower::lower_array;

    fn ctx_attention() -> SelectCtx {
        let mut full = HashMap::new();
        full.insert("Q".to_string(), (8, 16));
        full.insert("KT".to_string(), (12, 16));
        full.insert("VT".to_string(), (10, 12));
        SelectCtx {
            sizes: DimSizes::of(&[("M", 2), ("N", 3), ("D", 2), ("L", 2)]),
            full_shapes: full,
            model: CostModel::default(),
        }
    }

    #[test]
    fn attention_selects_single_fused_kernel() {
        let g = lower_array(&programs::attention());
        let plan = select(&g, &ctx_attention());
        // fully fusing attention is strictly cheaper than any split
        assert_eq!(plan.segments.len(), 1, "plan: {plan:?}");
        // the selector may legitimately prefer the pre-extension snapshot
        // (no work replication) over the mega-kernel — but either way the
        // chosen kernel is far more fused than the 7-operator original
        assert!(crate::rules::map_ids(&plan.segments[0].graph).len() <= 2);
        // the single segment implements the program output O
        assert!(plan.segments[0]
            .outputs
            .iter()
            .any(|(_, o)| o.as_deref() == Some("O")));
    }

    #[test]
    fn custom_op_forces_split() {
        let g = lower_array(&programs::with_custom_op());
        let mut full = HashMap::new();
        full.insert("X".to_string(), (8, 8));
        let ctx = SelectCtx {
            sizes: DimSizes::of(&[("M", 2), ("K", 2)]),
            full_shapes: full,
            model: CostModel::default(),
        };
        let plan = select(&g, &ctx);
        assert!(
            plan.segments.len() >= 3,
            "custom op must sit in its own segment: {:?}",
            plan.segments.len()
        );
    }

    #[test]
    fn plan_wiring_is_consistent() {
        let g = lower_array(&programs::with_custom_op());
        let mut full = HashMap::new();
        full.insert("X".to_string(), (8, 8));
        let ctx = SelectCtx {
            sizes: DimSizes::of(&[("M", 2), ("K", 2)]),
            full_shapes: full,
            model: CostModel::default(),
        };
        let plan = select(&g, &ctx);
        for (si, seg) in plan.segments.iter().enumerate() {
            for (_, vr) in &seg.inputs {
                if let ValueRef::SegmentOutput { segment, .. } = vr {
                    assert!(*segment < si, "segment {si} depends on later segment");
                }
            }
        }
        // exactly one segment output implements the program output Y
        let count = plan
            .segments
            .iter()
            .flat_map(|s| &s.outputs)
            .filter(|(_, o)| o.as_deref() == Some("Y"))
            .count();
        assert_eq!(count, 1);
    }
}
