//! Persistent worker pool for the compiled engine's parallel regions.
//!
//! PR 1/2 fanned every parallel region out through a fresh
//! `std::thread::scope` — correct, but each region paid thread
//! spawn+join (tens of microseconds), which nested fan-out re-paid *per
//! enclosing iteration* and small grids could never amortize. This
//! module replaces the per-region scope with one process-wide pool:
//!
//! * **lazily initialized** — no threads exist until the first region
//!   actually fans out (threads=1 executions never touch the pool);
//! * **capped** — worker count only grows to the largest fan-out ever
//!   requested, clamped to [`crate::exec::engine::MAX_WORKERS`]; workers
//!   are never torn down (they park on a condvar between jobs, costing
//!   only an idle stack);
//! * **epoch-based job handoff** — a region submission bumps an epoch
//!   under the state lock and publishes one job (a `Fn(usize)` run once
//!   per worker index); parked workers wake on the epoch change, run
//!   their index if it is in range, and check in. The submitter blocks
//!   until every participating worker has checked in, so the job's
//!   borrowed environment (tape, buffers, steal queue, seed files) is
//!   guaranteed dead before [`WorkerPool::run`] returns — which is what
//!   makes the one `unsafe` lifetime erasure below sound.
//!
//! Worker panics are caught and re-raised on the submitting thread with
//! the original payload (capacity and read-before-assignment diagnostics
//! survive pooling exactly as they survived scoped threads). A job
//! submitted *from* a pool worker (impossible today — workers execute
//! with fan-out disabled — but cheap insurance) runs inline on the
//! caller rather than deadlocking on its own pool.
//!
//! **Known trade-off:** the pool runs one job at a time — concurrent
//! submitters (two executions driven from different OS threads in one
//! process) serialize their parallel regions on the submit lock, where
//! the old scoped engine let each execution spawn its own threads.
//! Single-execution callers (the CLI, benches, the autotuner's trial
//! loop) are unaffected; if concurrent in-process executions ever become
//! a hot path, the handoff needs per-job state instead of one slot.
//!
//! Determinism: the pool only changes *where* worker bodies run, not what
//! they compute or how results merge — outputs and `MemSim` counters
//! stay bit-identical to the scoped-thread engine and to the
//! interpreter (pinned by `tests/pool_stress.rs` and the parity suites).

use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Condvar, Mutex, MutexGuard, OnceLock};
use std::thread;

use super::engine::MAX_WORKERS;
use crate::util::fault;

/// Type-erased job: run once per participating worker index.
type JobFn = dyn Fn(usize) + Sync;

/// Raw job pointer shipped to workers. Lifetime-erased; validity is
/// guaranteed by [`WorkerPool::run`] blocking until all check-ins.
#[derive(Clone, Copy)]
struct JobPtr(*const JobFn);
// SAFETY: the pointee is `Sync` (shared by all workers by construction)
// and outlives every dereference (see module docs on the handoff
// protocol), so shipping the pointer across threads is sound.
unsafe impl Send for JobPtr {}

struct State {
    /// Bumped once per submission; workers detect new work by comparing
    /// against the last epoch they served.
    epoch: u64,
    /// The most recently published job and its worker count. `None` only
    /// before the first submission ever — the slot is deliberately *not*
    /// cleared on completion, so a slow non-participating worker that
    /// wakes after a job finished observes a stale (possibly dangling)
    /// entry; that is sound because it only *copies* the pointer and,
    /// seeing `w >= nw`, never dereferences it. A worker with `w < nw`
    /// is a participant, and the submitter cannot return (ending the
    /// pointee's lifetime) until that worker's check-in — which happens
    /// strictly after its dereference.
    job: Option<(JobPtr, usize)>,
    /// Workers spawned so far (monotone, ≤ [`MAX_WORKERS`]).
    spawned: usize,
    /// Participating workers that have not yet checked in.
    unfinished: usize,
    /// First worker panic of the current job, re-raised by the submitter.
    panic: Option<Box<dyn std::any::Any + Send>>,
    /// Indexes of workers that died (today only via injected faults —
    /// see [`crate::util::fault`]); respawned by the next submission so
    /// a worker death never strands future jobs.
    dead: Vec<usize>,
    /// Workers respawned after a death (monotone; chaos-suite telemetry).
    respawns: u64,
}

/// The process-wide persistent worker pool (see module docs).
pub struct WorkerPool {
    state: Mutex<State>,
    /// Workers park here between jobs.
    work_cv: Condvar,
    /// The submitter parks here until all check-ins.
    done_cv: Condvar,
    /// Serializes submitters (defense in depth: the engine only ever
    /// submits from the main execution thread).
    submit: Mutex<()>,
}

thread_local! {
    /// Set on pool worker threads; routes re-entrant submissions inline.
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// The global pool instance (created empty; threads spawn on first use).
pub fn global() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| WorkerPool {
        state: Mutex::new(State {
            epoch: 0,
            job: None,
            spawned: 0,
            unfinished: 0,
            panic: None,
            dead: Vec::new(),
            respawns: 0,
        }),
        work_cv: Condvar::new(),
        done_cv: Condvar::new(),
        submit: Mutex::new(()),
    })
}

impl WorkerPool {
    /// Lock the pool state, recovering from poison: every state mutation
    /// here is a plain counter/slot update that stays consistent even if
    /// a holder unwound mid-critical-section, so a poisoned lock must
    /// degrade to a recoverable condition, not take the daemon down.
    fn st(&self) -> MutexGuard<'_, State> {
        self.state
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Run `f(0)`, …, `f(nw-1)`, one call per pool worker, and block
    /// until all have finished. Panics in any call are re-raised here
    /// with their original payload. `nw` is clamped to [`MAX_WORKERS`];
    /// `nw == 0` is a no-op.
    pub fn run(&'static self, nw: usize, f: &(dyn Fn(usize) + Sync)) {
        if nw == 0 {
            return;
        }
        if IN_POOL_WORKER.with(|c| c.get()) {
            // Re-entrant submission from a worker body: run inline
            // instead of deadlocking on our own handoff.
            for w in 0..nw {
                f(w);
            }
            return;
        }
        let nw = nw.min(MAX_WORKERS);
        // A propagated worker panic unwinds `run` while this guard is
        // held, poisoning the mutex; the lock protects no data (it only
        // serializes submitters), so poisoning is recovered, not fatal.
        let _submit = self
            .submit
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        // Lifetime erasure (fat reference → 'static fat pointer; the
        // pointer-to-pointer step ignores trait-object lifetime bounds):
        // `f` must stay alive until every worker checks in, which the
        // wait loop below enforces before returning.
        let job = JobPtr(f as *const _ as *const JobFn);
        {
            let mut st = self.st();
            // Respawn any workers that died since the last job (injected
            // faults kill worker threads *after* check-in, so a death
            // never hangs the job it happened in — but the index must be
            // re-staffed before the next job can count on it).
            while let Some(w) = st.dead.pop() {
                let seen = st.epoch;
                thread::Builder::new()
                    .name(format!("bb-pool-{w}"))
                    .spawn(move || worker_loop(global(), w, seen))
                    .expect("respawning pool worker");
                st.respawns += 1;
            }
            while st.spawned < nw {
                let w = st.spawned;
                let seen = st.epoch;
                thread::Builder::new()
                    .name(format!("bb-pool-{w}"))
                    .spawn(move || worker_loop(global(), w, seen))
                    .expect("spawning pool worker");
                st.spawned += 1;
            }
            st.epoch += 1;
            st.job = Some((job, nw));
            st.unfinished = nw;
        }
        self.work_cv.notify_all();
        let mut st = self.st();
        while st.unfinished > 0 {
            st = self
                .done_cv
                .wait(st)
                .unwrap_or_else(|poisoned| poisoned.into_inner());
        }
        // `st.job` is intentionally left stale (see its field docs).
        let panic = st.panic.take();
        drop(st);
        if let Some(p) = panic {
            resume_unwind(p);
        }
    }

    /// Heterogeneous job handoff: run `f(0)`, …, `f(n_tasks-1)` — one
    /// call per **task**, not per worker — across up to `nw` pool
    /// workers, worker `w` draining the strided run `w, w+nw, …`.
    /// Blocks until every task has finished; worker panics re-raise on
    /// the caller with their original payload (via [`WorkerPool::run`]).
    ///
    /// Where [`WorkerPool::run`] hands every worker the *same* body
    /// parameterized by worker index (homogeneous grid chunks), this
    /// entry point lets each task index select arbitrarily different
    /// work — the serving layer uses it to coalesce a batch of requests
    /// into one pool submission, each task executing one request's plan.
    /// `nw <= 1` (or a single task) runs inline on the caller, touching
    /// no threads — mirroring the engine's threads=1 serial-path rule.
    pub fn run_tasks(&'static self, nw: usize, n_tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        let nw = nw.min(n_tasks).min(MAX_WORKERS);
        if nw <= 1 {
            for t in 0..n_tasks {
                f(t);
            }
            return;
        }
        self.run(nw, &|w| {
            let mut t = w;
            while t < n_tasks {
                f(t);
                t += nw;
            }
        });
    }

    /// Worker threads spawned so far — monotone and ≤ [`MAX_WORKERS`]
    /// (the stress suite's leak/cap check). Respawns reuse their dead
    /// predecessor's index and do **not** grow this count.
    pub fn spawned(&self) -> usize {
        self.st().spawned
    }

    /// Workers respawned after an (injected) death — the chaos suite's
    /// evidence that worker mortality is survived, not just avoided.
    pub fn respawns(&self) -> u64 {
        self.st().respawns
    }
}

/// The parked-worker loop: wait for an epoch bump, serve the job if this
/// worker's index participates, check in, re-park.
fn worker_loop(pool: &'static WorkerPool, w: usize, mut seen: u64) {
    IN_POOL_WORKER.with(|c| c.set(true));
    loop {
        let (job, nw) = {
            let mut st = pool.st();
            loop {
                if st.epoch != seen {
                    // An epoch bump always publishes a job first; the
                    // entry may be stale if this worker slept through
                    // completed epochs, in which case `w >= nw` below
                    // keeps the (possibly dangling) pointer untouched —
                    // see the `State::job` field docs.
                    let (job, nw) = st.job.expect("epoch bumped without a job");
                    seen = st.epoch;
                    break (job, nw);
                }
                st = pool
                    .work_cv
                    .wait(st)
                    .unwrap_or_else(|poisoned| poisoned.into_inner());
            }
        };
        if w >= nw {
            // Not participating in this job; wait for the next epoch.
            continue;
        }
        let err = catch_unwind(AssertUnwindSafe(|| {
            // SAFETY: `run` keeps the pointee alive until this worker's
            // check-in below.
            unsafe { (&*job.0)(w) }
        }))
        .err();
        let mut st = pool.st();
        if let Some(p) = err {
            if st.panic.is_none() {
                st.panic = Some(p);
            }
        }
        st.unfinished -= 1;
        if st.unfinished == 0 {
            pool.done_cv.notify_all();
        }
        // Injected worker mortality (chaos suite): die *after* checking
        // in, so the in-flight job still completes; the index is queued
        // for respawn by the next submission.
        if fault::injected(fault::Site::PoolWorker) {
            st.dead.push(w);
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_index_exactly_once() {
        let hits: Vec<AtomicUsize> = (0..6).map(|_| AtomicUsize::new(0)).collect();
        global().run(6, &|w| {
            hits[w].fetch_add(1, Ordering::SeqCst);
        });
        for (w, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "worker {w}");
        }
        assert!(global().spawned() >= 6);
        assert!(global().spawned() <= MAX_WORKERS);
    }

    #[test]
    fn reuses_workers_across_jobs() {
        let total = AtomicUsize::new(0);
        for _ in 0..50 {
            global().run(4, &|_| {
                total.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(total.load(Ordering::SeqCst), 200);
        assert!(global().spawned() <= MAX_WORKERS, "pool must stay capped");
    }

    #[test]
    fn run_tasks_covers_every_task_exactly_once() {
        // more tasks than workers: strided draining must cover all
        let hits: Vec<AtomicUsize> = (0..23).map(|_| AtomicUsize::new(0)).collect();
        global().run_tasks(4, 23, &|t| {
            hits[t].fetch_add(1, Ordering::SeqCst);
        });
        for (t, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "task {t}");
        }
        // nw=1 and single-task runs stay inline (no new workers needed)
        let inline = AtomicUsize::new(0);
        global().run_tasks(1, 5, &|_| {
            inline.fetch_add(1, Ordering::SeqCst);
        });
        global().run_tasks(8, 1, &|_| {
            inline.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(inline.load(Ordering::SeqCst), 6);
        // zero tasks is a no-op
        global().run_tasks(4, 0, &|_| panic!("no tasks to run"));
    }

    #[test]
    fn worker_panic_propagates_payload() {
        let r = std::panic::catch_unwind(|| {
            global().run(3, &|w| {
                if w == 1 {
                    panic!("pool test payload");
                }
            });
        });
        let p = r.expect_err("panic must propagate");
        let msg = p
            .downcast_ref::<&str>()
            .copied()
            .map(str::to_string)
            .or_else(|| p.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("pool test payload"), "got: {msg}");
        // the pool must remain usable after a panicked job
        let ok = AtomicUsize::new(0);
        global().run(3, &|_| {
            ok.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ok.load(Ordering::SeqCst), 3);
    }
}
