//! Tensor-level reference implementations of the example programs.
//!
//! These compute the same functions as the block programs, directly on full
//! matrices with `tensor::Mat` operations — the Rust-side oracles for
//! numeric cross-checks (Python's `ref.py` plays the same role for the
//! Pallas kernels, and the PJRT runtime cross-checks both against JAX).
//!
//! Storage conventions follow the block programs: matmul right operands are
//! the transposed-stored matrices (`KT`, `VT`, `YT`, …), so e.g.
//! `attention_ref` computes `softmax(Q·KTᵀ/√d)·VTᵀ`.

use crate::tensor::Mat;

/// Row-wise softmax (unsafe — no max subtraction, like the paper's §5 body).
pub fn softmax_rows(x: &Mat) -> Mat {
    let e = x.map(f32::exp);
    let denom: Vec<f32> = e.row_sum().iter().map(|s| 1.0 / s).collect();
    e.row_scale(&denom)
}

/// Row-wise LayerNorm without affine parameters.
pub fn layernorm_rows(x: &Mat) -> Mat {
    let k = x.cols as f32;
    let mean: Vec<f32> = x.row_sum().iter().map(|s| s / k).collect();
    let shifted = x.row_shift(&mean.iter().map(|m| -m).collect::<Vec<_>>());
    let sumsq = x.map(|v| v * v).row_sum();
    let rstd: Vec<f32> = sumsq
        .iter()
        .zip(&mean)
        .map(|(s2, mu)| (s2 / k - mu * mu).powf(-0.5))
        .collect();
    shifted.row_scale(&rstd)
}

/// Row-wise RMSNorm.
pub fn rmsnorm_rows(x: &Mat) -> Mat {
    let d = x.cols as f32;
    let rrms: Vec<f32> = x
        .map(|v| v * v)
        .row_sum()
        .iter()
        .map(|s| 1.0 / (s / d).sqrt())
        .collect();
    x.row_scale(&rrms)
}

pub fn swish(x: &Mat) -> Mat {
    x.map(|v| v / (1.0 + (-v).exp()))
}

pub fn relu(x: &Mat) -> Mat {
    x.map(|v| v.max(0.0))
}

/// §1 example: `C = relu(A · BTᵀ)`.
pub fn matmul_relu_ref(a: &Mat, bt: &Mat) -> Mat {
    relu(&a.dot_bt(bt))
}

/// Example 1: `O = softmax(Q·KTᵀ/√d) · VTᵀ` with `d = dd`.
pub fn attention_ref(q: &Mat, kt: &Mat, vt: &Mat, dd: f32) -> Mat {
    let scores = q.dot_bt(kt).map(|v| v * dd.powf(-0.5));
    softmax_rows(&scores).dot_bt(vt)
}

/// Example 2: `Z = LayerNorm(X) · YTᵀ`.
pub fn layernorm_matmul_ref(x: &Mat, yt: &Mat) -> Mat {
    layernorm_rows(x).dot_bt(yt)
}

/// Example 3: `O = (swish(RMS(X)·WTᵀ) ⊙ (RMS(X)·VTᵀ)) · UTᵀ`.
pub fn rmsnorm_ffn_swiglu_ref(x: &Mat, wt: &Mat, vt: &Mat, ut: &Mat) -> Mat {
    let r = rmsnorm_rows(x);
    let w = swish(&r.dot_bt(wt));
    let v = r.dot_bt(vt);
    w.hadamard(&v).dot_bt(ut)
}

/// Decoder block (see `array::programs::decoder_block`): returns `(O, H)`.
pub fn decoder_block_ref(
    q: &Mat,
    kt: &Mat,
    vt: &Mat,
    r: &Mat,
    wt: &Mat,
    vt2: &Mat,
    ut: &Mat,
    dd: f32,
) -> (Mat, Mat) {
    let attn = attention_ref(q, kt, vt, dd);
    let h = attn.add(r);
    let o = rmsnorm_ffn_swiglu_ref(&h, wt, vt2, ut);
    (o, h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(1);
        let x = rng.mat(4, 6);
        let s = softmax_rows(&x);
        for r in s.row_sum() {
            assert!((r - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn layernorm_rows_zero_mean_unit_var() {
        let mut rng = Rng::new(2);
        let x = rng.mat(3, 64);
        let y = layernorm_rows(&x);
        for i in 0..3 {
            let row = y.row(i);
            let mean: f32 = row.iter().sum::<f32>() / 64.0;
            let var: f32 = row.iter().map(|v| v * v).sum::<f32>() / 64.0 - mean * mean;
            assert!(mean.abs() < 1e-4, "mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "var {var}");
        }
    }

    #[test]
    fn rmsnorm_rows_unit_rms() {
        let mut rng = Rng::new(3);
        let x = rng.mat(3, 32);
        let y = rmsnorm_rows(&x);
        for i in 0..3 {
            let ms: f32 = y.row(i).iter().map(|v| v * v).sum::<f32>() / 32.0;
            assert!((ms - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn attention_is_convex_combination() {
        // each output row is a convex combination of VTᵀ's rows, so it must
        // lie within their min/max envelope
        let mut rng = Rng::new(4);
        let (q, kt, vt) = (rng.mat(4, 8), rng.mat(6, 8), rng.mat(5, 6));
        let o = attention_ref(&q, &kt, &vt, 8.0);
        let v = vt.transpose();
        for j in 0..o.cols {
            let lo = (0..v.rows).map(|i| v.at(i, j)).fold(f32::MAX, f32::min);
            let hi = (0..v.rows).map(|i| v.at(i, j)).fold(f32::MIN, f32::max);
            for i in 0..o.rows {
                assert!(o.at(i, j) >= lo - 1e-4 && o.at(i, j) <= hi + 1e-4);
            }
        }
    }
}
