//! Work-stealing scheduler for parallel grid loops.
//!
//! The engine's first parallel scheduler handed each worker one static
//! contiguous range — fine for uniform grids, wasteful for ragged ones
//! (a worker whose chunk holds the expensive iterations finishes last
//! while the rest idle). This module replaces that with the classic
//! work-stealing shape:
//!
//! * a parallel range is over-decomposed into up to
//!   [`crate::exec::engine::CHUNKS_PER_WORKER`] contiguous chunks per
//!   worker ([`split_chunks`]);
//! * each worker owns a deque seeded with a contiguous run of chunks
//!   (locality: neighboring iterations touch neighboring buffer slots);
//! * the owner pops from the **front** of its own deque, streaming its
//!   run in ascending iteration order, and, when empty, steals from the
//!   **back** of a victim's deque (the chunks the victim would reach
//!   last, so owner and thief approach each other) in round-robin
//!   victim order.
//!
//! Deques are `Mutex<VecDeque>` — the offline build has no lock-free
//! deque crate, and chunk granularity (tens of chunks per region, each
//! covering many block operations) keeps lock traffic negligible.
//!
//! Determinism: chunks partition the iteration space exactly, every
//! iteration runs exactly once, and the engine's merge discipline
//! (deferred stores to disjoint slots, summed counters, last-chunk var
//! snapshot) is order-insensitive — so stealing changes wall-clock only,
//! never results.

use std::collections::VecDeque;
use std::sync::Mutex;

/// A contiguous run of grid iterations `[lo, hi)`; `id` is the chunk's
/// position in ascending iteration order (the chunk with the highest id
/// contains the final iteration).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Chunk {
    pub id: usize,
    pub lo: usize,
    pub hi: usize,
}

/// Split `[start, trip)` into at most `max_chunks` contiguous, non-empty,
/// ascending chunks whose sizes differ by at most one.
pub fn split_chunks(start: usize, trip: usize, max_chunks: usize) -> Vec<Chunk> {
    let iters = trip.saturating_sub(start);
    if iters == 0 {
        return Vec::new();
    }
    let n = max_chunks.clamp(1, iters);
    let base = iters / n;
    let extra = iters % n;
    let mut out = Vec::with_capacity(n);
    let mut lo = start;
    for id in 0..n {
        let len = base + usize::from(id < extra);
        out.push(Chunk {
            id,
            lo,
            hi: lo + len,
        });
        lo += len;
    }
    debug_assert_eq!(lo, trip);
    out
}

/// Per-worker chunk deques: owners drain from the front, thieves from
/// the back.
pub struct StealQueue {
    deques: Vec<Mutex<VecDeque<Chunk>>>,
}

impl StealQueue {
    /// Distribute `chunks` (ascending) across `workers` deques in
    /// contiguous runs, so each owner starts on neighboring iterations.
    pub fn new(workers: usize, chunks: Vec<Chunk>) -> StealQueue {
        assert!(workers >= 1, "StealQueue needs at least one worker");
        let n = chunks.len().max(1);
        let mut deques: Vec<VecDeque<Chunk>> = (0..workers).map(|_| VecDeque::new()).collect();
        for (i, c) in chunks.into_iter().enumerate() {
            deques[i * workers / n].push_back(c);
        }
        StealQueue {
            deques: deques.into_iter().map(Mutex::new).collect(),
        }
    }

    /// Next chunk for worker `w`: the front of its own deque (ascending
    /// through its seeded run), then round-robin steals from the back of
    /// the other deques. `None` when every deque is empty — the region
    /// is drained.
    pub fn next(&self, w: usize) -> Option<Chunk> {
        if let Some(c) = self.deques[w].lock().unwrap().pop_front() {
            return Some(c);
        }
        let n = self.deques.len();
        for off in 1..n {
            let victim = (w + off) % n;
            if let Some(c) = self.deques[victim].lock().unwrap().pop_back() {
                return Some(c);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Coverage invariant: chunks are ascending, contiguous, non-empty,
    /// near-equal, and exactly tile `[start, trip)`.
    #[test]
    fn split_chunks_tiles_the_range() {
        for (start, trip, max_chunks) in [
            (0usize, 1usize, 4usize),
            (0, 7, 3),
            (1, 16, 4),
            (5, 105, 16),
            (0, 100, 256),
            (3, 3, 8), // empty range
        ] {
            let chunks = split_chunks(start, trip, max_chunks);
            let iters = trip.saturating_sub(start);
            if iters == 0 {
                assert!(chunks.is_empty());
                continue;
            }
            assert!(chunks.len() <= max_chunks);
            assert!(chunks.len() <= iters);
            let mut expect_lo = start;
            let (min_len, max_len) = chunks.iter().fold((usize::MAX, 0), |(lo, hi), c| {
                (lo.min(c.hi - c.lo), hi.max(c.hi - c.lo))
            });
            for (i, c) in chunks.iter().enumerate() {
                assert_eq!(c.id, i, "ids ascend");
                assert_eq!(c.lo, expect_lo, "contiguous");
                assert!(c.hi > c.lo, "non-empty");
                expect_lo = c.hi;
            }
            assert_eq!(expect_lo, trip, "covers the range");
            assert!(max_len - min_len <= 1, "balanced: {chunks:?}");
        }
    }

    #[test]
    fn steal_queue_drains_every_chunk_once() {
        let chunks = split_chunks(0, 40, 12);
        let total = chunks.len();
        let q = StealQueue::new(4, chunks);
        let mut seen = Vec::new();
        // single consumer playing all four workers round-robin: stealing
        // paths get exercised once the early deques drain
        let mut w = 0;
        while let Some(c) = q.next(w) {
            seen.push(c.id);
            w = (w + 1) % 4;
        }
        seen.sort_unstable();
        let want: Vec<usize> = (0..total).collect();
        assert_eq!(seen, want, "each chunk exactly once");
    }

    #[test]
    fn steal_queue_more_workers_than_chunks() {
        let chunks = split_chunks(0, 2, 8);
        let q = StealQueue::new(6, chunks);
        let mut got = 0;
        for w in 0..6 {
            while q.next(w).is_some() {
                got += 1;
            }
        }
        assert_eq!(got, 2);
    }
}
