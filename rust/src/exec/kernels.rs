//! Pre-monomorphized fused kernel bodies — the `Specialized` backend's
//! execution layer.
//!
//! [`crate::loopir::compile::specialize_skeleton`] rewrites recognized
//! tape regions into [`Instr::Fused`](crate::loopir::compile::Instr)
//! sites; this module is the registry that executes them. Each
//! [`KernelId`] names one concrete Rust `fn` ([`KernelBody`]) that
//! replays the site's primitive sequence with **no per-instruction
//! dispatch**: the loop structure, operand tables, and (for the
//! bespoke bodies) even the compute kinds were resolved when the
//! skeleton was specialized, so the hot loop is straight-line Rust
//! over the side tables.
//!
//! **SIMD and scalar twins.** Every body bottoms out in the `tensor`
//! micro-kernels (`dot_bt`, the elementwise expression VM's slice
//! programs), which carry their own AVX2/scalar twin pairs behind the
//! [`crate::tensor::simd`] runtime kill-switch — so each kernel body
//! automatically has a bit-identical scalar twin without duplicating
//! the loop nests here (`--no-simd` exercises it).
//!
//! **The cardinal invariant.** Each body performs byte-for-byte the
//! same loads, stores, var sets/clears, and counter increments the
//! generic `run_range` interpreter loop would have performed for the
//! instructions the site replaced — same [`MemSim`] charges (including
//! `peak_local_bytes` ordering), same panic messages, same register
//! end states. The 3-backend parity matrices pin this.
//!
//! [`MemSim`]: crate::loopir::interp::MemSim

use super::engine::{Machine, Sink};
use crate::loopir::compile::{
    accum_val, CompiledProgram, FusedSite, FusedStep, KernelId,
};
use crate::tensor::Val;
use std::sync::Arc;

/// A fused loop body: drives one [`FusedSite`] against the machine
/// state. Registered per [`KernelId`]; resolved once per site, not per
/// element.
pub(crate) type KernelBody = fn(&mut Machine, &CompiledProgram, &FusedSite, &mut Sink);

/// Registry lookup: the concrete body for a kernel id.
fn body_for(id: KernelId) -> KernelBody {
    match id {
        KernelId::DotAcc => dot_acc,
        KernelId::FlashInner => flash_inner,
        KernelId::SerialNest => serial_nest,
        KernelId::StreamRun => stream_run,
    }
}

/// Engine entry point for [`Instr::Fused`](crate::loopir::compile::Instr):
/// dispatch the site to its kernel body.
pub(crate) fn run_fused(m: &mut Machine, prog: &CompiledProgram, fi: usize, sink: &mut Sink) {
    let site = &prog.fused[fi];
    (body_for(site.kernel))(m, prog, site, sink)
}

// ---------------------------------------------------------------------------
// Primitive steps (exact mirrors of the engine's `run_range` arms —
// change both together; the parity matrices pin them)
// ---------------------------------------------------------------------------

#[inline]
fn step_load(
    m: &mut Machine,
    prog: &CompiledProgram,
    var: usize,
    buf: usize,
    acc: usize,
    sink: &mut Sink,
) {
    let flat = prog.accesses[acc].flat(&m.regs);
    let v = sink.load(buf, flat);
    m.mem.n_loads += 1;
    m.mem.loaded_bytes += v.bytes() as u64;
    m.set_var(var, v);
}

#[inline]
fn step_store(
    m: &mut Machine,
    prog: &CompiledProgram,
    var: usize,
    buf: usize,
    acc: usize,
    sink: &mut Sink,
) {
    let flat = prog.accesses[acc].flat(&m.regs);
    let v = m.vars[var]
        .clone()
        .unwrap_or_else(|| panic!("var t{var} read before assignment"));
    m.mem.n_stores += 1;
    m.mem.stored_bytes += v.bytes() as u64;
    sink.store(buf, flat, v);
}

#[inline]
fn step_compute(m: &mut Machine, prog: &CompiledProgram, var: usize, site: usize) {
    let cs = &prog.computes[site];
    let vars = &m.vars;
    let args: Vec<&Val> = cs
        .args
        .iter()
        .map(|a| {
            vars[*a]
                .as_deref()
                .unwrap_or_else(|| panic!("var t{a} read before assignment"))
        })
        .collect();
    let (v, fl) = cs.kind.apply(&args, &mut m.scratch);
    drop(args);
    m.mem.flops += fl;
    m.set_var(var, Arc::new(v));
}

#[inline]
fn step_accum(m: &mut Machine, var: usize, op: crate::ir::func::ReduceOp, src: usize) {
    let s = m.vars[src]
        .clone()
        .unwrap_or_else(|| panic!("var t{src} read before assignment"));
    let (v, fl) = accum_val(m.vars[var].as_deref(), op, s);
    m.mem.flops += fl;
    m.set_var(var, v);
}

#[inline]
fn exec_step(m: &mut Machine, prog: &CompiledProgram, step: &FusedStep, sink: &mut Sink) {
    match step {
        FusedStep::Load { var, buf, acc } => step_load(m, prog, *var, *buf, *acc, sink),
        FusedStep::Store { var, buf, acc } => step_store(m, prog, *var, *buf, *acc, sink),
        FusedStep::Compute { var, site } => step_compute(m, prog, *var, *site),
        FusedStep::Accum { var, op, src } => step_accum(m, *var, *op, *src),
        FusedStep::Loop(child) => run_fused_site(m, prog, &prog.fused[*child], sink),
    }
}

#[inline]
fn run_fused_site(m: &mut Machine, prog: &CompiledProgram, site: &FusedSite, sink: &mut Sink) {
    (body_for(site.kernel))(m, prog, site, sink)
}

// ---------------------------------------------------------------------------
// Kernel bodies
// ---------------------------------------------------------------------------

/// Generic collapsed serial loop: the loop control the tape's
/// `LoopBegin`/`LoopEnd` jumps would perform (register set, clears per
/// iteration, register left at its final value), with the body walked
/// over pre-extracted steps. An empty trip range does nothing — exactly
/// the engine's `start >= trip` skip.
fn serial_nest(m: &mut Machine, prog: &CompiledProgram, site: &FusedSite, sink: &mut Sink) {
    let lm = &prog.loops[site.loop_id.expect("serial_nest is a loop site")];
    for x in lm.start..lm.trip {
        m.regs[lm.reg] = x;
        for &c in &lm.clears {
            m.clear_var(c);
        }
        for step in &site.steps {
            exec_step(m, prog, step, sink);
        }
    }
}

/// A straight-line run inside a non-collapsed loop, executed once per
/// arrival.
fn stream_run(m: &mut Machine, prog: &CompiledProgram, site: &FusedSite, sink: &mut Sink) {
    for step in &site.steps {
        exec_step(m, prog, step, sink);
    }
}

/// The fused contraction loop `for k { a = load; b = load;
/// t = dot(a, b); acc += t }`. The classifier pinned the step shape and
/// the compute kind, so the body inlines the `dot_bt` micro-kernel and
/// its accumulate directly — no `ComputeKind` match per iteration.
fn dot_acc(m: &mut Machine, prog: &CompiledProgram, site: &FusedSite, sink: &mut Sink) {
    let lm = &prog.loops[site.loop_id.expect("dot_acc is a loop site")];
    let [
        FusedStep::Load { var: va, buf: ba, acc: aa },
        FusedStep::Load { var: vb, buf: bb, acc: ab },
        FusedStep::Compute { var: vt, site: _ },
        FusedStep::Accum { var: vacc, op, src: _ },
    ] = &site.steps[..]
    else {
        unreachable!("dot_acc classification pins the step shape")
    };
    for x in lm.start..lm.trip {
        m.regs[lm.reg] = x;
        for &c in &lm.clears {
            m.clear_var(c);
        }
        let fa = prog.accesses[*aa].flat(&m.regs);
        let a = sink.load(*ba, fa);
        m.mem.n_loads += 1;
        m.mem.loaded_bytes += a.bytes() as u64;
        m.set_var(*va, a.clone());
        let fb = prog.accesses[*ab].flat(&m.regs);
        let b = sink.load(*bb, fb);
        m.mem.n_loads += 1;
        m.mem.loaded_bytes += b.bytes() as u64;
        m.set_var(*vb, b.clone());
        // the Dot arm of ComputeKind::apply, monomorphized (dot_bt
        // carries its own SIMD/scalar twins)
        let (am, bm) = (a.as_block(), b.as_block());
        let t = Arc::new(Val::Block(am.dot_bt(bm)));
        m.mem.flops += 2 * (am.rows * am.cols * bm.rows) as u64;
        m.set_var(*vt, t.clone());
        // acc += t (classification pinned src == vt)
        let (v, fl) = accum_val(m.vars[*vacc].as_deref(), *op, t);
        m.mem.flops += fl;
        m.set_var(*vacc, v);
    }
}

/// Flash attention's inner softmax·V nest: a serial key-block loop
/// composing a [`dot_acc`] QKᵀ contraction with its exp/row-sum/·V
/// epilogue, accumulators streaming across key blocks without
/// materializing the score matrix. Child sites dispatch straight to
/// their bodies (the classifier guaranteed at least the dot child), so
/// the whole nest runs end to end inside fused code.
fn flash_inner(m: &mut Machine, prog: &CompiledProgram, site: &FusedSite, sink: &mut Sink) {
    let lm = &prog.loops[site.loop_id.expect("flash_inner is a loop site")];
    for x in lm.start..lm.trip {
        m.regs[lm.reg] = x;
        for &c in &lm.clears {
            m.clear_var(c);
        }
        for step in &site.steps {
            match step {
                FusedStep::Loop(child) => run_fused_site(m, prog, &prog.fused[*child], sink),
                other => exec_step(m, prog, other, sink),
            }
        }
    }
}
