//! Tape-executing engine for compiled block programs.
//!
//! Executes the flat instruction tape produced by [`crate::loopir::compile`]:
//! loop control is two ip-jumps per iteration over an integer register
//! file, buffer accesses are precomputed stride sums, and every block
//! operator is pre-resolved — no `HashMap` lookups, no per-op allocation
//! churn, no expression recompilation in the hot loop.
//!
//! Top-level `forall` grid loops that passed the compile-time parallel
//! analysis run their iterations across `std::thread::scope` workers
//! (no external crates). Each worker owns a private register file, var
//! file, and [`MemSim`]; it reads shared buffers directly (the analysis
//! guarantees no buffer is both read and written inside a parallel body)
//! and defers its stores, which the main thread applies in chunk order
//! after the join. Counters are merged by summation, so simulated traffic,
//! flop, and launch counts are **bit-identical** to the sequential
//! interpreter; `peak_local_bytes` is merged by `max` (it is a scope
//! approximation in the interpreter already).

use crate::loopir::compile::{accum_val, CompiledProgram, Instr, SlotSel};
use crate::loopir::interp::{BufVal, ExecConfig, ExecResult, MemSim};
use crate::loopir::BufId;
use crate::tensor::Val;
use std::collections::HashMap;
use std::sync::Arc;
use std::thread;

// Global memory is the interpreter's own `BufVal` (Arc payloads): engine
// setup/teardown moves pointers, never block data, and buffers can be
// shared with worker threads directly.

/// Where stores go: directly into the buffers (serial execution) or into
/// a per-worker deferred list applied after the parallel join.
enum Sink<'a> {
    Direct(&'a mut Vec<BufVal>),
    Deferred {
        shared: &'a [BufVal],
        pending: Vec<(BufId, usize, Arc<Val>)>,
    },
}

impl Sink<'_> {
    #[inline]
    fn load(&self, buf: BufId, flat: usize) -> Arc<Val> {
        let bv = match self {
            Sink::Direct(b) => &b[buf],
            Sink::Deferred { shared, .. } => &shared[buf],
        };
        bv.data[flat]
            .clone()
            .unwrap_or_else(|| panic!("engine: buffer {buf} element {flat} never stored"))
    }

    #[inline]
    fn store(&mut self, buf: BufId, flat: usize, v: Arc<Val>) {
        match self {
            Sink::Direct(b) => b[buf].data[flat] = Some(v),
            Sink::Deferred { pending, .. } => pending.push((buf, flat, v)),
        }
    }
}

/// Execution state: register file, var file, counters. One per thread.
struct Machine {
    regs: Vec<usize>,
    vars: Vec<Option<Arc<Val>>>,
    stack: Vec<f32>,
    mem: MemSim,
    live: u64,
    cap: Option<u64>,
}

impl Machine {
    fn new(n_regs: usize, n_vars: usize, cap: Option<u64>) -> Machine {
        Machine {
            regs: vec![0; n_regs],
            vars: vec![None; n_vars],
            stack: Vec::with_capacity(16),
            mem: MemSim::default(),
            live: 0,
            cap,
        }
    }

    // set_var/clear_var mirror Interp::set_var/clear_var exactly (the
    // threads=1 peak-parity test pins them); change both together.
    fn set_var(&mut self, var: usize, v: Arc<Val>) {
        if let Some(old) = &self.vars[var] {
            self.live = self.live.saturating_sub(old.bytes() as u64);
        }
        self.live += v.bytes() as u64;
        self.vars[var] = Some(v);
        if self.live > self.mem.peak_local_bytes {
            self.mem.peak_local_bytes = self.live;
        }
        if let Some(cap) = self.cap {
            assert!(
                self.live <= cap,
                "local memory capacity exceeded: {} > {cap}",
                self.live
            );
        }
    }

    fn clear_var(&mut self, var: usize) {
        if let Some(old) = self.vars[var].take() {
            self.live = self.live.saturating_sub(old.bytes() as u64);
        }
    }

    /// Execute the instruction range `[range.0, range.1)`.
    fn run_range(&mut self, prog: &CompiledProgram, range: (usize, usize), sink: &mut Sink) {
        let mut ip = range.0;
        while ip < range.1 {
            match &prog.instrs[ip] {
                Instr::LoopBegin(li) => {
                    let m = &prog.loops[*li];
                    if m.start >= m.trip {
                        ip = m.end_ip + 1;
                        continue;
                    }
                    self.regs[m.reg] = m.start;
                    for &c in &m.clears {
                        self.clear_var(c);
                    }
                    ip += 1;
                }
                Instr::LoopEnd(li) => {
                    let m = &prog.loops[*li];
                    let next = self.regs[m.reg] + 1;
                    if next < m.trip {
                        self.regs[m.reg] = next;
                        for &c in &m.clears {
                            self.clear_var(c);
                        }
                        ip = m.body_ip;
                    } else {
                        ip += 1;
                    }
                }
                Instr::Load { var, buf, acc } => {
                    let flat = prog.accesses[*acc].flat(&self.regs);
                    let v = sink.load(*buf, flat);
                    self.mem.n_loads += 1;
                    self.mem.loaded_bytes += v.bytes() as u64;
                    self.set_var(*var, v);
                    ip += 1;
                }
                Instr::Store { var, buf, acc } => {
                    let flat = prog.accesses[*acc].flat(&self.regs);
                    let v = self.vars[*var]
                        .clone()
                        .unwrap_or_else(|| panic!("var t{var} read before assignment"));
                    self.mem.n_stores += 1;
                    self.mem.stored_bytes += v.bytes() as u64;
                    sink.store(*buf, flat, v);
                    ip += 1;
                }
                Instr::Compute { var, site } => {
                    let cs = &prog.computes[*site];
                    let vars = &self.vars;
                    let args: Vec<&Val> = cs
                        .args
                        .iter()
                        .map(|a| {
                            vars[*a]
                                .as_deref()
                                .unwrap_or_else(|| panic!("var t{a} read before assignment"))
                        })
                        .collect();
                    let (v, fl) = cs.kind.apply(&args, &mut self.stack);
                    drop(args);
                    self.mem.flops += fl;
                    self.set_var(*var, Arc::new(v));
                    ip += 1;
                }
                Instr::Accum { var, op, src } => {
                    let s = self.vars[*src]
                        .clone()
                        .unwrap_or_else(|| panic!("var t{src} read before assignment"));
                    let (v, fl) = accum_val(self.vars[*var].as_deref(), *op, s);
                    self.mem.flops += fl;
                    self.set_var(*var, v);
                    ip += 1;
                }
                Instr::Misc(mi) => {
                    let site = &prog.miscs[*mi];
                    let mut arg_vals: Vec<Vec<Val>> = Vec::with_capacity(site.args.len());
                    for (buf, sels) in &site.args {
                        let flats = enumerate_slots(sels, &self.regs, &prog.bufs[*buf].strides);
                        let mut elems = Vec::with_capacity(flats.len());
                        for f in flats {
                            let v = sink.load(*buf, f);
                            self.mem.n_loads += 1;
                            self.mem.loaded_bytes += v.bytes() as u64;
                            elems.push((*v).clone());
                        }
                        arg_vals.push(elems);
                    }
                    let results = (site.f)(&arg_vals);
                    let (obuf, osels) = &site.out;
                    let flats = enumerate_slots(osels, &self.regs, &prog.bufs[*obuf].strides);
                    assert_eq!(
                        results.len(),
                        flats.len(),
                        "misc op {} returned {} values for {} slots",
                        site.tag,
                        results.len(),
                        flats.len()
                    );
                    for (f, v) in flats.into_iter().zip(results) {
                        self.mem.n_stores += 1;
                        self.mem.stored_bytes += v.bytes() as u64;
                        sink.store(*obuf, f, Arc::new(v));
                    }
                    ip += 1;
                }
            }
        }
    }
}

/// Row-major enumeration of the flat indices selected by a partial index
/// (same order as the interpreter's `scatter_slots`).
fn enumerate_slots(sels: &[SlotSel], regs: &[usize], strides: &[usize]) -> Vec<usize> {
    let mut out = vec![0usize];
    for (i, s) in sels.iter().enumerate() {
        match s {
            SlotSel::Reg(r) => {
                let add = regs[*r] * strides[i];
                for f in &mut out {
                    *f += add;
                }
            }
            SlotSel::Fixed(c) => {
                let add = c * strides[i];
                for f in &mut out {
                    *f += add;
                }
            }
            SlotSel::All(n) => {
                let mut next = Vec::with_capacity(out.len() * n);
                for base in &out {
                    for c in 0..*n {
                        next.push(base + c * strides[i]);
                    }
                }
                out = next;
            }
        }
    }
    out
}

/// Execute a compiled program under `cfg`. Semantics (outputs and the
/// traffic/flop/launch counters) are bit-identical to
/// [`crate::loopir::interp::exec`] on the same program and config.
pub fn exec_compiled(prog: &CompiledProgram, cfg: &ExecConfig) -> ExecResult {
    // Materialize global memory. Inputs share their Arc payloads with the
    // caller's BufVals — setup is pointer moves, not block copies.
    let mut bufs: Vec<BufVal> = prog
        .bufs
        .iter()
        .map(|meta| {
            if meta.is_input {
                let bv = cfg
                    .inputs
                    .get(&meta.name)
                    .unwrap_or_else(|| panic!("missing input buffer {}", meta.name));
                assert_eq!(
                    bv.dims, meta.dims,
                    "input {} has dims {:?}, program expects {:?}",
                    meta.name, bv.dims, meta.dims
                );
                bv.clone()
            } else {
                BufVal::new(meta.dims.clone())
            }
        })
        .collect();

    let workers = cfg
        .threads
        .unwrap_or_else(|| {
            thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
        .clamp(1, 64);

    let mut mach = Machine::new(prog.n_regs, prog.n_vars, cfg.local_capacity);

    for top in &prog.tops {
        if top.kernel {
            mach.mem.kernel_launches += 1;
        }
        let par = if workers > 1 { top.par_loop } else { None };
        let li = match par {
            Some(li) => li,
            None => {
                let mut sink = Sink::Direct(&mut bufs);
                mach.run_range(prog, top.ips, &mut sink);
                continue;
            }
        };
        let meta = &prog.loops[li];
        let iters = meta.trip.saturating_sub(meta.start);
        if iters < 2 {
            let mut sink = Sink::Direct(&mut bufs);
            mach.run_range(prog, top.ips, &mut sink);
            continue;
        }
        // contiguous, non-empty chunks of the grid range (ceil division)
        let nw = workers.min(iters);
        let chunk = iters / nw + usize::from(iters % nw != 0);
        let ranges: Vec<(usize, usize)> = (0..nw)
            .map(|w| {
                let lo = meta.start + w * chunk;
                let hi = (lo + chunk).min(meta.trip);
                (lo, hi)
            })
            .filter(|(lo, hi)| lo < hi)
            .collect();
        let base_live = mach.live;
        let cap = cfg.local_capacity;
        let results: Vec<(Machine, Vec<(BufId, usize, Arc<Val>)>)> = thread::scope(|s| {
            let shared: &Vec<BufVal> = &bufs;
            let handles: Vec<_> = ranges
                .iter()
                .map(|&(lo, hi)| {
                    s.spawn(move || {
                        let mut wm = Machine::new(prog.n_regs, prog.n_vars, cap);
                        // capacity baseline: the enclosing scope's live
                        // locals still occupy local memory
                        wm.live = base_live;
                        let mut sink = Sink::Deferred {
                            shared,
                            pending: Vec::new(),
                        };
                        let m = &prog.loops[li];
                        for x in lo..hi {
                            for &c in &m.clears {
                                wm.clear_var(c);
                            }
                            wm.regs[m.reg] = x;
                            wm.run_range(prog, (m.body_ip, m.end_ip), &mut sink);
                        }
                        let pending = match sink {
                            Sink::Deferred { pending, .. } => pending,
                            Sink::Direct(_) => unreachable!(),
                        };
                        (wm, pending)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| match h.join() {
                    Ok(r) => r,
                    // re-raise with the original payload so capacity and
                    // read-before-assignment diagnostics survive threading
                    Err(p) => std::panic::resume_unwind(p),
                })
                .collect()
        });
        let last = results.len() - 1;
        for (wi, (wm, pending)) in results.into_iter().enumerate() {
            for (b, f, v) in pending {
                bufs[b].data[f] = Some(v);
            }
            mach.mem.loaded_bytes += wm.mem.loaded_bytes;
            mach.mem.stored_bytes += wm.mem.stored_bytes;
            mach.mem.n_loads += wm.mem.n_loads;
            mach.mem.n_stores += wm.mem.n_stores;
            mach.mem.flops += wm.mem.flops;
            mach.mem.kernel_launches += wm.mem.kernel_launches;
            mach.mem.peak_local_bytes = mach.mem.peak_local_bytes.max(wm.mem.peak_local_bytes);
            if wi == last {
                // sequential semantics: after the loop, its assigned vars
                // hold the final iteration's values
                for &v in &prog.loops[li].clears {
                    match &wm.vars[v] {
                        Some(a) => mach.set_var(v, a.clone()),
                        None => mach.clear_var(v),
                    }
                }
            }
        }
    }

    let mut outputs = HashMap::new();
    for (i, meta) in prog.bufs.iter().enumerate() {
        if meta.is_output {
            outputs.insert(meta.name.clone(), bufs[i].clone());
        }
    }
    ExecResult {
        outputs,
        mem: mach.mem,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::dim::DimSizes;
    use crate::ir::expr::Expr;
    use crate::ir::graph::{map_over, ArgMode, Graph};
    use crate::ir::types::Ty;
    use crate::loopir::compile::compile;
    use crate::loopir::interp::exec;
    use crate::loopir::lower::lower;
    use crate::tensor::Rng;

    fn block_list(rng: &mut Rng, n: usize, r: usize, c: usize) -> BufVal {
        let mut bv = BufVal::new(vec![n]);
        for i in 0..n {
            bv.set(&[i], Val::Block(rng.mat(r, c)));
        }
        bv
    }

    fn map_graph() -> crate::ir::graph::Graph {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let o = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.ew1(Expr::var(0).exp().neg(), ins[0]);
            mb.collect(r);
        });
        g.output("B", o[0]);
        g
    }

    /// Same program, same config: engine output and counters must equal
    /// the interpreter's exactly — sequentially and with forced threads.
    #[test]
    fn engine_matches_interpreter_bitwise() {
        let ir = lower(&map_graph());
        let mut rng = Rng::new(9);
        let input = block_list(&mut rng, 8, 4, 4);
        for threads in [Some(1), Some(4)] {
            let mut cfg = ExecConfig::new(DimSizes::of(&[("N", 8)]));
            cfg.inputs.insert("A".into(), input.clone());
            cfg.threads = threads;
            let want = exec(&ir, &cfg);
            let prog = compile(&ir, &cfg);
            assert_eq!(prog.parallel_grid_loops(), 1);
            let got = exec_compiled(&prog, &cfg);
            for i in 0..8 {
                assert_eq!(
                    want.outputs["B"].get(&[i]),
                    got.outputs["B"].get(&[i]),
                    "threads={threads:?} element {i}"
                );
            }
            assert_eq!(want.mem.loaded_bytes, got.mem.loaded_bytes);
            assert_eq!(want.mem.stored_bytes, got.mem.stored_bytes);
            assert_eq!(want.mem.n_loads, got.mem.n_loads);
            assert_eq!(want.mem.n_stores, got.mem.n_stores);
            assert_eq!(want.mem.flops, got.mem.flops);
            assert_eq!(want.mem.kernel_launches, got.mem.kernel_launches);
        }
    }

    #[test]
    #[should_panic(expected = "capacity exceeded")]
    fn engine_enforces_local_capacity() {
        let ir = lower(&map_graph());
        let mut rng = Rng::new(3);
        let mut cfg = ExecConfig::new(DimSizes::of(&[("N", 2)]));
        cfg.inputs.insert("A".into(), block_list(&mut rng, 2, 8, 8));
        cfg.local_capacity = Some(100); // one 8x8 block = 256 bytes > 100
        cfg.threads = Some(1);
        let prog = compile(&ir, &cfg);
        let _ = exec_compiled(&prog, &cfg);
    }
}
