//! Tape-executing engine for compiled block programs.
//!
//! Executes the flat instruction tape produced by [`crate::loopir::compile`]:
//! loop control is two ip-jumps per iteration over an integer register
//! file, buffer accesses are precomputed stride sums, and every block
//! operator is pre-resolved — no `HashMap` lookups, no per-op allocation
//! churn, no expression recompilation in the hot loop.
//!
//! **Parallel scheduling.** Every `forall` loop the compile-time analysis
//! annotated [`LoopMeta::parallel`] may fan its iterations out across the
//! persistent worker pool of [`super::pool`] (no external crates; workers
//! are spawned lazily once, parked between regions, and handed jobs by
//! epoch — the per-region `thread::scope` spawn/join of earlier PRs is
//! gone from the hot path). Fan-out happens at the outermost parallel
//! loop the main thread reaches: a parallel top-level grid always; a
//! parallel loop *nested under a serial outer loop* when its bind-time
//! executed-instruction weight clears [`NESTED_FANOUT_MIN_WORK`] (a pool
//! handoff is cheap but not free, and it is paid per enclosing
//! iteration). The region is over-decomposed into up to
//! [`CHUNKS_PER_WORKER`] chunks per worker and drained through the
//! work-stealing deques of [`super::sched`], so ragged grids balance.
//!
//! Each worker owns a private register file, var file, and [`MemSim`],
//! **seeded** from the enclosing scope (registers and `Arc`-cloned vars —
//! the analysis guarantees seeded vars are loop-invariant). Workers read
//! shared buffers directly (no buffer is both read and written inside a
//! parallel body) and defer their stores, which the main thread applies
//! after the join; stores of distinct iterations hit disjoint slots, so
//! apply order is immaterial. Counters merge by summation, so simulated
//! traffic, flop, and launch counts are **bit-identical** to the
//! sequential interpreter; `peak_local_bytes` merges by `max` (a scope
//! approximation in the interpreter already). With one worker the engine
//! never leaves the serial path, which keeps even the peak-local
//! accounting bit-identical (pinned by the threads=1 parity test).

use crate::exec::sched::{split_chunks, StealQueue};
use crate::ir::exprvm::EwScratch;
use crate::loopir::compile::{accum_val, CompiledProgram, Instr, SlotSel};
use crate::loopir::interp::{BufVal, ExecConfig, ExecResult, MemSim};
use crate::loopir::BufId;
use crate::tensor::Val;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::thread;

/// Hard cap on scheduler workers, whatever `available_parallelism` or
/// `--threads` claims.
pub const MAX_WORKERS: usize = 64;

/// Over-decomposition factor: a parallel region is split into up to this
/// many chunks **per worker**, so the stealing deques can rebalance
/// ragged grids. `1` would reproduce the old static-chunk schedule.
pub const CHUNKS_PER_WORKER: usize = 4;

/// Minimum executed-instruction weight ([`crate::loopir::compile::LoopMeta::weight`],
/// which folds in bound trip counts of nested loops) before a *nested*
/// parallel loop is worth a pool handoff per enclosing iteration. The
/// persistent pool removed the thread spawn+join this constant was
/// originally sized against, but a handoff still costs a condvar
/// broadcast, worker seeding (register/var file clones), and the
/// deferred-store merge — while one tape instruction (a block op on a
/// small tile) runs in well under a microsecond. The threshold is kept
/// unchanged: it only gates a wall-clock trade, never results (fan-out
/// is bit-identical), and re-tuning it belongs with a measured bench.
/// Top-level grids always fan out (their handoff is paid once per
/// kernel, not once per outer iteration).
pub const NESTED_FANOUT_MIN_WORK: u64 = 1024;

// Global memory is the interpreter's own `BufVal` (Arc payloads): engine
// setup/teardown moves pointers, never block data, and buffers can be
// shared with worker threads directly.

/// Where stores go: directly into the buffers (serial execution) or into
/// a per-worker deferred list applied after the parallel join.
/// (`pub(crate)` so the fused kernel bodies of [`super::kernels`] can
/// replay load/store semantics without re-entering `run_range`.)
pub(crate) enum Sink<'a> {
    Direct(&'a mut Vec<BufVal>),
    Deferred {
        shared: &'a [BufVal],
        pending: Vec<(BufId, usize, Arc<Val>)>,
    },
}

impl Sink<'_> {
    #[inline]
    pub(crate) fn load(&self, buf: BufId, flat: usize) -> Arc<Val> {
        let bv = match self {
            Sink::Direct(b) => &b[buf],
            Sink::Deferred { shared, .. } => &shared[buf],
        };
        bv.data[flat]
            .clone()
            .unwrap_or_else(|| panic!("engine: buffer {buf} element {flat} never stored"))
    }

    #[inline]
    pub(crate) fn store(&mut self, buf: BufId, flat: usize, v: Arc<Val>) {
        match self {
            Sink::Direct(b) => b[buf].data[flat] = Some(v),
            Sink::Deferred { pending, .. } => pending.push((buf, flat, v)),
        }
    }
}

/// What one worker brings back from a parallel region.
struct WorkerOut {
    mem: MemSim,
    pending: Vec<(BufId, usize, Arc<Val>)>,
    /// Per-slice counter attribution (empty unless the region runs with
    /// slice tracking): this worker's contribution to each grid slice,
    /// recorded as per-iteration deltas keyed through the caller's
    /// iteration→slice table.
    slice_mem: Vec<MemSim>,
    /// Values of the loop's clear-set vars after the final iteration
    /// (`Some` only for the worker that ran the last chunk) — sequential
    /// semantics: after a loop, its assigned vars hold the final
    /// iteration's values.
    final_vars: Option<Vec<Option<Arc<Val>>>>,
}

/// Execution state: register file, var file, counters. One per thread.
/// (`pub(crate)` so the fused kernel bodies of [`super::kernels`] can
/// drive it directly.)
pub(crate) struct Machine {
    pub(crate) regs: Vec<usize>,
    pub(crate) vars: Vec<Option<Arc<Val>>>,
    /// Elementwise workspace (scalar stack + expression-VM slab file),
    /// reused across every compute site this machine executes.
    pub(crate) scratch: EwScratch,
    pub(crate) mem: MemSim,
    live: u64,
    cap: Option<u64>,
}

impl Machine {
    fn new(n_regs: usize, n_vars: usize, cap: Option<u64>) -> Machine {
        Machine {
            regs: vec![0; n_regs],
            vars: vec![None; n_vars],
            scratch: EwScratch::new(),
            mem: MemSim::default(),
            live: 0,
            cap,
        }
    }

    // set_var/clear_var mirror Interp::set_var/clear_var exactly (the
    // threads=1 peak-parity test pins them); change both together.
    pub(crate) fn set_var(&mut self, var: usize, v: Arc<Val>) {
        if let Some(old) = &self.vars[var] {
            self.live = self.live.saturating_sub(old.bytes() as u64);
        }
        self.live += v.bytes() as u64;
        self.vars[var] = Some(v);
        if self.live > self.mem.peak_local_bytes {
            self.mem.peak_local_bytes = self.live;
        }
        if let Some(cap) = self.cap {
            assert!(
                self.live <= cap,
                "local memory capacity exceeded: {} > {cap}",
                self.live
            );
        }
    }

    pub(crate) fn clear_var(&mut self, var: usize) {
        if let Some(old) = self.vars[var].take() {
            self.live = self.live.saturating_sub(old.bytes() as u64);
        }
    }

    /// Execute the instruction range `[range.0, range.1)`. `par_workers`
    /// is the fan-out budget for parallel loops met along the way
    /// (`<= 1` disables fan-out — always the case inside pool workers,
    /// which prevents re-entrant pool submissions).
    fn run_range(
        &mut self,
        prog: &CompiledProgram,
        range: (usize, usize),
        sink: &mut Sink,
        par_workers: usize,
    ) {
        let mut ip = range.0;
        while ip < range.1 {
            match &prog.instrs[ip] {
                Instr::LoopBegin(li) => {
                    let m = &prog.loops[*li];
                    if m.start >= m.trip {
                        ip = m.end_ip + 1;
                        continue;
                    }
                    if par_workers > 1 && m.parallel {
                        let iters = m.trip - m.start;
                        if iters >= 2 && m.weight >= NESTED_FANOUT_MIN_WORK {
                            if let Sink::Direct(bufs) = sink {
                                let end = m.end_ip;
                                let li = *li;
                                self.run_parallel_loop(prog, li, &mut **bufs, par_workers, None);
                                ip = end + 1;
                                continue;
                            }
                        }
                    }
                    self.regs[m.reg] = m.start;
                    for &c in &m.clears {
                        self.clear_var(c);
                    }
                    ip += 1;
                }
                Instr::LoopEnd(li) => {
                    let m = &prog.loops[*li];
                    let next = self.regs[m.reg] + 1;
                    if next < m.trip {
                        self.regs[m.reg] = next;
                        for &c in &m.clears {
                            self.clear_var(c);
                        }
                        ip = m.body_ip;
                    } else {
                        ip += 1;
                    }
                }
                Instr::Load { var, buf, acc } => {
                    let flat = prog.accesses[*acc].flat(&self.regs);
                    let v = sink.load(*buf, flat);
                    self.mem.n_loads += 1;
                    self.mem.loaded_bytes += v.bytes() as u64;
                    self.set_var(*var, v);
                    ip += 1;
                }
                Instr::Store { var, buf, acc } => {
                    let flat = prog.accesses[*acc].flat(&self.regs);
                    let v = self.vars[*var]
                        .clone()
                        .unwrap_or_else(|| panic!("var t{var} read before assignment"));
                    self.mem.n_stores += 1;
                    self.mem.stored_bytes += v.bytes() as u64;
                    sink.store(*buf, flat, v);
                    ip += 1;
                }
                Instr::Compute { var, site } => {
                    let cs = &prog.computes[*site];
                    let vars = &self.vars;
                    let args: Vec<&Val> = cs
                        .args
                        .iter()
                        .map(|a| {
                            vars[*a]
                                .as_deref()
                                .unwrap_or_else(|| panic!("var t{a} read before assignment"))
                        })
                        .collect();
                    let (v, fl) = cs.kind.apply(&args, &mut self.scratch);
                    drop(args);
                    self.mem.flops += fl;
                    self.set_var(*var, Arc::new(v));
                    ip += 1;
                }
                Instr::Accum { var, op, src } => {
                    let s = self.vars[*src]
                        .clone()
                        .unwrap_or_else(|| panic!("var t{src} read before assignment"));
                    let (v, fl) = accum_val(self.vars[*var].as_deref(), *op, s);
                    self.mem.flops += fl;
                    self.set_var(*var, v);
                    ip += 1;
                }
                Instr::Fused(fi) => {
                    // Specialized backend: the whole site runs through
                    // one pre-monomorphized kernel body — dispatch was
                    // resolved when the skeleton was specialized.
                    super::kernels::run_fused(self, prog, *fi, sink);
                    ip += 1;
                }
                Instr::Misc(mi) => {
                    let site = &prog.miscs[*mi];
                    let mut arg_vals: Vec<Vec<Val>> = Vec::with_capacity(site.args.len());
                    for (buf, sels) in &site.args {
                        let flats = enumerate_slots(sels, &self.regs, &prog.bufs[*buf].strides);
                        let mut elems = Vec::with_capacity(flats.len());
                        for f in flats {
                            let v = sink.load(*buf, f);
                            self.mem.n_loads += 1;
                            self.mem.loaded_bytes += v.bytes() as u64;
                            elems.push((*v).clone());
                        }
                        arg_vals.push(elems);
                    }
                    let results = (site.f)(&arg_vals);
                    let (obuf, osels) = &site.out;
                    let flats = enumerate_slots(osels, &self.regs, &prog.bufs[*obuf].strides);
                    assert_eq!(
                        results.len(),
                        flats.len(),
                        "misc op {} returned {} values for {} slots",
                        site.tag,
                        results.len(),
                        flats.len()
                    );
                    for (f, v) in flats.into_iter().zip(results) {
                        self.mem.n_stores += 1;
                        self.mem.stored_bytes += v.bytes() as u64;
                        sink.store(*obuf, f, Arc::new(v));
                    }
                    ip += 1;
                }
            }
        }
    }

    /// Fan the iterations of parallel loop `li` out across `workers` of
    /// the persistent pool ([`super::pool`]) via the work-stealing
    /// deques, then merge: apply deferred stores, sum counters, adopt the
    /// final iteration's var values, and leave the loop register at its
    /// sequential exit value. Worker panics re-raise here with their
    /// original payload (capacity and read-before-assignment diagnostics
    /// survive pooling).
    ///
    /// `slices`, when set to `(table, out)`, attributes counters per grid
    /// slice: `table[x]` names the slice owning iteration `x` (slices are
    /// contiguous but may have unequal widths — the ragged stacked-batch
    /// path), and each worker records per-iteration deltas into
    /// `out[table[x]]` (chunks need no slice alignment — the key is
    /// looked up per iteration), merged additively across workers.
    fn run_parallel_loop(
        &mut self,
        prog: &CompiledProgram,
        li: usize,
        bufs: &mut Vec<BufVal>,
        workers: usize,
        mut slices: Option<(&[usize], &mut [MemSim])>,
    ) {
        let meta = &prog.loops[li];
        let chunks = split_chunks(meta.start, meta.trip, workers * CHUNKS_PER_WORKER);
        debug_assert!(!chunks.is_empty(), "fan-out requires >= 2 iterations");
        let nw = workers.min(chunks.len());
        let last_chunk = chunks.len() - 1;
        let queue = StealQueue::new(nw, chunks);
        let base_live = self.live;
        let cap = self.cap;
        let slice_of: Option<&[usize]> = slices.as_ref().map(|(t, _)| *t);
        let n_slices = slices.as_ref().map_or(0, |(_, out)| out.len());
        // Workers are seeded with the enclosing scope's registers (outer
        // loop indices feed buffer accesses inside the body) and var file
        // (Arc clones; the analysis guarantees seeded vars are read-only
        // within the body).
        let seed_regs: Vec<usize> = self.regs.clone();
        let seed_vars: Vec<Option<Arc<Val>>> = self.vars.clone();
        // One slot per worker; the pool guarantees every index runs
        // exactly once before `run` returns, so the merge below sees
        // every slot filled. The merge itself is order-insensitive
        // (disjoint stores, summed counters, single last-chunk snapshot),
        // so pooling cannot change results vs scoped threads.
        let slots: Vec<Mutex<Option<WorkerOut>>> = (0..nw).map(|_| Mutex::new(None)).collect();
        {
            let shared: &[BufVal] = bufs;
            let queue = &queue;
            let seed_regs = &seed_regs;
            let seed_vars = &seed_vars;
            let slots = &slots;
            super::pool::global().run(nw, &move |w: usize| {
                let mut wm = Machine::new(prog.n_regs, prog.n_vars, cap);
                wm.regs.copy_from_slice(seed_regs);
                wm.vars = seed_vars.clone();
                // capacity baseline: the enclosing scope's live
                // locals still occupy local memory
                wm.live = base_live;
                let mut sink = Sink::Deferred {
                    shared,
                    pending: Vec::new(),
                };
                let m = &prog.loops[li];
                let mut slice_mem: Vec<MemSim> = vec![MemSim::default(); n_slices];
                let mut final_vars: Option<Vec<Option<Arc<Val>>>> = None;
                while let Some(chunk) = queue.next(w) {
                    for x in chunk.lo..chunk.hi {
                        let base = slice_of.map(|_| wm.mem.clone());
                        for &c in &m.clears {
                            wm.clear_var(c);
                        }
                        wm.regs[m.reg] = x;
                        wm.run_range(prog, (m.body_ip, m.end_ip), &mut sink, 0);
                        if let (Some(table), Some(base)) = (slice_of, base) {
                            slice_mem[table[x]].add_counters(&wm.mem.counter_delta(&base));
                        }
                    }
                    if chunk.id == last_chunk {
                        final_vars = Some(m.clears.iter().map(|&v| wm.vars[v].clone()).collect());
                    }
                }
                let pending = match sink {
                    Sink::Deferred { pending, .. } => pending,
                    Sink::Direct(_) => unreachable!(),
                };
                *slots[w].lock().unwrap() = Some(WorkerOut {
                    mem: wm.mem,
                    pending,
                    slice_mem,
                    final_vars,
                });
            });
        }
        for slot in slots {
            let wo = slot.into_inner().unwrap().expect("pool ran every worker index");
            for (b, f, v) in wo.pending {
                bufs[b].data[f] = Some(v);
            }
            self.mem.add_counters(&wo.mem);
            if let Some((_, out)) = slices.as_mut() {
                for (s, sm) in out.iter_mut().zip(&wo.slice_mem) {
                    s.add_counters(sm);
                }
            }
            if let Some(fv) = wo.final_vars {
                for (&v, val) in prog.loops[li].clears.iter().zip(fv) {
                    match val {
                        Some(a) => self.set_var(v, a),
                        None => self.clear_var(v),
                    }
                }
            }
        }
        // sequential register semantics: after the loop, its register
        // holds the final iteration's index
        self.regs[prog.loops[li].reg] = prog.loops[li].trip - 1;
    }
}

/// Row-major enumeration of the flat indices selected by a partial index
/// (same order as the interpreter's `scatter_slots`).
fn enumerate_slots(sels: &[SlotSel], regs: &[usize], strides: &[usize]) -> Vec<usize> {
    let mut out = vec![0usize];
    for (i, s) in sels.iter().enumerate() {
        match s {
            SlotSel::Reg(r) => {
                let add = regs[*r] * strides[i];
                for f in &mut out {
                    *f += add;
                }
            }
            SlotSel::Fixed(c) => {
                let add = c * strides[i];
                for f in &mut out {
                    *f += add;
                }
            }
            SlotSel::All(n) => {
                let mut next = Vec::with_capacity(out.len() * n);
                for base in &out {
                    for c in 0..*n {
                        next.push(base + c * strides[i]);
                    }
                }
                out = next;
            }
        }
    }
    out
}

/// Resolve a worker-count cap (`None` = one per available core) to the
/// effective worker budget, clamped to `[1, MAX_WORKERS]`. Shared by the
/// grid-loop fan-out here and the serving layer's batch fan-out
/// (`serve`), so the two budgets cannot drift.
pub fn worker_budget(threads: Option<usize>) -> usize {
    threads
        .unwrap_or_else(|| {
            thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
        .clamp(1, MAX_WORKERS)
}

/// Execute a compiled program under `cfg`. Semantics (outputs and the
/// traffic/flop/launch counters) are bit-identical to
/// [`crate::loopir::interp::exec`] on the same program and config.
pub fn exec_compiled(prog: &CompiledProgram, cfg: &ExecConfig) -> ExecResult {
    // Materialize global memory. Inputs share their Arc payloads with the
    // caller's BufVals — setup is pointer moves, not block copies.
    let mut bufs: Vec<BufVal> = prog
        .bufs
        .iter()
        .map(|meta| {
            if meta.is_input {
                let bv = cfg
                    .inputs
                    .get(&meta.name)
                    .unwrap_or_else(|| panic!("missing input buffer {}", meta.name));
                assert_eq!(
                    bv.dims, meta.dims,
                    "input {} has dims {:?}, program expects {:?}",
                    meta.name, bv.dims, meta.dims
                );
                bv.clone()
            } else {
                BufVal::new(meta.dims.clone())
            }
        })
        .collect();

    let workers = worker_budget(cfg.threads);

    let mut mach = Machine::new(prog.n_regs, prog.n_vars, cfg.local_capacity);

    let mut per_slice =
        vec![MemSim::default(); cfg.slices.as_ref().map(|w| w.len()).unwrap_or(0)];
    for top in &prog.tops {
        if top.kernel {
            mach.mem.kernel_launches += 1;
        }
        if let Some(widths) = cfg.slices.as_deref() {
            // Slice-attributed drive (the serving layer's stacked-batch
            // path): every top-level statement must be a grid loop whose
            // trip the slice widths tile exactly (unequal widths are the
            // ragged-batch case); counters accrue per slice, and each
            // non-empty slice is charged the kernel launch it would pay
            // running alone.
            let li = match prog.instrs.get(top.ips.0) {
                Some(Instr::LoopBegin(li)) => *li,
                _ => panic!(
                    "slice attribution requires every top-level statement to be a grid loop"
                ),
            };
            let (start, trip) = (prog.loops[li].start, prog.loops[li].trip);
            let total: usize = widths.iter().sum();
            assert!(
                start == 0 && !widths.is_empty() && total == trip,
                "slice attribution: widths {widths:?} do not cover {trip} iterations (start {start})"
            );
            // iteration → owning slice, looked up per iteration so
            // work-stealing chunks need no slice alignment
            let mut slice_of = Vec::with_capacity(trip);
            for (r, &w) in widths.iter().enumerate() {
                slice_of.extend(std::iter::repeat(r).take(w));
            }
            if workers > 1 && prog.loops[li].parallel && trip >= 2 {
                mach.run_parallel_loop(
                    prog,
                    li,
                    &mut bufs,
                    workers,
                    Some((slice_of.as_slice(), per_slice.as_mut_slice())),
                );
            } else {
                // Serial per-iteration drive: same clears-then-body
                // sequence the tape's LoopBegin/LoopEnd jumps produce.
                let m = &prog.loops[li];
                for x in 0..trip {
                    let base = mach.mem.clone();
                    for &c in &m.clears {
                        mach.clear_var(c);
                    }
                    mach.regs[m.reg] = x;
                    let mut sink = Sink::Direct(&mut bufs);
                    mach.run_range(prog, (m.body_ip, m.end_ip), &mut sink, workers);
                    per_slice[slice_of[x]].add_counters(&mach.mem.counter_delta(&base));
                }
                if trip > 0 {
                    // sequential register semantics (as after any loop)
                    mach.regs[m.reg] = trip - 1;
                }
            }
            if top.kernel {
                for (s, &w) in per_slice.iter_mut().zip(widths) {
                    if w > 0 {
                        s.kernel_launches += 1;
                    }
                }
            }
            continue;
        }
        // A parallel top-level grid fans out unconditionally (spawn cost
        // is once per kernel); anything else runs serially on the main
        // machine, fanning out nested parallel loops it encounters.
        let top_li = match prog.instrs.get(top.ips.0) {
            Some(Instr::LoopBegin(li))
                if workers > 1
                    && prog.loops[*li].parallel
                    && prog.loops[*li].trip.saturating_sub(prog.loops[*li].start) >= 2 =>
            {
                Some(*li)
            }
            _ => None,
        };
        match top_li {
            Some(li) => mach.run_parallel_loop(prog, li, &mut bufs, workers, None),
            None => {
                let mut sink = Sink::Direct(&mut bufs);
                mach.run_range(prog, top.ips, &mut sink, workers);
            }
        }
    }

    let mut outputs = HashMap::new();
    for (i, meta) in prog.bufs.iter().enumerate() {
        if meta.is_output {
            outputs.insert(meta.name.clone(), bufs[i].clone());
        }
    }
    ExecResult {
        outputs,
        mem: mach.mem,
        per_slice,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::dim::{Dim, DimSizes};
    use crate::ir::expr::Expr;
    use crate::ir::graph::{map_over, ArgMode, Graph};
    use crate::ir::types::Ty;
    use crate::loopir::compile::compile;
    use crate::loopir::interp::exec;
    use crate::loopir::lower::lower;
    use crate::loopir::{analyze_clears, BufDecl, COp, Index, LoopIr, LoopKind, Stmt};
    use crate::tensor::Rng;

    fn block_list(rng: &mut Rng, n: usize, r: usize, c: usize) -> BufVal {
        let mut bv = BufVal::new(vec![n]);
        for i in 0..n {
            bv.set(&[i], Val::Block(rng.mat(r, c)));
        }
        bv
    }

    fn map_graph() -> crate::ir::graph::Graph {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let o = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.ew1(Expr::var(0).exp().neg(), ins[0]);
            mb.collect(r);
        });
        g.output("B", o[0]);
        g
    }

    /// Same program, same config: engine output and counters must equal
    /// the interpreter's exactly — sequentially and with forced threads.
    #[test]
    fn engine_matches_interpreter_bitwise() {
        let ir = lower(&map_graph());
        let mut rng = Rng::new(9);
        let input = block_list(&mut rng, 8, 4, 4);
        for threads in [Some(1), Some(4)] {
            let mut cfg = ExecConfig::new(DimSizes::of(&[("N", 8)]));
            cfg.inputs.insert("A".into(), input.clone());
            cfg.threads = threads;
            let want = exec(&ir, &cfg);
            let prog = compile(&ir, &cfg);
            assert_eq!(prog.parallel_grid_loops(), 1);
            let got = exec_compiled(&prog, &cfg);
            for i in 0..8 {
                assert_eq!(
                    want.outputs["B"].get(&[i]),
                    got.outputs["B"].get(&[i]),
                    "threads={threads:?} element {i}"
                );
            }
            assert_eq!(want.mem.loaded_bytes, got.mem.loaded_bytes);
            assert_eq!(want.mem.stored_bytes, got.mem.stored_bytes);
            assert_eq!(want.mem.n_loads, got.mem.n_loads);
            assert_eq!(want.mem.n_stores, got.mem.n_stores);
            assert_eq!(want.mem.flops, got.mem.flops);
            assert_eq!(want.mem.kernel_launches, got.mem.kernel_launches);
        }
    }

    /// Slice attribution must be identical between the interpreter, the
    /// serial engine, and the fanned-out engine — per-slice counters and
    /// outputs alike (the stacked-batch parity contract's foundation).
    #[test]
    fn slice_attribution_matches_across_backends_and_threads() {
        let ir = lower(&map_graph());
        let mut rng = Rng::new(21);
        let input = block_list(&mut rng, 12, 4, 4);
        let mut cfg = ExecConfig::new(DimSizes::of(&[("N", 12)]));
        cfg.inputs.insert("A".into(), input.clone());
        cfg.slices = Some(vec![3, 3, 3, 3]);
        let want = exec(&ir, &cfg);
        assert_eq!(want.per_slice.len(), 4);
        assert_eq!(want.mem.kernel_launches, 1, "one stacked launch");
        assert_eq!(want.per_slice[0].kernel_launches, 1, "per-slice launch");
        // uniform body: every slice charges the same traffic, and the
        // slice sum reproduces the aggregate
        let sum: u64 = want.per_slice.iter().map(|s| s.loaded_bytes).sum();
        assert_eq!(sum, want.mem.loaded_bytes);
        for threads in [Some(1), Some(4)] {
            let mut c2 = cfg.clone();
            c2.threads = threads;
            let prog = compile(&ir, &c2);
            let got = exec_compiled(&prog, &c2);
            for i in 0..12 {
                assert_eq!(
                    want.outputs["B"].get(&[i]),
                    got.outputs["B"].get(&[i]),
                    "threads={threads:?} element {i}"
                );
            }
            assert_eq!(got.per_slice.len(), 4);
            for (r, (a, b)) in want.per_slice.iter().zip(&got.per_slice).enumerate() {
                assert_eq!(a.loaded_bytes, b.loaded_bytes, "threads={threads:?} slice {r}");
                assert_eq!(a.stored_bytes, b.stored_bytes, "threads={threads:?} slice {r}");
                assert_eq!(a.n_loads, b.n_loads, "threads={threads:?} slice {r}");
                assert_eq!(a.n_stores, b.n_stores, "threads={threads:?} slice {r}");
                assert_eq!(a.flops, b.flops, "threads={threads:?} slice {r}");
                assert_eq!(
                    a.kernel_launches, b.kernel_launches,
                    "threads={threads:?} slice {r}"
                );
            }
            assert_eq!(want.mem.kernel_launches, got.mem.kernel_launches);
        }
    }

    /// Ragged slice widths (unequal, with an empty slice) must agree
    /// between the interpreter, the serial engine, and the fanned-out
    /// engine — the foundation of ragged stacked-batch parity.
    #[test]
    fn ragged_slice_attribution_matches_across_backends() {
        let ir = lower(&map_graph());
        let mut rng = Rng::new(23);
        let input = block_list(&mut rng, 12, 4, 4);
        let widths = vec![5usize, 0, 3, 4];
        let mut cfg = ExecConfig::new(DimSizes::of(&[("N", 12)]));
        cfg.inputs.insert("A".into(), input.clone());
        cfg.slices = Some(widths.clone());
        let want = exec(&ir, &cfg);
        assert_eq!(want.per_slice.len(), 4);
        assert_eq!(want.mem.kernel_launches, 1, "one stacked launch");
        assert_eq!(want.per_slice[1], MemSim::default(), "empty slice charges nothing");
        let sum: u64 = want.per_slice.iter().map(|s| s.loaded_bytes).sum();
        assert_eq!(sum, want.mem.loaded_bytes, "slices partition the loads");
        for threads in [Some(1), Some(4)] {
            let mut c2 = cfg.clone();
            c2.threads = threads;
            let prog = compile(&ir, &c2);
            let got = exec_compiled(&prog, &c2);
            for i in 0..12 {
                assert_eq!(
                    want.outputs["B"].get(&[i]),
                    got.outputs["B"].get(&[i]),
                    "threads={threads:?} element {i}"
                );
            }
            assert_eq!(got.per_slice.len(), 4);
            for (r, (a, b)) in want.per_slice.iter().zip(&got.per_slice).enumerate() {
                assert_eq!(a.loaded_bytes, b.loaded_bytes, "threads={threads:?} slice {r}");
                assert_eq!(a.stored_bytes, b.stored_bytes, "threads={threads:?} slice {r}");
                assert_eq!(a.n_loads, b.n_loads, "threads={threads:?} slice {r}");
                assert_eq!(a.n_stores, b.n_stores, "threads={threads:?} slice {r}");
                assert_eq!(a.flops, b.flops, "threads={threads:?} slice {r}");
                assert_eq!(
                    a.kernel_launches, b.kernel_launches,
                    "threads={threads:?} slice {r}"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "capacity exceeded")]
    fn engine_enforces_local_capacity() {
        let ir = lower(&map_graph());
        let mut rng = Rng::new(3);
        let mut cfg = ExecConfig::new(DimSizes::of(&[("N", 2)]));
        cfg.inputs.insert("A".into(), block_list(&mut rng, 2, 8, 8));
        cfg.local_capacity = Some(100); // one 8x8 block = 256 bytes > 100
        cfg.threads = Some(1);
        let prog = compile(&ir, &cfg);
        let _ = exec_compiled(&prog, &cfg);
    }

    /// The scheduler constants must stay self-consistent: the chunk split
    /// derived from them tiles any range exactly, and one worker never
    /// over-decomposes below one iteration per chunk.
    #[test]
    fn scheduler_constants_invariant() {
        assert!(MAX_WORKERS >= 1);
        assert!(CHUNKS_PER_WORKER >= 1);
        for workers in [1usize, 2, 7, MAX_WORKERS] {
            for (start, trip) in [(0usize, 5usize), (1, 33), (0, 257)] {
                let chunks = split_chunks(start, trip, workers * CHUNKS_PER_WORKER);
                assert!(chunks.len() <= workers * CHUNKS_PER_WORKER);
                let covered: usize = chunks.iter().map(|c| c.hi - c.lo).sum();
                assert_eq!(covered, trip - start);
            }
        }
    }

    /// for m (serial) { forall n (parallel) { ... } } — the nested grid
    /// must fan out and still match the interpreter bit for bit,
    /// counters included.
    #[test]
    fn nested_parallel_loop_matches_interpreter() {
        let (m, n) = (Dim::new("M"), Dim::new("N"));
        let buf = |name: &str, is_input: bool| BufDecl {
            name: name.into(),
            dims: vec![m.clone(), n.clone()],
            item: crate::ir::types::Item::Block,
            is_input,
            is_output: !is_input,
            state_dim: None,
        };
        let mut ir = LoopIr {
            bufs: vec![buf("A", true), buf("B", false)],
            body: vec![Stmt::Loop {
                kind: LoopKind::For,
                dim: m.clone(),
                skip_first: false,
                clears: vec![],
                body: vec![Stmt::Loop {
                    kind: LoopKind::ForAll,
                    dim: n.clone(),
                    skip_first: false,
                    clears: vec![],
                    body: vec![
                        Stmt::Load {
                            var: 0,
                            buf: 0,
                            idx: vec![Index::Iter(m.clone()), Index::Iter(n.clone())],
                        },
                        Stmt::Compute {
                            var: 1,
                            op: COp::Func(crate::ir::func::FuncOp::Mul),
                            args: vec![0, 0],
                        },
                        Stmt::Store {
                            var: 1,
                            buf: 1,
                            idx: vec![Index::Iter(m.clone()), Index::Iter(n.clone())],
                        },
                    ],
                }],
            }],
            n_vars: 2,
            params: vec![],
        };
        analyze_clears(&mut ir);

        let mut rng = Rng::new(31);
        // inner grid must clear NESTED_FANOUT_MIN_WORK: 512 × 3 instrs
        let (mm, nn) = (3usize, 512usize);
        let mut bv = BufVal::new(vec![mm, nn]);
        for i in 0..mm {
            for j in 0..nn {
                bv.set(&[i, j], Val::Block(rng.mat(4, 4)));
            }
        }
        let mut cfg = ExecConfig::new(DimSizes::of(&[("M", mm), ("N", nn)]));
        cfg.inputs.insert("A".into(), bv);
        let want = exec(&ir, &cfg);
        for threads in [2usize, 4] {
            let mut c2 = cfg.clone();
            c2.threads = Some(threads);
            let prog = compile(&ir, &c2);
            assert!(!prog.loops[0].parallel && prog.loops[1].parallel);
            assert!(
                prog.loops[1].weight >= NESTED_FANOUT_MIN_WORK,
                "test grid must actually fan out (weight {})",
                prog.loops[1].weight
            );
            let got = exec_compiled(&prog, &c2);
            for i in 0..mm {
                for j in 0..nn {
                    assert_eq!(
                        want.outputs["B"].get(&[i, j]),
                        got.outputs["B"].get(&[i, j]),
                        "threads={threads} slot ({i},{j})"
                    );
                }
            }
            assert_eq!(want.mem.loaded_bytes, got.mem.loaded_bytes);
            assert_eq!(want.mem.stored_bytes, got.mem.stored_bytes);
            assert_eq!(want.mem.n_loads, got.mem.n_loads);
            assert_eq!(want.mem.n_stores, got.mem.n_stores);
            assert_eq!(want.mem.flops, got.mem.flops);
            assert_eq!(want.mem.kernel_launches, got.mem.kernel_launches);
        }
    }

    /// A parallel grid reading a var assigned by an *earlier* top-level
    /// nest (loop-invariant free read): workers must see the seeded
    /// value and agree with the interpreter exactly.
    #[test]
    fn seeded_free_var_matches_interpreter() {
        let n = Dim::new("N");
        let buf = |name: &str, is_input: bool, is_output: bool| BufDecl {
            name: name.into(),
            dims: vec![n.clone()],
            item: crate::ir::types::Item::Block,
            is_input,
            is_output,
            state_dim: None,
        };
        // top0: forall i { t0 = load A[i]; t1 = t0+t0; store t1 -> B[i] }
        //   (after the loop t1 holds 2·A[N-1])
        // top1: forall i { t2 = load A[i]; t3 = t2+t1; store t3 -> C[i] }
        //   (t1 is a loop-invariant free read seeded into workers)
        let grid = |dst: usize, body: Vec<Stmt>| Stmt::Loop {
            kind: LoopKind::ForAll,
            dim: n.clone(),
            skip_first: false,
            clears: vec![],
            body: {
                let mut b = body;
                b.push(Stmt::Store {
                    var: dst,
                    buf: if dst == 1 { 1 } else { 2 },
                    idx: vec![Index::Iter(n.clone())],
                });
                b
            },
        };
        let mut ir = LoopIr {
            bufs: vec![
                buf("A", true, false),
                buf("B", false, true),
                buf("C", false, true),
            ],
            body: vec![
                grid(
                    1,
                    vec![
                        Stmt::Load {
                            var: 0,
                            buf: 0,
                            idx: vec![Index::Iter(n.clone())],
                        },
                        Stmt::Compute {
                            var: 1,
                            op: COp::Func(crate::ir::func::FuncOp::Add),
                            args: vec![0, 0],
                        },
                    ],
                ),
                grid(
                    3,
                    vec![
                        Stmt::Load {
                            var: 2,
                            buf: 0,
                            idx: vec![Index::Iter(n.clone())],
                        },
                        Stmt::Compute {
                            var: 3,
                            op: COp::Func(crate::ir::func::FuncOp::Add),
                            args: vec![2, 1],
                        },
                    ],
                ),
            ],
            n_vars: 4,
            params: vec![],
        };
        analyze_clears(&mut ir);

        let mut rng = Rng::new(77);
        let input = block_list(&mut rng, 12, 4, 4);
        let mut cfg = ExecConfig::new(DimSizes::of(&[("N", 12)]));
        cfg.inputs.insert("A".into(), input);
        let want = exec(&ir, &cfg);
        let mut c2 = cfg.clone();
        c2.threads = Some(4);
        let prog = compile(&ir, &c2);
        assert_eq!(prog.parallel_grid_loops(), 2, "both grids parallel");
        let got = exec_compiled(&prog, &c2);
        for out in ["B", "C"] {
            for i in 0..12 {
                assert_eq!(
                    want.outputs[out].get(&[i]),
                    got.outputs[out].get(&[i]),
                    "output {out} slot {i}"
                );
            }
        }
        assert_eq!(want.mem.flops, got.mem.flops);
        assert_eq!(want.mem.loaded_bytes, got.mem.loaded_bytes);
    }
}
